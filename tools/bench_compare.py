"""Perf-regression gate: diff two BENCH_crew.json records.

The CI benchmark step has archived a BENCH_crew.json per commit since
PR 2, but the trajectory was collected and never *enforced* — a module
could quietly triple its wall time and nothing would go red.  This tool
closes the loop:

    python tools/bench_compare.py BASELINE.json CURRENT.json [--threshold 0.25]

For every module present in both records it compares ``seconds`` and
fails (exit 1) when any module regressed by more than ``--threshold``
(fractional; default 0.25 = +25%).  Guards against noise on small
absolute times with ``--min-seconds`` (default 0.2s: a 0.01s->0.02s
jitter on a trivial module is not a regression).  Records from
different fastness (``--full`` vs fast subset) or different backends are
incomparable and skip with a notice rather than fail, as does a missing
baseline (first run on a branch).  CI fetches the previous successful
run's artifact and runs this after the fresh benchmark.

``--require-ratio MODULE NUMER/DENOM OP VALUE`` (repeatable) adds an
*absolute* gate on the current record, independent of any baseline: the
module's rows are grouped by their ``weights`` field and the
``tokens_per_s`` ratio between the two named groups — at the largest
``horizon`` both groups report — must satisfy ``OP VALUE``.  CI uses

    --require-ratio decode_latency crew/dense '>=' 1.0

to pin the paper's headline claim (CREW at least matches dense decode
throughput once the VMEM-resident product-buffer kernel is carried
across the horizon) as a hard gate rather than a tracked trajectory.
Unlike the regression diff, a missing module or group here *fails*: the
gate is only meaningful if the benchmark actually ran.

``--require-field MODULE FIELD OP VALUE`` (repeatable) is the scalar
sibling: *every* row of MODULE that carries FIELD must satisfy
``OP VALUE``.  CI pins the disconnect chaos invariants with

    --require-field disconnect terminal_coverage '>=' 1.0
    --require-field disconnect audit_clean '>=' 1

so a front door that orphans a stream or leaks a block goes red even
though its wall time looks fine.  As with ratios, a missing module or
field fails the gate.
"""
from __future__ import annotations

import argparse
import json
import sys


def load_modules(path: str):
    with open(path) as fh:
        obj = json.load(fh)
    return obj, {m["name"]: m for m in obj.get("modules", [])}


def compare(baseline: dict, current: dict, *, threshold: float = 0.25,
            min_seconds: float = 0.2):
    """Returns (regressions, lines): regressions is the failing subset."""
    base_obj, base = baseline["obj"], baseline["modules"]
    cur_obj, cur = current["obj"], current["modules"]
    lines = []
    if base_obj.get("fast") != cur_obj.get("fast"):
        return None, ["records have different fastness; not comparable"]
    if base_obj.get("backend") and cur_obj.get("backend") \
            and base_obj["backend"] != cur_obj["backend"]:
        return None, [f"records from different backends "
                      f"({base_obj['backend']} vs {cur_obj['backend']}); "
                      "not comparable"]
    regressions = []
    for name in cur:
        if name not in base:
            lines.append(f"  {name}: new module (no baseline), skipped")
            continue
        b, c = base[name]["seconds"], cur[name]["seconds"]
        if max(b, c) < min_seconds:
            lines.append(f"  {name}: {b:.3f}s -> {c:.3f}s (below "
                         f"{min_seconds}s noise floor, skipped)")
            continue
        delta = (c - b) / max(b, 1e-9)
        tag = "REGRESSION" if delta > threshold else "ok"
        lines.append(f"  {name}: {b:.3f}s -> {c:.3f}s ({delta:+.1%}) {tag}")
        if delta > threshold:
            regressions.append((name, b, c, delta))
    return regressions, lines


_OPS = {
    ">=": lambda a, b: a >= b,
    ">": lambda a, b: a > b,
    "<=": lambda a, b: a <= b,
    "<": lambda a, b: a < b,
}


def check_ratio(modules: dict, module: str, spec: str, op: str,
                value: float):
    """Evaluate one --require-ratio gate against the current record.

    Returns (ok, line).  ``spec`` is ``numer/denom`` over the module's
    per-row ``weights`` tag; the compared metric is ``tokens_per_s`` at
    the largest ``horizon`` both groups report.  Any missing piece
    (module, group, common horizon) is a gate *failure* — an absent
    benchmark must not pass the bar it was meant to enforce.
    """
    if op not in _OPS:
        return False, f"  {module}: unknown comparator {op!r}"
    try:
        numer_tag, denom_tag = spec.split("/", 1)
    except ValueError:
        return False, f"  {module}: malformed ratio spec {spec!r}"
    rec = modules.get(module)
    if rec is None:
        return False, f"  {module}: module missing from current record"
    groups: dict = {}
    for row in rec.get("data", []):
        tag, h = row.get("weights"), row.get("horizon")
        if tag in (numer_tag, denom_tag) and h is not None \
                and "tokens_per_s" in row:
            groups.setdefault(tag, {})[int(h)] = float(row["tokens_per_s"])
    if numer_tag not in groups or denom_tag not in groups:
        missing = [t for t in (numer_tag, denom_tag) if t not in groups]
        return False, (f"  {module}: no rows for group(s) "
                       f"{', '.join(missing)}")
    common = sorted(set(groups[numer_tag]) & set(groups[denom_tag]))
    if not common:
        return False, f"  {module}: groups share no horizon"
    h = common[-1]
    numer, denom = groups[numer_tag][h], groups[denom_tag][h]
    ratio = numer / max(denom, 1e-9)
    ok = _OPS[op](ratio, value)
    return ok, (f"  {module}: {spec} tokens/s @ horizon={h} is "
                f"{numer:.1f}/{denom:.1f} = {ratio:.3f} "
                f"(require {op} {value}) {'ok' if ok else 'FAIL'}")


def check_field(modules: dict, module: str, field: str, op: str,
                value: float):
    """Evaluate one --require-field gate against the current record.

    Returns (ok, line).  Every row of ``module`` that has ``field``
    must satisfy ``OP VALUE``; a missing module, or no row carrying
    the field at all, is a gate failure for the same reason as above.
    """
    if op not in _OPS:
        return False, f"  {module}: unknown comparator {op!r}"
    rec = modules.get(module)
    if rec is None:
        return False, f"  {module}: module missing from current record"
    vals = [float(row[field]) for row in rec.get("data", [])
            if field in row]
    if not vals:
        return False, f"  {module}: no rows carry field {field!r}"
    bad = [v for v in vals if not _OPS[op](v, value)]
    ok = not bad
    shown = ", ".join(f"{v:g}" for v in vals)
    return ok, (f"  {module}: {field} over {len(vals)} row(s) = "
                f"[{shown}] (require {op} {value}) "
                f"{'ok' if ok else 'FAIL'}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="previous run's BENCH_crew.json")
    ap.add_argument("current", help="fresh BENCH_crew.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated fractional seconds increase per "
                         "module (default 0.25)")
    ap.add_argument("--min-seconds", type=float, default=0.2,
                    help="modules faster than this in both records are "
                         "noise, not signal (default 0.2)")
    ap.add_argument("--require-ratio", nargs=4, action="append", default=[],
                    metavar=("MODULE", "NUMER/DENOM", "OP", "VALUE"),
                    help="absolute gate on the current record: the "
                         "tokens_per_s ratio between two weights groups "
                         "at their largest common horizon must satisfy "
                         "OP VALUE (e.g. decode_latency crew/dense "
                         "'>=' 1.0); repeatable")
    ap.add_argument("--require-field", nargs=4, action="append", default=[],
                    metavar=("MODULE", "FIELD", "OP", "VALUE"),
                    help="absolute gate on the current record: every row "
                         "of MODULE carrying FIELD must satisfy OP VALUE "
                         "(e.g. disconnect terminal_coverage '>=' 1.0); "
                         "repeatable")
    args = ap.parse_args(argv)

    cur_obj, cur = load_modules(args.current)

    # Absolute gates first: they read only the current record, so they
    # apply even when no baseline exists for the regression diff.
    gate_failures = 0
    for module, spec, op, value in args.require_ratio:
        ok, line = check_ratio(cur, module, spec, op, float(value))
        print(line)
        gate_failures += 0 if ok else 1
    for module, field, op, value in args.require_field:
        ok, line = check_field(cur, module, field, op, float(value))
        print(line)
        gate_failures += 0 if ok else 1
    if gate_failures:
        print(f"bench_compare: {gate_failures} absolute gate(s) "
              "failed", file=sys.stderr)
        return 1

    try:
        base_obj, base = load_modules(args.baseline)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_compare: no usable baseline ({e}); skipping")
        return 0

    regressions, lines = compare(
        {"obj": base_obj, "modules": base},
        {"obj": cur_obj, "modules": cur},
        threshold=args.threshold, min_seconds=args.min_seconds)
    print(f"bench_compare: {args.baseline} "
          f"({base_obj.get('git_sha', '?')}) -> {args.current} "
          f"({cur_obj.get('git_sha', '?')})")
    for line in lines:
        print(line)
    if regressions is None:
        return 0
    if regressions:
        print(f"bench_compare: {len(regressions)} module(s) regressed "
              f"> {args.threshold:.0%}", file=sys.stderr)
        return 1
    print("bench_compare: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
