"""Perf-regression gate: diff two BENCH_crew.json records.

The CI benchmark step has archived a BENCH_crew.json per commit since
PR 2, but the trajectory was collected and never *enforced* — a module
could quietly triple its wall time and nothing would go red.  This tool
closes the loop:

    python tools/bench_compare.py BASELINE.json CURRENT.json [--threshold 0.25]

For every module present in both records it compares ``seconds`` and
fails (exit 1) when any module regressed by more than ``--threshold``
(fractional; default 0.25 = +25%).  Guards against noise on small
absolute times with ``--min-seconds`` (default 0.2s: a 0.01s->0.02s
jitter on a trivial module is not a regression).  Records from
different fastness (``--full`` vs fast subset) or different backends are
incomparable and skip with a notice rather than fail, as does a missing
baseline (first run on a branch).  CI fetches the previous successful
run's artifact and runs this after the fresh benchmark.
"""
from __future__ import annotations

import argparse
import json
import sys


def load_modules(path: str):
    with open(path) as fh:
        obj = json.load(fh)
    return obj, {m["name"]: m for m in obj.get("modules", [])}


def compare(baseline: dict, current: dict, *, threshold: float = 0.25,
            min_seconds: float = 0.2):
    """Returns (regressions, lines): regressions is the failing subset."""
    base_obj, base = baseline["obj"], baseline["modules"]
    cur_obj, cur = current["obj"], current["modules"]
    lines = []
    if base_obj.get("fast") != cur_obj.get("fast"):
        return None, ["records have different fastness; not comparable"]
    if base_obj.get("backend") and cur_obj.get("backend") \
            and base_obj["backend"] != cur_obj["backend"]:
        return None, [f"records from different backends "
                      f"({base_obj['backend']} vs {cur_obj['backend']}); "
                      "not comparable"]
    regressions = []
    for name in cur:
        if name not in base:
            lines.append(f"  {name}: new module (no baseline), skipped")
            continue
        b, c = base[name]["seconds"], cur[name]["seconds"]
        if max(b, c) < min_seconds:
            lines.append(f"  {name}: {b:.3f}s -> {c:.3f}s (below "
                         f"{min_seconds}s noise floor, skipped)")
            continue
        delta = (c - b) / max(b, 1e-9)
        tag = "REGRESSION" if delta > threshold else "ok"
        lines.append(f"  {name}: {b:.3f}s -> {c:.3f}s ({delta:+.1%}) {tag}")
        if delta > threshold:
            regressions.append((name, b, c, delta))
    return regressions, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="previous run's BENCH_crew.json")
    ap.add_argument("current", help="fresh BENCH_crew.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated fractional seconds increase per "
                         "module (default 0.25)")
    ap.add_argument("--min-seconds", type=float, default=0.2,
                    help="modules faster than this in both records are "
                         "noise, not signal (default 0.2)")
    args = ap.parse_args(argv)

    try:
        base_obj, base = load_modules(args.baseline)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_compare: no usable baseline ({e}); skipping")
        return 0
    cur_obj, cur = load_modules(args.current)

    regressions, lines = compare(
        {"obj": base_obj, "modules": base},
        {"obj": cur_obj, "modules": cur},
        threshold=args.threshold, min_seconds=args.min_seconds)
    print(f"bench_compare: {args.baseline} "
          f"({base_obj.get('git_sha', '?')}) -> {args.current} "
          f"({cur_obj.get('git_sha', '?')})")
    for line in lines:
        print(line)
    if regressions is None:
        return 0
    if regressions:
        print(f"bench_compare: {len(regressions)} module(s) regressed "
              f"> {args.threshold:.0%}", file=sys.stderr)
        return 1
    print("bench_compare: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
