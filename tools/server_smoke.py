"""Server integration smoke: one pass over the wire, exit 0/1.

CI runs this after the unit suites as a black-box check that the
whole front door composes (docs/serving.md): it boots a reduced model
behind ``Scheduler -> Supervisor -> SSEServer`` on a loopback port and
drives three probes through real sockets:

1. **stream** — POST /v1/generate, read the SSE stream to ``done``,
   and require token-for-token parity with a cold in-process
   ``generate`` on the same prompt;
2. **disconnect** — open a second stream and hang up after two token
   frames; the server must cancel the request at the next horizon
   boundary (terminal ``cancelled``) and ``audit_blocks()`` must come
   back clean — no orphaned slot, no leaked block;
3. **drain** — SIGTERM semantics via ``begin_drain()``: /readyz and a
   fresh submit must both answer 503 with a Retry-After header.

Horizons are slowed with a seeded delay injector so the mid-stream
hangup deterministically lands while the request is still decoding.
Any failed probe prints the reason and exits 1.
"""
from __future__ import annotations

import sys
import time

import numpy as np


def main() -> int:
    import jax

    from repro.configs import ARCHS
    from repro.models import build_model
    from repro.serve import Scheduler, SSEServer, Supervisor, generate
    from repro.serve.client import get_json, stream_generate
    from repro.serve.faults import FaultInjector

    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    sched = Scheduler(api, params, max_batch=2, cache_len=64,
                      buckets=(8, 16), block_size=8,
                      rng=jax.random.PRNGKey(0), stream_tokens=True,
                      faults=FaultInjector(0, delay_p=1.0,
                                           max_delay_s=0.05))
    sup = Supervisor(sched).start()
    srv = SSEServer(sup)
    srv.start_background()
    failures = []

    def check(name, ok, detail=""):
        print(f"[smoke] {name}: {'ok' if ok else 'FAIL'} {detail}")
        if not ok:
            failures.append(name)

    try:
        # 1. stream to completion, token-identical to cold generate
        p = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        r = stream_generate(srv.host, srv.port, p, max_new=6)
        ref = np.asarray(generate(api, params,
                                  jax.numpy.asarray(p)[None],
                                  max_new=6)["tokens"][0])
        check("stream-parity",
              r["http_status"] == 200
              and r["done"] is not None
              and r["done"]["status"] == "completed"
              and r["tokens"] == [int(t) for t in ref],
              f"tokens={r['tokens']}")

        # 2. hang up mid-stream -> cancelled + clean block audit
        p2 = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        r2 = stream_generate(srv.host, srv.port, p2, max_new=48,
                             disconnect_after=2)
        deadline = time.monotonic() + 60.0
        comp = None
        while time.monotonic() < deadline:
            comp = sup.results.get(r2["rid"])
            if comp is not None:
                break
            time.sleep(0.01)
        sup.wait_idle(timeout=60.0)
        check("disconnect-cancels",
              r2["disconnected"] and comp is not None
              and comp.status == "cancelled",
              f"rid={r2.get('rid')} status="
              f"{comp.status if comp else None}")
        audit = sched.audit_blocks()
        check("audit-clean", not audit, str(audit[:3]))

        # 3. drain -> honest 503 + Retry-After on both doors
        sup.begin_drain()
        rz = get_json(srv.host, srv.port, "/readyz")
        r3 = stream_generate(srv.host, srv.port, p, max_new=4)
        check("drain-503",
              rz["status"] == 503 and rz.get("retry_after") is not None
              and r3["http_status"] == 503
              and r3.get("retry_after") is not None,
              f"readyz={rz['status']} submit={r3['http_status']}")
    finally:
        srv.stop_background()
        sup.stop(drain=False)

    if failures:
        print(f"[smoke] FAILED: {failures}", file=sys.stderr)
        return 1
    print("[smoke] all probes passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
