"""Server integration smoke: one pass over the wire, exit 0/1.

CI runs this after the unit suites as a black-box check that the
whole front door composes (docs/serving.md): it boots a reduced model
behind ``Scheduler -> Supervisor -> SSEServer`` on a loopback port and
drives three probes through real sockets:

1. **stream** — POST /v1/generate, read the SSE stream to ``done``,
   and require token-for-token parity with a cold in-process
   ``generate`` on the same prompt;
2. **disconnect** — open a second stream and hang up after two token
   frames; the server must cancel the request at the next horizon
   boundary (terminal ``cancelled``) and ``audit_blocks()`` must come
   back clean — no orphaned slot, no leaked block;
3. **drain** — SIGTERM semantics via ``begin_drain()``: /readyz and a
   fresh submit must both answer 503 with a Retry-After header.

``--kill-restart`` runs the durability smoke instead (its own CI step,
next to the drain smoke): a **subprocess** server on a journal
directory is SIGKILLed mid-stream, restarted on the same journal, and
the resumable client's reconnect loop must assemble a stream
token-identical to a cold in-process ``generate`` — exactly one done
frame, no index gaps, clean block audit after the dust settles
(DESIGN.md §5.1).

Horizons are slowed with a seeded delay injector so the mid-stream
hangup deterministically lands while the request is still decoding.
Any failed probe prints the reason and exits 1.
"""
from __future__ import annotations

import argparse
import os
import signal
import socket
import subprocess
import sys
import tempfile
import time

import numpy as np


def main() -> int:
    import jax

    from repro.configs import ARCHS
    from repro.models import build_model
    from repro.serve import Scheduler, SSEServer, Supervisor, generate
    from repro.serve.client import get_json, stream_generate
    from repro.serve.faults import FaultInjector

    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    sched = Scheduler(api, params, max_batch=2, cache_len=64,
                      buckets=(8, 16), block_size=8,
                      rng=jax.random.PRNGKey(0), stream_tokens=True,
                      faults=FaultInjector(0, delay_p=1.0,
                                           max_delay_s=0.05))
    sup = Supervisor(sched).start()
    srv = SSEServer(sup)
    srv.start_background()
    failures = []

    def check(name, ok, detail=""):
        print(f"[smoke] {name}: {'ok' if ok else 'FAIL'} {detail}")
        if not ok:
            failures.append(name)

    try:
        # 1. stream to completion, token-identical to cold generate
        p = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        r = stream_generate(srv.host, srv.port, p, max_new=6)
        ref = np.asarray(generate(api, params,
                                  jax.numpy.asarray(p)[None],
                                  max_new=6)["tokens"][0])
        check("stream-parity",
              r["http_status"] == 200
              and r["done"] is not None
              and r["done"]["status"] == "completed"
              and r["tokens"] == [int(t) for t in ref],
              f"tokens={r['tokens']}")

        # 2. hang up mid-stream -> cancelled + clean block audit
        p2 = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        r2 = stream_generate(srv.host, srv.port, p2, max_new=48,
                             disconnect_after=2)
        deadline = time.monotonic() + 60.0
        comp = None
        while time.monotonic() < deadline:
            comp = sup.results.get(r2["rid"])
            if comp is not None:
                break
            time.sleep(0.01)
        sup.wait_idle(timeout=60.0)
        check("disconnect-cancels",
              r2["disconnected"] and comp is not None
              and comp.status == "cancelled",
              f"rid={r2.get('rid')} status="
              f"{comp.status if comp else None}")
        audit = sched.audit_blocks()
        check("audit-clean", not audit, str(audit[:3]))

        # 3. drain -> honest 503 + Retry-After on both doors
        sup.begin_drain()
        rz = get_json(srv.host, srv.port, "/readyz")
        r3 = stream_generate(srv.host, srv.port, p, max_new=4)
        check("drain-503",
              rz["status"] == 503 and rz.get("retry_after") is not None
              and r3["http_status"] == 503
              and r3.get("retry_after") is not None,
              f"readyz={rz['status']} submit={r3['http_status']}")
    finally:
        srv.stop_background()
        sup.stop(drain=False)

    if failures:
        print(f"[smoke] FAILED: {failures}", file=sys.stderr)
        return 1
    print("[smoke] all probes passed")
    return 0


def kill_restart(max_new: int = 24, seed: int = 0) -> int:
    """SIGKILL -> restart -> reconnect: the durability smoke.

    The in-process reference and the subprocess server build the same
    reduced model from the same seed, so greedy decode must produce the
    same tokens — including across full process death in the middle of
    the stream.
    """
    import threading

    import jax
    import repro

    from repro.configs import ARCHS
    from repro.models import build_model
    from repro.serve import generate
    from repro.serve.client import get_json, stream_generate

    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    ref = [int(t) for t in np.asarray(
        generate(api, params, jax.numpy.asarray(prompt)[None],
                 max_new=max_new)["tokens"][0])]

    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]

    # repro is a namespace package (no __init__.py): __path__, not __file__
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("REPRO_FAULTS", None)   # no suite-wide injector: the kill
    # (plus the explicit delay flags below) is the only chaos here

    def spawn(jdir: str, log_path: str) -> subprocess.Popen:
        log = open(log_path, "ab")
        try:
            return subprocess.Popen(
                [sys.executable, "-m", "repro.launch.serve",
                 "--arch", "qwen2-0.5b", "--reduced", "--listen",
                 "--host", "127.0.0.1", "--port", str(port),
                 "--journal-dir", jdir, "--fsync", "horizon",
                 "--max-batch", "2", "--cache-len", "64",
                 "--horizon", "4", "--seed", str(seed),
                 # slow horizons (output-preserving, seeded) so the
                 # SIGKILL deterministically lands mid-stream instead
                 # of racing a millisecond decode to the done frame
                 "--faults-seed", str(seed), "--fault-delay-p", "1.0",
                 "--fault-max-delay", "0.25"],
                env=env, stdout=log, stderr=log,
                stdin=subprocess.DEVNULL)
        finally:
            log.close()

    def wait_ready(proc: subprocess.Popen, timeout: float = 600.0) -> None:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if proc.poll() is not None:
                raise RuntimeError(
                    f"server exited with {proc.returncode} before ready")
            try:
                if get_json("127.0.0.1", port, "/readyz",
                            timeout=2.0)["status"] == 200:
                    return
            except OSError:
                pass
            time.sleep(0.1)
        raise RuntimeError("server not ready in time")

    failures = []

    def check(name, ok, detail=""):
        print(f"[smoke] {name}: {'ok' if ok else 'FAIL'} {detail}")
        if not ok:
            failures.append(name)

    with tempfile.TemporaryDirectory(prefix="repro-smoke-") as tmp:
        jdir = os.path.join(tmp, "journal")
        proc = spawn(jdir, os.path.join(tmp, "server-1.log"))
        result = {}
        try:
            wait_ready(proc)

            def client():
                result.update(stream_generate(
                    "127.0.0.1", port, prompt, max_new=max_new,
                    resume=True, max_reconnects=300, backoff_cap_s=1.0,
                    backoff_seed=seed, idempotency_key="smoke-restart",
                    timeout=300.0))

            th = threading.Thread(target=client)
            th.start()
            # kill once the submit is durable and panels are flowing —
            # mid-stream, several horizons short of the done frame
            deadline = time.monotonic() + 600.0
            while time.monotonic() < deadline:
                try:
                    m = get_json("127.0.0.1", port, "/metrics",
                                 timeout=5.0)
                except OSError:
                    m = {}
                if m.get("journal", {}).get("records_appended", 0) >= 3:
                    break
                time.sleep(0.05)
            time.sleep(0.2)
            proc.send_signal(signal.SIGKILL)
            proc.wait(timeout=30.0)
            print("[smoke] server SIGKILLed mid-stream; restarting on "
                  "the same journal")

            proc = spawn(jdir, os.path.join(tmp, "server-2.log"))
            wait_ready(proc)
            th.join(timeout=600.0)
            check("client-finished", not th.is_alive())

            m = get_json("127.0.0.1", port, "/metrics", timeout=30.0)
            jstats = m.get("journal", {})
            n = len(result.get("tokens", []))
            check("resume-parity",
                  result.get("done") is not None
                  and result["done"].get("status") == "completed"
                  and result.get("tokens") == ref,
                  f"tokens={result.get('tokens')} ref={ref}")
            check("exactly-once",
                  result.get("indices") == list(range(n))
                  and n == len(ref),
                  f"indices={result.get('indices')}")
            check("reconnected", result.get("reconnects", 0) >= 1,
                  f"reconnects={result.get('reconnects')}")
            check("journal-replayed",
                  jstats.get("replayed_requests", 0) >= 1,
                  f"journal={jstats}")
            check("audit-clean-after-restart",
                  bool(m.get("audit_clean", 0)), f"metrics={m}")
        finally:
            for name in ("server-1.log", "server-2.log"):
                path = os.path.join(tmp, name)
                if failures and os.path.exists(path):
                    sys.stderr.write(f"--- {name} ---\n")
                    with open(path, "r", errors="replace") as fh:
                        sys.stderr.write(fh.read())
            if proc.poll() is None:
                proc.terminate()
                try:
                    proc.wait(timeout=60.0)
                except subprocess.TimeoutExpired:
                    proc.kill()
                    proc.wait(timeout=30.0)

    if failures:
        print(f"[smoke] FAILED: {failures}", file=sys.stderr)
        return 1
    print("[smoke] kill-restart probes passed")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--kill-restart", action="store_true",
                    help="run the SIGKILL -> restart -> reconnect "
                         "durability smoke instead of the in-process "
                         "probes")
    args = ap.parse_args()
    sys.exit(kill_restart() if args.kill_restart else main())
