"""Docs-integrity gate: no dangling DESIGN.md / docs/ references.

Checks, over ``src/``, ``benchmarks/``, ``tests/``, ``README.md`` and the
docs themselves:

* every ``DESIGN.md §N[.M]`` citation points at a section anchor that
  actually exists in DESIGN.md (headings of the form ``## §N · ...``);
* every ``docs/<page>.md`` reference points at an existing file;
* every relative markdown link in README.md / DESIGN.md / docs/*.md
  resolves to an existing file.

Run as ``python tools/check_docs.py`` (CI runs it next to the ruff
gate); exits non-zero listing each dangling reference.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

SECTION_REF = re.compile(r"DESIGN\.md\s+§(\d+(?:\.\d+)?)")
DOCS_REF = re.compile(r"\bdocs/[\w\-./]+?\.md\b")
MD_LINK = re.compile(r"\]\(([^)\s]+)\)")
HEADING_ANCHOR = re.compile(r"^#{1,6}\s.*?§(\d+(?:\.\d+)?)", re.M)

SCAN_TREES = ("src", "benchmarks", "tests")
SCAN_SUFFIXES = {".py", ".md"}
MD_FILES = ("README.md", "DESIGN.md")


def _scan_files(root):
    files = [root / name for name in MD_FILES if (root / name).exists()]
    files += sorted((root / "docs").glob("**/*.md"))
    for tree in SCAN_TREES:
        files += sorted(p for p in (root / tree).rglob("*")
                        if p.suffix in SCAN_SUFFIXES
                        and "__pycache__" not in p.parts)
    return files


def design_anchors(root=ROOT) -> set:
    """Section numbers DESIGN.md actually defines headings for."""
    design = root / "DESIGN.md"
    if not design.exists():
        return set()
    return set(HEADING_ANCHOR.findall(design.read_text()))


def check(root=ROOT) -> list:
    """Returns a list of "file:line: problem" strings (empty == clean)."""
    problems = []
    anchors = design_anchors(root)
    if not anchors:
        problems.append("DESIGN.md: missing or defines no § anchors")

    for path in _scan_files(root):
        rel = path.relative_to(root)
        text = path.read_text(errors="replace")
        for i, line in enumerate(text.splitlines(), 1):
            for sec in SECTION_REF.findall(line):
                if sec not in anchors:
                    problems.append(
                        f"{rel}:{i}: cites DESIGN.md §{sec} but DESIGN.md "
                        f"has no §{sec} heading")
            for ref in DOCS_REF.findall(line):
                if not (root / ref).exists():
                    problems.append(
                        f"{rel}:{i}: references {ref} which does not exist")
            if path.suffix == ".md":
                for target in MD_LINK.findall(line):
                    if target.startswith(("http://", "https://", "#",
                                          "mailto:")):
                        continue
                    dest = (path.parent / target.split("#", 1)[0]).resolve()
                    if not dest.exists():
                        problems.append(
                            f"{rel}:{i}: broken link -> {target}")
    return problems


def main() -> int:
    problems = check()
    for p in problems:
        print(p, file=sys.stderr)
    if problems:
        print(f"docs integrity: {len(problems)} dangling reference(s)",
              file=sys.stderr)
        return 1
    print(f"docs integrity: OK ({len(design_anchors())} DESIGN.md anchors)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
