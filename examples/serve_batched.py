"""End-to-end serving driver (the paper's kind is inference): batched
requests through prefill + decode with dense vs CREW weights, PPA on top.

    PYTHONPATH=src python examples/serve_batched.py [--arch qwen2-0.5b]

Serves three waves of batched requests, reports per-wave latency, the CREW
compression report, and the CREW-PPA variant's extra compression with its
token-level agreement (the accuracy proxy the paper's Fig 6 trades off).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import build_model
from repro.serve import crewize_params, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    crew, report = crewize_params(params)
    ppa, report_ppa = crewize_params(params, ppa_thr=0.10)
    agg, agg_ppa = report.aggregate(), report_ppa.aggregate()
    print(f"[convert] CREW: {agg.row()}")
    print(f"[convert] CREW-PPA(10%): {agg_ppa.row()}")
    extra = 1 - agg_ppa.crew_bits_storage / agg.crew_bits_storage
    print(f"[convert] PPA extra compression: {100*extra:.1f}% "
          f"(paper Fig 6: ~17% at <1% accuracy loss)")

    wave_prompts = [
        jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
                    jnp.int32)
        for _ in range(args.waves)
    ]
    variants = {"dense": params, "crew": crew, "crew-ppa": ppa}
    tokens = {}
    for name, p in variants.items():
        lat = []
        for wave, prompts in enumerate(wave_prompts):
            t0 = time.time()
            out = generate(api, p, prompts, max_new=args.max_new)
            out["tokens"].block_until_ready()
            lat.append(time.time() - t0)
            tokens.setdefault(wave, {})[name] = np.asarray(out["tokens"])
        print(f"[serve] {name:9s} wave latencies "
              f"{['%.2fs' % t for t in lat]} (first includes compile)")

    for other in ("crew", "crew-ppa"):
        match = np.mean([
            (tokens[w]["dense"] == tokens[w][other]).mean()
            for w in tokens])
        print(f"[parity] dense vs {other}: {100*match:.1f}% token agreement")
    print("OK")


if __name__ == "__main__":
    main()
