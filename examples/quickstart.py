"""Quickstart: the CREW pipeline in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. build a reduced qwen2-family LM and initialize it,
2. quantize + CREW-decompose one weight matrix by hand (paper §IV-A),
3. CREW-convert the whole checkpoint,
4. serve the same prompts with dense and CREW weights and diff the tokens.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core import analyze_matrix, layout_stats, quantize_matrix
from repro.models import build_model
from repro.serve import crewize_params, generate

# -- 1. a small model ------------------------------------------------------
cfg = ARCHS["qwen2-0.5b"].reduced()
api = build_model(cfg)
params = api.init(jax.random.PRNGKey(0))
n_params = sum(x.size for x in jax.tree.leaves(params))
print(f"model: {cfg.arch_id}  ({n_params/1e3:.0f}k params)")

# -- 2. one matrix through the paper's offline pipeline --------------------
w = np.asarray(params["blocks"]["ffn"]["gate"]["w"][0])  # layer 0 gate proj
qm = quantize_matrix(w)                 # 8-bit linear quantization (§III)
layout = analyze_matrix(qm.q)           # per-input-row unique analysis
stats = layout_stats(layout)
print(f"layer-0 gate proj {w.shape}: UW/I={stats.uw_per_input_mean:.1f}, "
      f"MULs needed={100*stats.muls_fraction:.1f}%, "
      f"storage {100*stats.storage_reduction:+.1f}%")

# -- 3. CREW-convert the whole checkpoint ----------------------------------
crew_params, report = crewize_params(params)
agg = report.aggregate()
print(f"converted {report.n_converted} matrices "
      f"({report.n_skipped} small ones left dense): {agg.row()}")

# -- 4. serve both and compare --------------------------------------------
prompts = jnp.asarray(
    np.random.default_rng(0).integers(0, cfg.vocab, (4, 12)), jnp.int32)
dense_out = generate(api, params, prompts, max_new=16)
crew_out = generate(api, crew_params, prompts, max_new=16)
match = float((dense_out["tokens"] == crew_out["tokens"]).mean())
print(f"greedy token match dense vs CREW: {100*match:.1f}%")
print("dense:", np.asarray(dense_out["tokens"][0]))
print("crew :", np.asarray(crew_out["tokens"][0]))
assert match > 0.7
print("OK")
