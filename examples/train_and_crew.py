"""Train a small LM for a few hundred steps, then validate the paper's
premise on *genuinely trained* weights (not synthetic):

  * UW/I before vs after training (quantization-induced weight repetition),
  * CREW storage/multiplication reduction on the trained checkpoint,
  * PPA threshold sweep with the end-task metric (validation loss) — the
    trained-model counterpart of paper Fig 6's accuracy-vs-compression.

    PYTHONPATH=src python examples/train_and_crew.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.data import batch_for
from repro.models import build_model
from repro.serve import crewize_params
from repro.train import adamw, cosine_warmup, init_state, make_loss_fn, make_train_step


def eval_loss(api, params, cfg, *, steps=4, seed=1234):
    loss_fn = make_loss_fn(api, remat=False, q_chunk=16, kv_chunk=16)
    tot = 0.0
    for i in range(steps):
        batch = batch_for(cfg, 10_000 + i, 16, 64, seed=seed)
        tot += float(loss_fn(params, batch)[0])
    return tot / steps


def uw_report(params, label):
    _, report = crewize_params(params, min_cols=64)
    agg = report.aggregate()
    print(f"[crew] {label:14s} UW/I={agg.uw_per_input_mean:6.1f} "
          f"MULs%={100*agg.muls_fraction:6.2f} "
          f"storage {100*agg.storage_reduction:+6.1f}% "
          f"(runtime {100*agg.runtime_reduction:+6.1f}%)")
    return agg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--wide", action="store_true",
                    help="d_ff=1024 FC matrices — the paper's regime "
                         "(CREW needs rows much longer than 2^q levels)")
    args = ap.parse_args()

    cfg = ARCHS["qwen2-0.5b"].reduced()
    if args.wide:
        import dataclasses
        cfg = dataclasses.replace(cfg, d_model=256, d_ff=1024, n_layers=4,
                                  n_heads=4, n_kv=2, d_head=64, vocab=8192)
    api = build_model(cfg)
    opt = adamw(cosine_warmup(3e-3, 30, args.steps), weight_decay=0.01)
    state = init_state(api, opt, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(api, opt, q_chunk=16, kv_chunk=16))

    uw_init = uw_report(state.params, "at init")

    t0 = time.time()
    for i in range(args.steps):
        state, m = step_fn(state, batch_for(cfg, i, args.batch, args.seq))
        if i % 50 == 0 or i == args.steps - 1:
            print(f"[train] step {i:4d} loss {float(m['loss']):.4f} "
                  f"({time.time()-t0:.0f}s)")
    uw_trained = uw_report(state.params, "after training")

    base_loss = eval_loss(api, state.params, cfg)
    print(f"\n[eval] dense validation loss {base_loss:.4f}")
    print(f"{'thr%':>5s} {'val loss':>9s} {'delta':>8s} {'extra comp%':>12s}")
    crew0, rep0 = crewize_params(state.params, min_cols=64)
    loss0 = eval_loss(api, crew0, cfg)
    print(f"{'0':>5s} {loss0:9.4f} {loss0-base_loss:+8.4f} {0.0:12.1f}")
    bits0 = rep0.aggregate().crew_bits_storage
    for thr in (0.05, 0.10, 0.20):
        crew_t, rep_t = crewize_params(state.params, ppa_thr=thr, min_cols=64)
        loss_t = eval_loss(api, crew_t, cfg)
        extra = 100 * (1 - rep_t.aggregate().crew_bits_storage / bits0)
        print(f"{int(100*thr):>5d} {loss_t:9.4f} {loss_t-base_loss:+8.4f} "
              f"{extra:12.1f}")
    print("\nOK — trained-weight UW statistics above validate the paper's "
          "premise beyond synthetic weights.")


if __name__ == "__main__":
    main()
