"""Offline CREW analysis of the paper's five DNNs at their real dims —
the reproduction of Figs 1/3/5 + Tables I/II as one readable report.

    PYTHONPATH=src python examples/compress_analyze.py [--model GNMT]
"""
import argparse

import numpy as np

from repro.core import (analyze_matrix, aggregate_stats, frequency_histogram,
                        layout_stats, quantize_matrix, unique_histogram)
from repro.models.paper import PAPER_MODELS, fc_matrices


def bar(frac, width=40):
    return "#" * int(frac * width)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="Kaldi", choices=list(PAPER_MODELS))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    model = PAPER_MODELS[args.model]
    print(f"{model.name}: {len(model.fc_shapes)} FC matrices, "
          f"{model.size_mb_fp32():.0f} MB fp32 (paper Table IV dims)\n")

    stats, hist, freq = [], np.zeros(257, dtype=np.int64), np.zeros(50)
    for lname, w in fc_matrices(model, seed=args.seed):
        qm = quantize_matrix(w)
        layout = analyze_matrix(qm.q)
        stats.append(layout_stats(layout))
        h = unique_histogram(layout)
        hist[:h.size] += h
        freq += frequency_histogram(layout)

    agg = aggregate_stats(stats)
    print("Table I/II row:", agg.row(), "\n")

    print("Fig 3 — histogram of unique weights per input neuron:")
    binned = hist[:256].reshape(-1, 16).sum(axis=1)  # 16-wide bins, 0..255
    peak = binned.max()
    for i, c in enumerate(binned):
        if c:
            print(f"  UW {16*i:3d}-{16*i+15:3d} | {bar(c/peak)} {c}")

    print("\nFig 5 — usage-frequency histogram of unique weights "
          "(how often each unique value repeats in its row):")
    fpeak = freq.max()
    for i in range(0, 10):
        lo, hi = i * 2, i * 2 + 2
        print(f"  {lo:2d}-{hi:2d}% | {bar(freq[i]/fpeak)} {int(freq[i])}")
    low = freq[:1].sum() / freq.sum()
    print(f"\n{100*low:.0f}% of unique weights are used by <2% of their row "
          f"(paper: >50% under 1%) -> PPA's headroom.")


if __name__ == "__main__":
    main()
