"""Flash-attention Pallas kernel vs the chunked-attention oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.layers.attention import chunked_attention


@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (4, 1), (8, 2)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_chunked(h, kv, causal):
    rng = np.random.default_rng(h * 7 + kv + causal)
    B, S, D = 2, 41, 16
    q = jnp.asarray(rng.standard_normal((B, S, h, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, kv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, kv, D)), jnp.float32)
    ref = chunked_attention(q, k, v, causal=causal, q_chunk=16, kv_chunk=8)
    out = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("sq,sk", [(7, 64), (64, 7), (128, 128), (65, 33)])
def test_flash_shape_sweep(sq, sk):
    rng = np.random.default_rng(sq * sk)
    q = jnp.asarray(rng.standard_normal((1, sq, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, sk, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, sk, 2, 8)), jnp.float32)
    ref = chunked_attention(q, k, v, causal=False, q_chunk=16, kv_chunk=8)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((2, 32, 4, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((2, 32, 2, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((2, 32, 2, 16)), jnp.bfloat16)
    ref = chunked_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)
