"""Suite-wide collection config.

``hypothesis`` (requirements-dev.txt) drives the property tests in
test_core.py / test_pack.py.  When it is absent — minimal containers that
only carry the runtime deps — those modules are skipped at collection
instead of erroring the whole run; CI installs it and runs everything.
"""
import importlib.util

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore += ["test_core.py", "test_pack.py",
                       "test_convert_parity_prop.py"]
