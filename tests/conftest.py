"""Suite-wide collection config.

``hypothesis`` (requirements-dev.txt) drives the property tests in
test_core.py / test_pack.py.  When it is absent — minimal containers that
only carry the runtime deps — those modules are skipped at collection
instead of erroring the whole run; CI installs it and runs everything.
(test_paged_prop.py is *not* gated: its seeded sweep runs without
hypothesis, and only its hypothesis-drawn variant skips.)

``--hypothesis-seed N`` derandomizes every seed-driven property test:
it is exported as ``HYPOTHESIS_SEED`` before collection, where
test_paged_prop.py reads it as the base seed for both its seeded sweep
and its hypothesis draw sequence — so a CI fuzz failure replays exactly
with the same flag.
"""
import importlib.util
import os

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore += ["test_core.py", "test_pack.py",
                       "test_convert_parity_prop.py"]


def pytest_addoption(parser):
    parser.addoption(
        "--hypothesis-seed", action="store", default=None,
        help="base seed for seed-driven property tests "
             "(exported as HYPOTHESIS_SEED; default: env or 0)")


def pytest_configure(config):
    seed = config.getoption("--hypothesis-seed")
    if seed is not None:
        os.environ["HYPOTHESIS_SEED"] = str(seed)
