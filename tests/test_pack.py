"""Bit-packing property tests (hypothesis): straddled + word-aligned."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (
    build_width_classes, elems_per_word, pack_bits_straddled,
    pack_rows_word_aligned, straddled_size_bits, unpack_bits_straddled,
    unpack_rows_word_aligned,
)


@given(st.integers(0, 2 ** 32 - 1), st.integers(1, 12), st.integers(1, 70))
@settings(max_examples=40, deadline=None)
def test_straddled_roundtrip(seed, n, m):
    rng = np.random.default_rng(seed)
    widths = rng.integers(1, 9, size=n)
    idx = np.stack([rng.integers(0, 1 << w, size=m) for w in widths]).astype(np.int32)
    stream = pack_bits_straddled(idx, widths)
    assert stream.size == (straddled_size_bits(widths, m, include_side_channel=False) + 7) // 8
    out = unpack_bits_straddled(stream, widths, m)
    assert (out == idx).all()


@given(st.integers(0, 2 ** 32 - 1), st.integers(1, 16), st.integers(1, 8),
       st.integers(1, 90))
@settings(max_examples=40, deadline=None)
def test_word_aligned_roundtrip(seed, r, width, m):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, 1 << width, size=(r, m)).astype(np.int32)
    words = pack_rows_word_aligned(idx, width)
    assert words.dtype == np.uint32
    assert words.shape[1] == -(-m // elems_per_word(width))
    assert (unpack_rows_word_aligned(words, width, m) == idx).all()


def test_word_aligned_jnp_unpack_matches_numpy():
    import jax.numpy as jnp
    from repro.core.convert import unpack_words
    rng = np.random.default_rng(0)
    for width in range(1, 9):
        idx = rng.integers(0, 1 << width, size=(5, 33)).astype(np.int32)
        words = pack_rows_word_aligned(idx, width)
        out = np.asarray(unpack_words(jnp.asarray(words), width, 33))
        assert (out == idx).all(), width


@given(st.integers(0, 2 ** 32 - 1), st.integers(2, 20), st.integers(2, 40))
@settings(max_examples=25, deadline=None)
def test_width_classes_partition(seed, n, m):
    rng = np.random.default_rng(seed)
    widths = rng.integers(1, 9, size=n)
    idx = np.stack([rng.integers(0, 1 << w, size=m) for w in widths]).astype(np.int32)
    classes = build_width_classes(idx, widths)
    seen = np.concatenate([c.row_ids for c in classes])
    assert sorted(seen.tolist()) == list(range(n))  # exact partition
    for c in classes:
        assert (widths[c.row_ids] == c.width).all()
        out = unpack_rows_word_aligned(c.words, c.width, m)
        assert (out == idx[c.row_ids]).all()


def test_elems_per_word_bounds():
    assert elems_per_word(1) == 32
    assert elems_per_word(6) == 5
    assert elems_per_word(8) == 4
    import pytest
    with pytest.raises(ValueError):
        elems_per_word(0)
