"""Converter parity: the vectorized offline pipeline must be bit-identical
to the seed (per-row loop) implementations.

The seed algorithms are kept here as oracles: per-row ``np.unique`` for the
analysis, the per-bit scatter loop for the straddled bitstream, the per-row
assignment loop for the padded unique table, and per-column ``np.unique``
for the UCNN comparison.  Fixed adversarial matrices (constant rows,
all-unique rows, width-1 rows, negative ranges, single row/column) plus a
seeded random sweep cover both the histogram and the sort analysis paths;
the hypothesis sweep lives in test_convert_parity_prop.py.
"""
import numpy as np
import pytest

from repro.core import (analyze_matrix, index_width, pack_bits_straddled,
                        quantize_matrix, reconstruct, unpack_bits_straddled)
from repro.core.unique import _HIST_MAX_LEVELS
from repro.perfmodel import _col_unique_counts


# -------------------------------------------------------------------------
# Seed oracles (the pre-vectorization implementations, verbatim semantics)
# -------------------------------------------------------------------------

def seed_analyze(q):
    n, m = q.shape
    idx = np.empty((n, m), dtype=np.int32)
    widths = np.empty((n,), dtype=np.int32)
    rows = []
    for i in range(n):
        vals, inv, counts = np.unique(q[i], return_inverse=True,
                                      return_counts=True)
        rows.append((vals.astype(np.int32), counts))
        idx[i] = inv.astype(np.int32)
        widths[i] = index_width(vals.size)
    return rows, idx, widths


def seed_pack_bits_straddled(idx, widths):
    n, m = idx.shape
    widths = np.asarray(widths, dtype=np.int64)
    total_bits = int((widths * m).sum())
    out = np.zeros(((total_bits + 7) // 8,), dtype=np.uint8)
    bitpos = 0
    for i in range(n):
        w = int(widths[i])
        row = idx[i].astype(np.uint64)
        starts = bitpos + w * np.arange(m, dtype=np.int64)
        for b in range(w):
            pos = starts + b
            bit = ((row >> np.uint64(b)) & np.uint64(1)).astype(np.int64)
            np.bitwise_or.at(out, pos >> 3, (bit << (pos & 7)).astype(np.uint8))
        bitpos += w * m
    return out


def seed_padded_table(rows, k):
    out = np.zeros((len(rows), k), dtype=np.int32)
    for i, (vals, _) in enumerate(rows):
        out[i, :vals.size] = vals
        out[i, vals.size:] = vals[-1]
    return out


def seed_col_unique_counts(q):
    return np.array([np.unique(q[:, j]).size for j in range(q.shape[1])])


def assert_analysis_matches(q):
    rows_ref, idx_ref, widths_ref = seed_analyze(q)
    layout = analyze_matrix(q)
    assert layout.idx.dtype == idx_ref.dtype
    assert (layout.idx == idx_ref).all()
    assert layout.widths.dtype == widths_ref.dtype
    assert (layout.widths == widths_ref).all()
    for (vals, counts), row in zip(rows_ref, layout.rows):
        assert row.values.dtype == np.int32
        assert (row.values == vals).all()
        assert (row.counts == counts).all()
    assert (reconstruct(layout) == q).all()
    k = layout.max_unique()
    assert (layout.padded_unique_table(k)
            == seed_padded_table(rows_ref, k)).all()
    return layout


# -------------------------------------------------------------------------
# Fixed adversarial matrices
# -------------------------------------------------------------------------

ADVERSARIAL = {
    "constant_rows": np.full((5, 37), -3, dtype=np.int32),
    "constant_matrix_zero": np.zeros((4, 9), dtype=np.int32),
    "all_unique_rows": np.argsort(
        np.random.default_rng(0).random((6, 64)), axis=1).astype(np.int32) - 17,
    "width1_rows": np.tile(np.array([[7, -2]], dtype=np.int32), (3, 16)),
    "single_row": np.array([[5, 5, 1, -9, 1, 5]], dtype=np.int32),
    "single_col": np.array([[3], [3], [-1], [0]], dtype=np.int32),
    "mixed_widths": np.array(
        [[0] * 8, [0, 1] * 4, list(range(8)), [-4, -4, -4, -4, 100, 100, 7, 7]],
        dtype=np.int32),
    "extreme_range": np.array([[-(2 ** 20), 2 ** 20, 0, 0]], dtype=np.int32),
}


@pytest.mark.parametrize("name", sorted(ADVERSARIAL))
def test_adversarial_analysis_parity(name):
    assert_analysis_matches(ADVERSARIAL[name])


@pytest.mark.parametrize("name", sorted(ADVERSARIAL))
def test_adversarial_straddled_parity(name):
    layout = analyze_matrix(ADVERSARIAL[name])
    idx, widths = layout.idx, layout.widths
    ref = seed_pack_bits_straddled(idx, widths)
    out = pack_bits_straddled(idx, widths)
    assert out.dtype == ref.dtype and out.shape == ref.shape
    assert (out == ref).all()
    assert (unpack_bits_straddled(out, widths, idx.shape[1]) == idx).all()


def test_random_sweep_both_paths():
    """Seeded sweep across shapes/ranges; wide ranges force the sort path
    (range > _HIST_MAX_LEVELS), narrow ones the histogram path."""
    rng = np.random.default_rng(123)
    for _ in range(25):
        n = int(rng.integers(1, 30))
        m = int(rng.integers(1, 70))
        span = int(rng.choice([3, 128, 255, _HIST_MAX_LEVELS + 50, 10 ** 6]))
        q = rng.integers(-span, span + 1, size=(n, m)).astype(np.int32)
        layout = assert_analysis_matches(q)
        stream = pack_bits_straddled(layout.idx, layout.widths)
        assert (stream == seed_pack_bits_straddled(layout.idx,
                                                   layout.widths)).all()
        assert (unpack_bits_straddled(stream, layout.widths, m)
                == layout.idx).all()


def test_quantized_end_to_end_parity():
    rng = np.random.default_rng(7)
    w = (rng.standard_t(4, size=(96, 257)) * 0.05).astype(np.float32)
    q = quantize_matrix(w).q
    assert_analysis_matches(q)


def test_col_unique_counts_parity():
    rng = np.random.default_rng(11)
    for shape in [(1, 1), (7, 13), (64, 32), (128, 5)]:
        q = rng.integers(-20, 21, size=shape).astype(np.int32)
        assert (_col_unique_counts(q) == seed_col_unique_counts(q)).all()
    const = np.full((9, 4), 3, dtype=np.int32)
    assert (_col_unique_counts(const) == 1).all()


def test_padded_table_row_ids_subset():
    q = np.random.default_rng(5).integers(-8, 9, size=(12, 40)).astype(np.int32)
    layout = analyze_matrix(q)
    k = layout.max_unique()
    full = layout.padded_unique_table(k)
    sel = np.array([7, 0, 11, 3])
    assert (layout.padded_unique_table(k, row_ids=sel) == full[sel]).all()


def test_padded_table_overflow_raises():
    q = np.arange(24, dtype=np.int32).reshape(2, 12)  # 12 uniques per row
    layout = analyze_matrix(q)
    with pytest.raises(ValueError, match="row 0 has 12 uniques"):
        layout.padded_unique_table(8)


def test_straddled_out_of_range_raises():
    idx = np.array([[0, 1], [2, 5]], dtype=np.int32)
    with pytest.raises(ValueError, match="row 1: index exceeds 2 bits"):
        pack_bits_straddled(idx, np.array([1, 2]))
