"""Pallas kernel vs pure-jnp oracle: shape/dtype/width/strategy sweeps.

The kernel runs in interpret mode on CPU (the BlockSpecs are the TPU
tiling contract); every configuration must match ref.py to float32
accumulation tolerance.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import crew_uniform_from_dense, crew_var_from_dense
from repro.core.pack import pack_rows_word_aligned
from repro.kernels.crew_matmul import crew_matmul_pallas
from repro.kernels.ops import crew_matmul, pick_strategy
from repro.kernels.ref import crew_matmul_ref, unpack_ref


def make_case(rng, n, m, width, b, dtype=jnp.float32):
    k = 1 << width
    idx = rng.integers(0, k, size=(n, m)).astype(np.int32)
    words = pack_rows_word_aligned(idx, width)
    uniq = (rng.standard_normal((n, k)) * 0.1).astype(np.float32)
    x = (rng.standard_normal((b, n))).astype(np.float32)
    return (jnp.asarray(x, dtype), jnp.asarray(words),
            jnp.asarray(uniq, dtype))


@pytest.mark.parametrize("width", [1, 2, 3, 4, 5, 6, 7, 8])
@pytest.mark.parametrize("strategy", ["gather", "onehot"])
def test_kernel_width_sweep(width, strategy):
    rng = np.random.default_rng(width)
    x, words, uniq = make_case(rng, n=96, m=160, width=width, b=3)
    ref = crew_matmul_ref(x, words, uniq, width=width, m=160)
    out = crew_matmul_pallas(x, words, uniq, width=width, m_out=160,
                             strategy=strategy, block_n=32, block_words=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,m,b", [(7, 13, 1), (128, 256, 4), (200, 100, 2),
                                   (33, 515, 5)])
def test_kernel_shape_sweep(n, m, b):
    rng = np.random.default_rng(n * m)
    x, words, uniq = make_case(rng, n=n, m=m, width=5, b=b)
    ref = crew_matmul_ref(x, words, uniq, width=5, m=m)
    for strategy in ("gather", "onehot"):
        out = crew_matmul_pallas(x, words, uniq, width=5, m_out=m,
                                 strategy=strategy, block_n=64, block_words=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtype_sweep(dtype):
    rng = np.random.default_rng(42)
    x, words, uniq = make_case(rng, n=64, m=96, width=4, b=2, dtype=dtype)
    ref = crew_matmul_ref(x, words, uniq, width=4, m=96)
    out = crew_matmul_pallas(x, words, uniq, width=4, m_out=96,
                             strategy="gather")
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_unpack_ref_matches_numpy():
    from repro.core.pack import unpack_rows_word_aligned
    rng = np.random.default_rng(7)
    idx = rng.integers(0, 64, size=(9, 47)).astype(np.int32)
    words = pack_rows_word_aligned(idx, 6)
    out = np.asarray(unpack_ref(jnp.asarray(words), 6, 47))
    assert (out == unpack_rows_word_aligned(words, 6, 47)).all()


class TestOpsDispatch:
    def setup_method(self, _):
        rng = np.random.default_rng(0)
        self.w = (rng.standard_t(4, size=(96, 144)) * 0.05).astype(np.float32)
        self.x = jnp.asarray(rng.standard_normal((4, 96)).astype(np.float32))

    def test_uniform_strategies_agree(self):
        cm, _, qm = crew_uniform_from_dense(self.w, dtype=jnp.float32)
        ref = self.x @ jnp.asarray(qm.q * float(qm.scale), jnp.float32)
        for strat in ("xla-dense", "xla-gather", "pallas-gather",
                      "pallas-onehot", "auto"):
            out = crew_matmul(self.x, cm, strategy=strat)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-4, atol=2e-4)

    def test_var_strategies_agree(self):
        cm, _, qm = crew_var_from_dense(self.w, dtype=jnp.float32)
        ref = self.x @ jnp.asarray(qm.q * float(qm.scale), jnp.float32)
        for strat in ("xla-dense", "xla-gather", "pallas-gather"):
            out = crew_matmul(self.x, cm, strategy=strat)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-4, atol=2e-4)

    def test_leading_dims(self):
        cm, _, _ = crew_uniform_from_dense(self.w, dtype=jnp.float32)
        x3 = jnp.reshape(jnp.tile(self.x, (2, 1)), (2, 4, 96))
        out = crew_matmul(x3, cm, strategy="xla-dense")
        assert out.shape == (2, 4, 144)

    def test_pick_strategy(self):
        assert pick_strategy(1, 6, compute_rich=False) == "pallas-onehot"
        assert pick_strategy(128, 8, compute_rich=False) == "pallas-gather"
        assert pick_strategy(4, 6, compute_rich=True) == "xla-dense"


class TestEpilogueFusion:
    """Fused bias/activation epilogue (DESIGN.md §3): the in-kernel
    epilogue on the last n-block must match applying the same ops to the
    oracle output, across strategies and the ops-level dispatch."""

    def setup_method(self, _):
        rng = np.random.default_rng(11)
        self.x, self.words, self.uniq = make_case(rng, n=96, m=160, width=4,
                                                  b=3)
        self.bias = jnp.asarray(
            (rng.standard_normal(160) * 0.5).astype(np.float32))
        self.ref = crew_matmul_ref(self.x, self.words, self.uniq, width=4,
                                   m=160)

    @pytest.mark.parametrize("strategy", ["gather", "onehot"])
    @pytest.mark.parametrize("activation", [None, "relu", "silu", "gelu"])
    def test_kernel_epilogue(self, strategy, activation):
        import jax
        ref = self.ref + self.bias[None]
        if activation is not None:
            ref = getattr(jax.nn, activation)(ref)
        out = crew_matmul_pallas(
            self.x, self.words, self.uniq, width=4, m_out=160,
            strategy=strategy, bias=self.bias, activation=activation,
            block_n=32, block_words=8)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_kernel_activation_without_bias(self):
        import jax
        out = crew_matmul_pallas(self.x, self.words, self.uniq, width=4,
                                 m_out=160, strategy="gather",
                                 activation="gelu", block_n=32, block_words=8)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(jax.nn.gelu(self.ref)),
                                   rtol=1e-5, atol=1e-5)

    def test_unknown_activation_rejected(self):
        with pytest.raises(ValueError, match="activation"):
            crew_matmul_pallas(self.x, self.words, self.uniq, width=4,
                               m_out=160, activation="tanh")
        rng = np.random.default_rng(0)
        w = (rng.standard_normal((96, 144)) * 0.05).astype(np.float32)
        cm, _, _ = crew_uniform_from_dense(w, dtype=jnp.float32)
        with pytest.raises(ValueError, match="activation"):
            crew_matmul(self.x, cm, activation="tanh")

    def test_ops_epilogue_all_strategies_agree(self):
        """Every dispatch strategy — fused in-kernel or XLA trailing ops —
        produces the same epilogue'd output."""
        import jax
        rng = np.random.default_rng(12)
        w = (rng.standard_t(4, size=(96, 144)) * 0.05).astype(np.float32)
        x = jnp.asarray(rng.standard_normal((4, 96)).astype(np.float32))
        bias = jnp.asarray((rng.standard_normal(144) * 0.5)
                           .astype(np.float32))
        cm, _, qm = crew_uniform_from_dense(w, dtype=jnp.float32)
        ref = jax.nn.silu(
            x @ jnp.asarray(qm.q * float(qm.scale), jnp.float32) + bias)
        for strat in ("xla-dense", "xla-gather", "pallas-gather",
                      "pallas-onehot", "auto"):
            out = crew_matmul(x, cm, strategy=strat, bias=bias,
                              activation="silu")
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-4, atol=2e-4)

    def test_linear_apply_fused_matches_unfused_dense(self):
        """Dense path: activation= is the same ops in the same order —
        bitwise equal to applying the activation outside."""
        import jax
        from repro.layers import linear
        rng = np.random.default_rng(13)
        params = {"w": jnp.asarray(rng.standard_normal((32, 48))
                                   .astype(np.float32)),
                  "b": jnp.asarray(rng.standard_normal(48)
                                   .astype(np.float32))}
        x = jnp.asarray(rng.standard_normal((5, 32)).astype(np.float32))
        fused = linear.apply(params, x, activation="gelu")
        unfused = jax.nn.gelu(linear.apply(params, x))
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))


class TestVarAutoDispatch:
    """CrewMatrixVar strategy="auto" must consult the autotune store per
    width class (the satellite fix: it used to hardcode dense)."""

    def _class_keys(self, cm, b):
        import jax
        from repro.perf.autotune import make_key
        return [make_key(b, int(c.uniq.shape[0]), cm.n_out,
                         int(c.uniq.shape[1]), c.width,
                         jax.default_backend())
                for c in cm.classes]

    def test_var_auto_uses_measured_winner(self):
        from repro.perf import autotune
        from repro.perf.autotune import AutotuneStore, Measurement
        rng = np.random.default_rng(0)
        w = (rng.standard_t(4, size=(96, 144)) * 0.05).astype(np.float32)
        x = jnp.asarray(rng.standard_normal((4, 96)).astype(np.float32))
        cm, _, qm = crew_var_from_dense(w, dtype=jnp.float32)
        ref = np.asarray(x @ jnp.asarray(qm.q * float(qm.scale), jnp.float32))
        autotune.set_store(AutotuneStore())
        try:
            # measured winners drive every class, and the result is right
            for key in self._class_keys(cm, 4):
                autotune.get_store().put(
                    key, Measurement(strategy="xla-gather", times_s={}))
            out = np.asarray(crew_matmul(x, cm, strategy="auto"))
            np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)
            # a poisoned store entry proves the lookup actually happened
            for key in self._class_keys(cm, 4):
                autotune.get_store().put(
                    key, Measurement(strategy="no-such", times_s={}))
            with pytest.raises(ValueError, match="unknown strategy"):
                crew_matmul(x, cm, strategy="auto")
            # epilogue'd var calls consult the same *plain* class keys —
            # the epilogue is applied after the class sum, so per-class
            # strategy cost (and its measurement) is epilogue-independent
            with pytest.raises(ValueError, match="unknown strategy"):
                crew_matmul(x, cm, strategy="auto",
                            bias=jnp.zeros(cm.n_out), activation="silu")
        finally:
            autotune.set_store(None)

    def test_var_auto_cold_cache_matches_prior(self):
        """Cold cache: every class falls back to the analytical prior —
        same numbers as the explicit whole-matrix strategies."""
        from repro.perf import autotune
        from repro.perf.autotune import AutotuneStore
        rng = np.random.default_rng(1)
        w = (rng.standard_t(4, size=(64, 160)) * 0.05).astype(np.float32)
        x = jnp.asarray(rng.standard_normal((2, 64)).astype(np.float32))
        cm, _, qm = crew_var_from_dense(w, dtype=jnp.float32)
        ref = np.asarray(x @ jnp.asarray(qm.q * float(qm.scale), jnp.float32))
        autotune.set_store(AutotuneStore())
        try:
            out = np.asarray(crew_matmul(x, cm, strategy="auto"))
        finally:
            autotune.set_store(None)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_ppa_end_to_end_compression_and_distortion():
    """PPA shrinks index widths; output distortion is bounded and monotone
    in the threshold (the paper bounds *frequency mass*, not weight
    distance, so rare outliers may move far — Algorithm 1 semantics)."""
    rng = np.random.default_rng(1)
    w = (rng.standard_t(4, size=(128, 256)) * 0.05).astype(np.float32)
    x = jnp.asarray(rng.standard_normal((2, 128)).astype(np.float32))
    cm0, lay0, _ = crew_uniform_from_dense(w, dtype=jnp.float32)
    ref = np.asarray(crew_matmul(x, cm0, strategy="xla-dense"))
    rels = []
    for thr in (0.01, 0.05):
        cm1, lay1, _ = crew_uniform_from_dense(w, ppa_thr=thr,
                                               dtype=jnp.float32)
        out = np.asarray(crew_matmul(x, cm1, strategy="xla-dense"))
        rels.append(np.linalg.norm(out - ref) / (np.linalg.norm(ref) + 1e-9))
        assert lay1.widths.mean() < lay0.widths.mean()  # compression happened
    assert rels[0] <= rels[1] + 1e-9  # distortion monotone in threshold
    assert rels[1] < 0.5              # bounded (quantized-grid neighbours)
