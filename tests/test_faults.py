"""Deterministic fault injection (serve.faults, DESIGN.md §5 "request
lifecycle"): the injected schedule is a pure function of the seed, hook
streams are independent, and a seeded chaos run over the scheduler keeps
every lifecycle invariant — one terminal Completion per rid, completed
outputs token-identical to cold serve.generate, a consistent prefix pool
after drain, and run-to-run identical terminal statuses."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.serve import (FaultInjector, PrefixTrie, Scheduler, Shed,
                         generate)
from repro.serve.faults import default_injector


@pytest.fixture(scope="module")
def qwen():
    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _ref_tokens(api, params, prompt, max_new):
    out = generate(api, params, jax.numpy.asarray(prompt)[None],
                   max_new=max_new)
    return np.asarray(out["tokens"][0])


class TestInjectorPurity:
    def _drive(self, seed):
        inj = FaultInjector(seed, delay_p=0.4, max_delay_s=0.001,
                            preempt_p=0.4, expire_p=0.4,
                            drop_p=0.4, max_drop=3)
        trie = PrefixTrie(16, block_size=2)
        out = []
        for i in range(40):
            trie.insert(np.asarray([2 * i, 2 * i + 2], np.int32))
            out.append((inj.horizon_delay(), inj.should_preempt(),
                        inj.should_expire(i), inj.pool_drop(trie)))
        return out, inj.trace

    def test_same_seed_same_decisions_and_trace(self):
        a, trace_a = self._drive(5)
        b, trace_b = self._drive(5)
        assert a == b and trace_a == trace_b
        c, trace_c = self._drive(6)
        assert trace_c != trace_a

    def test_hook_streams_independent(self):
        """Consuming one hook's stream never shifts another's — the
        property that keeps fault schedules stable when the scheduler
        calls hooks at different per-step rates."""
        a = FaultInjector(7, preempt_p=0.5, expire_p=0.5)
        b = FaultInjector(7, preempt_p=0.5, expire_p=0.5)
        for i in range(9):
            b.should_expire(i)              # advance only b's expire stream
        assert ([a.should_preempt() for _ in range(20)]
                == [b.should_preempt() for _ in range(20)])

    def test_streams_advance_on_misses_too(self):
        """Decisions draw at a fixed rate per call even when nothing is
        injected, so raising a probability never reshuffles the other
        outcomes' positions."""
        lo = FaultInjector(9, preempt_p=0.0)
        hi = FaultInjector(9, preempt_p=1.0)
        for _ in range(10):
            assert lo.should_preempt() is False
            assert hi.should_preempt() is True

    def test_pool_drop_handles_missing_trie(self):
        inj = FaultInjector(0, drop_p=1.0, max_drop=2)
        assert inj.pool_drop(None) == 0     # prefix_cache=False scheduler
        assert inj.trace == []

    def test_default_injector_env_switch(self, monkeypatch):
        monkeypatch.delenv("REPRO_FAULTS", raising=False)
        assert default_injector() is None
        monkeypatch.setenv("REPRO_FAULTS", "0")
        assert default_injector() is None
        monkeypatch.setenv("REPRO_FAULTS", "7")
        inj = default_injector()
        assert inj is not None and inj.seed == 7
        # benign: only output-preserving faults are on — supervised
        # crashes recover token-identically, so they qualify; client
        # disconnects (cancel streams) and stalls (slow) do not
        assert inj.preempt_p > 0 and inj.drop_p > 0 and inj.crash_p > 0
        assert inj.delay_p == 0 and inj.expire_p == 0
        assert inj.disconnect_p == 0 and inj.stall_p == 0

    def test_hook_indices_append_only(self):
        """Every seeded schedule the suite pins keys off each hook's
        position in _HOOKS; new hooks must append, never reorder."""
        assert FaultInjector._HOOKS[:4] == ("delay", "preempt",
                                            "expire", "drop")
        assert FaultInjector._HOOKS[4:] == ("crash", "disconnect",
                                            "stall", "kill")


class TestSupervisionHookPurity:
    def _drive(self, seed):
        inj = FaultInjector(seed, crash_p=0.4, disconnect_p=0.4,
                            max_disconnect_tokens=6,
                            stall_p=0.4, max_stall_s=0.001)
        out = []
        for i in range(40):
            out.append((inj.should_crash(), inj.disconnect_after(i),
                        inj.client_stall()))
        return out, inj.trace

    def test_same_seed_same_decisions_and_trace(self):
        a, trace_a = self._drive(5)
        b, trace_b = self._drive(5)
        assert a == b and trace_a == trace_b
        c, trace_c = self._drive(6)
        assert trace_c != trace_a
        # something actually fired on each hook at p=0.4 over 40 calls
        hooks = {h for h, *_ in trace_a}
        assert hooks == {"crash", "disconnect", "stall"}

    def test_new_streams_independent_of_old(self):
        """Supervision hooks must not perturb the scheduler-facing
        streams (they seed from appended _HOOKS indices), so arming a
        crash schedule never reshuffles a pinned preempt schedule."""
        a = FaultInjector(7, preempt_p=0.5, crash_p=0.5)
        b = FaultInjector(7, preempt_p=0.5, crash_p=0.5)
        for i in range(9):                  # advance only b's new streams
            b.should_crash()
            b.disconnect_after(i)
            b.client_stall()
        assert ([a.should_preempt() for _ in range(20)]
                == [b.should_preempt() for _ in range(20)])

    def test_disconnect_stream_advances_on_misses(self):
        """disconnect_after draws its token count even on a miss, so
        raising disconnect_p never shifts later hit positions."""
        lo = FaultInjector(9, disconnect_p=0.0)
        hi = FaultInjector(9, disconnect_p=1.0, max_disconnect_tokens=6)
        for i in range(10):
            assert lo.disconnect_after(i) is None
            k = hi.disconnect_after(i)
            assert k is not None and 0 <= k <= 6
        # the misses consumed draws at the same rate as the hits: flip
        # lo hot and the two streams are in lockstep from here on
        lo.disconnect_p = 1.0
        lo.max_disconnect_tokens = 6
        assert ([lo.disconnect_after(0) for _ in range(5)]
                == [hi.disconnect_after(0) for _ in range(5)])


class TestSeededChaos:
    def _workload(self, cfg):
        rng = np.random.default_rng(11)
        lens = [8, 12, 20, 8, 16, 12, 20, 8, 16, 12]
        news = [4, 6, 4, 6, 4, 6, 4, 6, 4, 6]
        return [(rng.integers(0, cfg.vocab, n).astype(np.int32), m)
                for n, m in zip(lens, news)]

    def _drive(self, api, params, reqs, seed):
        """Submit/step/cancel on a fixed schedule under an aggressive
        injector; returns (sched, {i: rid}, {rid: Completion})."""
        sched = Scheduler(
            api, params, max_batch=2, cache_len=64, buckets=(8, 16),
            horizon=4, block_size=8, max_queue=6,
            faults=FaultInjector(seed, preempt_p=0.5, expire_p=0.1,
                                 drop_p=0.5, max_drop=2))
        rids = {}
        for i, (p, m) in enumerate(reqs):
            # every third request carries a (fault-expirable) deadline
            # far beyond the test's wall clock
            dl = 1000.0 if i % 3 == 0 else None
            r = sched.submit(p, max_new=m, deadline_s=dl)
            rids[i] = r.rid if isinstance(r, Shed) else r
            sched.step()
            if i in (4, 7):                 # cancel a mid-run rid
                sched.cancel(rids[i - 2])
        return sched, rids, sched.run()

    def test_chaos_preserves_lifecycle_invariants(self, qwen):
        cfg, api, params = qwen
        reqs = self._workload(cfg)
        refs = {i: _ref_tokens(api, params, p, m)
                for i, (p, m) in enumerate(reqs)}
        sched, rids, res = self._drive(api, params, reqs, seed=9)
        # something actually happened: the schedule injected faults
        assert sched.metrics.preempted >= 1
        assert any(h == "drop" for h, *_ in sched._faults.trace)
        # exactly one terminal Completion per submitted rid
        assert sorted(res) == sorted(rids.values())
        statuses = {i: res[rids[i]].status for i in rids}
        assert set(statuses.values()) <= {"completed", "cancelled",
                                          "timed_out", "shed"}
        # completed outputs are token-identical to cold generate
        n_completed = 0
        for i in rids:
            if statuses[i] == "completed":
                n_completed += 1
                np.testing.assert_array_equal(res[rids[i]].tokens, refs[i])
        assert n_completed >= 1
        # the prefix pool is consistent after drain (refcounts, LRU,
        # free-list/node-table accounting)
        assert sched._trie.check_invariants() == []
        # purity end to end: same seed -> same fault schedule -> same
        # terminal statuses (and the same per-status outputs)
        sched2, rids2, res2 = self._drive(api, params, reqs, seed=9)
        assert sched2._faults.trace == sched._faults.trace
        assert {i: res2[rids2[i]].status for i in rids2} == statuses

    def test_different_seed_different_schedule(self, qwen):
        cfg, api, params = qwen
        reqs = self._workload(cfg)
        sched_a, _, _ = self._drive(api, params, reqs, seed=9)
        sched_b, _, _ = self._drive(api, params, reqs, seed=10)
        assert sched_a._faults.trace != sched_b._faults.trace
