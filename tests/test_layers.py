"""Layer-level correctness: attention parity, SSM chunk/decode parity,
xLSTM step parity, MoE semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers import attention, mamba2, moe, recurrent, xlstm


def naive_attention(q, k, v, causal=True):
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, d).astype(jnp.float32)
    sc = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * d ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, -1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, s, h, d).astype(q.dtype)


class TestAttention:
    @pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (4, 1)])  # MHA/GQA/MQA
    @pytest.mark.parametrize("causal", [True, False])
    def test_chunked_matches_naive(self, h, kv, causal):
        rng = np.random.default_rng(h * 10 + kv)
        B, S, D = 2, 45, 16
        q = jnp.asarray(rng.standard_normal((B, S, h, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, kv, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, kv, D)), jnp.float32)
        ref = naive_attention(q, k, v, causal)
        out = attention.chunked_attention(q, k, v, causal=causal,
                                          q_chunk=16, kv_chunk=8)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_prefill_decode_parity(self):
        """Decoding token-by-token equals the full causal forward."""
        rng = jax.random.PRNGKey(0)
        B, S, d, h, kv, hd = 2, 12, 32, 4, 2, 8
        params = attention.init(rng, d, h, kv, hd)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32)
        full, _ = attention.attend(params, x, n_heads=h, n_kv=kv, d_head=hd,
                                   q_chunk=4, kv_chunk=4)
        cache = attention.init_kv_cache(B, S + 2, kv, hd, dtype=jnp.float32)
        outs = []
        for t in range(S):
            y, cache = attention.attend_decode(params, x[:, t:t+1], cache,
                                               n_heads=h, n_kv=kv, d_head=hd)
            outs.append(y)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                                   rtol=2e-4, atol=2e-4)


class TestMamba2:
    def test_chunked_decode_parity(self):
        rng = jax.random.PRNGKey(0)
        B, S, d = 2, 10, 16
        kw = dict(expand=2, head_dim=8, state=4)
        params = mamba2.init(rng, d, **kw, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.5
        full, h_fin = mamba2.apply_chunked(params, x, head_dim=8, state=4,
                                           chunk=5)
        cache = mamba2.init_state(B, d, **kw)
        outs = []
        for t in range(S):
            y, cache = mamba2.apply_decode(params, x[:, t:t+1], cache,
                                           head_dim=8, state=4)
            outs.append(y)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                                   rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(np.asarray(cache["h"]), np.asarray(h_fin),
                                   rtol=5e-4, atol=5e-4)

    def test_chunk_size_invariance(self):
        rng = jax.random.PRNGKey(2)
        params = mamba2.init(rng, 16, expand=2, head_dim=8, state=4,
                             dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 16)) * 0.5
        outs = [mamba2.apply_chunked(params, x, head_dim=8, state=4, chunk=c)[0]
                for c in (2, 4, 16)]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                       rtol=5e-4, atol=5e-4)


class TestXLSTM:
    def test_mlstm_statefulness(self):
        """Splitting a sequence across two calls with carried state equals
        one full call."""
        rng = jax.random.PRNGKey(0)
        d, h = 16, 2
        params = xlstm.mlstm_init(rng, d, h, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d)) * 0.5
        full, _ = xlstm.mlstm_apply(params, x, n_heads=h)
        y1, st = xlstm.mlstm_apply(params, x[:, :4], n_heads=h)
        y2, _ = xlstm.mlstm_apply(params, x[:, 4:], st, n_heads=h)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(full), rtol=2e-4, atol=2e-4)

    def test_slstm_statefulness(self):
        rng = jax.random.PRNGKey(2)
        d, h = 16, 4
        params = xlstm.slstm_init(rng, d, h, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 6, d)) * 0.5
        full, _ = xlstm.slstm_apply(params, x, n_heads=h)
        y1, st = xlstm.slstm_apply(params, x[:, :3], n_heads=h)
        y2, _ = xlstm.slstm_apply(params, x[:, 3:], st, n_heads=h)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(full), rtol=2e-4, atol=2e-4)


class TestMoE:
    def test_routing_and_shapes(self):
        rng = jax.random.PRNGKey(0)
        d, ff, e = 16, 32, 4
        params = moe.init(rng, d, ff, e, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
        y, stats = moe.apply(params, x, top_k=2, group_size=8)
        assert y.shape == x.shape
        assert not bool(jnp.isnan(y).any())
        assert float(stats.aux_loss) > 0.0
        assert 0.0 <= float(stats.dropped_fraction) <= 1.0

    def test_capacity_drops(self):
        """capacity_factor -> 0 forces drops; output shrinks toward zero."""
        rng = jax.random.PRNGKey(2)
        params = moe.init(rng, 8, 16, 4, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 8))
        _, s_lo = moe.apply(params, x, top_k=2, capacity_factor=0.1,
                            group_size=16)
        _, s_hi = moe.apply(params, x, top_k=2, capacity_factor=4.0,
                            group_size=16)
        assert float(s_lo.dropped_fraction) > float(s_hi.dropped_fraction)
        assert float(s_hi.dropped_fraction) == pytest.approx(0.0, abs=1e-6)


class TestRecurrent:
    def test_lstm_gru_shapes_and_state(self):
        rng = jax.random.PRNGKey(0)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 8))
        lp = recurrent.lstm_init(rng, 8, 12, dtype=jnp.float32)
        y, (h, c) = recurrent.lstm_apply(lp, x)
        assert y.shape == (2, 5, 12) and h.shape == (2, 12)
        gp = recurrent.gru_init(rng, 8, 12, dtype=jnp.float32)
        y2, h2 = recurrent.gru_apply(gp, x)
        assert y2.shape == (2, 5, 12) and h2.shape == (2, 12)
        mats = recurrent.gate_matrices({"l": lp, "g": gp})
        assert len(mats) == 4  # wx/wh for each cell
