"""repro.dist unit coverage that needs no forced-device children:
constrain outside any context, resolve on degenerate shapes, context
stack discipline, and the compressed-mean quantization math on one
device."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.compat import abstract_mesh
from repro.dist.compress import init_error
from repro.dist.ctx import constrain, current_ctx, sharding_ctx
from repro.dist.sharding import (SERVE_RULES, TRAIN_RULES, TRAIN_RULES_DP,
                                 named_sharding_tree, resolve)

MESH = abstract_mesh((16, 16), ("data", "model"))
MESH3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


class TestConstrainOutsideCtx:
    def test_identity_no_ctx(self):
        x = jnp.ones((4, 8))
        assert current_ctx() is None
        assert constrain(x, "batch", "embed") is x

    def test_noop_under_jit(self):
        @jax.jit
        def f(x):
            return constrain(x, "batch", None) * 2.0

        np.testing.assert_array_equal(np.asarray(f(jnp.ones((4, 2)))),
                                      2.0 * np.ones((4, 2)))

    def test_ctx_stack_restored_on_exception(self):
        with pytest.raises(RuntimeError):
            with sharding_ctx(MESH, TRAIN_RULES):
                assert current_ctx() == (MESH, TRAIN_RULES)
                raise RuntimeError("boom")
        assert current_ctx() is None

    def test_ctx_nesting_innermost_wins(self):
        with sharding_ctx(MESH, TRAIN_RULES):
            with sharding_ctx(MESH3, SERVE_RULES):
                assert current_ctx() == (MESH3, SERVE_RULES)
            assert current_ctx() == (MESH, TRAIN_RULES)
        assert current_ctx() is None


class TestResolveDegenerate:
    def test_size_one_dims_replicate(self):
        # nothing >1 divides 1: every claim fails, fully replicated
        assert resolve(P("batch", "embed"), (1, 1), MESH, TRAIN_RULES) == P()

    def test_short_spec_pads_replicated(self):
        assert resolve(P("embed"), (64, 128), MESH, TRAIN_RULES) == P("data")

    def test_long_spec_extra_entries_dropped(self):
        assert resolve(P("embed", "mlp", "heads"), (64, 128), MESH,
                       TRAIN_RULES) == P("data", "model")

    def test_scalar_shape(self):
        assert resolve(P(), (), MESH, TRAIN_RULES) == P()

    def test_unknown_logical_axis_replicates(self):
        assert resolve(P("no_such_axis"), (64,), MESH, TRAIN_RULES) == P()

    def test_missing_mesh_axis_skipped(self):
        # "pod" is absent from the 2-d mesh: the tuple claim degrades to
        # its ("data",) remainder instead of erroring
        assert resolve(P("batch"), (64,), MESH, TRAIN_RULES) == P("data")

    def test_dp_rules_claim_whole_mesh(self):
        assert resolve(P("batch", "seq"), (512, 128), MESH, TRAIN_RULES_DP) \
            == P(("data", "model"))
        # batch too small for the full 256-way claim: prefix fallback
        assert resolve(P("batch", "seq"), (64, 128), MESH, TRAIN_RULES_DP) \
            == P("data")
        assert resolve(P("embed", "mlp"), (64, 128), MESH, TRAIN_RULES_DP) \
            == P()

    def test_named_sharding_tree_single_device(self):
        mesh = jax.make_mesh((1,), ("data",))
        tree = {"w": P("embed", "mlp"), "step": P()}
        vals = {"w": jnp.zeros((4, 4)), "step": jnp.zeros(())}
        shard = named_sharding_tree(tree, vals, mesh, TRAIN_RULES)
        assert shard["w"].mesh == mesh
        assert shard["step"].spec == P()


class TestConstrainInCtx:
    def test_single_device_ctx_roundtrip(self):
        mesh = jax.make_mesh((1,), ("data",))

        @jax.jit
        def f(x):
            return constrain(x, "batch", None) + 1.0

        with sharding_ctx(mesh, TRAIN_RULES):
            out = f(jnp.zeros((4, 2)))
        np.testing.assert_array_equal(np.asarray(out), np.ones((4, 2)))
        assert current_ctx() is None


def test_init_error_zero_tree():
    g = {"a": jnp.ones((3,)), "b": {"c": jnp.ones((2, 2), jnp.bfloat16)}}
    e = init_error(g)
    assert e["b"]["c"].dtype == jnp.bfloat16
    assert float(jnp.abs(e["a"]).sum()) == 0.0
