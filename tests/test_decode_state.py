"""The VMEM-resident decode kernel + the CrewPlan/serve API contract.

Four contracts from the decode-state redesign (DESIGN.md §3, docs/api.md):

* **bitwise kernel parity** — ``crew_matmul_decode_pallas`` threading its
  product buffer across H steps is bit-for-bit the one-shot kernel on
  identically padded operands with matched blocking, for every index
  width class and H in {1, 4, 8};
* **decode-shaped autotune keys** — ``kind="decode"`` keys (with swept
  block shapes) round-trip the JSON store across processes, exactly like
  the ship-a-warmed-cache flow serves them;
* **deprecation shims** — the pre-CrewPlan kwargs and dict-style
  SchedulerMetrics reads keep working for one release and warn exactly
  once per process;
* **serving parity** — with forced ``pallas-decode`` winners the engine
  and scheduler carry the product-buffer state and still emit tokens
  identical to the stateless path (``decode_state="off"``).
"""
import os
import pathlib
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CrewMatrixUniform, crew_uniform_from_dense
from repro.core.pack import pack_rows_word_aligned
from repro.kernels.crew_matmul import (crew_matmul_decode_pallas,
                                       crew_matmul_pallas, decode_pbuf_rows)
from repro.kernels.ops import crew_matmul, crew_matmul_decode, \
    init_decode_state
from repro.kernels.plan import CrewPlan, reset_deprecation_warnings
from repro.perf import autotune
from repro.perf.autotune import AutotuneStore, Measurement, make_key

ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.fixture(autouse=True)
def fresh_store():
    autotune.set_store(AutotuneStore())
    yield
    autotune.set_store(None)


def make_case(rng, n, m, width, b, steps=1):
    k = 1 << width
    idx = rng.integers(0, k, size=(n, m)).astype(np.int32)
    words = pack_rows_word_aligned(idx, width)
    uniq = (rng.standard_normal((n, k)) * 0.1).astype(np.float32)
    xs = [jnp.asarray(rng.standard_normal((b, n)).astype(np.float32))
          for _ in range(steps)]
    return xs, jnp.asarray(words), jnp.asarray(uniq)


def _ref_one_shot(x, words, uniq, width, m, block_words=None, **kw):
    """The pre-decode-kernel reduction on identically padded operands:
    one n-block covering all of decode_pbuf_rows(N) — the matched-blocking
    contract the decode kernel's docstring pins."""
    n = x.shape[1]
    n_pad = decode_pbuf_rows(n)
    if n_pad != n:
        x = jnp.pad(x, ((0, 0), (0, n_pad - n)))
        words = jnp.pad(words, ((0, n_pad - n), (0, 0)))
        uniq = jnp.pad(uniq, ((0, n_pad - n), (0, 0)))
    bw = words.shape[1] if block_words is None else block_words
    return crew_matmul_pallas(x, words, uniq, width=width, m_out=m,
                              strategy="gather", block_n=n_pad,
                              block_words=bw, **kw)


class TestDecodeKernelParity:
    @pytest.mark.parametrize("width", [1, 2, 3, 4, 5, 6, 7, 8])
    @pytest.mark.parametrize("horizon", [1, 4, 8])
    def test_bitwise_parity_width_by_horizon(self, width, horizon):
        """Every width class, H in {1,4,8}: the carried buffer changes
        residency, never bits — each step's output is bit-identical to
        the one-shot kernel on that step's activation."""
        rng = np.random.default_rng(width * 100 + horizon)
        xs, words, uniq = make_case(rng, n=40, m=52, width=width, b=2,
                                    steps=horizon)
        pbuf = jnp.zeros((2, decode_pbuf_rows(40), 1 << width), jnp.float32)
        for x in xs:
            out, pbuf = crew_matmul_decode_pallas(
                x, words, uniq, pbuf, width=width, m_out=52)
            ref = _ref_one_shot(x, words, uniq, width, 52)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    @pytest.mark.parametrize("block_words", [None, 1, 2, 5])
    def test_block_words_sweep(self, block_words):
        """Swept m-tilings (the autotune block sweep's candidates) keep
        the bitwise contract: each m-block still sees the whole padded N
        reduction, so tiling only changes the grid, not the bits."""
        rng = np.random.default_rng(7)
        xs, words, uniq = make_case(rng, n=33, m=70, width=4, b=1, steps=3)
        pbuf = jnp.zeros((1, decode_pbuf_rows(33), 16), jnp.float32)
        for x in xs:
            out, pbuf = crew_matmul_decode_pallas(
                x, words, uniq, pbuf, width=4, m_out=70,
                block_words=block_words)
            ref = _ref_one_shot(x, words, uniq, 4, 70,
                                block_words=block_words)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_fused_epilogue_parity(self):
        """bias + activation ride the same fused epilogue as the one-shot
        kernel — applied per finished m-block, bit-identical."""
        rng = np.random.default_rng(11)
        xs, words, uniq = make_case(rng, n=24, m=36, width=3, b=2, steps=4)
        bias = jnp.asarray(np.linspace(-1, 1, 36).astype(np.float32))
        pbuf = jnp.zeros((2, decode_pbuf_rows(24), 8), jnp.float32)
        for x in xs:
            out, pbuf = crew_matmul_decode_pallas(
                x, words, uniq, pbuf, width=3, m_out=36, bias=bias,
                activation="silu")
            ref = _ref_one_shot(x, words, uniq, 3, 36, bias=bias,
                                activation="silu")
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_carried_state_matches_stateless_ops_path(self):
        """ops-level: ``crew_matmul_decode`` threading state across H
        steps == the stateless ``plan="pallas-decode"`` apply (which
        zero-initializes a fresh buffer every call) — the carry is a
        residency optimization, not a numerical dependency."""
        rng = np.random.default_rng(3)
        w = (rng.standard_t(4, size=(48, 64)) * 0.05).astype(np.float32)
        cm, _, _ = crew_uniform_from_dense(w, dtype=jnp.float32)
        state = init_decode_state(cm, 2)
        for t in range(4):
            x = jnp.asarray(rng.standard_normal((2, 48)).astype(np.float32))
            out, state = crew_matmul_decode(x, cm, state)
            ref = crew_matmul(x, cm, CrewPlan(strategy="pallas-decode"))
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        assert state["pbuf"].shape == (2, decode_pbuf_rows(48), cm.k)

    def test_none_state_falls_back_stateless(self):
        """state=None is the historical path: same numbers as
        ``crew_matmul``, and the returned state stays None (a cold
        autotune store must not invent a carry)."""
        rng = np.random.default_rng(5)
        w = (rng.standard_t(4, size=(32, 40)) * 0.05).astype(np.float32)
        cm, _, _ = crew_uniform_from_dense(w, dtype=jnp.float32)
        x = jnp.asarray(rng.standard_normal((1, 32)).astype(np.float32))
        out, state = crew_matmul_decode(x, cm, None, plan="xla-dense")
        assert state is None
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(crew_matmul(x, cm, "xla-dense")))


class TestDecodeAutotuneKeys:
    def test_decode_key_is_distinct_namespace(self):
        assert make_key(1, 2, 3, 4, 5, "cpu", kind="decode") \
            == "b1-n2-m3-k4-w5-cpu-decode"
        assert make_key(1, 2, 3, 4, 5, "cpu", kind="decode") \
            != make_key(1, 2, 3, 4, 5, "cpu")

    def test_decode_keys_roundtrip_json_across_processes(self, tmp_path):
        """A conversion process warms decode-shaped winners (including a
        swept block shape); the serving process must resolve them from
        REPRO_AUTOTUNE_CACHE — block fields intact."""
        path = str(tmp_path / "autotune.json")
        code = """
from repro.perf import autotune
from repro.perf.autotune import Measurement, make_key
store = autotune.get_store()
store.put(make_key(1, 48, 64, 32, 5, "cpu", kind="decode"),
          Measurement(strategy="pallas-decode", times_s={},
                      block={"block_words": 4}))
store.put(make_key(4, 48, 64, 32, 5, "cpu", kind="decode"),
          Measurement(strategy="xla-cached", times_s={"xla-cached": 0.1}))
print("CHILD-WROTE")
"""
        env = dict(os.environ)
        env["REPRO_AUTOTUNE_CACHE"] = path
        env["PYTHONPATH"] = str(ROOT / "src")
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, timeout=120,
                             env=env)
        assert out.returncode == 0, out.stderr[-2000:]

        os.environ["REPRO_AUTOTUNE_CACHE"] = path
        try:
            autotune.set_store(None)
            plan = autotune.lookup_plan(
                make_key(1, 48, 64, 32, 5, "cpu", kind="decode"))
            assert plan.strategy == "pallas-decode"
            assert plan.block_words == 4
            assert autotune.lookup(
                make_key(4, 48, 64, 32, 5, "cpu", kind="decode")) \
                == "xla-cached"
            # the one-shot key space stays cold: decode never shadows it
            assert autotune.lookup(make_key(1, 48, 64, 32, 5, "cpu")) is None
        finally:
            del os.environ["REPRO_AUTOTUNE_CACHE"]
            autotune.set_store(None)

    def test_measure_decode_records_and_winner_is_correct(self):
        rng = np.random.default_rng(9)
        w = (rng.standard_t(4, size=(40, 56)) * 0.05).astype(np.float32)
        cm, _, qm = crew_uniform_from_dense(w, dtype=jnp.float32)
        x = jnp.asarray(rng.standard_normal((1, 40)).astype(np.float32))
        rec = autotune.measure_crew_matmul_decode(
            x, cm, candidates=("xla-cached", "pallas-decode"), repeats=1)
        key = make_key(1, cm.n_in, cm.n_out, cm.k, cm.width,
                       jax.default_backend(), kind="decode")
        assert autotune.get_store().get(key) is rec
        ref = np.asarray(x @ jnp.asarray(qm.q * float(qm.scale), jnp.float32))
        out = np.asarray(crew_matmul(x, cm, CrewPlan(strategy=rec.strategy)))
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


class TestDeprecationShims:
    """Each deprecated spelling works, warns once per process, and never
    warns again (the warn-once registry is keyed per surface)."""

    @pytest.fixture(autouse=True)
    def fresh_registry(self):
        reset_deprecation_warnings()
        yield
        reset_deprecation_warnings()

    def _case(self):
        rng = np.random.default_rng(0)
        w = (rng.standard_t(4, size=(16, 24)) * 0.05).astype(np.float32)
        cm, _, _ = crew_uniform_from_dense(w, dtype=jnp.float32)
        x = jnp.asarray(rng.standard_normal((2, 16)).astype(np.float32))
        return x, cm

    def _assert_warns_once(self, fn):
        with pytest.warns(DeprecationWarning):
            first = fn()
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            second = fn()      # second use: shim already burned, silent
        return first, second

    def test_crew_matmul_strategy_kwarg(self):
        x, cm = self._case()
        old, new = self._assert_warns_once(
            lambda: crew_matmul(x, cm, strategy="xla-dense"))
        ref = crew_matmul(x, cm, "xla-dense")
        np.testing.assert_array_equal(np.asarray(old), np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(new), np.asarray(ref))

    def test_crew_matmul_activation_kwarg(self):
        x, cm = self._case()
        old, _ = self._assert_warns_once(
            lambda: crew_matmul(x, cm, "xla-dense", activation="gelu"))
        ref = crew_matmul(
            x, cm, CrewPlan(strategy="xla-dense", activation="gelu"))
        np.testing.assert_array_equal(np.asarray(old), np.asarray(ref))

    def test_linear_apply_crew_strategy_kwarg(self):
        from repro.layers import linear
        x, cm = self._case()
        params = {"w": cm, "b": jnp.zeros((cm.n_out,), jnp.float32)}
        old, _ = self._assert_warns_once(
            lambda: linear.apply(params, x, crew_strategy="xla-dense"))
        ref = linear.apply(params, x, plan="xla-dense")
        np.testing.assert_array_equal(np.asarray(old), np.asarray(ref))

    def test_scheduler_metrics_dict_reads(self):
        from repro.serve import SchedulerMetrics
        m = SchedulerMetrics()
        m.decode_steps = 3
        val, again = self._assert_warns_once(lambda: m["decode_steps"])
        assert val == again == 3
        self._assert_warns_once(lambda: m.__setitem__("decode_steps", 5))
        assert m.decode_steps == 5
        with pytest.raises(KeyError):
            m["not_a_counter"]


@pytest.fixture(scope="module")
def served():
    """Reduced model + CREW twin with every decode-shaped key forced to
    ``pallas-decode`` — the carried-state path engages deterministically
    regardless of this host's measured timings."""
    from repro.configs import ARCHS
    from repro.models import build_model
    from repro.serve import crewize_params

    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    crew, _ = crewize_params(params)

    store = AutotuneStore()
    leaves = [l for l in jax.tree_util.tree_leaves(
        crew, is_leaf=lambda v: isinstance(v, CrewMatrixUniform))
        if isinstance(l, CrewMatrixUniform)]
    assert leaves, "crewize_params produced no CREW leaves"
    for cm in leaves:
        # key on the trailing (matrix) axes: stacked leaves carry a
        # leading layer dim, and the decode key describes one layer's
        # apply shape (the same shape the per-layer scan step applies)
        n, k = int(cm.words.shape[-2]), int(cm.uniq.shape[-1])
        for b in (1, 2):
            store.put(make_key(b, n, cm.n_out, k, cm.width,
                               jax.default_backend(), kind="decode"),
                      Measurement(strategy="pallas-decode", times_s={}))
    return cfg, api, params, crew, store


class TestServingParity:
    """Forced carried-state decode vs the stateless path: token parity
    end to end (the ISSUE's acceptance bar) with the state demonstrably
    engaged, for the one-shot engine and the horizon scheduler."""

    def test_generate_auto_equals_off(self, served):
        from repro.serve import decode_state_for_params, generate
        cfg, api, params, crew, store = served
        rng = np.random.default_rng(0)
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab, (2, 6)).astype(np.int32))
        autotune.set_store(store)
        assert decode_state_for_params(crew, 2) is not None
        warm = generate(api, crew, prompts, max_new=8)
        autotune.set_store(AutotuneStore())   # cold: state resolves None
        cold = generate(api, crew, prompts, max_new=8)
        autotune.set_store(store)
        off = generate(api, crew, prompts, max_new=8, decode_state="off")
        np.testing.assert_array_equal(np.asarray(warm["tokens"]),
                                      np.asarray(cold["tokens"]))
        np.testing.assert_array_equal(np.asarray(warm["tokens"]),
                                      np.asarray(off["tokens"]))
        np.testing.assert_allclose(np.asarray(warm["logprobs"]),
                                   np.asarray(cold["logprobs"]),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("horizon", [1, 4])
    def test_scheduler_carried_state_token_parity(self, served, horizon):
        from repro.serve import Scheduler, generate
        cfg, api, params, crew, store = served
        autotune.set_store(store)
        rng = np.random.default_rng(1)
        prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
                   for n in (5, 9)]
        sched = Scheduler(api, crew, max_batch=2, cache_len=32,
                          buckets=(16,), horizon=horizon)
        rids = [sched.submit(p, max_new=6) for p in prompts]
        res = sched.run()
        assert sched._crew_state and \
            any(s is not None for s in sched._crew_state.values())
        for rid, p in zip(rids, prompts):
            ref = generate(api, crew, jnp.asarray(p)[None], max_new=6,
                           decode_state="off")
            np.testing.assert_array_equal(
                res[rid].tokens, np.asarray(ref["tokens"][0]))

    def test_scheduler_decode_state_off(self, served):
        from repro.serve import Scheduler, generate
        cfg, api, params, crew, store = served
        autotune.set_store(store)
        rng = np.random.default_rng(2)
        p = rng.integers(0, cfg.vocab, 7).astype(np.int32)
        sched = Scheduler(api, crew, max_batch=1, cache_len=32,
                          buckets=(16,), horizon=4, decode_state="off")
        rid = sched.submit(p, max_new=6)
        res = sched.run()
        assert all(s is None for s in sched._crew_state.values())
        ref = generate(api, crew, jnp.asarray(p)[None], max_new=6,
                       decode_state="off")
        np.testing.assert_array_equal(res[rid].tokens,
                                      np.asarray(ref["tokens"][0]))
