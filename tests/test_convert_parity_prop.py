"""Hypothesis sweep for converter parity (vectorized == seed bit-exact).

Skipped wholesale when hypothesis is absent (tests/conftest.py) — the fixed
adversarial/seeded coverage lives in test_convert_parity.py.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import analyze_matrix, pack_bits_straddled, unpack_bits_straddled

from test_convert_parity import (assert_analysis_matches,
                                 seed_pack_bits_straddled)


@given(st.integers(0, 2 ** 32 - 1), st.integers(1, 24), st.integers(1, 48),
       st.sampled_from([2, 17, 255, 5000, 2 ** 20]))
@settings(max_examples=40, deadline=None)
def test_property_analysis_parity(seed, n, m, span):
    rng = np.random.default_rng(seed)
    q = rng.integers(-span, span + 1, size=(n, m)).astype(np.int32)
    assert_analysis_matches(q)


@given(st.integers(0, 2 ** 32 - 1), st.integers(1, 14), st.integers(1, 60))
@settings(max_examples=40, deadline=None)
def test_property_straddled_parity(seed, n, m):
    rng = np.random.default_rng(seed)
    widths = rng.integers(1, 9, size=n)
    idx = np.stack([rng.integers(0, 1 << w, size=m) for w in widths]) \
        .astype(np.int32)
    stream = pack_bits_straddled(idx, widths)
    assert (stream == seed_pack_bits_straddled(idx, widths)).all()
    assert (unpack_bits_straddled(stream, widths, m) == idx).all()


@given(st.integers(0, 2 ** 32 - 1), st.integers(1, 20), st.integers(1, 40))
@settings(max_examples=25, deadline=None)
def test_property_reconstruct_roundtrip(seed, n, m):
    rng = np.random.default_rng(seed)
    q = rng.integers(-127, 128, size=(n, m)).astype(np.int32)
    layout = analyze_matrix(q)
    from repro.core import reconstruct
    assert (reconstruct(layout) == q).all()
    assert (layout.widths >= 1).all() and (layout.widths <= 8).all()
