"""HLO accounting: exactness on scan-free modules, trip-count handling,
collective detection, perfmodel sanity."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import RooflineTerms, model_flops
from repro.roofline.hlo import account, cost_analysis_dict


def compile_fn(f, *shapes):
    return jax.jit(f).lower(*shapes).compile()


class TestHloAccounting:
    def test_scanfree_matches_cost_analysis(self):
        c = compile_fn(lambda a, b: a @ b,
                       jax.ShapeDtypeStruct((128, 64), jnp.float32),
                       jax.ShapeDtypeStruct((64, 32), jnp.float32))
        acc = account(c.as_text())
        assert acc.flops == 2 * 128 * 64 * 32
        assert acc.bytes_hbm == pytest.approx(
            float(cost_analysis_dict(c)["bytes accessed"]), rel=0.01)

    def test_scan_trip_multiplier(self):
        def f(x, ws):
            return jax.lax.scan(lambda x, w: (jnp.tanh(x @ w), None), x, ws)[0]
        c = compile_fn(f, jax.ShapeDtypeStruct((32, 32), jnp.float32),
                       jax.ShapeDtypeStruct((12, 32, 32), jnp.float32))
        acc = account(c.as_text())
        assert acc.flops == 12 * 2 * 32 ** 3
        assert 12 in acc.trip_counts.values()

    def test_nested_scan(self):
        def f(x, ws):
            def outer(x, wg):
                return jax.lax.scan(
                    lambda x, w: (x @ w, None), x, wg)[0], None
            return jax.lax.scan(outer, x, ws)[0]
        c = compile_fn(f, jax.ShapeDtypeStruct((16, 16), jnp.float32),
                       jax.ShapeDtypeStruct((3, 5, 16, 16), jnp.float32))
        acc = account(c.as_text())
        assert acc.flops == 15 * 2 * 16 ** 3

    def test_backward_counted(self):
        """Backward-pass matmuls are accounted (fwd + dx + dw = 3 dots;
        remat recompute may be CSE'd by XLA at this size, so allow 3-4)."""
        def loss(w, x):
            f = jax.checkpoint(lambda x: jnp.tanh(x @ w))
            return jnp.sum(f(x) ** 2)
        g = jax.grad(loss, argnums=(0, 1))
        c = compile_fn(g, jax.ShapeDtypeStruct((32, 32), jnp.float32),
                       jax.ShapeDtypeStruct((8, 32), jnp.float32))
        acc = account(c.as_text())
        dot = 2 * 8 * 32 * 32
        assert 3 * dot <= acc.flops <= 4 * dot

    def test_dtype_bytes(self):
        c = compile_fn(lambda x: (x.astype(jnp.bfloat16) * 2).astype(jnp.int8),
                       jax.ShapeDtypeStruct((1024,), jnp.float32))
        acc = account(c.as_text())
        assert acc.bytes_hbm >= 1024 * 4 + 1024  # f32 in + int8 out


class TestTerms:
    def test_bound_selection(self):
        t = RooflineTerms(flops=197e12, bytes_hbm=1.0, bytes_collective=0.0)
        assert t.bound == "compute" and t.t_compute == pytest.approx(1.0)
        t = RooflineTerms(flops=0.0, bytes_hbm=819e9, bytes_collective=0.0)
        assert t.bound == "memory" and t.t_memory == pytest.approx(1.0)
        t = RooflineTerms(flops=0.0, bytes_hbm=0.0, bytes_collective=50e9)
        assert t.bound == "collective" and t.t_collective == pytest.approx(1.0)

    def test_model_flops(self):
        from repro.configs import ARCHS, SHAPES_BY_NAME
        cfg = ARCHS["qwen2-0.5b"]
        t = SHAPES_BY_NAME["train_4k"]
        mf = model_flops(cfg, t, backward=True)
        assert mf == pytest.approx(6 * cfg.param_count() * 256 * 4096)
        d = SHAPES_BY_NAME["decode_32k"]
        assert model_flops(cfg, d, backward=False) == pytest.approx(
            2 * cfg.param_count() * 128)


class TestPerfmodel:
    def test_paper_range(self):
        """CREW within the paper's reported band, UCNN clearly below, and
        CREW ~2x UCNN (paper: 2.61x, 1.25x, ratio 2.10x)."""
        from repro.models.paper import PAPER_MODELS, fc_matrices
        from repro.perfmodel import compare_schemes
        r = compare_schemes("Kaldi", fc_matrices(PAPER_MODELS["Kaldi"]))
        assert 2.0 <= r["crew"]["speedup"] <= 4.0
        assert 1.1 <= r["ucnn"]["speedup"] <= 2.0
        assert r["crew"]["speedup"] > 1.7 * r["ucnn"]["speedup"]
        assert r["crew"]["energy_savings"] > 1.7
        assert r["crew"]["mults_frac"] < 0.05  # >95% of multiplies removed
        assert r["crew"]["model_mb"] < r["baseline"]["model_mb"]

    def test_overlap_baseline_shrinks_gap(self):
        from repro.models.paper import PAPER_MODELS, fc_matrices
        from repro.perfmodel import compare_schemes
        mats = fc_matrices(PAPER_MODELS["Kaldi"])
        serial = compare_schemes("Kaldi", mats, overlap_baseline=False)
        fair = compare_schemes("Kaldi", mats, overlap_baseline=True)
        assert fair["crew"]["speedup"] < serial["crew"]["speedup"]
        assert fair["crew"]["speedup"] > 1.0  # still a real win


def test_dryrun_records_exist_and_pass():
    """The committed dry-run records (deliverable e) are complete: every
    runnable cell compiled on both production meshes."""
    import glob, json, os
    base = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    if not os.path.isdir(base):
        pytest.skip("dry-run records not generated yet")
    recs = [json.load(open(f)) for f in glob.glob(base + "/*/*.json")]
    assert len(recs) >= 104
    assert all(r["status"] == "ok" for r in recs)
    meshes = {r["mesh"] for r in recs}
    assert meshes == {"single", "multi"}
    assert {r["chips"] for r in recs} == {256, 512}
