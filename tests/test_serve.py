"""Serving: CREW conversion fidelity, engine parity, abstract-param shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.serve import abstract_crew_params, crewize_params, generate


@pytest.fixture(scope="module")
def qwen():
    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


class TestConvert:
    def test_reconstruction_fidelity(self, qwen):
        """CREW-converted weights reconstruct to the quantized dense values
        (lossless vs the 8-bit grid; error bounded by quantization step)."""
        _, _, params = qwen
        from repro.core.convert import CrewMatrixUniform, crew_reconstruct_uniform
        crew, _ = crewize_params(params, min_cols=1, dtype=jnp.float32)

        def check2d(w2d, cm2d):
            rec = np.asarray(crew_reconstruct_uniform(cm2d))[:, :w2d.shape[1]]
            step = np.abs(w2d).max() / 127  # per-matrix quantization scale
            assert np.abs(rec - w2d).max() <= step / 2 + 1e-6

        def walk(dense, conv):
            if isinstance(conv, CrewMatrixUniform):
                w = np.asarray(dense)
                flat_w = w.reshape(-1, *w.shape[-2:])
                flat_words = conv.words.reshape(-1, *conv.words.shape[-2:])
                flat_uniq = conv.uniq.reshape(-1, *conv.uniq.shape[-2:])
                for i in range(flat_w.shape[0]):  # scan-stacked layers
                    check2d(flat_w[i], CrewMatrixUniform(
                        words=flat_words[i], uniq=flat_uniq[i],
                        width=conv.width, n_out=conv.n_out))
                return
            if isinstance(conv, dict):
                for k in conv:
                    walk(dense[k], conv[k])

        walk(params, crew)

    def test_stacked_leaves_keep_stack_axes(self, qwen):
        _, _, params = qwen
        crew, report = crewize_params(params)
        from repro.core.convert import CrewMatrixUniform
        found_stacked = False
        for leaf in jax.tree.leaves(
                crew, is_leaf=lambda x: isinstance(x, CrewMatrixUniform)):
            if isinstance(leaf, CrewMatrixUniform) and leaf.words.ndim == 3:
                found_stacked = True
                assert leaf.uniq.shape[:2] == leaf.words.shape[:2]
        assert found_stacked  # scan-stacked layers were converted in place
        assert report.n_converted > 0

    def test_abstract_matches_real_shapes(self, qwen):
        """abstract_crew_params (dry-run path) predicts the exact shapes
        crewize_params produces at the same width."""
        _, api, params = qwen
        crew, _ = crewize_params(params, max_unique=64)  # forces width<=6
        abs_params = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        abs_crew = abstract_crew_params(abs_params, width=6)

        from repro.core.convert import CrewMatrixUniform

        def pairs(a, b):
            if isinstance(a, CrewMatrixUniform):
                assert isinstance(b, CrewMatrixUniform)
                if a.width == b.width:  # real width can be < forced cap
                    assert a.words.shape == b.words.shape
                assert a.uniq.shape[:-1] == b.uniq.shape[:-1]
                return
            if isinstance(a, dict):
                for k in a:
                    pairs(a[k], b[k])

        pairs(crew, abs_crew)

    def test_report_stats_sane(self, qwen):
        _, _, params = qwen
        _, report = crewize_params(params)
        agg = report.aggregate()
        assert 0 < agg.muls_fraction < 1
        assert agg.uw_per_input_max <= 256


class TestEngine:
    def test_dense_crew_token_parity(self, qwen):
        cfg, api, params = qwen
        crew, _ = crewize_params(params)
        prompts = jnp.arange(24, dtype=jnp.int32).reshape(2, 12) % cfg.vocab
        a = generate(api, params, prompts, max_new=8)
        b = generate(api, crew, prompts, max_new=8)
        # greedy decoding on 8-bit-quantized weights: expect near-total match
        match = float((a["tokens"] == b["tokens"]).mean())
        assert match >= 0.75

    def test_prefill_decode_consistency(self, qwen):
        """generate() greedy continuation equals argmax of teacher-forced
        forward logits for the first generated token."""
        cfg, api, params = qwen
        prompts = (jnp.arange(10, dtype=jnp.int32)[None] * 7) % cfg.vocab
        out = generate(api, params, prompts, max_new=4)
        logits, _ = api.forward(params, {"tokens": prompts},
                                q_chunk=8, kv_chunk=8)
        first = int(jnp.argmax(logits[0, -1]))
        assert int(out["tokens"][0, 0]) == first

    def test_sampling_temperature(self, qwen):
        cfg, api, params = qwen
        prompts = jnp.zeros((1, 6), jnp.int32)
        a = generate(api, params, prompts, max_new=16, temperature=1.0,
                     rng=jax.random.PRNGKey(0))
        b = generate(api, params, prompts, max_new=16, temperature=1.0,
                     rng=jax.random.PRNGKey(1))
        assert not bool(jnp.all(a["tokens"] == b["tokens"]))

    def test_chunked_prefill_split_matches_monolithic(self, qwen):
        """generate(chunk=...) — the prefill-from-cache program split —
        is bitwise-identical to the monolithic prefill, across prompt
        lengths that tile the chunk evenly and with a padded tail."""
        cfg, api, params = qwen
        for s, max_new in ((12, 6), (8, 6), (21, 6), (21, 2)):
            # (21, 2): the padded tail chunk's window [16, 24) crosses
            # cache_len=23 — the dead rows must drop, not clamp-shift
            # the window back over valid cache rows
            prompts = (jnp.arange(2 * s, dtype=jnp.int32).reshape(2, s) * 3
                       ) % cfg.vocab
            mono = generate(api, params, prompts, max_new=max_new,
                            cache_len=s + max_new)
            split = generate(api, params, prompts, max_new=max_new,
                             cache_len=s + max_new, chunk=8)
            np.testing.assert_array_equal(np.asarray(mono["tokens"]),
                                          np.asarray(split["tokens"]))
            np.testing.assert_array_equal(np.asarray(mono["logprobs"]),
                                          np.asarray(split["logprobs"]))
        with pytest.raises(ValueError, match="chunk"):
            generate(api, params, jnp.zeros((1, 4), jnp.int32), chunk=0)
