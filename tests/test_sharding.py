"""Sharding resolution rules (AbstractMesh — no device-count coupling)."""
import jax
from jax.sharding import PartitionSpec as P

from repro.dist.compat import abstract_mesh
from repro.dist.sharding import SERVE_RULES, TRAIN_RULES, resolve, resolve_tree

MESH = abstract_mesh((16, 16), ("data", "model"))
MESH3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


class TestResolve:
    def test_fsdp_tp_weight(self):
        assert resolve(P("embed", "mlp"), (4096, 14336), MESH, TRAIN_RULES) \
            == P("data", "model")

    def test_batch_multi_pod(self):
        assert resolve(P("batch", "seq"), (256, 4096), MESH3, TRAIN_RULES) \
            == P(("pod", "data"))

    def test_mqa_kv_replicates(self):
        # kv=1 head cannot split 16 ways
        got = resolve(P(None, "batch", "kv_seq", "kv_heads", None),
                      (4, 128, 32768, 1, 128), MESH, SERVE_RULES)
        assert got == P(None, "data", "model")  # seq takes model instead

    def test_gqa_kv_heads_win_over_seq(self):
        got = resolve(P(None, "batch", "kv_seq", "kv_heads", None),
                      (4, 128, 32768, 16, 128), MESH, SERVE_RULES)
        assert got == P(None, "data", None, "model")

    def test_batch_one_falls_back_to_sp(self):
        got = resolve(P(None, "batch", "kv_seq", "kv_heads", None),
                      (9, 1, 524288, 32, 112), MESH, SERVE_RULES)
        # batch=1 unshardable; kv_heads=32 takes model; seq takes data
        assert got == P(None, None, "data", "model")

    def test_expert_conflict_drops_mlp(self):
        got = resolve(P(None, "expert", "embed", "mlp"),
                      (16, 64, 2048, 1024), MESH, TRAIN_RULES)
        assert got == P(None, "model", "data")

    def test_indivisible_replicates(self):
        assert resolve(P("embed", "heads"), (63, 128), MESH, TRAIN_RULES) \
            == P(None, "model")

    def test_partial_tuple_claim(self):
        # batch=32 divides 32 (pod*data) in the 3d mesh
        assert resolve(P("batch",), (32,), MESH3, TRAIN_RULES) \
            == P(("pod", "data"))
        # batch=2 only divides pod
        assert resolve(P("batch",), (2,), MESH3, TRAIN_RULES) == P("pod")


def test_resolve_tree_mixed():
    tree = {"w": P("embed", "mlp"), "b": P("mlp")}
    shapes = {"w": jax.ShapeDtypeStruct((64, 256), "float32"),
              "b": jax.ShapeDtypeStruct((256,), "float32")}
    out = resolve_tree(tree, shapes, MESH, TRAIN_RULES)
    assert out["w"] == P("data", "model")
    assert out["b"] == P("model")


def test_crewize_spec_mirrors_dense():
    import jax.numpy as jnp
    from repro.serve.convert import abstract_crew_params, crewize_spec
    spec = {"q": {"w": P(None, "embed", "heads")}}
    params = {"q": {"w": jax.ShapeDtypeStruct((4, 896, 1792), jnp.bfloat16)}}
    crew = abstract_crew_params(params, width=6)
    cspec = crewize_spec(spec, crew)
    cw = cspec["q"]["w"]
    assert tuple(cw.words) == (None, "embed", "heads")
    assert tuple(cw.uniq) == (None, "embed", None)
    # words dim padded to a TP-divisible multiple
    assert crew["q"]["w"].words.shape[-1] % 16 == 0
