"""The paper's own workloads run end-to-end with CREW weights."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import paper_runtime as rt
from repro.models.paper import PAPER_MODELS
from repro.serve import crewize_params


class TestPaperDims:
    def test_table_iv_sizes(self):
        """FC parameter volumes land on the paper's Table IV model sizes."""
        expect_mb = {"DS2": 144, "GNMT": 518, "Transformer": 336,
                     "Kaldi": 18, "PTBLM": 137}
        for name, m in PAPER_MODELS.items():
            got = m.size_mb_fp32()
            want = expect_mb[name]
            assert abs(got - want) / want < 0.35, (name, got, want)


class TestPTBLM:
    def test_forward_and_crew_parity(self):
        params = rt.ptblm_init(jax.random.PRNGKey(0), vocab=500, width=0.04)
        toks = jnp.arange(24, dtype=jnp.int32).reshape(2, 12) % 500
        logits = rt.ptblm_apply(params, toks)
        assert logits.shape == (2, 12, 500)
        assert not bool(jnp.isnan(logits).any())
        crew, rep = crewize_params(params, min_cols=32)
        assert rep.n_converted > 0
        out = rt.ptblm_apply(crew, toks)
        # same argmax for most positions (8-bit quantization level diffs)
        agree = float((jnp.argmax(out, -1) == jnp.argmax(logits, -1)).mean())
        assert agree > 0.8


class TestDS2:
    def test_forward_and_crew_parity(self):
        params = rt.ds2_init(jax.random.PRNGKey(0), n_features=20,
                             width=0.04, n_layers=2)
        feats = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 20))
        logits = rt.ds2_apply(params, feats)
        assert logits.shape == (2, 16, 29)
        assert not bool(jnp.isnan(logits).any())
        crew, rep = crewize_params(params, min_cols=16)
        assert rep.n_converted > 0
        out = rt.ds2_apply(crew, feats)
        rel = float(jnp.linalg.norm(out - logits) / jnp.linalg.norm(logits))
        assert rel < 0.2

    def test_bidirectionality(self):
        """Flipping time flips the output (up to the head): not causal."""
        params = rt.ds2_init(jax.random.PRNGKey(0), n_features=8,
                             width=0.02, n_layers=1)
        feats = jax.random.normal(jax.random.PRNGKey(1), (1, 10, 8))
        a = rt.ds2_apply(params, feats)
        b = rt.ds2_apply(params, feats[:, ::-1])
        assert not np.allclose(np.asarray(a), np.asarray(b[:, ::-1]))


class TestKaldi:
    def test_forward_and_crew(self):
        params = rt.kaldi_init(jax.random.PRNGKey(0), width=0.1)
        feats = jax.random.normal(jax.random.PRNGKey(1), (4, 44))
        logits = rt.kaldi_apply(params, feats)
        assert logits.shape[0] == 4 and not bool(jnp.isnan(logits).any())
        crew, rep = crewize_params(params, min_cols=32)
        assert rep.n_converted > 0
        out = rt.kaldi_apply(crew, feats)
        rel = float(jnp.linalg.norm(out - logits) / jnp.linalg.norm(logits))
        assert rel < 0.2

    def test_paper_dims_default(self):
        params = rt.kaldi_init(jax.random.PRNGKey(0))
        n = sum(x.size for x in jax.tree.leaves(params))
        assert 4.0e6 < n < 5.2e6  # ~18 MB fp32 (Table IV)
