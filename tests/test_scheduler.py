"""Continuous-batching scheduler (DESIGN.md §5): token parity vs the
one-shot engine, fixed program set, admission/backfill/drain edge cases,
and continuous-vs-static throughput on the mixed traffic workload."""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.serve import Scheduler, generate

ROOT = pathlib.Path(__file__).resolve().parents[1]

# Under REPRO_FAULTS the whole suite runs with the benign chaos injector
# (serve.faults): forced preemptions / pool drops are output-preserving,
# so parity assertions stay unconditional — but exact work accounting
# (prefill counts, chunk counts, compiled-program tallies) legitimately
# shifts when requests bounce through preempt/resume.
FAULT_MODE = os.environ.get("REPRO_FAULTS", "").strip() not in ("", "0")


@pytest.fixture(scope="module")
def qwen():
    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _ref_tokens(api, params, prompt, max_new):
    out = generate(api, params, jnp.asarray(prompt)[None], max_new=max_new)
    return np.asarray(out["tokens"][0])


class TestParity:
    def test_mixed_lengths_greedy_parity_fixed_programs(self, qwen):
        """Five requests with five different (prompt_len, max_new) pairs
        through two slots: the queue outruns the slots, admission
        staggers, slots backfill — and every request's greedy tokens
        equal its per-request ``serve.generate`` run, while only the
        fixed bucket set compiles (no per-request retrace)."""
        cfg, api, params = qwen
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
                   for n in (5, 12, 20, 7, 16)]
        max_news = [4, 8, 6, 10, 3]

        sched = Scheduler(api, params, max_batch=2, cache_len=64,
                          buckets=(8, 16, 24))
        rids = [sched.submit(p, max_new=m)
                for p, m in zip(prompts, max_news)]
        res = sched.run()

        assert sorted(res) == sorted(rids)
        for rid, p, m in zip(rids, prompts, max_news):
            got = res[rid].tokens
            assert got.shape == (m,)
            np.testing.assert_array_equal(got, _ref_tokens(api, params, p, m))
            assert res[rid].logprobs.shape == (m,)
            assert np.all(res[rid].logprobs <= 0)

        # queue outran the slots: every request prefillled exactly once,
        # and the program set is bucket-sized, not request-sized.
        # (Fault mode bounces requests through preempt/resume, which
        # re-prefills and may touch extra chunk/window buckets — counts
        # stay bucket-bounded but lose their exact values.)
        if not FAULT_MODE:
            assert sched.metrics.prefills == len(prompts)
        counts = sched.program_counts()
        if not FAULT_MODE:
            assert counts["prefill"] == 3   # buckets 8, 16, 24 all used
        assert counts["decode"] <= 2    # batch buckets {1, 2}

        # replaying more traffic compiles nothing outside the bucket set:
        # a solo request may touch the not-yet-used batch bucket 1 (under
        # horizon stepping the mixed drain can finish without ever
        # decoding a lone lane), and a second replay compiles nothing.
        sched.submit(prompts[0], max_new=3)
        sched.run()
        counts = sched.program_counts()
        if not FAULT_MODE:
            assert counts["prefill"] == 3
        assert counts["decode"] <= 2    # batch buckets {1, 2}
        sched.submit(prompts[1], max_new=3)
        sched.run()
        if not FAULT_MODE:
            assert sched.program_counts() == counts


class TestEdgeCases:
    def test_backfill_after_early_eos(self, qwen):
        """A request that hits EOS mid-stream frees its slot; the queued
        request behind it is admitted and completes with full parity."""
        cfg, api, params = qwen
        rng = np.random.default_rng(1)
        a = rng.integers(0, cfg.vocab, 6).astype(np.int32)
        b = rng.integers(0, cfg.vocab, 9).astype(np.int32)
        ref_a = _ref_tokens(api, params, a, 8)
        eos = int(ref_a[2])  # greedy token #3 becomes the stop token

        sched = Scheduler(api, params, max_batch=1, cache_len=32,
                          buckets=(16,))
        rid_a = sched.submit(a, max_new=8, eos_id=eos)
        rid_b = sched.submit(b, max_new=5)
        res = sched.run()

        np.testing.assert_array_equal(res[rid_a].tokens, ref_a[:3])
        assert res[rid_a].tokens[-1] == eos
        np.testing.assert_array_equal(res[rid_b].tokens,
                                      _ref_tokens(api, params, b, 5))

    def test_eos_on_first_token_retires_at_admission(self, qwen):
        """EOS sampled from the prefill logits retires the request before
        it ever reaches a decode step."""
        cfg, api, params = qwen
        rng = np.random.default_rng(2)
        p = rng.integers(0, cfg.vocab, 5).astype(np.int32)
        eos = int(_ref_tokens(api, params, p, 1)[0])

        sched = Scheduler(api, params, max_batch=1, cache_len=32,
                          buckets=(8,))
        rid = sched.submit(p, max_new=8, eos_id=eos)
        res = sched.run()
        np.testing.assert_array_equal(res[rid].tokens, [eos])
        assert sched.metrics.decode_steps == 0

    def test_empty_queue_drain(self, qwen):
        _, api, params = qwen
        sched = Scheduler(api, params, max_batch=2, cache_len=32,
                          buckets=(8,))
        assert sched.run() == {}
        assert sched.step() is False
        assert sched.pending == 0

    def test_submit_validation(self, qwen):
        _, api, params = qwen
        sched = Scheduler(api, params, max_batch=2, cache_len=32,
                          buckets=(8, 16))
        with pytest.raises(ValueError, match="cache_len"):
            sched.submit(np.zeros(8, np.int32), max_new=32)
        with pytest.raises(ValueError, match="empty"):
            sched.submit(np.zeros(0, np.int32))
        with pytest.raises(ValueError, match="max_new"):
            sched.submit(np.zeros(4, np.int32), max_new=0)
        # prompts longer than the largest chunk bucket are admissible now:
        # chunked prefill advances bucket-by-bucket (DESIGN.md §5)
        assert sched.submit(np.ones(17, np.int32), max_new=4) >= 0

    def test_long_prompt_chunked_prefill_parity(self, qwen):
        """A prompt longer than every chunk bucket — rejected outright by
        the monolithic-prefill scheduler — prefills in bucket-sized
        chunks and still matches ``serve.generate`` token for token."""
        cfg, api, params = qwen
        rng = np.random.default_rng(7)
        p = rng.integers(0, cfg.vocab, 37).astype(np.int32)
        sched = Scheduler(api, params, max_batch=2, cache_len=64,
                          buckets=(8, 16))
        rid = sched.submit(p, max_new=5)
        res = sched.run()
        np.testing.assert_array_equal(res[rid].tokens,
                                      _ref_tokens(api, params, p, 5))
        # 37 = 16 + 16 + 5: two full chunks + one tail bucket
        if not FAULT_MODE:   # a forced preempt/resume re-chunks the tail
            assert sched.metrics.chunks == 3

    def test_sampled_streams_differ_per_request(self, qwen):
        """temperature > 0: two identical prompts in flight draw from
        independent per-request key streams."""
        cfg, api, params = qwen
        rng = np.random.default_rng(3)
        p = rng.integers(0, cfg.vocab, 6).astype(np.int32)
        sched = Scheduler(api, params, max_batch=2, cache_len=64,
                          buckets=(8,), temperature=1.0,
                          rng=jax.random.PRNGKey(7))
        ra = sched.submit(p, max_new=12)
        rb = sched.submit(p, max_new=12)
        res = sched.run()
        assert not np.array_equal(res[ra].tokens, res[rb].tokens)


class TestThroughput:
    def test_continuous_beats_static_on_mixed_workload(self):
        """The traffic benchmark's mixed workload: continuous batching
        sustains at least the static-batching tokens/sec (it runs ~half
        the decode steps; the measured margin is ~1.4-2.6x)."""
        sys.path.insert(0, str(ROOT))
        try:
            from benchmarks import traffic
        finally:
            sys.path.pop(0)
        traffic.prepare(fast=True)
        # wall-clock comparisons can flake on loaded CI runners; the step
        # counts are deterministic, so assert those on every attempt and
        # give the timing a couple of tries (measured margin ~1.4-2.6x).
        for attempt in range(3):
            rows = {(r["mode"], r["weights"]): r
                    for r in traffic.serve_throughput(fast=True)}
            for weights in ("dense", "crew"):
                cont = rows[("continuous", weights)]
                stat = rows[("static", weights)]
                assert cont["tokens"] == stat["tokens"]  # same useful work
                assert cont["decode_steps"] < stat["decode_steps"]
            if all(rows[("continuous", w)]["tokens_per_s"]
                   >= rows[("static", w)]["tokens_per_s"]
                   for w in ("dense", "crew")):
                break
        else:
            raise AssertionError(
                f"continuous slower than static on 3 attempts: {rows}")


def test_scheduler_under_serve_mesh_matches_single_device():
    """dist integration: the same requests through a Scheduler tracing
    under ``sharding_ctx(mesh, SERVE_RULES)`` yield the single-device
    greedy tokens (child process forces an 8-device host platform)."""
    code = """
import jax, numpy as np
from repro.configs import ARCHS
from repro.models import build_model
from repro.serve import Scheduler
from repro.launch.mesh import make_mesh

cfg = ARCHS["qwen2-0.5b"].reduced()
api = build_model(cfg)
params = api.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in (5, 12)]

def serve(mesh):
    s = Scheduler(api, params, max_batch=2, cache_len=32, buckets=(16,),
                  mesh=mesh)
    rids = [s.submit(p, max_new=4) for p in prompts]
    res = s.run()
    return [res[r].tokens for r in rids]

single = serve(None)
mesh = make_mesh((2, 4), ("data", "model"))
sharded = serve(mesh)
for a, b in zip(single, sharded):
    np.testing.assert_array_equal(a, b)
print("MESH-PARITY-OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=480, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESH-PARITY-OK" in out.stdout
