"""Measured strategy dispatch: store round-trip, measurement determinism,
cross-process REPRO_AUTOTUNE_CACHE persistence, and the crew_matmul auto
wiring."""
import pathlib

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import crew_uniform_from_dense
from repro.kernels.ops import crew_matmul, pick_strategy, resolve_auto_strategy
from repro.perf import autotune
from repro.perf.autotune import AutotuneStore, Measurement, make_key

ROOT = pathlib.Path(__file__).resolve().parents[1]
_ENV = "REPRO_AUTOTUNE_CACHE"


@pytest.fixture()
def case():
    rng = np.random.default_rng(0)
    w = (rng.standard_t(4, size=(64, 96)) * 0.05).astype(np.float32)
    cm, _, qm = crew_uniform_from_dense(w, dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 64)).astype(np.float32))
    return x, cm, qm


@pytest.fixture(autouse=True)
def fresh_store():
    autotune.set_store(AutotuneStore())
    yield
    autotune.set_store(None)


class TestStore:
    def test_json_roundtrip(self, tmp_path):
        path = str(tmp_path / "sub" / "autotune.json")
        store = AutotuneStore(path)
        rec = Measurement(strategy="xla-dense",
                          times_s={"xla-dense": 0.5, "pallas-gather": 1.0})
        store.put("k1", rec)
        store.put("k0", Measurement(strategy="pallas-onehot", times_s={}))

        loaded = AutotuneStore.open(path)
        assert len(loaded) == 2
        assert loaded.get("k1") == rec
        assert loaded.get("k0").strategy == "pallas-onehot"
        assert sorted(loaded.keys()) == ["k0", "k1"]

    def test_missing_file_ok(self, tmp_path):
        store = AutotuneStore.open(str(tmp_path / "absent.json"))
        assert len(store) == 0

    def test_memory_store_never_touches_disk(self):
        store = AutotuneStore()
        store.put("k", Measurement(strategy="xla-dense", times_s={}))
        store.save()  # no path -> no-op
        assert store.get("k").strategy == "xla-dense"


class TestMeasure:
    def test_measures_deterministic_winner(self, case):
        x, cm, _ = case
        fake_times = {"xla-dense": 1.0, "xla-gather": 0.25,
                      "pallas-gather": 3.0, "pallas-onehot": 2.0}
        calls = []

        def timer(fn, repeats):
            fn()
            calls.append(repeats)
            return fake_times[list(fake_times)[len(calls) - 1]]

        rec = autotune.measure_crew_matmul(
            x, cm, candidates=tuple(fake_times), repeats=2, timer=timer)
        assert rec.strategy == "xla-gather"
        assert len(calls) == 4

        # second call returns the cached record without re-timing
        rec2 = autotune.measure_crew_matmul(
            x, cm, candidates=tuple(fake_times), timer=timer)
        assert rec2 is rec
        assert len(calls) == 4

    def test_failed_candidate_scores_inf(self, case):
        x, cm, _ = case
        rec = autotune.measure_crew_matmul(
            x, cm, candidates=("xla-dense", "no-such-strategy"), repeats=1)
        assert rec.strategy == "xla-dense"
        assert rec.times_s["no-such-strategy"] == float("inf")

    def test_winner_correctness_all_candidates(self, case):
        """The measured path must produce numerically correct output."""
        x, cm, qm = case
        rec = autotune.measure_crew_matmul(x, cm, repeats=1)
        ref = np.asarray(x @ jnp.asarray(qm.q * float(qm.scale), jnp.float32))
        out = np.asarray(crew_matmul(x, cm, strategy=rec.strategy))
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


class TestAutoDispatch:
    def test_cold_cache_uses_analytical_prior(self, case):
        _, cm, _ = case
        for b in (1, 4, 128):
            assert resolve_auto_strategy(b, cm) == pick_strategy(
                b, cm.width, compute_rich=b >= 64)

    def test_warm_cache_overrides_prior(self, case):
        x, cm, _ = case
        import jax
        b = x.shape[0]
        key = make_key(b, cm.n_in, cm.n_out, cm.k, cm.width,
                       jax.default_backend())
        forced = Measurement(strategy="xla-gather", times_s={})
        autotune.get_store().put(key, forced)
        assert resolve_auto_strategy(b, cm) == "xla-gather"
        # and the end-to-end auto call still computes the right numbers
        ref = np.asarray(crew_matmul(x, cm, strategy="xla-dense"))
        out = np.asarray(crew_matmul(x, cm, strategy="auto"))
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


class TestEpilogueKeys:
    def test_epilogue_tag_and_key_format(self):
        from repro.perf.autotune import epilogue_tag
        assert epilogue_tag(False, None) == "none"
        assert epilogue_tag(True, None) == "bias"
        assert epilogue_tag(False, "silu") == "silu"
        assert epilogue_tag(True, "gelu") == "bias+gelu"
        # "none" keeps the historical format (persisted caches stay valid)
        assert make_key(1, 2, 3, 4, 5, "cpu") == "b1-n2-m3-k4-w5-cpu"
        assert make_key(1, 2, 3, 4, 5, "cpu", epilogue="bias+gelu") \
            == "b1-n2-m3-k4-w5-cpu-ebias+gelu"

    def test_epilogue_measurement_keys_are_distinct(self, case):
        """An epilogue'd apply shape records under its own key and never
        shadows (or reads) the plain shape's measurement."""
        import jax
        from repro.kernels.ops import resolve_auto_strategy
        from repro.perf.autotune import epilogue_tag
        x, cm, _ = case
        b = x.shape[0]
        bias = jnp.zeros((cm.n_out,), jnp.float32)
        rec = autotune.measure_crew_matmul(
            x, cm, candidates=("xla-gather",), repeats=1,
            bias=bias, activation="gelu")
        tag = epilogue_tag(True, "gelu")
        key_epi = make_key(b, cm.n_in, cm.n_out, cm.k, cm.width,
                           jax.default_backend(), epilogue=tag)
        key_plain = make_key(b, cm.n_in, cm.n_out, cm.k, cm.width,
                             jax.default_backend())
        assert autotune.lookup(key_epi) == rec.strategy == "xla-gather"
        assert autotune.lookup(key_plain) is None
        # auto dispatch: the epilogue'd call uses the measurement, the
        # plain call still falls back to the analytical prior
        assert resolve_auto_strategy(b, cm, epilogue=tag) == "xla-gather"
        assert resolve_auto_strategy(b, cm) == pick_strategy(
            b, cm.width, compute_rich=b >= 64)

    def test_epilogue_measurement_is_correct(self, case):
        """The epilogue'd measured path computes bias+activation output."""
        import jax
        x, cm, qm = case
        bias = jnp.asarray(np.linspace(-1, 1, cm.n_out).astype(np.float32))
        rec = autotune.measure_crew_matmul(
            x, cm, repeats=1, bias=bias, activation="silu")
        ref = jax.nn.silu(
            np.asarray(x @ jnp.asarray(qm.q * float(qm.scale), jnp.float32))
            + np.asarray(bias)[None])
        out = np.asarray(crew_matmul(x, cm, strategy=rec.strategy,
                                     bias=bias, activation="silu"))
        np.testing.assert_allclose(out, np.asarray(ref), rtol=2e-4,
                                   atol=2e-4)


class TestPersistenceAcrossProcesses:
    """REPRO_AUTOTUNE_CACHE is the ship-a-warmed-cache-with-the-checkpoint
    mechanism (docs/serving.md §2): a store written by an offline
    conversion *process* must be a lookup hit in the serving process."""

    def test_subprocess_write_parent_lookup_hit(self, tmp_path):
        import os
        import subprocess
        import sys
        path = str(tmp_path / "autotune.json")
        code = """
import os
from repro.perf import autotune
from repro.perf.autotune import Measurement, make_key
store = autotune.get_store()
assert store.path == os.environ["REPRO_AUTOTUNE_CACHE"]
store.put(make_key(2, 64, 96, 31, 5, "cpu"),
          Measurement(strategy="xla-gather", times_s={"xla-gather": 0.5}))
store.put(make_key(2, 64, 96, 31, 5, "cpu", epilogue="bias+silu"),
          Measurement(strategy="pallas-onehot", times_s={}))
print("CHILD-WROTE")
"""
        env = dict(os.environ)
        env["REPRO_AUTOTUNE_CACHE"] = path
        env["PYTHONPATH"] = str(ROOT / "src")
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, timeout=120,
                             env=env)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "CHILD-WROTE" in out.stdout

        # parent: a fresh env-pointed store resolves the child's winners
        os.environ[_ENV] = path
        try:
            autotune.set_store(None)
            plain = make_key(2, 64, 96, 31, 5, "cpu")
            tagged = make_key(2, 64, 96, 31, 5, "cpu", epilogue="bias+silu")
            assert autotune.lookup(plain) == "xla-gather"
            assert autotune.lookup(tagged) == "pallas-onehot"
        finally:
            del os.environ[_ENV]
            autotune.set_store(None)

    def test_epilogue_tagged_keys_never_collide_in_persisted_store(
            self, tmp_path):
        """Every (epilogue, plain) key pair is distinct on disk: a cache
        warmed pre-epilogue (plain keys only) can never be shadowed by —
        or shadow — an epilogue'd measurement."""
        from itertools import product
        from repro.perf.autotune import AutotuneStore, epilogue_tag
        path = str(tmp_path / "store.json")
        store = AutotuneStore(path)
        tags = [epilogue_tag(b, a) for b, a in
                product((False, True), (None, "silu", "gelu"))]
        assert len(set(tags)) == len(tags)
        for i, tag in enumerate(tags):
            store.put(make_key(1, 8, 8, 4, 3, "cpu", epilogue=tag),
                      Measurement(strategy=f"s{i}", times_s={}))
        loaded = AutotuneStore.open(path)
        assert len(loaded) == len(tags)     # no key collided / overwrote
        for i, tag in enumerate(tags):
            key = make_key(1, 8, 8, 4, 3, "cpu", epilogue=tag)
            assert loaded.get(key).strategy == f"s{i}"


def test_serve_autotune_warms_cache(case):
    """autotune_crew_params walks a (stacked) CREW tree and records one
    winner per distinct (B, shape) key."""
    from repro.serve import autotune_crew_params
    _, cm, _ = case
    stacked = type(cm)(
        words=jnp.stack([cm.words, cm.words]),
        uniq=jnp.stack([cm.uniq, cm.uniq]),
        width=cm.width, n_out=cm.n_out)
    params = {"layer": {"w": stacked}, "other": {"scale": jnp.ones(3)}}
    winners = autotune_crew_params(params, batch_sizes=(1,), repeats=1)
    assert len(winners) == 1
    (key, strat), = winners.items()
    assert strat in autotune.DEFAULT_CANDIDATES
    assert autotune.lookup(key) == strat
