"""CREW core: quantization, unique analysis, stats, PPA — unit + property."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (QuantConfig, analyze_matrix, dequantize_matrix,
                        force_max_unique, index_width, layout_stats,
                        ppa_layout, quantize_matrix, reconstruct)


def heavy_tailed(rng, n, m):
    return (rng.standard_t(4, size=(n, m)) * 0.05).astype(np.float32)


class TestQuantization:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        w = heavy_tailed(rng, 64, 128)
        qm = quantize_matrix(w)
        err = np.abs(dequantize_matrix(qm) - w).max()
        assert err <= float(qm.scale) / 2 + 1e-7

    def test_levels_bounded(self):
        rng = np.random.default_rng(1)
        for bits in (4, 6, 8):
            qm = quantize_matrix(heavy_tailed(rng, 32, 64), QuantConfig(bits=bits))
            assert qm.q.max() <= qm.cfg.qmax and qm.q.min() >= -qm.cfg.qmax
            assert np.unique(qm.q).size <= qm.cfg.levels

    def test_per_channel(self):
        rng = np.random.default_rng(2)
        w = heavy_tailed(rng, 32, 8)
        qm = quantize_matrix(w, QuantConfig(per_channel=True))
        assert qm.scale.shape == (8,)
        err = np.abs(dequantize_matrix(qm) - w)
        assert (err <= qm.scale[None, :] / 2 + 1e-7).all()

    def test_rejects_non_2d(self):
        with pytest.raises(ValueError):
            quantize_matrix(np.zeros((2, 3, 4)))


class TestUniqueAnalysis:
    def test_reconstruction_lossless(self):
        rng = np.random.default_rng(3)
        qm = quantize_matrix(heavy_tailed(rng, 100, 257))
        layout = analyze_matrix(qm.q)
        assert (reconstruct(layout) == qm.q).all()

    def test_index_width(self):
        assert index_width(1) == 1
        assert index_width(2) == 1
        assert index_width(3) == 2
        assert index_width(44) == 6
        assert index_width(256) == 8

    def test_counts_sum_to_m(self):
        rng = np.random.default_rng(4)
        qm = quantize_matrix(heavy_tailed(rng, 16, 77))
        layout = analyze_matrix(qm.q)
        for r in layout.rows:
            assert int(r.counts.sum()) == 77

    def test_padded_table_uses_last_value(self):
        q = np.array([[1, 1, 5, 5, 9]])
        layout = analyze_matrix(q)
        tab = layout.padded_unique_table(8)
        assert tab.shape == (1, 8)
        assert (tab[0, 3:] == 9).all()

    @given(st.integers(0, 2 ** 32 - 1), st.integers(2, 24), st.integers(2, 48))
    @settings(max_examples=25, deadline=None)
    def test_property_lossless(self, seed, n, m):
        rng = np.random.default_rng(seed)
        q = rng.integers(-127, 128, size=(n, m)).astype(np.int32)
        layout = analyze_matrix(q)
        assert (reconstruct(layout) == q).all()
        assert (layout.widths >= 1).all() and (layout.widths <= 8).all()


class TestStats:
    def test_paper_accounting(self):
        """Hand-checkable example in the spirit of paper Fig. 2."""
        q = np.array([[3, 3, 7, 7], [1, 1, 1, 1], [2, 5, 2, 5]], dtype=np.int32)
        layout = analyze_matrix(q)
        st_ = layout_stats(layout, bits=8)
        # UW per input: 2, 1, 2 -> mean 5/3; MULs = 5 / 12
        assert st_.uw_per_input_mean == pytest.approx(5 / 3)
        assert st_.muls_fraction == pytest.approx(5 / 12)
        # dense = 96 bits; idx = (1+1+1)*4 + 3*3 side channel = 21 bits
        assert st_.dense_bits == 96
        # metadata: 5 uniques * 8 + 3 rows * 9
        assert st_.crew_bits_storage == 21 + 5 * 8 + 27

    def test_storage_reduction_at_scale(self):
        """Realistic dims + heavy-tailed weights reproduce a paper-like
        storage reduction (Table II reports 16-34 %)."""
        rng = np.random.default_rng(5)
        qm = quantize_matrix(heavy_tailed(rng, 1024, 1024))
        st_ = layout_stats(analyze_matrix(qm.q))
        assert st_.storage_reduction > 0.10
        assert st_.saved_muls > 0.90


class TestPPA:
    def test_reduces_widths_and_stays_reconstructable(self):
        rng = np.random.default_rng(6)
        qm = quantize_matrix(heavy_tailed(rng, 64, 512))
        layout = analyze_matrix(qm.q)
        res = ppa_layout(layout, thr=0.05)
        assert res.rows_approximated > 0
        # approximate model still reconstructs exactly from its own layout
        q2 = reconstruct(res.layout)
        assert q2.shape == qm.q.shape
        # widths never grow
        assert (res.layout.widths <= layout.widths).all()
        # moved mass is bounded by the threshold per approximated row
        assert res.weight_mass_moved < 0.05

    def test_threshold_zero_is_noop(self):
        rng = np.random.default_rng(7)
        qm = quantize_matrix(heavy_tailed(rng, 16, 128))
        layout = analyze_matrix(qm.q)
        res = ppa_layout(layout, thr=0.0)
        assert res.rows_approximated == 0
        assert (reconstruct(res.layout) == qm.q).all()

    def test_distortion_monotone_in_threshold(self):
        rng = np.random.default_rng(8)
        qm = quantize_matrix(heavy_tailed(rng, 48, 256))
        layout = analyze_matrix(qm.q)
        moved = [ppa_layout(layout, thr).weight_mass_moved
                 for thr in (0.01, 0.05, 0.10, 0.20)]
        assert all(a <= b + 1e-12 for a, b in zip(moved, moved[1:]))

    def test_force_max_unique(self):
        rng = np.random.default_rng(9)
        qm = quantize_matrix(heavy_tailed(rng, 32, 512))
        layout = analyze_matrix(qm.q)
        res = force_max_unique(layout, 16)
        assert res.layout.max_unique() <= 16
        assert (res.layout.widths <= 4).all()
        # cap >= max is a no-op
        res2 = force_max_unique(layout, layout.max_unique())
        assert res2.rows_approximated == 0
