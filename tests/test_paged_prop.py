"""Property-based KV-integrity harness over the paged scheduler.

Random workloads — interleaved submits (with shared-prefix prompt
families), engine steps, cancellations, deadlines, partial drains, and
seeded fault injection — are generated from a seed and driven through a
module-cached :class:`~repro.serve.Scheduler` at block sizes {4, 8, 16}.
After **every** event the harness asserts the paged-KV conservation law
(``Scheduler.audit_blocks``): every pool block's refcount equals its
owner count across free list ∪ prefix trie ∪ live slot block tables ∪
parked pins, plus the trie's structural audit.  After the final drain,
every rid has exactly one terminal :class:`Completion`, every COMPLETED
stream is token-identical to a cold one-shot ``serve.generate`` run, and
every partial (cancelled / timed-out) stream is a prefix of it.

The workload is a pure function of ``(base seed, block size, case)``:

* ``test_paged_workload_seeded`` — the always-on tier-1 entry point, a
  plain parametrized sweep (``PAGED_PROP_EXAMPLES`` cases per block
  size, default 4; CI's dedicated fuzz step raises it).  Runs with or
  without ``hypothesis`` installed.
* ``test_paged_workload_hypothesis`` — the same executor with
  ``hypothesis`` drawing the seeds (shrinking a seed is meaningless,
  but the knobs are real: ``--hypothesis-seed`` / ``HYPOTHESIS_SEED``
  derandomizes the draw sequence, threaded through conftest.py).
  Skipped when hypothesis is absent (minimal containers).

Failures reproduce exactly: the test id carries ``(block size, case)``
and the base seed is printed by the assert context, so
``pytest "tests/test_paged_prop.py::test_paged_workload_seeded[case-bs]"
--hypothesis-seed N`` replays the identical workload, faults included.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.serve import FaultInjector, Scheduler, Shed, generate

try:
    import hypothesis
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # minimal container: seeded sweep only
    HAVE_HYPOTHESIS = False

BLOCK_SIZES = (4, 8, 16)
N_EXAMPLES = int(os.environ.get("PAGED_PROP_EXAMPLES", "4"))
BASE_SEED = int(os.environ.get("HYPOTHESIS_SEED", "0") or "0")
CACHE_LEN = 64
# small fixed draw sets keep the distinct (prompt_len, max_new) shape
# combinations — and so the cold-generate reference compiles — bounded
# across hundreds of workloads
TAIL_LENS = (1, 5)
MAX_NEWS = (4, 8)


@pytest.fixture(scope="module")
def model():
    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return api, params, cfg.vocab


_SCHEDS = {}          # block_size -> Scheduler (compiled programs reused)
_REFS = {}            # (prompt bytes, max_new) -> cold generate tokens


def _sched_for(bs, api, params):
    sched = _SCHEDS.get(bs)
    # a failed example can leave work in flight; rebuild rather than
    # cascade reset() errors through every later example at this bs
    if sched is not None and (sched._live or sched._queue_len()):
        sched = None
    if sched is None:
        sched = _SCHEDS[bs] = Scheduler(
            api, params, max_batch=2, cache_len=CACHE_LEN,
            buckets=(8, 16), horizon=4, block_size=bs,
            max_queue=6, preempt_after_steps=2, faults=False)
    return sched


def _ref(api, params, prompt, max_new):
    key = (prompt.tobytes(), int(max_new))
    if key not in _REFS:
        out = generate(api, params, jnp.asarray(prompt)[None],
                       max_new=max_new)
        _REFS[key] = np.asarray(out["tokens"][0])
    return _REFS[key]


def _gen_workload(rng, bs, vocab):
    """(events, faults) — a pure function of the rng state.

    Prompts come from two shared-prefix families (block-aligned heads of
    1 and 2 blocks) plus head-less strays, so warm admissions, partial
    matches, and trie adoption all occur; deadlines ride on a fault
    injector's ``expire_p`` (no wall-clock sleeps).  Every draw happens
    unconditionally where possible so the event stream depends only on
    the seed, not on scheduler timing.
    """
    fmode = int(rng.integers(0, 4))
    if fmode == 0:
        faults = False              # fault-free
    elif fmode == 1:
        faults = None               # suite default (REPRO_FAULTS env)
    else:
        faults = FaultInjector(int(rng.integers(1 << 30)),
                               preempt_p=0.3, expire_p=0.05,
                               drop_p=0.3, max_drop=2)
    heads = [rng.integers(0, vocab, bs * k).astype(np.int32)
             for k in (1, 2)]
    events = []
    for _ in range(int(rng.integers(6, 15))):
        u = rng.random()
        if u < 0.55:
            head = (heads[int(rng.integers(2))]
                    if rng.random() < 0.7 else heads[0][:0])
            tail = rng.integers(
                0, vocab, TAIL_LENS[int(rng.integers(2))]).astype(np.int32)
            events.append((
                "submit",
                np.concatenate([head, tail]),
                MAX_NEWS[int(rng.integers(2))],
                None if rng.random() < 0.8 else 5.0,
                int(rng.integers(0, 2)),
            ))
        elif u < 0.75:
            events.append(("step",))
        elif u < 0.85:
            events.append(("cancel", int(rng.integers(0, 64))))
        else:
            events.append(("drain",))
    return events, faults


def _run_workload(sched, api, params, events, faults):
    sched.reset(faults=faults)
    rids = []
    meta = {}                       # rid -> (prompt, max_new)
    results = {}
    for ev in events:
        if ev[0] == "submit":
            _, prompt, max_new, deadline, priority = ev
            r = sched.submit(prompt, max_new=max_new,
                             deadline_s=deadline, priority=priority)
            rid = r.rid if isinstance(r, Shed) else r
            rids.append(rid)
            meta[rid] = (prompt, max_new)
        elif ev[0] == "step":
            sched.step()
        elif ev[0] == "cancel" and rids:
            sched.cancel(rids[ev[1] % len(rids)])
        elif ev[0] == "drain":
            results.update(sched.run())
        errs = sched.audit_blocks()
        assert not errs, f"after {ev[0]}: {errs}"
    results.update(sched.run())
    results.update(sched.pop_results())
    assert sched.pending == 0
    errs = sched.audit_blocks()
    assert not errs, f"after final drain: {errs}"
    # exactly one terminal Completion per submitted rid (shed included)
    assert sorted(results) == sorted(set(rids))
    for rid, comp in results.items():
        prompt, max_new = meta[rid]
        if comp.status == "completed":
            np.testing.assert_array_equal(
                comp.tokens, _ref(api, params, prompt, max_new),
                err_msg=f"rid {rid} completed off the greedy stream")
        elif comp.tokens.size:      # cancelled / timed out mid-stream
            ref = _ref(api, params, prompt, max_new)
            np.testing.assert_array_equal(
                comp.tokens, ref[:comp.tokens.size],
                err_msg=f"rid {rid} ({comp.status}) partial stream "
                        "diverged from the greedy prefix")


def _check(model, bs, entropy):
    api, params, vocab = model
    rng = np.random.default_rng(np.random.SeedSequence(entropy))
    events, faults = _gen_workload(rng, bs, vocab)
    _run_workload(_sched_for(bs, api, params), api, params, events, faults)


@pytest.mark.parametrize("bs", BLOCK_SIZES)
@pytest.mark.parametrize("case", range(N_EXAMPLES))
def test_paged_workload_seeded(model, bs, case):
    _check(model, bs, [BASE_SEED, bs, case])


if HAVE_HYPOTHESIS:
    @hypothesis.settings(max_examples=N_EXAMPLES, deadline=None)
    @hypothesis.seed(BASE_SEED)
    @hypothesis.given(seed=st.integers(0, 2**31 - 1),
                      bs=st.sampled_from(BLOCK_SIZES))
    def test_paged_workload_hypothesis(model, seed, bs):
        _check(model, bs, [seed, bs])
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_paged_workload_hypothesis():
        pass
