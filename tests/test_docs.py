"""Docs integrity: the tier-1 mirror of the CI ``tools/check_docs.py``
gate — every ``DESIGN.md §N`` / ``docs/*.md`` citation in the tree must
resolve, and the checker itself must catch dangling references."""
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))
import check_docs  # noqa: E402


def test_repo_docs_are_clean():
    assert check_docs.check() == []


def test_design_anchors_cover_cited_sections():
    anchors = check_docs.design_anchors()
    # the sections the source docstrings lean on
    for sec in ("3", "3.7", "4", "5", "7", "8"):
        assert sec in anchors, f"DESIGN.md lost its §{sec} heading"


# fixture strings are assembled so this file itself never contains a
# literal dangling reference (the checker scans tests/ too)
_SPEC = "DESIGN" + ".md"
_DOCS = "docs" + "/"


def test_checker_flags_dangling_references(tmp_path):
    (tmp_path / "DESIGN.md").write_text("# DESIGN\n\n## §1 · Only one\n")
    src = tmp_path / "src"
    src.mkdir()
    (src / "mod.py").write_text(
        f'"""See {_SPEC} §9 and {_DOCS}missing.md."""\n')
    (tmp_path / "README.md").write_text(f"[gone]({_DOCS}also_missing.md)\n")

    problems = "\n".join(check_docs.check(tmp_path))
    assert "§9" in problems
    assert _DOCS + "missing.md" in problems
    assert _DOCS + "also_missing.md" in problems


def test_checker_accepts_clean_tree(tmp_path):
    (tmp_path / "DESIGN.md").write_text("# DESIGN\n\n## §1 · A\n## §2 · B\n")
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "guide.md").write_text("see [spec](../DESIGN.md)\n")
    src = tmp_path / "src"
    src.mkdir()
    (src / "mod.py").write_text(f'"""{_SPEC} §2; see {_DOCS}guide.md."""\n')
    assert check_docs.check(tmp_path) == []
