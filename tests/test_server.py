"""The HTTP/SSE front door (serve.server + serve.client,
docs/serving.md): endpoint contract, stream parity with cold generate,
disconnect-propagated cancellation with a clean block audit, honest
503 + Retry-After during drain, and admission-rejection status codes."""
import time

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.serve import (FaultInjector, Scheduler, SSEServer, Supervisor,
                         generate)
from repro.serve.client import get_json, resume_stream, stream_generate


@pytest.fixture(scope="module")
def qwen():
    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


@pytest.fixture(scope="module")
def stack(qwen):
    """One live server for the module: scheduler (slow horizons so
    mid-stream races resolve deterministically) + supervisor + SSE
    listener on an ephemeral port."""
    cfg, api, params = qwen
    sched = Scheduler(api, params, max_batch=2, cache_len=64,
                      buckets=(8, 16), block_size=8, stream_tokens=True,
                      tenant_rate=30.0, tenant_burst=30.0,
                      faults=FaultInjector(0, delay_p=1.0,
                                           max_delay_s=0.03))
    sup = Supervisor(sched).start()
    srv = SSEServer(sup).start_background()
    yield cfg, api, params, sup, srv
    srv.stop_background()
    sup.stop(drain=False)


def _ref_tokens(api, params, prompt, max_new):
    out = generate(api, params, jax.numpy.asarray(prompt)[None],
                   max_new=max_new)
    return np.asarray(out["tokens"][0])


def _prompt(cfg, seed=0, size=8):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, size).astype(np.int32)


def _wait_terminal(sup, rid, timeout=60.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        comp = sup.results.get(rid)
        if comp is not None:
            return comp
        time.sleep(0.02)
    raise AssertionError(f"no terminal for rid {rid}")


class TestEndpoints:
    def test_healthz(self, stack):
        *_, srv = stack
        assert get_json(srv.host, srv.port, "/healthz") == \
            {"ok": True, "status": 200}

    def test_readyz_while_accepting(self, stack):
        *_, srv = stack
        assert get_json(srv.host, srv.port, "/readyz") == \
            {"ready": True, "status": 200}

    def test_metrics_shape(self, stack):
        *_, srv = stack
        m = get_json(srv.host, srv.port, "/metrics")
        for key in ("steps", "completed", "cancelled", "pending",
                    "draining", "recoveries"):
            assert key in m

    def test_unknown_route_404(self, stack):
        *_, srv = stack
        assert get_json(srv.host, srv.port, "/nope")["status"] == 404


class TestGenerate:
    def test_stream_parity_with_cold_generate(self, stack):
        cfg, api, params, sup, srv = stack
        p = _prompt(cfg, seed=1)
        r = stream_generate(srv.host, srv.port, p, max_new=6)
        assert r["http_status"] == 200 and r["rid"] >= 0
        assert r["done"]["status"] == "completed"
        ref = _ref_tokens(api, params, p, 6)
        assert r["tokens"] == [int(t) for t in ref]
        assert r["indices"] == list(range(6))
        assert r["done"]["tokens"] == r["tokens"]
        assert r["done"]["ttft_s"] > 0

    def test_malformed_body_400(self, stack):
        *_, sup, srv = stack
        import http.client
        import json
        for body in (b"", b"not json", b'{"prompt": []}',
                     b'{"prompt": [1], "max_new": 0}'):
            conn = http.client.HTTPConnection(srv.host, srv.port,
                                              timeout=10)
            try:
                conn.request("POST", "/v1/generate", body=body,
                             headers={"Content-Type": "application/json"})
                resp = conn.getresponse()
                assert resp.status == 400, body
                assert "error" in json.loads(resp.read().decode())
            finally:
                conn.close()

    def test_tenant_rate_429(self, stack):
        cfg, *_ , srv = stack
        p = _prompt(cfg, seed=2)
        # worst-case cost 8 + 48 = 56 >> the 30-token bucket
        r = stream_generate(srv.host, srv.port, p, max_new=48,
                            tenant="greedy-tenant")
        assert r["http_status"] == 429
        assert r["error"] == "tenant-rate"
        assert r.get("retry_after", 0) >= 1

    def test_slow_client_still_completes(self, stack):
        """A client that stalls mid-read exercises the write path
        without breaking the stream (the send queue absorbs it)."""
        cfg, api, params, sup, srv = stack
        p = _prompt(cfg, seed=3)
        r = stream_generate(srv.host, srv.port, p, max_new=6,
                            stall_s=0.4, stall_at=2)
        assert r["done"]["status"] == "completed"
        assert r["tokens"] == \
            [int(t) for t in _ref_tokens(api, params, p, 6)]


class TestDisconnect:
    def test_disconnect_mid_stream_cancels_and_audits_clean(self, stack):
        cfg, api, params, sup, srv = stack
        p = _prompt(cfg, seed=4)
        r = stream_generate(srv.host, srv.port, p, max_new=48,
                            disconnect_after=2)
        assert r["disconnected"] and r["rid"] >= 0
        comp = _wait_terminal(sup, r["rid"])
        assert comp.status == "cancelled"
        assert sup.wait_idle(60.0)
        assert sup.scheduler.audit_blocks() == []

    def test_disconnect_before_first_token(self, stack):
        cfg, api, params, sup, srv = stack
        p = _prompt(cfg, seed=5)
        r = stream_generate(srv.host, srv.port, p, max_new=48,
                            disconnect_after=0)
        assert r["disconnected"] and r["rid"] >= 0
        comp = _wait_terminal(sup, r["rid"])
        assert comp.status == "cancelled"
        assert sup.wait_idle(60.0)
        assert sup.scheduler.audit_blocks() == []


class TestResume:
    """Resumable streams over the wire (DESIGN.md §5.1): SSE ``id:``
    frames, ``Last-Event-ID`` re-attach with dedup on the absolute
    output index, idempotent re-submission, per-tenant counters."""

    def test_disconnect_then_resume_is_token_identical(self, stack):
        """A resumable client hangs up after two frames; the request
        keeps decoding in its grace window and a reconnect with
        ``Last-Event-ID`` picks up exactly where the first socket
        stopped — the two halves concatenate to the cold stream."""
        cfg, api, params, sup, srv = stack
        p = _prompt(cfg, seed=8)
        r = stream_generate(srv.host, srv.port, p, max_new=12,
                            resume=True, disconnect_after=2)
        assert r["disconnected"] and r["rid"] >= 0
        assert r["indices"] == [0, 1]
        r2 = resume_stream(srv.host, srv.port, r["rid"],
                           last_index=r["indices"][-1])
        assert r2["done"] is not None
        assert r2["done"]["status"] == "completed"
        assert r2["indices"] == list(range(2, 12))
        ref = _ref_tokens(api, params, p, 12)
        assert r["tokens"] + r2["tokens"] == [int(t) for t in ref]

    def test_finished_stream_replays_in_full(self, stack):
        """``GET /v1/stream/<rid>`` on a finished request replays the
        whole stream from the terminal record — reconnecting after the
        done frame was missed still yields every token."""
        cfg, api, params, sup, srv = stack
        p = _prompt(cfg, seed=9)
        r = stream_generate(srv.host, srv.port, p, max_new=6,
                            resume=True)
        assert r["done"]["status"] == "completed"
        r2 = resume_stream(srv.host, srv.port, r["rid"], last_index=-1)
        assert r2["done"]["status"] == "completed"
        assert r2["tokens"] == r["tokens"]
        assert r2["indices"] == list(range(6))

    def test_unknown_rid_is_stream_gone(self, stack):
        *_, srv = stack
        r = resume_stream(srv.host, srv.port, 10 ** 9)
        assert r["done"] is None
        assert r["error"] == "stream gone"

    def test_idempotency_key_reattaches_not_requeues(self, stack):
        """Retrying a POST with the same ``Idempotency-Key`` attaches
        to the original rid (marked by ``X-Idempotent-Replay``) and
        replays the same tokens instead of enqueueing a duplicate."""
        cfg, api, params, sup, srv = stack
        p = _prompt(cfg, seed=10)
        r1 = stream_generate(srv.host, srv.port, p, max_new=6,
                             idempotency_key="srv-idem-1")
        assert r1["done"]["status"] == "completed"
        assert "idempotent_replay" not in r1
        r2 = stream_generate(srv.host, srv.port, p, max_new=6,
                             idempotency_key="srv-idem-1")
        assert r2["rid"] == r1["rid"]
        assert r2.get("idempotent_replay") is True
        assert r2["tokens"] == r1["tokens"]
        assert r2["done"]["status"] == "completed"

    def test_metrics_report_per_tenant_counters(self, stack):
        cfg, api, params, sup, srv = stack
        p = _prompt(cfg, seed=11)
        r = stream_generate(srv.host, srv.port, p, max_new=4,
                            tenant="metrics-tenant")
        assert r["done"]["status"] == "completed"
        m = get_json(srv.host, srv.port, "/metrics")
        bucket = m["tenants"]["metrics-tenant"]
        assert bucket["submitted"] >= 1
        assert bucket["completed"] >= 1
        assert bucket["tokens"] >= 4


class TestDrainOverHTTP:
    def test_drain_flips_readyz_and_sheds_with_retry_after(self, qwen):
        """Drain needs its own stack (begin_drain is one-way): readyz
        flips to 503 + Retry-After, a mid-drain submit is shed with the
        same headers, in-flight work still completes token-identically,
        and a shut-down listener refuses connections."""
        cfg, api, params = qwen
        sched = Scheduler(api, params, max_batch=2, cache_len=64,
                          buckets=(8, 16), block_size=8,
                          stream_tokens=True,
                          faults=FaultInjector(0, delay_p=1.0,
                                               max_delay_s=0.05))
        sup = Supervisor(sched).start()
        srv = SSEServer(sup).start_background()
        try:
            p1, p2 = _prompt(cfg, seed=6), _prompt(cfg, seed=7)
            import threading
            res1 = {}
            th = threading.Thread(target=lambda: res1.update(
                stream_generate(srv.host, srv.port, p1, max_new=16)))
            th.start()
            t0 = time.monotonic()
            while not sup.scheduler.pending and \
                    time.monotonic() - t0 < 30:
                time.sleep(0.01)
            sup.begin_drain()
            rz = get_json(srv.host, srv.port, "/readyz")
            # Retry-After is now *derived* (remaining drain budget x
            # observed step EWMA), so pin the floor, not a constant
            assert rz["status"] == 503 and rz["retry_after"] >= 1
            assert rz["error"] == "draining"
            r2 = stream_generate(srv.host, srv.port, p2, max_new=4)
            assert r2["http_status"] == 503
            assert r2.get("retry_after", 0) >= 1
            th.join(120.0)
            assert res1["done"]["status"] == "completed"
            assert res1["tokens"] == \
                [int(t) for t in _ref_tokens(api, params, p1, 16)]
            assert sup.drain(60.0)
            assert sup.scheduler.audit_blocks() == []
        finally:
            srv.stop_background()
            sup.stop(drain=False)
