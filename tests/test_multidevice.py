"""Multi-device correctness via subprocesses (the parent test process must
keep the default single-device platform; each case forces
--xla_force_host_platform_device_count in a child)."""
import os
import subprocess
import sys


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_child(code: str, devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """FSDP x TP sharded train step == single-device train step."""
    run_child("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import ARCHS
from repro.models import build_model
from repro.train import adamw, init_state, make_train_step, TrainState
from repro.dist.sharding import TRAIN_RULES, named_sharding_tree
from repro.dist.ctx import sharding_ctx
from repro.data import batch_for
from repro.launch.mesh import make_mesh

cfg = ARCHS["qwen2-0.5b"].reduced()
api = build_model(cfg)
opt = adamw(1e-3)
batch = batch_for(cfg, 0, 8, 32)
step_fn = make_train_step(api, opt, dtype=jnp.float32, remat=False,
                          q_chunk=8, kv_chunk=8)

# reference: plain single-logical-device execution
state0 = init_state(api, opt, jax.random.PRNGKey(0))
ref_state, ref_metrics = jax.jit(step_fn)(state0, batch)

# sharded: 2x4 mesh, FSDP+TP with activation constraints
mesh = make_mesh((2, 4), ("data", "model"))
p_spec = api.param_spec()
state_spec = TrainState(step=P(), params=p_spec,
                        opt={"mu": p_spec, "nu": p_spec})
state1 = init_state(api, opt, jax.random.PRNGKey(0))
shard = named_sharding_tree(state_spec, state1, mesh, TRAIN_RULES)
state1 = jax.tree.map(jax.device_put, state1, shard)

def wrapped(s, b):
    with sharding_ctx(mesh, TRAIN_RULES):
        return step_fn(s, b)

with mesh:
    out_state, out_metrics = jax.jit(wrapped, out_shardings=(shard, None))(state1, batch)

assert abs(float(ref_metrics["loss"]) - float(out_metrics["loss"])) < 1e-4, (
    float(ref_metrics["loss"]), float(out_metrics["loss"]))
for a, b in zip(jax.tree.leaves(ref_state.params), jax.tree.leaves(out_state.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)
print("SHARDED-OK")
""")


def test_pipeline_parallel_matches_sequential():
    run_child("""
import jax, jax.numpy as jnp, numpy as np
from repro.dist.pipeline import pipeline_apply
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("pipe",))
n_stages, n_micro, mb, d = 4, 6, 3, 8
rng = np.random.default_rng(0)
ws = jnp.asarray(rng.standard_normal((n_stages, d, d)) * 0.3, jnp.float32)
xs = jnp.asarray(rng.standard_normal((n_micro, mb, d)), jnp.float32)

def stage_fn(w, x):
    return jnp.tanh(x @ w)

ref = xs
for s in range(n_stages):
    ref = jax.vmap(lambda x: stage_fn(ws[s], x))(ref)

out = pipeline_apply(stage_fn, ws, xs, mesh, axis="pipe")
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)
print("PIPELINE-OK")
""")


def test_grad_compression_error_feedback():
    """int8-compressed DP gradient mean with error feedback converges to the
    exact mean over steps."""
    run_child("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.compat import shard_map
from repro.dist.compress import compressed_mean, init_error
from repro.launch.mesh import make_mesh

mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)  # per-device grads

@jax.jit
def step(g, err):
    def f(g, err):
        m, e = compressed_mean(g[0], err[0], "data")
        return m[None], e[None]
    return shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                     out_specs=(P("data"), P("data")))(g, err)

err = jnp.zeros_like(g)
exact = g.mean(axis=0)
acc_c = jnp.zeros(64); acc_e = jnp.zeros(64)
for _ in range(30):
    m, err = step(g, err)
    acc_c = acc_c + m[0]
    acc_e = acc_e + exact
# error feedback keeps the ACCUMULATED update unbiased
rel = float(jnp.linalg.norm(acc_c - acc_e) / jnp.linalg.norm(acc_e))
assert rel < 0.01, rel
print("COMPRESS-OK", rel)
""")


def test_elastic_checkpoint_reshard():
    """Checkpoint saved from a 2x4 mesh restores onto a 8x1 mesh (elastic
    restart onto a different topology)."""
    run_child("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import PartitionSpec as P, NamedSharding
from repro import ckpt as ckptlib
from repro.launch.mesh import make_mesh

tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
mesh_a = make_mesh((2, 4), ("data", "model"))
tree_a = jax.tree.map(
    lambda x: jax.device_put(x, NamedSharding(mesh_a, P("data", "model"))), tree)

with tempfile.TemporaryDirectory() as d:
    ckptlib.save(d, 1, tree_a)
    mesh_b = make_mesh((8,), ("data",))
    shard_b = {"w": NamedSharding(mesh_b, P("data", None))}
    out, _ = ckptlib.restore(d, 1, tree, shardings=shard_b)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["w"].sharding.spec == P("data", None)
print("ELASTIC-OK")
""")


def test_dryrun_single_cell_smoke():
    """The dry-run driver itself works end-to-end on a tiny forced-device
    child (512 devices, one real cell)."""
    out = run_child("""
import sys
sys.argv = ["dryrun", "--arch", "xlstm-125m", "--shape", "decode_32k",
            "--mesh", "single", "--out", ""]
from repro.launch import dryrun
dryrun.main()
""", devices=512, timeout=560)
    assert "OK" in out
