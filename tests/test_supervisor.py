"""Supervised serving (serve.supervisor, DESIGN.md §5 "wire protocol &
supervision"): the pump delivers every token exactly once per index and
exactly one done event per rid; disconnect-propagated cancels release
their slots; crash recovery (injected or fault-scheduled) resumes greedy
streams token-identically; graceful drain finishes in-flight work within
the watchdog budget and sheds newcomers with a typed terminal."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.serve import (FaultInjector, Scheduler, Shed, Supervisor,
                         generate)


@pytest.fixture(scope="module")
def qwen():
    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _ref_tokens(api, params, prompt, max_new):
    out = generate(api, params, jax.numpy.asarray(prompt)[None],
                   max_new=max_new)
    return np.asarray(out["tokens"][0])


def _sched(api, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", 64)
    kw.setdefault("buckets", (8, 16))
    kw.setdefault("block_size", 8)
    kw.setdefault("stream_tokens", True)
    kw.setdefault("faults", False)
    return Scheduler(api, params, **kw)


class Collector:
    """Thread-safe per-rid event sink with wait-for-terminal."""

    def __init__(self):
        self.lock = threading.Lock()
        self.tokens = {}            # rid -> [(index, token)]
        self.done = {}              # rid -> [Completion]
        self.first_token = threading.Event()

    def __call__(self, ev):
        with self.lock:
            if ev.kind == "token":
                self.tokens.setdefault(ev.rid, []).append(
                    (ev.index, ev.token))
                self.first_token.set()
            else:
                self.done.setdefault(ev.rid, []).append(ev.completion)

    def wait_done(self, rid, timeout=120.0):
        t0 = time.monotonic()
        while time.monotonic() - t0 < timeout:
            with self.lock:
                if rid in self.done:
                    return self.done[rid][0]
            time.sleep(0.01)
        raise AssertionError(f"no terminal for rid {rid}")


def _prompts(cfg, n, seed=0, size=8):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size).astype(np.int32)
            for _ in range(n)]


class TestStreaming:
    def test_tokens_in_order_then_exactly_one_done(self, qwen):
        cfg, api, params = qwen
        (p,) = _prompts(cfg, 1)
        sup = Supervisor(_sched(api, params)).start()
        try:
            col = Collector()
            rid = sup.submit(p, max_new=6, on_event=col)
            comp = col.wait_done(rid)
            assert comp.status == "completed"
            ref = _ref_tokens(api, params, p, 6)
            assert [t for _, t in col.tokens[rid]] == [int(t) for t in ref]
            assert [i for i, _ in col.tokens[rid]] == list(range(6))
            assert len(col.done[rid]) == 1
            np.testing.assert_array_equal(comp.tokens, ref)
            assert sup.results[rid] is comp
            assert sup.scheduler.audit_blocks() == []
        finally:
            sup.stop(drain=False)

    def test_requires_stream_tokens(self, qwen):
        cfg, api, params = qwen
        with pytest.raises(ValueError, match="stream_tokens"):
            Supervisor(_sched(api, params, stream_tokens=False))

    def test_shed_submission_still_gets_done_event(self, qwen):
        cfg, api, params = qwen
        (p,) = _prompts(cfg, 1)
        sup = Supervisor(_sched(api, params)).start()
        try:
            sup.begin_drain()
            col = Collector()
            res = sup.submit(p, max_new=4, on_event=col)
            assert isinstance(res, Shed) and res.reason == "draining"
            comp = col.wait_done(res.rid, timeout=10.0)
            assert comp.status == "shed"
            assert comp.reason.startswith("draining")
            assert len(col.done[res.rid]) == 1
        finally:
            sup.stop(drain=False)


class TestCancel:
    def test_cancel_mid_flight_releases_slot(self, qwen):
        cfg, api, params = qwen
        (p,) = _prompts(cfg, 1)
        # slow horizons so the cancel lands mid-stream deterministically
        sup = Supervisor(_sched(
            api, params,
            faults=FaultInjector(0, delay_p=1.0, max_delay_s=0.05),
        )).start()
        try:
            col = Collector()
            rid = sup.submit(p, max_new=48, on_event=col)
            assert col.first_token.wait(60.0)
            sup.cancel(rid)
            comp = col.wait_done(rid)
            assert comp.status == "cancelled"
            assert len(col.done[rid]) == 1
            assert sup.wait_idle(60.0)
            assert sup.scheduler.audit_blocks() == []
        finally:
            sup.stop(drain=False)

    def test_cancel_is_idempotent(self, qwen):
        cfg, api, params = qwen
        (p,) = _prompts(cfg, 1)
        sup = Supervisor(_sched(api, params)).start()
        try:
            col = Collector()
            rid = sup.submit(p, max_new=4, on_event=col)
            col.wait_done(rid)
            assert sup.cancel(rid) is False     # already terminal: no-op
            assert sup.cancel(9999) is False    # unknown rid: no-op
        finally:
            sup.stop(drain=False)


class TestCrashRecovery:
    def test_injected_crash_resumes_token_identical(self, qwen):
        cfg, api, params = qwen
        p1, p2 = _prompts(cfg, 2, seed=3)
        # max_batch=1 so one request is in flight and one queued at the
        # crash: both descriptor flavors must survive snapshot/restore
        sup = Supervisor(_sched(
            api, params, max_batch=1,
            faults=FaultInjector(0, delay_p=1.0, max_delay_s=0.05),
        )).start()
        try:
            col = Collector()
            r1 = sup.submit(p1, max_new=24, on_event=col)
            r2 = sup.submit(p2, max_new=8, on_event=col)
            assert col.first_token.wait(60.0)
            sup.inject_crash("test crash")
            for rid, p, m in ((r1, p1, 24), (r2, p2, 8)):
                comp = col.wait_done(rid)
                assert comp.status == "completed"
                ref = _ref_tokens(api, params, p, m)
                np.testing.assert_array_equal(comp.tokens, ref)
                # the *stream* also saw each index exactly once, in order
                assert [i for i, _ in col.tokens[rid]] == list(range(m))
                assert [t for _, t in col.tokens[rid]] == \
                    [int(t) for t in ref]
                assert len(col.done[rid]) == 1
            assert sup.recoveries >= 1
            assert sup.recovery_log[0]["requests"] >= 1
            assert sup.scheduler.audit_blocks() == []
        finally:
            sup.stop(drain=False)

    def test_cancelled_rid_not_resurrected_by_recovery(self, qwen):
        cfg, api, params = qwen
        p1, p2 = _prompts(cfg, 2, seed=4)
        sup = Supervisor(_sched(
            api, params,
            faults=FaultInjector(0, delay_p=1.0, max_delay_s=0.05),
        )).start()
        try:
            col = Collector()
            r1 = sup.submit(p1, max_new=48, on_event=col)
            r2 = sup.submit(p2, max_new=8, on_event=col)
            assert col.first_token.wait(60.0)
            sup.cancel(r1)
            sup.inject_crash("crash right after a cancel")
            c1 = col.wait_done(r1)
            c2 = col.wait_done(r2)
            assert c1.status == "cancelled"
            assert len(col.done[r1]) == 1
            assert c2.status == "completed"
            np.testing.assert_array_equal(
                c2.tokens, _ref_tokens(api, params, p2, 8))
            assert sup.scheduler.audit_blocks() == []
        finally:
            sup.stop(drain=False)

    def test_seeded_crash_schedule_preserves_invariants(self, qwen):
        """Exactly one terminal per rid under a hot seeded crash
        schedule — the REPRO_FAULTS=1 contract (default_injector arms
        crash_p on exactly this path)."""
        cfg, api, params = qwen
        prompts = _prompts(cfg, 6, seed=5)
        sup = Supervisor(_sched(
            api, params,
            faults=FaultInjector(2, crash_p=0.25, preempt_p=0.3),
        )).start()
        try:
            col = Collector()
            rids = [sup.submit(p, max_new=6, on_event=col)
                    for p in prompts]
            comps = {rid: col.wait_done(rid) for rid in rids}
            for rid, p in zip(rids, prompts):
                assert len(col.done[rid]) == 1
                assert comps[rid].status == "completed"
                ref = _ref_tokens(api, params, p, 6)
                np.testing.assert_array_equal(comps[rid].tokens, ref)
                assert [t for _, t in col.tokens[rid]] == \
                    [int(t) for t in ref]
            assert sup.recoveries >= 1, "schedule never fired a crash"
            assert sup.scheduler.audit_blocks() == []
        finally:
            sup.stop(drain=False)

    def test_crash_loop_gives_up_with_terminals(self, qwen):
        """A scheduler that crashes every step must not recover forever:
        past max_recoveries the survivors are cancelled, so every rid
        still ends in exactly one terminal."""
        cfg, api, params = qwen
        (p,) = _prompts(cfg, 1, seed=6)
        sup = Supervisor(
            _sched(api, params, faults=FaultInjector(0, crash_p=1.0)),
            max_recoveries=3).start()
        try:
            col = Collector()
            rid = sup.submit(p, max_new=4, on_event=col)
            comp = col.wait_done(rid)
            assert comp.status == "cancelled"
            assert len(col.done[rid]) == 1
            assert any(e["gave_up"] for e in sup.recovery_log)
            assert sup.wait_idle(60.0)
        finally:
            sup.stop(drain=False)


class TestResumable:
    def test_release_grace_expiry_cancels(self, qwen):
        """A released (disconnected-but-resumable) stream that nobody
        reclaims is cancelled once its grace window expires — the
        no-orphaned-slot invariant, on a timer."""
        cfg, api, params = qwen
        (p,) = _prompts(cfg, 1, seed=20)
        sup = Supervisor(_sched(
            api, params,
            faults=FaultInjector(0, delay_p=1.0, max_delay_s=0.05),
        ), resume_grace_s=0.0).start()
        try:
            col = Collector()
            rid = sup.submit(p, max_new=48, on_event=col)
            assert col.first_token.wait(60.0)
            sup.release(rid)            # detaches col: poll results
            t0 = time.monotonic()
            while rid not in sup.results and time.monotonic() - t0 < 60:
                time.sleep(0.02)
            assert sup.results[rid].status == "cancelled"
            assert sup.wait_idle(60.0)
            assert sup.scheduler.audit_blocks() == []
        finally:
            sup.stop(drain=False)

    def test_release_then_attach_resumes_within_grace(self, qwen):
        cfg, api, params = qwen
        (p,) = _prompts(cfg, 1, seed=21)
        sup = Supervisor(_sched(
            api, params,
            faults=FaultInjector(0, delay_p=1.0, max_delay_s=0.05),
        ), resume_grace_s=30.0).start()
        try:
            col = Collector()
            rid = sup.submit(p, max_new=16, on_event=col)
            assert col.first_token.wait(60.0)
            sup.release(rid)
            # reconnect: replay everything from index 0 into a fresh
            # subscriber; the stream must still be exactly-once-per-index
            col2 = Collector()
            assert sup.attach(rid, col2)
            comp = col2.wait_done(rid)
            assert comp.status == "completed"
            ref = _ref_tokens(api, params, p, 16)
            assert [i for i, _ in col2.tokens[rid]] == list(range(16))
            assert [t for _, t in col2.tokens[rid]] == \
                [int(t) for t in ref]
            assert len(col2.done[rid]) == 1
        finally:
            sup.stop(drain=False)

    def test_idempotency_key_binds_once(self, qwen):
        from repro.serve import Duplicate

        cfg, api, params = qwen
        (p,) = _prompts(cfg, 1, seed=22)
        sup = Supervisor(_sched(api, params)).start()
        try:
            col = Collector()
            rid = sup.submit(p, max_new=4, on_event=col,
                             idempotency_key="once")
            assert isinstance(rid, int)
            dup = sup.submit(p, max_new=4, idempotency_key="once")
            assert isinstance(dup, Duplicate) and dup.rid == rid
            assert sup.idempotent_rid("once") == rid
            assert sup.idempotent_rid(None) is None
            col.wait_done(rid)
            # the binding outlives the terminal: late retries re-attach
            dup2 = sup.submit(p, max_new=4, idempotency_key="once")
            assert isinstance(dup2, Duplicate) and dup2.rid == rid
        finally:
            sup.stop(drain=False)

    def test_shed_does_not_consume_idempotency_key(self, qwen):
        """A shed is a rejection, not acceptance: the client's retry
        with the same key must be able to enqueue for real."""
        cfg, api, params = qwen
        (p,) = _prompts(cfg, 1, seed=23)
        sup = Supervisor(_sched(api, params)).start()
        try:
            sup.begin_drain()
            res = sup.submit(p, max_new=4, idempotency_key="retry-me")
            assert isinstance(res, Shed)
            assert sup.idempotent_rid("retry-me") is None
        finally:
            sup.stop(drain=False)


class TestObservability:
    def test_retry_after_derived_from_drain_budget(self, qwen):
        cfg, api, params = qwen
        sup = Supervisor(_sched(api, params)).start()
        try:
            assert sup.retry_after_s() == 1     # no drain, no steps yet
            with sup._lock:
                sup._step_ewma = 0.5
                sup._drain_budget = 100
                sup._drain_steps = 60
            assert sup.retry_after_s() == 20    # ceil(40 * 0.5)
            with sup._lock:
                sup._drain_steps = 100000       # over budget: floor at 1
            assert sup.retry_after_s() == 1
            with sup._lock:
                sup._step_ewma = 60.0
                sup._drain_steps = 0
            assert sup.retry_after_s() == 600   # clamped to the ceiling
        finally:
            sup.stop(drain=False)

    def test_request_log_one_line_per_terminal(self, qwen, tmp_path):
        from repro.serve import RequestLog

        cfg, api, params = qwen
        (p,) = _prompts(cfg, 1, seed=24)
        path = str(tmp_path / "requests.jsonl")
        sup = Supervisor(_sched(api, params),
                         request_log=RequestLog(path)).start()
        try:
            col = Collector()
            rid = sup.submit(p, max_new=4, on_event=col, tenant="acme")
            col.wait_done(rid)
            sup.begin_drain()
            res = sup.submit(p, max_new=4, on_event=col)
            assert isinstance(res, Shed)
            col.wait_done(res.rid)
        finally:
            sup.stop(drain=False)
        import json
        lines = [json.loads(ln) for ln in open(path)]
        by_rid = {ln["rid"]: ln for ln in lines}
        assert set(by_rid) == {rid, res.rid}
        assert by_rid[rid]["status"] == "completed"
        assert by_rid[rid]["tenant"] == "acme"
        assert by_rid[rid]["tokens"] == 4
        assert by_rid[rid]["queue_s"] >= 0.0
        assert by_rid[res.rid]["status"] == "shed"
        assert by_rid[res.rid]["reason"].startswith("draining")

    def test_per_tenant_counters(self, qwen):
        cfg, api, params = qwen
        p1, p2 = _prompts(cfg, 2, seed=25)
        sup = Supervisor(_sched(api, params)).start()
        try:
            col = Collector()
            r1 = sup.submit(p1, max_new=4, on_event=col, tenant="acme")
            r2 = sup.submit(p2, max_new=4, on_event=col)
            col.wait_done(r1)
            col.wait_done(r2)
            t = sup.scheduler.metrics.tenants
            assert t["acme"]["submitted"] == 1
            assert t["acme"]["completed"] == 1
            assert t["acme"]["tokens"] == 4
            assert t["-"]["submitted"] == 1     # no tenant -> "-" bucket
            assert t["-"]["completed"] == 1
        finally:
            sup.stop(drain=False)


class TestDrain:
    def test_drain_finishes_inflight_and_sheds_new(self, qwen):
        cfg, api, params = qwen
        p1, p2 = _prompts(cfg, 2, seed=7)
        sup = Supervisor(_sched(
            api, params,
            faults=FaultInjector(0, delay_p=1.0, max_delay_s=0.05),
        )).start()
        try:
            col = Collector()
            r1 = sup.submit(p1, max_new=16, on_event=col)
            assert col.first_token.wait(60.0)
            assert sup.accepting
            sup.begin_drain()
            assert not sup.accepting and sup.draining
            res = sup.submit(p2, max_new=4, on_event=col)
            assert isinstance(res, Shed) and res.reason == "draining"
            assert sup.drain(120.0)
            comp = col.wait_done(r1)
            assert comp.status == "completed"
            np.testing.assert_array_equal(
                comp.tokens, _ref_tokens(api, params, p1, 16))
            assert col.wait_done(res.rid).status == "shed"
            assert sup.scheduler.audit_blocks() == []
        finally:
            sup.stop(drain=False)

    def test_wedged_drain_cancels_within_budget(self, qwen):
        """A drain whose work never finishes must not hang shutdown:
        past the watchdog step budget the survivors are cancelled."""
        cfg, api, params = qwen
        (p,) = _prompts(cfg, 1, seed=8)
        sup = Supervisor(_sched(
            api, params,
            faults=FaultInjector(0, delay_p=1.0, max_delay_s=0.02),
        )).start()
        try:
            col = Collector()
            rid = sup.submit(p, max_new=48, on_event=col)
            assert col.first_token.wait(60.0)
            sup.begin_drain()
            with sup._lock:
                sup._drain_budget = 1       # pretend the budget is spent
            assert sup.drain(120.0)
            comp = col.wait_done(rid)
            assert comp.status == "cancelled"
            assert sup.scheduler.audit_blocks() == []
        finally:
            sup.stop(drain=False)
