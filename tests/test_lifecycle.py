"""Request lifecycle (DESIGN.md §5 "request lifecycle"): every rid gets
exactly one terminal Completion; cancellation, deadlines, bounded-queue
shedding with priority displacement, tenant token-rate admission,
preempt-to-prefix-pool resume parity across horizons, and the run()
watchdog diagnostics."""
import time

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.serve import (FaultInjector, RequestState, Scheduler,
                         SchedulerStalledError, Shed, generate)


@pytest.fixture(scope="module")
def qwen():
    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _ref_tokens(api, params, prompt, max_new):
    out = generate(api, params, jax.numpy.asarray(prompt)[None],
                   max_new=max_new)
    return np.asarray(out["tokens"][0])


def _sched(api, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", 64)
    kw.setdefault("buckets", (8, 16))
    kw.setdefault("block_size", 8)
    return Scheduler(api, params, **kw)


class TestCancel:
    def test_cancel_queued_terminates_immediately(self, qwen):
        cfg, api, params = qwen
        rng = np.random.default_rng(0)
        a = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        b = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        sched = _sched(api, params, max_batch=1, faults=False)
        ra = sched.submit(a, max_new=4)
        rb = sched.submit(b, max_new=4)
        assert sched.cancel(rb) is True          # still queued
        assert sched.request_state(rb) is RequestState.CANCELLED
        assert sched.cancel(rb) is False         # already terminal
        assert sched.cancel(999) is False        # unknown rid
        res = sched.run()
        assert res[rb].status == "cancelled"
        assert res[rb].reason == "cancelled while queued"
        assert res[rb].tokens.size == 0 and res[rb].n_steps == 0
        assert res[ra].status == "completed"
        np.testing.assert_array_equal(res[ra].tokens,
                                      _ref_tokens(api, params, a, 4))
        assert sched.metrics.cancelled == 1

    def test_cancel_mid_flight_keeps_partial_tokens(self, qwen):
        cfg, api, params = qwen
        rng = np.random.default_rng(1)
        p = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        ref = _ref_tokens(api, params, p, 16)
        sched = _sched(api, params, horizon=1, faults=False)
        rid = sched.submit(p, max_new=16)
        for _ in range(3):                       # prefill + a few decodes
            sched.step()
        assert sched.request_state(rid) is RequestState.DECODING
        assert sched.cancel(rid) is True
        assert sched.cancel(rid) is False        # cancel already pending
        res = sched.run()
        comp = res[rid]
        assert comp.status == "cancelled"
        assert "mid-flight" in comp.reason
        assert 0 < comp.tokens.size < 16
        # whatever was generated before the cancel is the greedy prefix
        np.testing.assert_array_equal(comp.tokens, ref[:comp.tokens.size])
        assert sched.request_state(rid) is None  # drained by pop_results


class TestDeadlines:
    def test_zero_deadline_times_out_in_queue(self, qwen):
        cfg, api, params = qwen
        rng = np.random.default_rng(2)
        p = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        sched = _sched(api, params, faults=False)
        with pytest.raises(ValueError, match="deadline_s"):
            sched.submit(p, max_new=4, deadline_s=-1.0)
        rid = sched.submit(p, max_new=4, deadline_s=0.0)
        res = sched.run()
        assert res[rid].status == "timed_out"
        assert "in queue" in res[rid].reason
        assert res[rid].tokens.size == 0
        assert sched.metrics.timed_out == 1

    def test_deadline_expires_in_flight(self, qwen):
        cfg, api, params = qwen
        rng = np.random.default_rng(3)
        p = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        sched = _sched(api, params, horizon=1, faults=False)
        rid = sched.submit(p, max_new=32, deadline_s=0.2)
        sched.step()                             # admitted within deadline
        assert sched.request_state(rid) is RequestState.DECODING
        time.sleep(0.25)                         # overrun while decoding
        res = sched.run()
        assert res[rid].status == "timed_out"
        assert "in flight" in res[rid].reason
        assert res[rid].tokens.size < 32

    def test_fault_forced_expiry_skips_the_clock(self, qwen):
        """should_expire lets the chaos layer exercise the timeout path
        without wall-clock sleeps — only deadline-bearing requests are
        eligible."""
        cfg, api, params = qwen
        rng = np.random.default_rng(4)
        a = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        b = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        sched = _sched(api, params,
                       faults=FaultInjector(0, expire_p=1.0))
        ra = sched.submit(a, max_new=4, deadline_s=1000.0)
        rb = sched.submit(b, max_new=4)          # no deadline: immune
        res = sched.run()
        assert res[ra].status == "timed_out"
        assert res[rb].status == "completed"
        np.testing.assert_array_equal(res[rb].tokens,
                                      _ref_tokens(api, params, b, 4))


class TestAdmissionControl:
    def test_bounded_queue_sheds_newcomer_typed(self, qwen):
        cfg, api, params = qwen
        rng = np.random.default_rng(5)
        prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)
                   for _ in range(3)]
        sched = _sched(api, params, max_batch=1, max_queue=2, faults=False)
        rids = [sched.submit(p, max_new=4) for p in prompts[:2]]
        shed = sched.submit(prompts[2], max_new=4)
        assert isinstance(shed, Shed) and shed.reason == "queue-full"
        assert sched.request_state(shed.rid) is RequestState.SHED
        res = sched.run()
        assert sorted(res) == sorted(rids + [shed.rid])  # one each
        assert res[shed.rid].status == "shed"
        assert "queue-full" in res[shed.rid].reason
        for rid, p in zip(rids, prompts):
            assert res[rid].status == "completed"
            np.testing.assert_array_equal(res[rid].tokens,
                                          _ref_tokens(api, params, p, 4))
        assert sched.metrics.shed == 1

    def test_priority_displaces_lower_priority_victim(self, qwen):
        cfg, api, params = qwen
        rng = np.random.default_rng(6)
        low_p = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        high_p = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        sched = _sched(api, params, max_batch=1, max_queue=1, faults=False)
        low = sched.submit(low_p, max_new=4, priority=5)
        high = sched.submit(high_p, max_new=4, priority=0)
        assert isinstance(high, int)             # admitted, not shed
        res = sched.run()
        assert res[low].status == "shed"
        assert "displaced" in res[low].reason
        assert res[high].status == "completed"
        np.testing.assert_array_equal(res[high].tokens,
                                      _ref_tokens(api, params, high_p, 4))

    def test_tenant_token_rate(self, qwen):
        cfg, api, params = qwen
        rng = np.random.default_rng(7)
        p = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        # burst covers one request's worst case (8 + 4 tokens), refill is
        # negligible within the test
        sched = _sched(api, params, tenant_rate=0.001, tenant_burst=12.0,
                       faults=False)
        ok = sched.submit(p, max_new=4, tenant="a")
        assert isinstance(ok, int)
        shed = sched.submit(p, max_new=4, tenant="a")    # bucket empty
        assert isinstance(shed, Shed) and shed.reason == "tenant-rate"
        other = sched.submit(p, max_new=4, tenant="b")   # fresh bucket
        free = sched.submit(p, max_new=4)                # untenanted
        assert isinstance(other, int) and isinstance(free, int)
        res = sched.run()
        assert res[shed.rid].status == "shed"
        for rid in (ok, other, free):
            assert res[rid].status == "completed"
        assert sched.metrics.shed == 1


class TestPreemptResume:
    @pytest.mark.parametrize("horizon", [1, 4, 8])
    def test_forced_preempt_resume_parity(self, qwen, horizon):
        """Fault-forced preemptions park KV in the prefix pool and
        re-queue; resumed greedy outputs are token-identical to the
        uninterrupted scheduler AND to cold-cache serve.generate, for
        every horizon."""
        cfg, api, params = qwen
        rng = np.random.default_rng(8)
        prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
                   for n in (12, 18, 12)]
        max_news = [10, 6, 12]
        refs = [_ref_tokens(api, params, p, m)
                for p, m in zip(prompts, max_news)]

        def drain(faults):
            sched = _sched(api, params, horizon=horizon, faults=faults)
            rids = [sched.submit(p, max_new=m)
                    for p, m in zip(prompts, max_news)]
            return sched, rids, sched.run()

        # high forcing rate: short drains only see a handful of steps,
        # so a mild probability can miss every one for some horizons
        chaos, rids_c, res_c = drain(FaultInjector(3, preempt_p=0.8))
        assert chaos.metrics.preempted >= 1
        assert chaos.metrics.resumed >= 1
        # wholesale pinned-block reattach: resume recomputes nothing
        assert chaos.metrics.resume_reprefill_tokens == 0
        assert chaos.metrics.prefill_tokens_saved > 0
        calm, rids_q, res_q = drain(False)
        assert calm.metrics.preempted == 0
        for ref, rc, rq in zip(refs, rids_c, rids_q):
            assert res_c[rc].status == "completed"
            np.testing.assert_array_equal(res_c[rc].tokens, ref)
            np.testing.assert_array_equal(res_c[rc].tokens,
                                          res_q[rq].tokens)

    def test_aged_pressure_preempts_longest_decode(self, qwen):
        """preempt_after_steps: a starved queue eventually preempts the
        longest-running decode; both requests finish parity-exact."""
        cfg, api, params = qwen
        rng = np.random.default_rng(9)
        a = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        b = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        sched = _sched(api, params, max_batch=1, horizon=1,
                       preempt_after_steps=2, faults=False)
        ra = sched.submit(a, max_new=12)
        rb = sched.submit(b, max_new=4)
        res = sched.run()
        # the single slot may ping-pong under sustained aged pressure;
        # each residency makes forward progress, so it stays bounded
        assert sched.metrics.preempted >= 1
        assert sched.metrics.resumed >= 1
        np.testing.assert_array_equal(res[ra].tokens,
                                      _ref_tokens(api, params, a, 12))
        np.testing.assert_array_equal(res[rb].tokens,
                                      _ref_tokens(api, params, b, 4))

    def test_priority_arrival_preempts_running_decode(self, qwen):
        cfg, api, params = qwen
        rng = np.random.default_rng(10)
        low_p = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        high_p = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        sched = _sched(api, params, max_batch=1, horizon=1, faults=False)
        low = sched.submit(low_p, max_new=12, priority=5)
        sched.step()                             # low is decoding
        high = sched.submit(high_p, max_new=4, priority=0)
        res = sched.run()
        assert sched.metrics.preempted >= 1
        np.testing.assert_array_equal(res[low].tokens,
                                      _ref_tokens(api, params, low_p, 12))
        np.testing.assert_array_equal(res[high].tokens,
                                      _ref_tokens(api, params, high_p, 4))


class TestPagedInterleavings:
    """Eviction x preemption interleavings over the shared block pool:
    the refcount ownership model (pool.py) must keep parked and
    in-use blocks safe from every eviction path."""

    def test_parked_blocks_survive_pool_drops_until_resume(self, qwen):
        """A preempted request's parked blocks carry an extra pin
        reference, so LRU eviction — here forced to fire maximally on
        every step between park and resume — can never free them; the
        resume still hits its parked prefix instead of re-prefilling
        cold."""
        cfg, api, params = qwen
        rng = np.random.default_rng(20)
        a = rng.integers(0, cfg.vocab, 12).astype(np.int32)
        b = rng.integers(0, cfg.vocab, 12).astype(np.int32)
        sched = _sched(api, params, max_batch=1, horizon=1,
                       preempt_after_steps=1, pool_blocks=2,
                       faults=FaultInjector(0, drop_p=1.0, max_drop=8))
        ra = sched.submit(a, max_new=12)
        rb = sched.submit(b, max_new=4)
        res = sched.run()
        assert sched.metrics.preempted >= 1
        assert sched.metrics.resumed >= 1
        # pinned parked blocks survived the every-step drops: the resume
        # matched its aligned parked prefix (>= 1 block) from the pool
        assert sched.metrics.prefill_tokens_saved > 0
        assert not sched._parked          # pins released at resume
        assert not sched.audit_blocks()
        np.testing.assert_array_equal(res[ra].tokens,
                                      _ref_tokens(api, params, a, 12))
        np.testing.assert_array_equal(res[rb].tokens,
                                      _ref_tokens(api, params, b, 4))

    def test_shared_prefix_eviction_mid_decode_keeps_blocks_live(self, qwen):
        """Evicting the cached prefix mid-decode of a sharing slot must
        not free in-use blocks: a slot table reference holds refcount
        >= 2, so the trie's eviction sweep skips every shared block —
        and frees them normally once the sharer retires."""
        cfg, api, params = qwen
        rng = np.random.default_rng(21)
        head = rng.integers(0, cfg.vocab, 24).astype(np.int32)
        warm = np.concatenate(
            [head, rng.integers(0, cfg.vocab, 6).astype(np.int32)])
        sched = _sched(api, params, max_batch=1, horizon=1,
                       pool_blocks=4, faults=False)
        ra = sched.submit(head, max_new=4)
        sched.run()                       # trie now caches head's blocks
        rb = sched.submit(warm, max_new=12)
        sched.step()                      # warm admit + prefill suffix
        assert sched.metrics.zero_copy_hits > 0
        # maximal eviction pressure mid-decode: every cached block is
        # shared with rb's live table (refcount >= 2) -> zero victims
        assert sched._trie.drop_lru_leaves(99) == 0
        assert not sched.audit_blocks()
        while sched.step():
            pass
        res = sched.pop_results()
        np.testing.assert_array_equal(res[rb].tokens,
                                      _ref_tokens(api, params, warm, 12))
        # sharer retired: the cached chain's leaf is refcount-1 again
        assert sched._trie.drop_lru_leaves(99) >= 1
        assert not sched.audit_blocks()


class TestWatchdog:
    def test_max_steps_budget_trips_with_diagnostics(self, qwen):
        cfg, api, params = qwen
        rng = np.random.default_rng(11)
        p = rng.integers(0, cfg.vocab, 20).astype(np.int32)
        sched = _sched(api, params, faults=False)
        sched.submit(p, max_new=16)
        with pytest.raises(SchedulerStalledError) as ei:
            sched.run(max_steps=1)
        msg = str(ei.value)
        assert "budget 1" in msg
        assert "slot 0" in msg and "state=" in msg and "queue:" in msg

    def test_no_progress_detector_trips(self, qwen):
        _, api, params = qwen
        sched = _sched(api, params, faults=False)
        sched.step = lambda: True        # wedged: busy, nothing advances
        with pytest.raises(SchedulerStalledError, match="no forward"):
            sched.run()

    def test_idle_run_is_clean(self, qwen):
        _, api, params = qwen
        sched = _sched(api, params, faults=False)
        assert sched.run() == {}


class TestAccounting:
    def test_one_terminal_outcome_per_rid_and_counters(self, qwen):
        """A mixed ending — completed, cancelled, timed out, shed — lands
        exactly one Completion per rid, with matching terminal-status
        counters and queue high-water mark."""
        cfg, api, params = qwen
        rng = np.random.default_rng(12)
        prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)
                   for _ in range(4)]
        sched = _sched(api, params, max_batch=1, max_queue=3, faults=False)
        done = sched.submit(prompts[0], max_new=4)
        gone = sched.submit(prompts[1], max_new=4)
        late = sched.submit(prompts[2], max_new=4, deadline_s=0.0)
        shed = sched.submit(prompts[3], max_new=4)
        assert isinstance(shed, Shed)
        sched.cancel(gone)
        res = sched.run()
        assert sorted(res) == sorted([done, gone, late, shed.rid])
        statuses = {rid: res[rid].status for rid in res}
        assert statuses == {done: "completed", gone: "cancelled",
                            late: "timed_out", shed.rid: "shed"}
        m = sched.metrics
        assert (m.completed, m.cancelled, m.timed_out, m.shed) == (1, 1, 1, 1)
        assert m.queue_peak == 3
        d = m.to_dict()
        for key in ("completed", "cancelled", "timed_out", "shed",
                    "preempted", "resumed", "queue_peak"):
            assert key in d

    def test_status_values_match_request_state(self, qwen):
        cfg, api, params = qwen
        rng = np.random.default_rng(13)
        p = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        sched = _sched(api, params, faults=False)
        rid = sched.submit(p, max_new=4)
        assert sched.request_state(rid) is RequestState.QUEUED
        res = sched.run()
        assert res[rid].status == RequestState.COMPLETED.value
        assert res[rid].reason == ""


class TestDrain:
    def test_submit_after_drain_sheds_typed(self, qwen):
        """The drain bugfix: a post-drain submission gets its typed
        terminal Completion (SHED, reason "draining") immediately
        instead of queueing forever behind a closed front door."""
        cfg, api, params = qwen
        rng = np.random.default_rng(20)
        p = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        sched = _sched(api, params, faults=False)
        assert not sched.draining
        sched.begin_drain()
        assert sched.draining
        res = sched.submit(p, max_new=4)
        assert isinstance(res, Shed) and res.reason == "draining"
        assert sched.request_state(res.rid) is RequestState.SHED
        out = sched.run()
        assert out[res.rid].status == "shed"
        assert out[res.rid].reason.startswith("draining")
        assert sched.metrics.shed == 1 and sched.pending == 0

    def test_drain_mid_horizon_finishes_inflight(self, qwen):
        """begin_drain (the SIGTERM path) mid-run, with one slot still
        advancing prefill chunks and another decoding: in-flight and
        queued work all complete token-identically; only newcomers
        shed."""
        cfg, api, params = qwen
        rng = np.random.default_rng(21)
        short = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        longp = rng.integers(0, cfg.vocab, 24).astype(np.int32)
        late = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        sched = _sched(api, params, faults=False)
        ra = sched.submit(short, max_new=32)
        sched.step()                    # ra decoding (4 horizons of work)
        rb = sched.submit(longp, max_new=4)
        sched.step()                    # rb mid-chunked-prefill
        assert sched.request_state(ra) is RequestState.DECODING
        assert sched.request_state(rb) is RequestState.PREFILLING
        sched.begin_drain()
        shed = sched.submit(late, max_new=4)
        assert isinstance(shed, Shed) and shed.reason == "draining"
        res = sched.run()
        assert res[ra].status == "completed"
        assert res[rb].status == "completed"
        np.testing.assert_array_equal(res[ra].tokens,
                                      _ref_tokens(api, params, short, 32))
        np.testing.assert_array_equal(res[rb].tokens,
                                      _ref_tokens(api, params, longp, 4))
        assert res[shed.rid].status == "shed"
        assert sched.audit_blocks() == []

    def test_drain_races_deadline_expiry(self, qwen):
        """A request whose deadline expires during the drain must end
        TIMED_OUT (the deadline's terminal), not linger or shed — the
        drain changes admission, never in-flight lifecycle rules."""
        cfg, api, params = qwen
        rng = np.random.default_rng(22)
        p1 = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        p2 = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        sched = _sched(api, params, max_batch=1, faults=False)
        live = sched.submit(p1, max_new=4)
        dead = sched.submit(p2, max_new=4, deadline_s=0.0)  # expires now
        sched.begin_drain()
        res = sched.run()
        assert res[live].status == "completed"
        assert res[dead].status == "timed_out"
        assert sorted(res) == [live, dead]
        assert sched.pending == 0 and sched.audit_blocks() == []

    def test_drain_survives_forced_reset(self, qwen):
        """Crash recovery mid-drain must stay draining: reset(force)
        keeps the drain flag so a recovered front door does not quietly
        reopen admission."""
        cfg, api, params = qwen
        rng = np.random.default_rng(23)
        p = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        sched = _sched(api, params, faults=False)
        sched.begin_drain()
        sched.reset(force=True)
        assert sched.draining
        assert isinstance(sched.submit(p, max_new=4), Shed)


class TestCancelIdempotence:
    def test_cancel_terminal_and_popped_rids_is_noop(self, qwen):
        """The cancel bugfix: cancelling an already-terminal rid — even
        after its Completion was popped — is an idempotent no-op (False),
        never a KeyError.  A disconnect can race the natural finish."""
        cfg, api, params = qwen
        rng = np.random.default_rng(24)
        p = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        sched = _sched(api, params, faults=False)
        rid = sched.submit(p, max_new=4)
        res = sched.run()
        assert res[rid].status == "completed"
        assert sched.cancel(rid) is False       # terminal, results popped
        assert sched.cancel(rid) is False       # and stays a no-op

    def test_cancel_preempted_parked_rid_releases_pins(self, qwen):
        """Cancelling a request parked in the prefix pool mid-preemption
        releases its pinned blocks (no leak) and terminates it exactly
        once."""
        cfg, api, params = qwen
        rng = np.random.default_rng(25)
        pa = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        pb = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        sched = _sched(api, params, max_batch=1, faults=False)
        ra = sched.submit(pa, max_new=16)
        sched.step()                    # ra decoding, tokens generated
        assert sched.request_state(ra) is RequestState.DECODING
        rb = sched.submit(pb, max_new=4, priority=-1)
        sched.step()                    # priority preempt: ra parked
        assert sched.request_state(ra) is RequestState.QUEUED
        assert sched.metrics.preempted == 1
        assert sched.cancel(ra) is True
        assert sched.request_state(ra) is RequestState.CANCELLED
        assert sched.cancel(ra) is False        # idempotent second call
        res = sched.run()
        assert res[ra].status == "cancelled"
        assert res[rb].status == "completed"
        np.testing.assert_array_equal(res[rb].tokens,
                                      _ref_tokens(api, params, pb, 4))
        assert sched.audit_blocks() == []

    def test_pending_cancel_survives_preemption_race(self, qwen):
        """A cancel that lands while its rid is live, with the rid
        preempted back to the queue before the next boundary (the
        supervisor-thread interleaving), must still terminate the rid
        at that boundary instead of being dropped with the pending set."""
        cfg, api, params = qwen
        rng = np.random.default_rng(26)
        pa = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        pb = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        sched = _sched(api, params, max_batch=1, faults=False)
        ra = sched.submit(pa, max_new=16)
        sched.step()
        rb = sched.submit(pb, max_new=4, priority=-1)
        sched.step()                    # ra parked in the queue
        assert sched.request_state(ra) is RequestState.QUEUED
        # the race: cancel() recorded the rid while it was live, the
        # boundary arrives after the preemption re-queued it
        sched._cancel_pending.add(ra)
        res = sched.run()
        assert res[ra].status == "cancelled"
        assert res[ra].reason == "cancelled while parked"
        assert res[rb].status == "completed"
        assert sorted(res) == [ra, rb]
        assert sched.audit_blocks() == []
