"""Perf-regression gate (tools/bench_compare.py): threshold math, noise
floor, incomparable records, and missing-baseline tolerance."""
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))
import bench_compare  # noqa: E402


def _record(path, seconds, fast=True, backend="cpu", sha="abc"):
    obj = {"fast": fast, "backend": backend, "git_sha": sha,
           "modules": [{"name": n, "seconds": s, "rows": 1}
                       for n, s in seconds.items()]}
    path.write_text(json.dumps(obj))
    return path


def test_pass_within_threshold(tmp_path, capsys):
    a = _record(tmp_path / "a.json", {"tab1": 2.0, "traffic": 1.0})
    b = _record(tmp_path / "b.json", {"tab1": 2.4, "traffic": 0.9})
    assert bench_compare.main([str(a), str(b)]) == 0
    assert "REGRESSION" not in capsys.readouterr().out


def test_fail_beyond_threshold(tmp_path, capsys):
    a = _record(tmp_path / "a.json", {"tab1": 2.0})
    b = _record(tmp_path / "b.json", {"tab1": 2.6})   # +30% > 25%
    assert bench_compare.main([str(a), str(b)]) == 1
    assert "REGRESSION" in capsys.readouterr().out


def test_custom_threshold(tmp_path):
    a = _record(tmp_path / "a.json", {"tab1": 2.0})
    b = _record(tmp_path / "b.json", {"tab1": 2.6})
    assert bench_compare.main([str(a), str(b), "--threshold", "0.5"]) == 0


def test_noise_floor_skips_tiny_modules(tmp_path, capsys):
    # 3x regression on a 10ms module is jitter, not signal
    a = _record(tmp_path / "a.json", {"tab2": 0.01})
    b = _record(tmp_path / "b.json", {"tab2": 0.03})
    assert bench_compare.main([str(a), str(b)]) == 0
    assert "noise floor" in capsys.readouterr().out


def test_new_module_has_no_baseline(tmp_path, capsys):
    a = _record(tmp_path / "a.json", {"tab1": 2.0})
    b = _record(tmp_path / "b.json", {"tab1": 2.0, "prefix_reuse": 9.0})
    assert bench_compare.main([str(a), str(b)]) == 0
    assert "new module" in capsys.readouterr().out


def test_incomparable_records_skip(tmp_path, capsys):
    a = _record(tmp_path / "a.json", {"tab1": 1.0}, fast=False)
    b = _record(tmp_path / "b.json", {"tab1": 9.0}, fast=True)
    assert bench_compare.main([str(a), str(b)]) == 0
    assert "not comparable" in capsys.readouterr().out
    a = _record(tmp_path / "a.json", {"tab1": 1.0}, backend="tpu")
    b = _record(tmp_path / "b.json", {"tab1": 9.0}, backend="cpu")
    assert bench_compare.main([str(a), str(b)]) == 0


def test_missing_baseline_is_not_an_error(tmp_path):
    b = _record(tmp_path / "b.json", {"tab1": 1.0})
    assert bench_compare.main([str(tmp_path / "absent.json"), str(b)]) == 0


# --------------------------------------------------------------------------
# --require-ratio: the absolute CREW >= dense decode-throughput gate
# --------------------------------------------------------------------------

def _decode_record(path, crew_tps, dense_tps, fast=True):
    rows = [{"weights": w, "horizon": h, "tokens_per_s": tps}
            for w, by_h in (("crew", crew_tps), ("dense", dense_tps))
            for h, tps in by_h.items()]
    obj = {"fast": fast, "backend": "cpu", "git_sha": "abc",
           "modules": [{"name": "decode_latency", "seconds": 3.0,
                        "rows": len(rows), "data": rows}]}
    path.write_text(json.dumps(obj))
    return path


def _ratio_args(a, b, op=">=", value="1.0"):
    return ["--require-ratio", "decode_latency", "crew/dense", op, value,
            str(a), str(b)]


def test_ratio_gate_passes_at_largest_common_horizon(tmp_path, capsys):
    # H=1 would fail the bar; the gate reads the largest common horizon
    a = _record(tmp_path / "a.json", {"decode_latency": 3.0})
    b = _decode_record(tmp_path / "b.json",
                       {1: 50.0, 8: 210.0}, {1: 100.0, 8: 200.0})
    assert bench_compare.main(_ratio_args(a, b)) == 0
    assert "horizon=8" in capsys.readouterr().out


def test_ratio_gate_fails_below_bar(tmp_path, capsys):
    a = _record(tmp_path / "a.json", {"decode_latency": 3.0})
    b = _decode_record(tmp_path / "b.json",
                       {1: 50.0, 8: 150.0}, {1: 100.0, 8: 200.0})
    assert bench_compare.main(_ratio_args(a, b)) == 1
    assert "FAIL" in capsys.readouterr().out


def test_ratio_gate_applies_without_baseline(tmp_path):
    # the regression diff tolerates a missing baseline; the absolute
    # gate still runs (and still fails) on the current record alone
    b = _decode_record(tmp_path / "b.json", {8: 100.0}, {8: 200.0})
    assert bench_compare.main(
        _ratio_args(tmp_path / "absent.json", b)) == 1


def test_ratio_gate_missing_module_or_group_fails(tmp_path, capsys):
    b = _record(tmp_path / "b.json", {"tab1": 1.0})
    assert bench_compare.main(
        _ratio_args(tmp_path / "absent.json", b)) == 1
    assert "missing" in capsys.readouterr().out
    # module present but one weights group absent
    b2 = _decode_record(tmp_path / "b2.json", {}, {8: 200.0})
    assert bench_compare.main(
        _ratio_args(tmp_path / "absent.json", b2)) == 1
