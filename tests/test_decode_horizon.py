"""Horizon decode + buffer donation (DESIGN.md §5): greedy token parity
with per-request ``serve.generate`` for every horizon H in {1, 4, 8},
donated-KV aliasing declared by the lowered prefill/decode/horizon
programs, and program sets that stay bucket-bounded under horizon
stepping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.serve import Scheduler, generate
from repro.serve.engine import _decode_program, _prefill_program


@pytest.fixture(scope="module")
def qwen():
    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _ref_tokens(api, params, prompt, max_new):
    out = generate(api, params, jnp.asarray(prompt)[None], max_new=max_new)
    return np.asarray(out["tokens"][0])


class TestHorizonParity:
    @pytest.mark.parametrize("horizon", [1, 4, 8])
    def test_greedy_parity_vs_generate(self, qwen, horizon):
        """Mixed (prompt_len, max_new) requests through 2 slots: every
        request's greedy tokens equal its one-shot ``serve.generate``
        run regardless of H — retirement is delayed to the horizon
        boundary, but a request's stream depends only on its own
        prompt, so boundary slack never changes outputs."""
        cfg, api, params = qwen
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
                   for n in (5, 12, 7, 16)]
        # deliberately not multiples of H: lanes die mid-horizon
        max_news = [3, 9, 6, 11]

        sched = Scheduler(api, params, max_batch=2, cache_len=64,
                          buckets=(8, 16), horizon=horizon)
        rids = [sched.submit(p, max_new=m)
                for p, m in zip(prompts, max_news)]
        res = sched.run()

        assert sorted(res) == sorted(rids)
        for rid, p, m in zip(rids, prompts, max_news):
            np.testing.assert_array_equal(
                res[rid].tokens, _ref_tokens(api, params, p, m))
            assert res[rid].logprobs.shape == (m,)
            assert np.all(res[rid].logprobs <= 0)
        # device steps come in whole horizons; the program set stays
        # bucket-bounded (batch buckets {1, 2})
        assert sched.metrics.decode_steps % horizon == 0
        assert sched.metrics.decode_steps == \
            sched.metrics.horizons * horizon
        assert sched.program_counts()["decode"] <= 2

    def test_eos_mid_horizon_retires_at_boundary(self, qwen):
        """An EOS sampled at a non-boundary step stops the stream exactly
        there (parity with generate's prefix), and the freed slot
        backfills the queued request behind it."""
        cfg, api, params = qwen
        rng = np.random.default_rng(1)
        a = rng.integers(0, cfg.vocab, 6).astype(np.int32)
        b = rng.integers(0, cfg.vocab, 9).astype(np.int32)
        ref_a = _ref_tokens(api, params, a, 8)
        eos = int(ref_a[2])  # dies at token 3 of an H=4 horizon

        sched = Scheduler(api, params, max_batch=1, cache_len=32,
                          buckets=(16,), horizon=4)
        rid_a = sched.submit(a, max_new=8, eos_id=eos)
        rid_b = sched.submit(b, max_new=5)
        res = sched.run()

        np.testing.assert_array_equal(res[rid_a].tokens, ref_a[:3])
        assert res[rid_a].tokens[-1] == eos
        np.testing.assert_array_equal(res[rid_b].tokens,
                                      _ref_tokens(api, params, b, 5))
        # lane A idled from its mid-horizon death to the boundary
        assert sched.metrics.wasted_lane_steps > 0

    def test_sampled_parity_across_horizons(self, qwen):
        """temperature > 0: the per-request fold_in(rid, n_generated) key
        stream makes sampled outputs horizon-invariant too."""
        cfg, api, params = qwen
        rng = np.random.default_rng(2)
        p = rng.integers(0, cfg.vocab, 6).astype(np.int32)
        outs = []
        for h in (1, 4, 8):
            sched = Scheduler(api, params, max_batch=2, cache_len=64,
                              buckets=(8,), horizon=h, temperature=1.0,
                              rng=jax.random.PRNGKey(7))
            rid = sched.submit(p, max_new=10)
            outs.append(sched.run()[rid].tokens)
        np.testing.assert_array_equal(outs[0], outs[1])
        np.testing.assert_array_equal(outs[0], outs[2])


class TestDonation:
    """The jit programs must *declare* KV-buffer donation: the lowered
    module carries ``tf.aliasing_output`` on the donated cache arguments
    (jax marks donated inputs with the alias attribute at lowering; the
    pinned CPU jaxlib honors it at runtime)."""

    def test_scheduler_programs_declare_donated_kv(self, qwen):
        _, api, params = qwen
        sched = Scheduler(api, params, max_batch=2, cache_len=32,
                          buckets=(8,), horizon=4, block_size=8)
        nb = 1
        lowered = sched._horizon_fn.lower(
            sched._pk, sched._pv, params,
            jnp.zeros((nb, sched._nb_full), jnp.int32),
            jnp.zeros(nb, jnp.int32), jnp.zeros(nb, jnp.int32),
            jnp.zeros((nb, 2), jnp.uint32), jnp.zeros(nb, jnp.int32),
            jnp.zeros(nb, jnp.int32), jnp.full(nb, -1, jnp.int32),
            jnp.zeros(nb, bool))
        assert lowered.as_text().count("tf.aliasing_output") >= 2  # pk, pv

        g = 2
        lowered = sched._chunk_fn.lower(
            sched._pk, sched._pv, params, jnp.zeros((g, 8), jnp.int32),
            jnp.zeros((g, 1), jnp.int32), jnp.zeros(g, jnp.int32),
            jnp.ones(g, jnp.int32), jnp.zeros((g, 2), jnp.uint32),
            jnp.zeros(g, jnp.int32))
        assert lowered.as_text().count("tf.aliasing_output") >= 2

    def test_prefix_hits_run_zero_kv_copy_programs(self, qwen):
        """Paged admission moves no KV: a warm prefix hit is a refcount
        bump into the slot's block table and completion adopts blocks by
        reference, so the scheduler has *no* copy or insert programs —
        ``program_counts()`` pins both at zero even after a fully warm
        drain."""
        cfg, api, params = qwen
        rng = np.random.default_rng(5)
        head = rng.integers(0, cfg.vocab, 16).astype(np.int32)
        sched = Scheduler(api, params, max_batch=2, cache_len=64,
                          buckets=(8, 16), block_size=8)
        prompts = [np.concatenate(
            [head, rng.integers(0, cfg.vocab, 5).astype(np.int32)])
            for _ in range(3)]
        for p in prompts:
            sched.submit(p, max_new=4)
        sched.run()
        # second wave: every admission hits the cached 16-token head
        rids = [sched.submit(p, max_new=4) for p in prompts]
        res = sched.run()
        assert sorted(res) == sorted(rids)
        assert sched.metrics.zero_copy_hits > 0
        counts = sched.program_counts()
        assert counts["copy"] == 0
        assert counts["insert"] == 0
        assert not sched.audit_blocks()

    def test_engine_decode_program_declares_donated_cache(self, qwen):
        cfg, api, params = qwen
        prompts = jnp.arange(8, dtype=jnp.int32)[None] % cfg.vocab
        keys = jax.random.split(jax.random.PRNGKey(0), 4)
        first, cache = _prefill_program(api, params, prompts, keys[0], 12,
                                        0.0, "auto")
        lowered = _decode_program.lower(api, params, cache, first, keys[1:],
                                        0.0, "auto")
        # k, v (len is a scalar; aliasing it is backend-discretionary)
        assert lowered.as_text().count("tf.aliasing_output") >= 2

    def test_horizon_decode_matches_token_sync_after_donation(self, qwen):
        """End-to-end donation safety: repeated drains through the same
        (donated, in-place-updated) slot cache keep producing the
        token-identical streams — no stale-buffer reuse."""
        cfg, api, params = qwen
        rng = np.random.default_rng(3)
        p = rng.integers(0, cfg.vocab, 7).astype(np.int32)
        ref = _ref_tokens(api, params, p, 6)
        sched = Scheduler(api, params, max_batch=2, cache_len=32,
                          buckets=(8,), horizon=8)
        for _ in range(3):
            rid = sched.submit(p, max_new=6)
            np.testing.assert_array_equal(sched.run()[rid].tokens, ref)
