"""Cross-request prefix reuse (DESIGN.md §5): radix-trie match/insert/evict
semantics, warm-vs-cold greedy token parity, chunked-prefill bitwise parity
with the monolithic prefill, counter behavior on shared vs disjoint
traffic, and LRU eviction safety under pool pressure."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.serve import PrefixTrie, Scheduler, generate

# Under REPRO_FAULTS forced preempt/resume re-prefills through the pool
# (its own inserted blocks), and forced drops evict cached blocks — both
# output-preserving, so parity pins stay unconditional, but exact saved-
# token / program-count accounting legitimately shifts.
FAULT_MODE = os.environ.get("REPRO_FAULTS", "").strip() not in ("", "0")


@pytest.fixture(scope="module")
def qwen():
    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _ref_tokens(api, params, prompt, max_new):
    out = generate(api, params, jnp.asarray(prompt)[None], max_new=max_new)
    return np.asarray(out["tokens"][0])


# --------------------------------------------------------------------------
# Host-side trie (no jax)
# --------------------------------------------------------------------------

class TestTrie:
    def test_match_insert_roundtrip(self):
        t = PrefixTrie(8, block_size=4)
        toks = np.arange(10, dtype=np.int32)        # blocks [0:4), [4:8)
        assert t.match(toks) == ([], 0)
        new, start = t.insert(toks)
        assert len(new) == 2 and start == 0
        ids, hit = t.match(toks)
        assert ids == new and hit == 8
        # a prompt sharing one block matches exactly that block
        other = np.concatenate([toks[:4], toks[:4] + 90])
        ids, hit = t.match(other)
        assert ids == new[:1] and hit == 4

    def test_insert_extends_existing_prefix(self):
        t = PrefixTrie(8, block_size=4)
        t.insert(np.arange(8, dtype=np.int32))
        new, start = t.insert(np.arange(16, dtype=np.int32))
        assert len(new) == 2 and start == 8         # only the tail is new
        assert len(t) == 4

    def test_lru_leaf_eviction(self):
        t = PrefixTrie(2, block_size=2)
        a = np.asarray([1, 2], np.int32)
        b = np.asarray([3, 4], np.int32)
        c = np.asarray([5, 6], np.int32)
        t.insert(a)
        t.insert(b)
        t.match(a)                                  # refresh a; b is LRU
        t.insert(c)                                 # pool full -> evict b
        assert t.evictions == 1
        assert t.match(b) == ([], 0)
        assert t.match(a)[1] == 2 and t.match(c)[1] == 2

    def test_interior_nodes_never_evicted(self):
        t = PrefixTrie(3, block_size=2)
        t.insert(np.asarray([1, 2, 3, 4, 5, 6], np.int32))  # chain of 3
        # the two interior nodes are pinned by their child refcounts;
        # only the chain leaf is evictable
        new, _ = t.insert(np.asarray([7, 8], np.int32))
        assert len(new) == 1 and t.evictions == 1
        assert t.match(np.asarray([1, 2, 3, 4, 5, 6], np.int32))[1] == 4

    def test_eviction_follows_recency_order_exactly(self):
        """Successive evictions under sustained pressure walk the trie's
        recency order stalest-first — the contract the insertion-ordered
        O(1) LRU map must preserve from the old tick-scan implementation.
        """
        t = PrefixTrie(4, block_size=1)
        blocks = [np.asarray([v], np.int32) for v in (1, 2, 3, 4)]
        for b in blocks:
            t.insert(b)                 # recency now 1, 2, 3, 4
        t.match(blocks[1])              # -> 1, 3, 4, 2
        t.match(blocks[0])              # -> 3, 4, 2, 1
        expected_victims = [3, 4, 2, 1]
        for i, v in enumerate(expected_victims):
            t.insert(np.asarray([10 + i], np.int32))    # evicts stalest
            assert t.evictions == i + 1
            # a missed match touches nothing, so probing the victim does
            # not perturb the recency order the next round depends on
            assert t.match(np.asarray([v], np.int32)) == ([], 0)
        for i in range(4):      # the four fresh inserts all survived
            assert t.match(np.asarray([10 + i], np.int32))[1] == 1

    def test_eviction_skips_protected_path_in_order(self):
        """Under pressure from its own insert path, eviction takes the
        stalest node *not* on the path — order is preserved across the
        skip."""
        t = PrefixTrie(2, block_size=1)
        t.insert(np.asarray([1], np.int32))
        t.insert(np.asarray([2], np.int32))     # recency 1, 2
        # extending [1] needs a block; [1] itself is stalest but on the
        # protected path -> the victim is [2], the next-stalest
        new, _ = t.insert(np.asarray([1, 9], np.int32))
        assert len(new) == 1 and t.evictions == 1
        assert t.match(np.asarray([2], np.int32)) == ([], 0)
        assert t.match(np.asarray([1, 9], np.int32))[1] == 2

    def test_pool_exhausted_by_own_path_inserts_partially(self):
        t = PrefixTrie(1, block_size=2)
        new, start = t.insert(np.asarray([1, 2, 3, 4], np.int32))
        assert len(new) == 1 and start == 0         # second block dropped
        assert t.free_blocks == 0


# --------------------------------------------------------------------------
# Chunked prefill == monolithic prefill (bitwise, model level)
# --------------------------------------------------------------------------

class TestChunkedPrefillParity:
    def test_chunk_by_chunk_matches_monolithic_bitwise(self, qwen):
        """prefill_chunk over 8-token chunks reproduces api.prefill's
        logits AND cache contents bit for bit — the property that makes
        warm/cold scheduler outputs token-identical by construction."""
        cfg, api, params = qwen
        rng = np.random.default_rng(0)
        s, cache_len, ch = 21, 48, 8
        prompt = rng.integers(0, cfg.vocab, s).astype(np.int32)
        ref_logits, ref_cache = api.prefill(
            params, {"tokens": jnp.asarray(prompt)[None]}, cache_len)

        cache = api.init_cache(1, cache_len)
        padded = np.zeros(-(-s // ch) * ch, np.int32)
        padded[:s] = prompt
        last = None
        for pos in range(0, s, ch):
            logits, cache = api.prefill_chunk(
                params, jnp.asarray(padded[pos:pos + ch])[None], cache)
            true_c = min(ch, s - pos)
            last = logits[0, true_c - 1]
            cache = {**cache, "len": jnp.asarray(min(pos + ch, s), jnp.int32)}
        np.testing.assert_array_equal(np.asarray(last),
                                      np.asarray(ref_logits[0, s - 1]))
        np.testing.assert_array_equal(np.asarray(cache["k"][:, 0, :s]),
                                      np.asarray(ref_cache["k"][:, 0, :s]))
        np.testing.assert_array_equal(np.asarray(cache["v"][:, 0, :s]),
                                      np.asarray(ref_cache["v"][:, 0, :s]))

    def test_attend_prefill_cached_per_slot_offsets(self):
        """Layer level: a [B] offset vector RoPEs/scatters each lane at
        its own position — lane b of a batched chunk call equals a
        batch-1 call at lane b's offset."""
        from repro.layers import attention
        rng = jax.random.PRNGKey(0)
        n_heads, n_kv, d_head, d_model, c, s = 4, 2, 8, 32, 3, 16
        params = attention.init(rng, d_model, n_heads, n_kv, d_head)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, c, d_model),
                              jnp.float32)
        kv = attention.init_kv_cache(2, s, n_kv, d_head, dtype=jnp.float32)
        kv["k"] = jax.random.normal(jax.random.PRNGKey(2), kv["k"].shape)
        kv["v"] = jax.random.normal(jax.random.PRNGKey(3), kv["v"].shape)
        offs = jnp.asarray([2, 7], jnp.int32)
        y_vec, cache_vec = attention.attend_prefill_cached(
            params, x, {"k": kv["k"], "v": kv["v"], "len": offs},
            n_heads=n_heads, n_kv=n_kv, d_head=d_head)
        for b in range(2):
            y_b, cache_b = attention.attend_prefill_cached(
                params, x[b:b + 1],
                {"k": kv["k"][b:b + 1], "v": kv["v"][b:b + 1],
                 "len": jnp.asarray(int(offs[b]), jnp.int32)},
                n_heads=n_heads, n_kv=n_kv, d_head=d_head)
            np.testing.assert_allclose(np.asarray(y_vec[b]),
                                       np.asarray(y_b[0]), rtol=1e-5,
                                       atol=1e-5)
            np.testing.assert_array_equal(np.asarray(cache_vec["k"][b]),
                                          np.asarray(cache_b["k"][0]))


# --------------------------------------------------------------------------
# Scheduler: warm-vs-cold parity, counters, fixed programs, eviction
# --------------------------------------------------------------------------

class TestPrefixReuse:
    def _shared_prompts(self, cfg, n=4, prefix_len=24, suffix_len=6, seed=0):
        rng = np.random.default_rng(seed)
        prefix = rng.integers(0, cfg.vocab, prefix_len).astype(np.int32)
        return [np.concatenate([prefix,
                                rng.integers(0, cfg.vocab, suffix_len)
                                .astype(np.int32)])
                for _ in range(n)]

    def test_warm_cold_parity_and_saved_tokens(self, qwen):
        """The same shared-prefix batch twice through one scheduler: the
        second wave hits the trie (prefill_tokens_saved > 0) and every
        request — warm or cold — matches cold-cache serve.generate."""
        cfg, api, params = qwen
        prompts = self._shared_prompts(cfg)
        refs = [_ref_tokens(api, params, p, 4) for p in prompts]
        sched = Scheduler(api, params, max_batch=2, cache_len=64,
                          buckets=(8, 16), block_size=8)
        # wave 1: two concurrent admits against an empty trie — cold
        rids = [sched.submit(p, max_new=4) for p in prompts[:2]]
        res = sched.run()
        if not FAULT_MODE:  # a forced resume hits its own pool blocks
            assert sched.metrics.prefill_tokens_saved == 0
        for rid, ref in zip(rids, refs):
            np.testing.assert_array_equal(res[rid].tokens, ref)
        # wave 2: warm — shared prefix blocks come from the pool
        rids = [sched.submit(p, max_new=4) for p in prompts]
        res = sched.run()
        saved = sched.metrics.prefill_tokens_saved
        # all four requests hit the 24-token shared prefix (3 blocks)
        if not FAULT_MODE:  # preempt/resume adds hits, drops remove them
            assert saved == 4 * 24
        assert sched.metrics.prefix_hit_tokens >= saved
        for rid, ref in zip(rids, refs):
            np.testing.assert_array_equal(res[rid].tokens, ref)
        for rid in rids:
            assert res[rid].ttft_s > 0.0

    def test_disjoint_prompts_save_nothing(self, qwen):
        cfg, api, params = qwen
        rng = np.random.default_rng(3)
        # vocab-offset ranges guarantee no shared block between prompts
        # (and no prompt repeats, so nothing ever matches its own insert)
        prompts = [rng.integers(i * 97, i * 97 + 90, 20).astype(np.int32)
                   for i in range(1, 9)]
        sched = Scheduler(api, params, max_batch=2, cache_len=64,
                          buckets=(8, 16), block_size=8)
        rids = [sched.submit(p, max_new=3) for p in prompts]
        res = sched.run()
        assert sorted(res) == sorted(rids)
        if not FAULT_MODE:  # a forced resume matches its own insert
            assert sched.metrics.prefill_tokens_saved == 0
            assert sched.metrics.prefix_hit_tokens == 0
        assert sched.metrics.pool_inserts > 0    # cached, just unmatched

    def test_fixed_program_set_with_chunked_prefill(self, qwen):
        """Replaying shared-prefix traffic compiles nothing outside the
        {chunk, batch, block-count} bucket sets — no per-request
        retrace, hits or misses."""
        cfg, api, params = qwen
        prompts = self._shared_prompts(cfg, n=3)
        sched = Scheduler(api, params, max_batch=2, cache_len=64,
                          buckets=(8, 16), block_size=8)
        for p in prompts:
            sched.submit(p, max_new=4)
        sched.run()
        counts = sched.program_counts()
        if not FAULT_MODE:  # resume offsets can touch extra window buckets
            # chunk buckets {8, 16} x table-width buckets (pow2 blocks)
            assert counts["prefill"] <= 4
            assert counts["decode"] <= 2    # batch buckets {1, 2}
            assert counts["copy"] == 0      # zero-copy: no block movers
            assert counts["insert"] == 0
        # replay (now warm): same program set, bit for bit
        for _ in range(2):
            for p in prompts:
                sched.submit(p, max_new=4)
            sched.run()
        if not FAULT_MODE:
            assert sched.program_counts() == counts

    def test_lru_eviction_under_pool_pressure_keeps_slots_correct(self, qwen):
        """A prefix budget far smaller than the traffic's block footprint
        churns (evictions > 0) while every completion stays parity-exact
        — eviction can never corrupt a live slot because a block
        referenced by a live table carries refcount >= 2 and the trie
        only ever evicts refcount-1 leaves."""
        cfg, api, params = qwen
        rng = np.random.default_rng(5)
        prompts = [rng.integers(0, cfg.vocab, 24).astype(np.int32)
                   for _ in range(6)]
        refs = [_ref_tokens(api, params, p, 3) for p in prompts]
        sched = Scheduler(api, params, max_batch=2, cache_len=64,
                          buckets=(8, 16), block_size=8, pool_blocks=4)
        for wave in range(2):
            rids = [sched.submit(p, max_new=3) for p in prompts]
            res = sched.run()
            for rid, ref in zip(rids, refs):
                np.testing.assert_array_equal(res[rid].tokens, ref)
        assert sched.metrics.pool_evictions > 0
        assert sched.metrics.pool_inserts > 0

    def test_prefix_cache_disabled_is_cold_every_time(self, qwen):
        cfg, api, params = qwen
        prompts = self._shared_prompts(cfg, n=2)
        refs = [_ref_tokens(api, params, p, 3) for p in prompts]
        sched = Scheduler(api, params, max_batch=2, cache_len=64,
                          buckets=(8, 16), prefix_cache=False)
        for _ in range(2):
            rids = [sched.submit(p, max_new=3) for p in prompts]
            res = sched.run()
            for rid, ref in zip(rids, refs):
                np.testing.assert_array_equal(res[rid].tokens, ref)
        assert sched.metrics.prefill_tokens_saved == 0
        assert sched.program_counts()["copy"] == 0

    def test_tail_chunk_window_crossing_cache_end_stays_exact(self, qwen):
        """A prompt whose bucket-padded tail chunk crosses ``cache_len``
        must drop the dead padding rows, not clamp the scatter window
        back over valid KV (dynamic_update_slice semantics silently
        corrupted this: prompt 98, buckets (16,32,64), cache 100 -> the
        pos-64 chunk pads to [64, 128) in a 100-row cache)."""
        cfg, api, params = qwen
        rng = np.random.default_rng(11)
        p = rng.integers(0, cfg.vocab, 98).astype(np.int32)
        sched = Scheduler(api, params, max_batch=2, cache_len=100,
                          buckets=(16, 32, 64))
        rid = sched.submit(p, max_new=2)
        res = sched.run()
        np.testing.assert_array_equal(res[rid].tokens,
                                      _ref_tokens(api, params, p, 2))

    def test_insert_window_crossing_cache_end_keeps_pool_exact(self, qwen):
        """A pool insert whose bucket-padded read window crosses
        ``cache_len`` must clamp per padding row (garbage -> scratch
        block), not shift the window start (which poisoned the *real*
        blocks: A=16 tok then B=A+40 inserts 5 blocks at start=16 padded
        to 8 -> reads [16, 80) from a 64-row stripe).  C then consumes
        B's cached prefix and must stay parity-exact."""
        cfg, api, params = qwen
        rng = np.random.default_rng(12)
        a = rng.integers(0, cfg.vocab, 16).astype(np.int32)
        b = np.concatenate([a, rng.integers(0, cfg.vocab, 40)
                            .astype(np.int32)])
        c = np.concatenate([b[:48], rng.integers(0, cfg.vocab, 8)
                            .astype(np.int32)])
        sched = Scheduler(api, params, max_batch=1, cache_len=64,
                          buckets=(8, 16), block_size=8)
        rids = [sched.submit(p, max_new=3) for p in (a, b, c)]
        res = sched.run()
        if not FAULT_MODE:  # a forced drop can evict B's blocks first
            assert sched.metrics.prefill_tokens_saved > 0  # C hit B's blocks
        for rid, p in zip(rids, (a, b, c)):
            np.testing.assert_array_equal(res[rid].tokens,
                                          _ref_tokens(api, params, p, 3))

    @pytest.mark.parametrize("n", [7, 8, 9, 15, 16, 17])
    def test_warm_parity_at_block_edges(self, qwen, n):
        """Prompt lengths straddling block multiples (block_size ± 1 and
        the multiple itself): these sit on the off-by-one frontier where
        the hit cap (one block short of the prompt), the warm suffix's
        chunk offset, and the decode write block index all flip.  Warm
        and cold waves through one scheduler must both match cold
        ``serve.generate``, and the pool must audit clean after."""
        cfg, api, params = qwen
        rng = np.random.default_rng(100 + n)
        p = rng.integers(0, cfg.vocab, n).astype(np.int32)
        ref = _ref_tokens(api, params, p, 4)
        sched = Scheduler(api, params, max_batch=1, cache_len=64,
                          buckets=(8, 16), block_size=8)
        for _wave in range(2):
            rid = sched.submit(p, max_new=4)
            res = sched.run()
            np.testing.assert_array_equal(res[rid].tokens, ref)
        assert not sched.audit_blocks()
        if not FAULT_MODE and n > 8:
            # the second wave hit the prompt's full blocks, capped one
            # block short: ((n - 1) // 8) * 8 tokens served from the pool
            assert sched.metrics.prefill_tokens_saved == ((n - 1) // 8) * 8

    def test_sequence_filling_cache_to_last_row(self, qwen):
        """prompt + max_new == cache_len: the run's final decode write
        lands in the last row of the slot's last table block — one
        position past would index off the table entirely (the paged
        twin of the dense straddle-``cache_len`` regressions)."""
        cfg, api, params = qwen
        rng = np.random.default_rng(13)
        p = rng.integers(0, cfg.vocab, 60).astype(np.int32)
        sched = Scheduler(api, params, max_batch=1, cache_len=64,
                          buckets=(8, 16), block_size=8)
        rid = sched.submit(p, max_new=4)
        res = sched.run()
        np.testing.assert_array_equal(res[rid].tokens,
                                      _ref_tokens(api, params, p, 4))
        assert not sched.audit_blocks()

    def test_metrics_dataclass_contract(self, qwen):
        """SchedulerMetrics: dict-style reads, to_dict round-trip, and
        unknown keys rejected."""
        from repro.serve import SchedulerMetrics
        m = SchedulerMetrics()
        m["chunks"] = 3
        assert m.chunks == 3 == m["chunks"]
        d = m.to_dict()
        assert d["chunks"] == 3 and "prefill_tokens_saved" in d
        with pytest.raises(KeyError):
            m["no_such_counter"] = 1

    def test_prefill_interleaves_with_decode(self, qwen):
        """Co-scheduling: while one slot decodes a long output, a newly
        admitted long prompt advances chunk-by-chunk across steps —
        decode emission and chunk dispatch appear in the same steps."""
        cfg, api, params = qwen
        rng = np.random.default_rng(8)
        a = rng.integers(0, cfg.vocab, 5).astype(np.int32)
        b = rng.integers(0, cfg.vocab, 40).astype(np.int32)
        sched = Scheduler(api, params, max_batch=2, cache_len=64,
                          buckets=(8,), horizon=2, prefix_cache=False)
        ra = sched.submit(a, max_new=24)
        sched.step()                     # a prefills + starts decoding
        rb = sched.submit(b, max_new=4)
        interleaved = 0
        while True:
            c0 = sched.metrics.chunks
            d0 = sched.metrics.decode_lanes
            busy = sched.step()
            if (sched.metrics.chunks > c0
                    and sched.metrics.decode_lanes > d0):
                interleaved += 1
            if not busy:
                break
        res = sched.pop_results()
        # b's 40-token prompt takes 5 chunk dispatches at bucket 8; each
        # rides a step that also emitted decode tokens for a
        if not FAULT_MODE:  # a forced preempt of `a` breaks the overlap
            assert interleaved >= 4
        np.testing.assert_array_equal(res[ra].tokens,
                                      _ref_tokens(api, params, a, 24))
        np.testing.assert_array_equal(res[rb].tokens,
                                      _ref_tokens(api, params, b, 4))
