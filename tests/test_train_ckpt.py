"""Optimizer, loss, data determinism, checkpoint fault-tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ckpt as ckptlib
from repro.train import adamw, apply_updates, cosine_warmup, cross_entropy, sgd


class TestOptim:
    def test_adamw_converges_quadratic(self):
        opt = adamw(0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = opt.init(params)
        target = jnp.asarray([1.0, 1.0])
        for step in range(150):
            g = {"w": 2 * (params["w"] - target)}
            upd, state, _ = opt.update(g, state, params, jnp.asarray(step))
            params = apply_updates(params, upd)
        np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0],
                                   atol=1e-2)

    def test_sgd_momentum(self):
        opt = sgd(0.05, momentum=0.9)
        params = {"w": jnp.asarray(4.0)}
        state = opt.init(params)
        for step in range(200):
            g = {"w": 2 * params["w"]}
            upd, state, _ = opt.update(g, state, params, jnp.asarray(step))
            params = apply_updates(params, upd)
        assert abs(float(params["w"])) < 5e-2

    def test_grad_clip(self):
        opt = adamw(1e-3, grad_clip=1.0)
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
        _, _, metrics = opt.update(g, state, params, jnp.asarray(0))
        assert float(metrics["grad_norm"]) == pytest.approx(100.0)

    def test_cosine_warmup(self):
        sched = cosine_warmup(1.0, warmup=10, total=110)
        assert float(sched(jnp.asarray(0))) == 0.0
        assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(sched(jnp.asarray(110))) == pytest.approx(0.0, abs=1e-6)

    def test_weight_decay_applied(self):
        opt = adamw(1e-2, weight_decay=10.0)
        params = {"w": jnp.asarray(1.0)}
        state = opt.init(params)
        upd, _, _ = opt.update({"w": jnp.asarray(0.0)}, state, params,
                               jnp.asarray(0))
        assert float(upd["w"]) < 0  # pure decay pulls toward zero


class TestLoss:
    def test_cross_entropy_matches_manual(self):
        logits = jnp.asarray([[[2.0, 0.0, -1.0], [0.5, 0.5, 0.5]]])
        labels = jnp.asarray([[0, 2]])
        got = float(cross_entropy(logits, labels))
        lp = jax.nn.log_softmax(logits, -1)
        want = -float(lp[0, 0, 0] + lp[0, 1, 2]) / 2
        assert got == pytest.approx(want, rel=1e-6)

    def test_ignore_mask(self):
        logits = jnp.zeros((1, 3, 4))
        labels = jnp.asarray([[1, -1, -1]])
        got = float(cross_entropy(logits, labels))
        assert got == pytest.approx(np.log(4.0), rel=1e-6)


class TestData:
    def test_deterministic_and_distinct(self):
        from repro.configs import ARCHS
        from repro.data import batch_for
        cfg = ARCHS["qwen2-0.5b"].reduced()
        a = batch_for(cfg, 7, 4, 16)
        b = batch_for(cfg, 7, 4, 16)
        c = batch_for(cfg, 8, 4, 16)
        assert (np.asarray(a["tokens"]) == np.asarray(b["tokens"])).all()
        assert not (np.asarray(a["tokens"]) == np.asarray(c["tokens"])).all()
        # labels are next-token shifted
        full = batch_for(cfg, 7, 4, 16)
        assert (np.asarray(full["labels"][:, :-1])
                == np.asarray(full["tokens"][:, 1:])).all()


class TestCheckpoint:
    def _tree(self, seed=0):
        rng = np.random.default_rng(seed)
        return {"a": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32),
                "b": {"c": jnp.asarray(rng.integers(0, 9, 5), jnp.int32)}}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        ckptlib.save(str(tmp_path), 3, tree, extra={"k": "v"})
        out, man = ckptlib.restore(str(tmp_path), 3, tree)
        assert man["extra"]["k"] == "v"
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_resume_latest_and_gc(self, tmp_path):
        tree = self._tree()
        for step in (1, 2, 3, 4, 5):
            ckptlib.save(str(tmp_path), step, tree, keep=2)
        assert ckptlib.latest_step(str(tmp_path)) == 5
        kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
        assert kept == ["step_00000004", "step_00000005"]
        out, man = ckptlib.resume_latest(str(tmp_path), tree)
        assert man["step"] == 5

    def test_crash_mid_save_ignored(self, tmp_path):
        """A leftover .tmp dir (simulated crash) is invisible to restore
        and garbage-collected by the next save."""
        tree = self._tree()
        ckptlib.save(str(tmp_path), 1, tree)
        os.makedirs(tmp_path / "step_00000002.tmp")
        (tmp_path / "step_00000002.tmp" / "junk").write_text("partial")
        assert ckptlib.latest_step(str(tmp_path)) == 1
        ckptlib.save(str(tmp_path), 3, tree)
        assert not (tmp_path / "step_00000002.tmp").exists()

    def test_config_drift_detected(self, tmp_path):
        ckptlib.save(str(tmp_path), 1, self._tree())
        other = {"a": jnp.zeros((5, 5)), "b": {"c": jnp.zeros(5, jnp.int32)}}
        with pytest.raises(ValueError, match="tree hash"):
            ckptlib.restore(str(tmp_path), 1, other)

    def test_restore_into_dtype(self, tmp_path):
        """Restore targets the dtype of `like` (mesh/dtype-independent)."""
        tree = self._tree()
        ckptlib.save(str(tmp_path), 1, tree)
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        out, _ = ckptlib.restore(str(tmp_path), 1, like)
        assert out["a"].dtype == np.float32

    def test_train_state_roundtrip(self, tmp_path):
        from repro.configs import ARCHS
        from repro.models import build_model
        from repro.train import init_state
        cfg = ARCHS["qwen2-0.5b"].reduced()
        api = build_model(cfg)
        opt = adamw(1e-3)
        state = init_state(api, opt, jax.random.PRNGKey(0))
        ckptlib.save(str(tmp_path), 10, state)
        out, man = ckptlib.resume_latest(str(tmp_path), state)
        assert man["step"] == 10
        for x, y in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
