"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + NaN assertions (full configs are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, runnable_shapes
from repro.data import batch_for
from repro.models import build_model
from repro.train import adamw, init_state, make_train_step

ALL_ARCHS = sorted(ARCHS)


def make_batch(cfg, b, s):
    return batch_for(cfg, 0, b, s)


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_forward_smoke(arch_id):
    cfg = ARCHS[arch_id].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32)
    kw = {} if cfg.family == "ssm_xlstm" else dict(q_chunk=16, kv_chunk=16)
    logits, aux = api.forward(params, batch, **kw)
    s_out = 32 - (cfg.vision_patches if cfg.family == "vlm" else 0)
    s_out += cfg.vision_patches if cfg.family == "vlm" else 0  # logits cover patches too
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert not bool(jnp.isnan(logits).any()), arch_id
    assert np.isfinite(float(aux["moe_aux"]))


@pytest.mark.parametrize("arch_id", [a for a in ALL_ARCHS
                                     if ARCHS[a].has_decode])
def test_decode_smoke(arch_id):
    cfg = ARCHS[arch_id].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache = api.init_cache(2, 16, dtype=jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(3):
        logits, cache = api.decode_step(params, tok, cache)
        assert logits.shape == (2, cfg.vocab)
        assert not bool(jnp.isnan(logits).any()), arch_id
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert int(cache["len"]) == 3


@pytest.mark.parametrize("arch_id", ["qwen2-0.5b", "olmoe-1b-7b",
                                     "zamba2-7b", "xlstm-125m",
                                     "hubert-xlarge", "phi-3-vision-4.2b"])
def test_train_step_smoke(arch_id):
    """One family member per forward path: a jitted train step runs, loss
    is finite, params change."""
    cfg = ARCHS[arch_id].reduced()
    api = build_model(cfg)
    opt = adamw(1e-3)
    state = init_state(api, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(api, opt, q_chunk=16, kv_chunk=16))
    batch = make_batch(cfg, 4, 32)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state.step) == 1
    # at least one parameter leaf moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(new_state.params)))
    assert moved


def test_loss_decreases_qwen():
    """A few steps of training on the synthetic stream reduce the loss."""
    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    opt = adamw(3e-3)
    state = init_state(api, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(api, opt, q_chunk=16, kv_chunk=16))
    losses = []
    for i in range(10):
        state, m = step(state, batch_for(cfg, i % 2, 8, 32))
        losses.append(float(m["loss"]))
    assert min(losses[-3:]) < losses[0]


def test_microbatch_equivalence():
    """n_microbatches=4 gives the same update as n_microbatches=1."""
    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    opt = adamw(1e-3)
    batch = make_batch(cfg, 8, 16)
    outs = []
    for n_micro in (1, 4):
        state = init_state(api, opt, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(api, opt, n_microbatches=n_micro,
                                       dtype=jnp.float32, remat=False,
                                       q_chunk=8, kv_chunk=8))
        new_state, m = step(state, batch)
        outs.append(new_state.params)
    a, b = (jax.tree.leaves(o) for o in outs)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-3, atol=2e-5)


def test_runnable_shapes_matrix():
    """The mandated skip rules produce exactly the 31-cell matrix."""
    cells = [(cfg.arch_id, s.name) for cfg in ARCHS.values()
             for s in runnable_shapes(cfg)]
    assert len(cells) == 31
    # encoder: no decode cells
    assert ("hubert-xlarge", "decode_32k") not in cells
    assert ("hubert-xlarge", "long_500k") not in cells
    # full-attention archs skip long_500k
    for a in ("qwen2-0.5b", "mistral-nemo-12b", "granite-20b", "granite-34b",
              "moonshot-v1-16b-a3b", "olmoe-1b-7b", "phi-3-vision-4.2b"):
        assert (a, "long_500k") not in cells
    # sub-quadratic archs run it
    assert ("zamba2-7b", "long_500k") in cells
    assert ("xlstm-125m", "long_500k") in cells


def test_param_counts_match_published_class():
    """Analytical param counts are in the right ballpark of the arch names."""
    # moonshot: the assignment pins 48L x 64e x d_ff=1408, which totals
    # ~28B (Moonlight's published 16B assumes 27 layers) — the assigned
    # dims are authoritative; noted in DESIGN.md §4.
    expect = {"qwen2-0.5b": (0.3e9, 0.8e9), "mistral-nemo-12b": (10e9, 14e9),
              "granite-20b": (18e9, 23e9), "granite-34b": (32e9, 38e9),
              "olmoe-1b-7b": (6e9, 8e9), "moonshot-v1-16b-a3b": (25e9, 30e9),
              "zamba2-7b": (6e9, 9e9), "xlstm-125m": (0.1e9, 0.2e9),
              "hubert-xlarge": (0.8e9, 1.2e9),
              "phi-3-vision-4.2b": (3.5e9, 4.6e9)}
    for arch_id, (lo, hi) in expect.items():
        n = ARCHS[arch_id].param_count()
        assert lo <= n <= hi, (arch_id, n)
    # MoE active params well below total
    moe = ARCHS["olmoe-1b-7b"]
    assert moe.active_param_count() < 0.4 * moe.param_count()
