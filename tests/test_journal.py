"""Durable request journal (serve.journal, DESIGN.md §5.1): record
framing and replay semantics, torn-tail truncation at *every* byte
offset (a SIGKILL can land mid-write anywhere), cold-restart recovery
through ``Supervisor.start`` with greedy-token-identical resumes, and
the structured per-request JSONL log."""
import json
import os
import struct

import numpy as np
import pytest

from repro.serve.journal import Journal, RequestLog

# ---------------------------------------------------------------------
# Pure journal semantics (no model, no jax)
# ---------------------------------------------------------------------


def _write_reference(path, *, fsync="none"):
    """A small but representative record sequence; returns the journal's
    record dicts in append order (for boundary bookkeeping)."""
    j = Journal(path, fsync=fsync)
    j.append_submit(0, [5, 6, 7], max_new=8, eos_id=None, deadline_s=None,
                    priority=0, tenant="acme", submitted_s=1.0,
                    idem_key="key-0")
    j.append_submit(1, [9, 10], max_new=4, eos_id=2, deadline_s=3.5,
                    priority=1, tenant=None, submitted_s=1.1)
    j.append_tokens(0, 0, [11, 12], [-0.5, -0.25])
    j.append_tokens(1, 0, [13], [-1.0])
    # re-decode after a crash overwrites the same indices
    j.append_tokens(0, 1, [12, 14], [-0.25, -0.125])
    j.append_terminal(1, status="completed", reason="", prompt_len=2,
                      tokens=[13, 15], logprobs=[-1.0, -0.75],
                      ttft_s=0.01, queue_s=0.002, tenant=None)
    j.commit()
    j.close()
    return j


class TestReplaySemantics:
    def test_round_trip(self, tmp_path):
        _write_reference(str(tmp_path))
        j = Journal(str(tmp_path))
        rep = j.replay
        assert rep.records == 6 and rep.truncated_bytes == 0
        assert rep.next_rid == 2
        assert set(rep.outstanding) == {0}
        req = rep.outstanding[0]
        assert req["prompt"] == [5, 6, 7] and req["tenant"] == "acme"
        # tokens records applied with overwrite-at-start semantics
        assert req["tokens"] == [11, 12, 14]
        assert req["logprobs"] == [-0.5, -0.25, -0.125]
        assert set(rep.terminals) == {1}
        assert rep.terminals[1]["tokens"] == [13, 15]
        assert rep.idempotency == {"key-0": 0}
        assert rep.replay_ms >= 0.0
        j.close()

    def test_terminal_clears_outstanding_and_binds_idem(self, tmp_path):
        j = Journal(str(tmp_path), fsync="none")
        j.append_submit(3, [1], max_new=2, eos_id=None, deadline_s=None,
                        priority=0, tenant=None, submitted_s=0.0)
        j.append_terminal(3, status="completed", reason="", prompt_len=1,
                          tokens=[7], logprobs=[-0.1], ttft_s=0.0,
                          idem_key="late-key")
        j.close()
        rep = Journal(str(tmp_path)).replay
        assert rep.outstanding == {} and set(rep.terminals) == {3}
        assert rep.idempotency == {"late-key": 3}
        assert rep.next_rid == 4

    def test_unknown_rid_tokens_tolerated(self, tmp_path):
        j = Journal(str(tmp_path), fsync="none")
        j.append_tokens(42, 0, [1, 2], [-0.1, -0.2])
        j.append_terminal(43, status="shed", reason="queue-full",
                          prompt_len=0, tokens=[], logprobs=[], ttft_s=0.0)
        j.close()
        rep = Journal(str(tmp_path)).replay
        assert rep.outstanding == {}
        assert set(rep.terminals) == {43}
        assert rep.next_rid == 44      # terminals advance the high-water

    def test_bad_fsync_policy_rejected(self, tmp_path):
        with pytest.raises(ValueError, match="fsync"):
            Journal(str(tmp_path), fsync="sometimes")

    def test_segment_rotation_and_idle_compaction(self, tmp_path):
        j = Journal(str(tmp_path), fsync="none", segment_bytes=128)
        for rid in range(8):
            j.append_submit(rid, [1, 2, 3, 4], max_new=4, eos_id=None,
                            deadline_s=None, priority=0, tenant=None,
                            submitted_s=0.0)
            j.commit()
        assert j.segments() > 1        # rotated past the tiny budget
        for rid in range(8):
            j.append_terminal(rid, status="completed", reason="",
                              prompt_len=4, tokens=[9], logprobs=[-0.1],
                              ttft_s=0.0)
        j.commit(idle=True)            # nothing outstanding: compact
        assert j.segments() == 1 and j.total_bytes() == 0
        rep = Journal(str(tmp_path)).replay
        assert rep.records == 0 and rep.outstanding == {}
        j.close()


class TestTornTail:
    def test_truncation_at_every_byte_offset(self, tmp_path):
        """A kill can land mid-write at any byte.  For every prefix
        length the journal must open cleanly, keep exactly the records
        fully contained in the prefix (losing at most the torn last
        one), cut the file back to the last good boundary, and replay
        to the state obtained by applying just the kept records."""
        ref_dir = tmp_path / "ref"
        _write_reference(str(ref_dir))
        (seg,) = [os.path.join(str(ref_dir), n)
                  for n in os.listdir(str(ref_dir))]
        blob = open(seg, "rb").read()
        full = Journal(str(ref_dir))
        # record boundaries, from a clean replay of the intact file
        bounds = [0]
        records = []
        off = 0
        while off < len(blob):
            (ln,) = struct.unpack_from("<I", blob, off)
            records.append(json.loads(
                blob[off + 8:off + 8 + ln].decode()))
            off += 8 + ln
            bounds.append(off)
        assert len(records) == full.replay.records
        full.close()

        for cut in range(len(blob) + 1):
            d = tmp_path / f"cut-{cut}"
            os.makedirs(str(d))
            with open(os.path.join(str(d), os.path.basename(seg)),
                      "wb") as f:
                f.write(blob[:cut])
            j = Journal(str(d))
            n_keep = sum(1 for b in bounds[1:] if b <= cut)
            good = bounds[n_keep]
            assert j.replay.records == n_keep, f"cut={cut}"
            assert j.replay.truncated_bytes == cut - good, f"cut={cut}"
            # the torn bytes are gone from disk: reopening is clean
            j.close()
            j2 = Journal(str(d))
            assert j2.replay.records == n_keep
            assert j2.replay.truncated_bytes == 0
            # replayed state == state from exactly the kept records
            out, term, idem = {}, {}, {}
            for rec in records[:n_keep]:
                Journal._apply(rec, out, term, idem)
            assert j2.replay.outstanding == out, f"cut={cut}"
            assert j2.replay.terminals == term, f"cut={cut}"
            assert j2.replay.idempotency == idem, f"cut={cut}"
            j2.close()

    def test_corrupt_middle_drops_tail_and_later_segments(self, tmp_path):
        j = Journal(str(tmp_path), fsync="none", segment_bytes=96)
        for rid in range(6):
            j.append_submit(rid, [1, 2], max_new=2, eos_id=None,
                            deadline_s=None, priority=0, tenant=None,
                            submitted_s=0.0)
            j.commit()
        segs = sorted(os.listdir(str(tmp_path)))
        assert len(segs) >= 2
        j.close()
        # flip a payload byte early in the first segment
        first = os.path.join(str(tmp_path), segs[0])
        blob = bytearray(open(first, "rb").read())
        blob[10] ^= 0xFF
        open(first, "wb").write(bytes(blob))
        rep = Journal(str(tmp_path)).replay
        # everything from the corrupt record on is dropped, including
        # the later segments (they may depend on the lost records)
        assert rep.records == 0
        assert sorted(os.listdir(str(tmp_path)))[0] == segs[0]
        assert len([n for n in os.listdir(str(tmp_path))
                    if n.startswith("wal-")]) == 1


class TestRequestLog:
    def test_one_line_per_terminal(self, tmp_path):
        import dataclasses

        from repro.serve.scheduler import Completion

        path = str(tmp_path / "requests.jsonl")
        log = RequestLog(path)
        comp = Completion(rid=7, prompt_len=3,
                          tokens=np.asarray([1, 2], np.int32),
                          logprobs=np.asarray([-0.5, -0.25], np.float32),
                          n_steps=2, ttft_s=0.125, status="completed",
                          reason="", tenant="acme", queue_s=0.5)
        log.log(comp)
        log.log(dataclasses.replace(comp, rid=8, status="shed",
                                    reason="queue-full"))
        log.close()
        lines = [json.loads(ln) for ln in open(path)]
        assert [ln["rid"] for ln in lines] == [7, 8]
        assert lines[0]["tenant"] == "acme"
        assert lines[0]["status"] == "completed"
        assert lines[0]["tokens"] == 2
        assert lines[0]["ttft_s"] == 0.125
        assert lines[0]["queue_s"] == 0.5
        assert lines[1]["reason"] == "queue-full"
        assert all("ts" in ln for ln in lines)


# ---------------------------------------------------------------------
# Cold-restart recovery through the scheduler + supervisor
# ---------------------------------------------------------------------


@pytest.fixture(scope="module")
def qwen():
    import jax

    from repro.configs import ARCHS
    from repro.models import build_model

    cfg = ARCHS["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _sched(api, params, journal, **kw):
    from repro.serve import Scheduler

    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", 64)
    kw.setdefault("buckets", (8, 16))
    kw.setdefault("block_size", 8)
    kw.setdefault("stream_tokens", True)
    kw.setdefault("faults", False)
    return Scheduler(api, params, journal=journal, **kw)


def _ref_tokens(api, params, prompt, max_new):
    import jax

    from repro.serve import generate

    out = generate(api, params, jax.numpy.asarray(prompt)[None],
                   max_new=max_new)
    return np.asarray(out["tokens"][0])


def _prompts(cfg, n, seed=0, size=8):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size).astype(np.int32)
            for _ in range(n)]


class TestColdRestart:
    def test_mid_stream_death_resumes_token_identical(self, qwen, tmp_path):
        """Kill the first supervisor mid-decode (no drain, no snapshot
        handoff); a second scheduler+supervisor on the same journal
        directory must re-admit the outstanding requests and finish
        every stream greedy-token-identically, audit clean."""
        from repro.serve import FaultInjector, Supervisor
        from test_supervisor import Collector

        cfg, api, params = qwen
        p1, p2 = _prompts(cfg, 2, seed=11)
        jdir = str(tmp_path / "journal")

        sched1 = _sched(api, params, Journal(jdir, fsync="record"),
                        faults=FaultInjector(0, delay_p=1.0,
                                             max_delay_s=0.05))
        sup1 = Supervisor(sched1).start()
        col1 = Collector()
        r1 = sup1.submit(p1, max_new=24, on_event=col1,
                         idempotency_key="cold-1")
        r2 = sup1.submit(p2, max_new=16, on_event=col1)
        assert col1.first_token.wait(60.0)
        # process death: abandon the supervisor mid-flight; only what
        # the journal already holds survives
        sup1.stop(drain=False)
        sched1.journal.close()
        partial = {rid: [t for _, t in col1.tokens.get(rid, [])]
                   for rid in (r1, r2)}
        assert any(partial.values())

        sched2 = _sched(api, params, Journal(jdir, fsync="record"))
        sup2 = Supervisor(sched2).start()
        try:
            assert sup2.replayed == 2 and sup2.replay_ms >= 0.0
            # the idempotency binding survived the restart
            assert sup2.idempotent_rid("cold-1") == r1
            col2 = Collector()
            assert sup2.attach(r1, col2)
            assert sup2.attach(r2, col2)
            for rid, p, m in ((r1, p1, 24), (r2, p2, 16)):
                comp = col2.wait_done(rid)
                assert comp.status == "completed"
                ref = _ref_tokens(api, params, p, m)
                np.testing.assert_array_equal(comp.tokens, ref)
                # the reattached stream saw every index exactly once —
                # including the tokens generated before the death
                assert [i for i, _ in col2.tokens[rid]] == list(range(m))
                assert [t for _, t in col2.tokens[rid]] == \
                    [int(t) for t in ref]
                assert len(col2.done[rid]) == 1
                # what the first process delivered is a prefix of it
                assert partial[rid] == \
                    [int(t) for t in ref[:len(partial[rid])]]
            assert sup2.wait_idle(60.0)
            assert sched2.audit_blocks() == []
            # fresh submits never collide with replayed rids
            col3 = Collector()
            r3 = sup2.submit(p1, max_new=4, on_event=col3)
            assert r3 not in (r1, r2)
            col3.wait_done(r3)
        finally:
            sup2.stop(drain=False)
            sched2.journal.close()

    def test_finished_rid_replays_terminal_after_restart(self, qwen,
                                                         tmp_path):
        from repro.serve import Supervisor
        from test_supervisor import Collector

        cfg, api, params = qwen
        (p,) = _prompts(cfg, 1, seed=12)
        jdir = str(tmp_path / "journal")

        sched1 = _sched(api, params, Journal(jdir, fsync="record"))
        sup1 = Supervisor(sched1).start()
        col1 = Collector()
        rid = sup1.submit(p, max_new=6, on_event=col1)
        comp1 = col1.wait_done(rid)
        sup1.stop(drain=False)
        sched1.journal.close()

        sched2 = _sched(api, params, Journal(jdir, fsync="record"))
        sup2 = Supervisor(sched2).start()
        try:
            assert sup2.replayed == 0          # nothing was outstanding
            col2 = Collector()
            assert sup2.attach(rid, col2)      # replays the Completion
            comp2 = col2.wait_done(rid, timeout=5.0)
            assert comp2.status == "completed"
            np.testing.assert_array_equal(comp2.tokens, comp1.tokens)
            assert [t for _, t in col2.tokens[rid]] == \
                [int(t) for t in comp1.tokens]
            assert not sup2.attach(99999, col2)    # unknown rid
        finally:
            sup2.stop(drain=False)
            sched2.journal.close()

    def test_truncated_journal_replays_to_consistent_scheduler_state(
            self, qwen, tmp_path):
        """The scheduler half of the torn-tail property: cut the
        journal at a handful of offsets (every record boundary plus
        mid-record cuts) and require each prefix to restore into a
        scheduler that finishes cleanly with a clean block audit."""
        from repro.serve import Supervisor
        from test_supervisor import Collector

        cfg, api, params = qwen
        p1, p2 = _prompts(cfg, 2, seed=13)
        jdir = str(tmp_path / "journal")
        sched1 = _sched(api, params, Journal(jdir, fsync="record"))
        sup1 = Supervisor(sched1).start()
        col1 = Collector()
        sup1.submit(p1, max_new=8, on_event=col1)
        rid2 = sup1.submit(p2, max_new=8, on_event=col1)
        col1.wait_done(rid2)
        sup1.stop(drain=False)
        sched1.journal.close()
        (seg,) = [os.path.join(jdir, n) for n in os.listdir(jdir)]
        blob = open(seg, "rb").read()

        rng = np.random.default_rng(13)
        cuts = sorted({0, len(blob), *rng.integers(
            1, len(blob), size=6).tolist()})
        for cut in cuts:
            d = str(tmp_path / f"cut-{cut}")
            os.makedirs(d)
            with open(os.path.join(d, os.path.basename(seg)), "wb") as f:
                f.write(blob[:cut])
            sched = _sched(api, params, Journal(d, fsync="record"))
            sup = Supervisor(sched).start()
            try:
                cols = Collector()
                for rid in list(sched.outstanding_rids()):
                    assert sup.attach(rid, cols)
                    comp = cols.wait_done(rid)
                    assert comp.status == "completed", f"cut={cut}"
                assert sup.wait_idle(60.0)
                assert sched.audit_blocks() == [], f"cut={cut}"
            finally:
                sup.stop(drain=False)
                sched.journal.close()
