"""Deterministic synthetic data pipeline (stateless, step-indexed).

Every batch is a pure function of (seed, step) — there is no iterator
state to checkpoint, restarts are exact, and elastic rescaling (different
host count or batch slicing) re-derives identical global batches.  This is
the property that makes the fault-tolerance story exact rather than
approximate; a real deployment swaps ``synth_lm_batch`` for a deterministic
tokenized-shard reader with the same (seed, step) -> batch contract.

The token stream is a order-3 LCG mixture with local structure (repeated
n-grams) so a small LM actually learns on it — loss decreases — which the
end-to-end example and the trained-weight CREW analysis rely on.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

__all__ = ["synth_lm_batch", "synth_encoder_batch", "synth_vlm_batch",
           "batch_for"]


def _tokens(key, batch: int, seq: int, vocab: int) -> jnp.ndarray:
    """Structured synthetic tokens: Markov-ish stream, learnable."""
    k1, k2, k3 = jax.random.split(key, 3)
    base = jax.random.randint(k1, (batch, seq), 0, vocab)
    # inject bigram structure: with p=0.5, token t+1 = f(token t)
    nxt = (base * 131 + 7) % vocab
    coin = jax.random.bernoulli(k2, 0.5, (batch, seq))
    toks = jnp.where(coin, jnp.roll(nxt, 1, axis=1), base)
    # occasional repeated spans make induction heads learnable
    rep = jnp.roll(toks, seq // 4, axis=1)
    coin2 = jax.random.bernoulli(k3, 0.15, (batch, 1))
    return jnp.where(coin2, rep, toks).astype(jnp.int32)


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def synth_lm_batch(key, batch: int, seq: int, vocab: int) -> Dict[str, jnp.ndarray]:
    toks = _tokens(key, batch, seq + 1, vocab)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4))
def synth_encoder_batch(key, batch: int, seq: int, d_model: int, vocab: int):
    k1, k2 = jax.random.split(key)
    frames = jax.random.normal(k1, (batch, seq, d_model), jnp.float32)
    labels = jax.random.randint(k2, (batch, seq), 0, vocab).astype(jnp.int32)
    return {"frames": frames, "labels": labels}


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4, 5))
def synth_vlm_batch(key, batch: int, seq: int, patches: int, d_model: int,
                    vocab: int):
    k1, k2 = jax.random.split(key)
    lm = synth_lm_batch(k2, batch, seq - patches, vocab)
    return {
        "tokens": lm["tokens"],
        "patches": jax.random.normal(k1, (batch, patches, d_model), jnp.float32),
        "labels": lm["labels"],
    }


def batch_for(cfg, step: int, batch: int, seq: int, *, seed: int = 0):
    """The (seed, step) -> batch contract, family-dispatching."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    if cfg.family == "encoder":
        return synth_encoder_batch(key, batch, seq, cfg.d_model, cfg.vocab)
    if cfg.family == "vlm":
        return synth_vlm_batch(key, batch, seq, cfg.vision_patches,
                               cfg.d_model, cfg.vocab)
    return synth_lm_batch(key, batch, seq, cfg.vocab)
