"""Cell builder: one (architecture x input-shape x mesh x mode) dry-run unit.

A *cell* bundles everything ``dryrun.py`` needs to lower+compile one entry
of the assignment matrix:

    fn          — the step function (train_step / prefill forward / decode)
    args        — abstract ShapeDtypeStruct arguments (params, batch, cache)
    in_shard    — NamedSharding tree resolved from the logical specs
    out_shard   — NamedSharding tree (or None -> GSPMD-chosen)
    donate      — argnums to donate (train state / decode cache)

Modes:
    dense — bf16 dense weights (serve) / f32 master weights (train).
    crew  — CREW-compressed weights (serve cells): packed uint32 index
            words + bf16 unique tables, sharded exactly like the dense
            weights they replace.  Training always runs dense (CREW is a
            post-training format, §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import SHAPES_BY_NAME, get_config, runnable_shapes
from ..configs.base import ModelConfig, ShapeConfig
from ..dist.ctx import sharding_ctx
from ..dist.sharding import (SERVE_RULES, TRAIN_RULES, TRAIN_RULES_DP,
                             named_sharding_tree)
from ..models import ModelApi, build_model
from ..serve.convert import abstract_crew_params, crewize_spec
from ..train import TrainState, adamw, cosine_warmup, make_train_step

__all__ = ["Cell", "make_cell", "batch_spec"]

# Default training knobs for the dry-run (production-shaped, per DESIGN.md):
# 8 microbatches of grad accumulation; selective remat; bf16 activations.
TRAIN_MICROBATCHES = 8
CREW_ASSUMED_WIDTH = 6  # measured network-wide max index width (8-bit quant)


@dataclasses.dataclass
class Cell:
    cfg: ModelConfig
    shape: ShapeConfig
    mode: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shard: Tuple[Any, ...]
    out_shard: Any
    donate: Tuple[int, ...]
    static: Dict[str, Any]
    mesh: Any = None
    rules: Any = None

    def jitted(self):
        fn = self.fn
        if self.mesh is not None:
            mesh, rules, inner = self.mesh, self.rules, self.fn

            def fn(*args):
                # activation sharding constraints resolve at trace time
                with sharding_ctx(mesh, rules):
                    return inner(*args)

        return jax.jit(fn, in_shardings=self.in_shard,
                       out_shardings=self.out_shard,
                       donate_argnums=self.donate)


def batch_spec(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, P]:
    """Logical PartitionSpec tree for the input batch of a cell."""
    if shape.kind == "decode":
        return {"tokens": P("batch", None)}
    spec: Dict[str, P] = {}
    if cfg.family == "encoder":
        spec["frames"] = P("batch", "seq", None)
    else:
        spec["tokens"] = P("batch", "seq")
        if cfg.family == "vlm":
            spec["patches"] = P("batch", "seq", None)
    if shape.kind == "train":
        spec["labels"] = P("batch", "seq")
    return spec


def _opt_spec(param_spec):
    return {"mu": param_spec, "nu": param_spec}


def _replicated(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def _train_cell(api: ModelApi, shape: ShapeConfig, mesh: Mesh,
                n_micro: int, variant: str = "base") -> Cell:
    cfg = api.cfg
    # variant "opt": DP-first rules — batch claims all mesh axes; right for
    # models whose head/ff dims fight 16-way TP (§Perf iteration B).  Grad
    # accumulation off so the full global batch covers the device count
    # (micro-batching would drop the per-step batch below 256 and strand
    # the model axis again).
    rules = TRAIN_RULES_DP if variant == "opt" else TRAIN_RULES
    if variant == "opt":
        n_micro = 1
    opt = adamw(cosine_warmup(3e-4, 2000, 100_000))
    step_fn = make_train_step(api, opt, n_microbatches=n_micro,
                              dtype=jnp.bfloat16, remat=True)

    params_abs = api.abstract_params(dtype=jnp.float32)
    state_abs = TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        params=params_abs,
        opt=jax.eval_shape(opt.init, params_abs),
    )
    batch_abs = api.input_specs(shape, dtype=jnp.float32)

    p_spec = api.param_spec()
    state_spec = TrainState(step=P(), params=p_spec, opt=_opt_spec(p_spec))
    state_shard = named_sharding_tree(state_spec, state_abs, mesh, rules)
    batch_shard = named_sharding_tree(batch_spec(cfg, shape), batch_abs,
                                      mesh, rules)

    metrics_abs = jax.eval_shape(step_fn, state_abs, batch_abs)[1]
    out_shard = (state_shard, _replicated(mesh, metrics_abs))

    return Cell(cfg=cfg, shape=shape, mode="dense", fn=step_fn,
                args=(state_abs, batch_abs),
                in_shard=(state_shard, batch_shard), out_shard=out_shard,
                donate=(0,), static={"n_microbatches": n_micro},
                mesh=mesh, rules=rules)


def _serve_params(api: ModelApi, mode: str, mesh: Mesh):
    params_abs = api.abstract_params(dtype=jnp.bfloat16)
    p_spec = api.param_spec()
    if mode == "crew":
        params_abs = abstract_crew_params(params_abs,
                                          width=CREW_ASSUMED_WIDTH,
                                          pad_words_to=16)
        p_spec = crewize_spec(p_spec, params_abs)
    shard = named_sharding_tree(p_spec, params_abs, mesh, SERVE_RULES)
    return params_abs, shard


def _prefill_cell(api: ModelApi, shape: ShapeConfig, mesh: Mesh,
                  mode: str, variant: str = "base") -> Cell:
    cfg = api.cfg
    params_abs, params_shard = _serve_params(api, mode, mesh)
    batch_abs = api.input_specs(shape, dtype=jnp.bfloat16)
    batch_shard = named_sharding_tree(batch_spec(cfg, shape), batch_abs,
                                      mesh, SERVE_RULES)
    logits_mode = "all" if cfg.family == "encoder" else "last"
    crew_strategy = "xla-dense" if mode == "crew" else "auto"
    # variant "opt": flash-attention Pallas kernel via shard_map (§Perf)
    attn_impl = "flash" if variant == "opt" else "chunked"

    def prefill_step(params, batch):
        logits, _ = api.forward(params, batch, dtype=jnp.bfloat16,
                                remat=False, logits_mode=logits_mode,
                                crew_strategy=crew_strategy,
                                attn_impl=attn_impl)
        return logits

    return Cell(cfg=cfg, shape=shape, mode=mode, fn=prefill_step,
                args=(params_abs, batch_abs),
                in_shard=(params_shard, batch_shard), out_shard=None,
                donate=(), static={}, mesh=mesh, rules=SERVE_RULES)


def _decode_cell(api: ModelApi, shape: ShapeConfig, mesh: Mesh,
                 mode: str, variant: str = "base") -> Cell:
    cfg = api.cfg
    params_abs, params_shard = _serve_params(api, mode, mesh)
    tokens_abs = api.input_specs(shape, dtype=jnp.bfloat16)["tokens"]
    # variant "opt": int8 KV cache — halves the dominant decode HBM stream;
    # attention runs natively int8 (§Perf iteration C).  SSM/xLSTM states
    # stay bf16 (they are O(1)-sized).
    cache_dtype = jnp.int8 if (variant == "opt"
                               and cfg.family in ("dense", "moe", "vlm"))         else jnp.bfloat16
    cache_abs = api.abstract_cache(shape.global_batch, shape.seq_len,
                                   dtype=cache_dtype)
    tok_shard = named_sharding_tree({"tokens": P("batch", None)},
                                    {"tokens": tokens_abs}, mesh,
                                    SERVE_RULES)["tokens"]
    cache_shard = named_sharding_tree(api.cache_spec(), cache_abs, mesh,
                                      SERVE_RULES)
    crew_strategy = "xla-dense" if mode == "crew" else "auto"

    def decode(params, tokens, cache):
        return api.decode_step(params, tokens, cache, dtype=jnp.bfloat16,
                               crew_strategy=crew_strategy)

    out_shard = (None, cache_shard)
    return Cell(cfg=cfg, shape=shape, mode=mode, fn=decode,
                args=(params_abs, tokens_abs, cache_abs),
                in_shard=(params_shard, tok_shard, cache_shard),
                out_shard=out_shard, donate=(2,), static={},
                mesh=mesh, rules=SERVE_RULES)


def make_cell(arch_id: str, shape_name: str, mesh: Mesh, *,
              mode: str = "dense", variant: str = "base",
              n_micro: int = TRAIN_MICROBATCHES) -> Cell:
    cfg = get_config(arch_id)
    shape = SHAPES_BY_NAME[shape_name]
    if shape not in runnable_shapes(cfg):
        raise ValueError(f"cell ({arch_id}, {shape_name}) is a mandated skip")
    api = build_model(cfg)
    if shape.kind == "train":
        if mode != "dense":
            raise ValueError("training runs dense (CREW is post-training)")
        return _train_cell(api, shape, mesh, n_micro, variant)
    if shape.kind == "prefill":
        return _prefill_cell(api, shape, mesh, mode, variant)
    return _decode_cell(api, shape, mesh, mode, variant)
