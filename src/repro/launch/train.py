"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 200 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/run1

Production-shaped loop: sharded state (TRAIN_RULES: FSDP x TP), activation
sharding ctx, deterministic step-indexed data, periodic atomic checkpoints,
resume-latest on restart (kill it mid-run and relaunch: it continues from
the last checkpoint with bit-identical batches).  On this CPU container use
``--reduced`` (smoke-scale config) and the default 1-device mesh; on a real
cluster the same script runs with ``--mesh 16x16``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="",
                    help="e.g. 16x16 (axes data,model); empty = all devices on data")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from .. import ckpt as ckptlib
    from ..configs import get_config
    from ..data import batch_for
    from ..dist.ctx import sharding_ctx
    from ..dist.sharding import TRAIN_RULES, named_sharding_tree
    from ..models import build_model
    from ..train import TrainState, adamw, cosine_warmup, init_state, make_train_step
    from .mesh import make_mesh

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)

    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        mesh = make_mesh(shape, ("data", "model")[: len(shape)])
    else:
        mesh = make_mesh((len(jax.devices()),), ("data",))

    opt = adamw(cosine_warmup(args.lr, args.warmup, args.steps))
    step_fn = make_train_step(api, opt, n_microbatches=args.microbatches,
                              dtype=jnp.bfloat16, remat=args.remat,
                              q_chunk=min(512, args.seq),
                              kv_chunk=min(512, args.seq))

    state = init_state(api, opt, jax.random.PRNGKey(args.seed))

    from jax.sharding import PartitionSpec as P
    p_spec = api.param_spec()
    state_spec = TrainState(step=P(), params=p_spec,
                            opt={"mu": p_spec, "nu": p_spec})
    state_shard = named_sharding_tree(state_spec, state, mesh, TRAIN_RULES)
    state = jax.tree.map(jax.device_put, state, state_shard)

    start = 0
    if args.ckpt_dir:
        restored, manifest = ckptlib.resume_latest(args.ckpt_dir, state,
                                                   shardings=state_shard)
        if restored is not None:
            state = restored
            start = int(manifest["step"])
            print(f"[train] resumed from step {start}")

    def wrapped(state, batch):
        with sharding_ctx(mesh, TRAIN_RULES):
            return step_fn(state, batch)

    jit_step = jax.jit(wrapped, donate_argnums=(0,),
                       out_shardings=(state_shard, None))

    t0 = time.time()
    with mesh:
        for step in range(start, args.steps):
            batch = batch_for(cfg, step, args.batch, args.seq, seed=args.seed)
            state, metrics = jit_step(state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                print(f"[train] step {step:5d} loss {m['loss']:.4f} "
                      f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} "
                      f"({time.time() - t0:.1f}s)")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                path = ckptlib.save(args.ckpt_dir, step + 1, state,
                                    extra={"arch": cfg.arch_id,
                                           "seed": args.seed})
                print(f"[train] checkpoint -> {path}")
    print(f"[train] done: {args.steps - start} steps in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
