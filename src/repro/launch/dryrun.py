import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh x mode).

The two lines above MUST execute before any other import (jax locks the
device count on first init): this process sees 512 host-platform devices so
``jax.make_mesh`` can build the production meshes.  Nothing is allocated at
model scale — params/batches/caches are ShapeDtypeStructs; ``compile()``
produces an executable and its memory/cost analyses without touching data.

Per cell this records into ``experiments/dryrun/<mesh>/<arch>__<shape>__<mode>.json``:
  * memory_analysis (per-device argument/output/temp bytes) — proves fit,
  * cost_analysis   (per-device FLOPs / bytes accessed),
  * per-kind collective bytes parsed from the optimized HLO,
  * the three §Roofline terms + dominant bound,
  * MODEL_FLOPS and the HLO/model FLOP ratio,
  * compile wall-time.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both          # full matrix
  python -m repro.launch.dryrun --all --modes dense,crew   # + CREW serve cells
"""
import argparse
import json
import time
import traceback



def run_cell(arch_id: str, shape_name: str, mesh_kind: str, mode: str,
             out_dir: str, variant: str = "base") -> dict:
    from ..configs import SHAPES_BY_NAME, get_config
    from ..roofline import TPU_V5E, model_flops, roofline_terms
    from .cells import make_cell
    from .mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    rec = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
        "mode": mode, "variant": variant,
        "chips": int(n_chips), "status": "error",
    }
    t0 = time.time()
    try:
        cell = make_cell(arch_id, shape_name, mesh, mode=mode,
                         variant=variant)
        with mesh:
            jitted = cell.jitted()
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        from ..roofline.hlo import account, cost_analysis_dict
        cost = cost_analysis_dict(compiled)
        acc = account(hlo)
        terms = roofline_terms(cost, hlo)

        cfg = get_config(arch_id)
        shape = SHAPES_BY_NAME[shape_name]
        mf = model_flops(cfg, shape, backward=(shape.kind == "train"))
        mf_dev = mf / n_chips
        hlo_flops = terms.flops

        rec.update({
            "status": "ok",
            "t_lower_s": round(t_lower, 2),
            "t_compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "total_nonalias_bytes": (
                    ma.argument_size_in_bytes + ma.output_size_in_bytes
                    + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
                "hbm_per_chip": TPU_V5E.hbm_bytes,
                # CPU-backend lowering materializes f32 twins of every bf16
                # buffer (no native bf16 on CPU), so `temp` is a ~2x upper
                # bound on TPU temp; report both verdicts (EXPERIMENTS.md
                # §Dry-run discusses).
                "fits": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                         + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
                        < TPU_V5E.hbm_bytes,
                "fits_tpu_est": (
                    ma.argument_size_in_bytes + ma.output_size_in_bytes
                    + ma.temp_size_in_bytes / 2 - ma.alias_size_in_bytes)
                    < TPU_V5E.hbm_bytes,
            },
            "cost_raw": {k: float(v) for k, v in cost.items()
                         if k in ("flops", "bytes accessed", "transcendentals")},
            "collectives": acc.collectives,
            "loop_trip_counts": acc.trip_counts,
            "roofline": terms.as_dict(),
            "model_flops_total": mf,
            "model_flops_per_dev": mf_dev,
            "hlo_over_model_flops": (hlo_flops / mf_dev) if mf_dev else None,
        })
        print(f"[dryrun] {arch_id} x {shape_name} x {mesh_kind} x {mode}: OK "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s, "
              f"bound={terms.bound}, fits={rec['memory']['fits']}"
              f"/tpu_est={rec['memory']['fits_tpu_est']})")
        print(f"  memory_analysis: arg={ma.argument_size_in_bytes/1e9:.2f}GB "
              f"out={ma.output_size_in_bytes/1e9:.2f}GB "
              f"temp={ma.temp_size_in_bytes/1e9:.2f}GB "
              f"alias={ma.alias_size_in_bytes/1e9:.2f}GB")
        print(f"  cost_analysis: flops/dev={terms.flops:.3e} "
              f"bytes/dev={terms.bytes_hbm:.3e} coll/dev={terms.bytes_collective:.3e}")
    except Exception as e:  # noqa: BLE001 — record and continue the queue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {arch_id} x {shape_name} x {mesh_kind} x {mode}: "
              f"FAIL {rec['error']}")

    if out_dir:
        os.makedirs(os.path.join(out_dir, mesh_kind), exist_ok=True)
        path = os.path.join(out_dir, mesh_kind,
                            f"{arch_id}__{shape_name}__{mode}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--modes", default="dense")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="base")
    args = ap.parse_args()

    from ..configs import ARCHS, runnable_shapes

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    modes = args.modes.split(",")

    cells = []
    if args.all:
        for arch_id, cfg in ARCHS.items():
            for shape in runnable_shapes(cfg):
                for mode in modes:
                    if mode == "crew" and shape.kind == "train":
                        continue
                    cells.append((arch_id, shape.name, mode))
    else:
        cells = [(args.arch, args.shape, m) for m in modes]

    n_ok = 0
    results = []
    for mesh_kind in meshes:
        for arch_id, shape_name, mode in cells:
            if args.skip_existing:
                p = os.path.join(args.out, mesh_kind,
                                 f"{arch_id}__{shape_name}__{mode}.json")
                if os.path.exists(p):
                    with open(p) as f:
                        if json.load(f).get("status") == "ok":
                            print(f"[dryrun] skip existing {p}")
                            n_ok += 1
                            continue
            rec = run_cell(arch_id, shape_name, mesh_kind, mode, args.out,
                           variant=args.variant)
            results.append(rec)
            n_ok += rec["status"] == "ok"
    total = len(cells) * len(meshes)
    print(f"[dryrun] {n_ok}/{total} cells OK")
    if n_ok < total:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
