"""Serving launcher CLI: load/initialize a model, optionally CREW-convert,
and serve batched generation requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --crew --requests 4 --prompt-len 16 --max-new 32

Prints per-phase latencies and — with ``--crew`` — the CREW compression
report (UW/I, MULs%, storage reduction) plus a token-level parity check
against the dense weights.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--crew", action="store_true")
    ap.add_argument("--ppa-thr", type=float, default=None)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from .. import ckpt as ckptlib
    from ..configs import get_config
    from ..models import build_model
    from ..serve import crewize_params, generate

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.arch_id} is encoder-only: nothing to serve")

    params = api.init(jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        from ..train import adamw, init_state
        state_like = init_state(api, adamw(1e-3), jax.random.PRNGKey(args.seed))
        restored, _ = ckptlib.resume_latest(args.ckpt_dir, state_like)
        if restored is not None:
            params = restored.params
            print("[serve] loaded checkpoint params")

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.requests, args.prompt_len)),
        jnp.int32)

    t0 = time.time()
    out_dense = generate(api, params, prompts, max_new=args.max_new,
                         temperature=args.temperature)
    out_dense["tokens"].block_until_ready()
    t_dense = time.time() - t0
    print(f"[serve] dense: {args.requests} reqs x {args.max_new} new tokens "
          f"in {t_dense:.2f}s (incl. compile)")

    if args.crew:
        t0 = time.time()
        crew, report = crewize_params(params, ppa_thr=args.ppa_thr)
        agg = report.aggregate()
        print(f"[serve] CREW conversion ({time.time()-t0:.1f}s): "
              f"{report.n_converted} matrices converted, "
              f"{report.n_skipped} left dense")
        print(f"[serve] CREW stats: {agg.row()}")
        t0 = time.time()
        out_crew = generate(api, crew, prompts, max_new=args.max_new,
                            temperature=args.temperature)
        out_crew["tokens"].block_until_ready()
        print(f"[serve] crew:  same batch in {time.time()-t0:.2f}s "
              f"(incl. compile)")
        match = float((out_dense["tokens"] == out_crew["tokens"]).mean())
        print(f"[serve] dense-vs-crew token match: {100*match:.1f}%"
              + (" (greedy, quantization-level differences only)"
                 if match < 1.0 else ""))
    print("[serve] sample tokens:", np.asarray(out_dense["tokens"][0][:16]))


if __name__ == "__main__":
    main()
