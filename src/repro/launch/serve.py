"""Serving launcher CLI: a mixed-traffic driver over the continuous-batching
scheduler (DESIGN.md §5, docs/serving.md).

Generates a Poisson request stream with mixed prompt/output lengths, feeds
it through ``serve.Scheduler``, and reports per-request latency percentiles
plus sustained tokens/sec.  ``--crew`` serves CREW-converted weights
(optionally autotune-warmed); ``--compare-static`` replays the same
workload through static-batched ``serve.generate`` waves for a
continuous-vs-static throughput comparison.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \\
        --requests 16 --rate 50 --prompt-len 4:24 --max-new 4:32

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \\
        --crew --autotune --requests 16 --max-batch 4 --compare-static

Range flags (``--prompt-len``, ``--max-new``) take either a single int or
an inclusive ``LO:HI`` range sampled uniformly per request; ``--rate 0``
makes every request arrive at t=0 (closed-loop batch).

``--shared-prefix FRAC`` makes each prompt draw its first FRAC tokens
from one of ``--prefix-pool`` fixed prefixes (system prompts / few-shot
templates), the traffic shape the scheduler's radix-tree prefix cache
exists for — the report then shows ``prefill_tokens_saved`` and the TTFT
percentiles the reuse buys (``benchmarks/prefix_reuse.py`` measures the
same axis steady-state).  The whole trace — arrivals, lengths, prefix
assignment — is a pure function of ``--seed``, so latency percentiles
are reproducible run-to-run.

Overload knobs: ``--deadline`` attaches a TTL to every request,
``--max-queue`` bounds the queue (over it, submits are shed), and
``--preempt-after`` enables aged preemption to the prefix pool.  The
report then buckets outcomes by terminal status and adds
goodput-under-SLO (completions within ``--slo`` per second) — the
overload number ``benchmarks/overload.py`` tracks.

Chaos knobs: ``--faults-seed`` arms a ``FaultInjector`` whose schedule
is a pure function of the seed, with per-hook rates
(``--fault-preempt-p``, ``--fault-crash-p``, ``--fault-disconnect-p``,
…) — the CLI twin of the ``REPRO_FAULTS`` env switch, for reproducible
chaos runs outside the test suite.

Wire modes (docs/serving.md):

* ``--listen [--host H --port P]`` — run the supervised HTTP/SSE front
  door (serve.server) instead of the synthetic driver.  SIGINT/SIGTERM
  drain gracefully (readiness flips to 503 + Retry-After, in-flight
  work finishes); a second signal stops hard.  Crash/stall faults are
  recovered by the supervisor with streams resumed token-identically.
* ``--connect HOST:PORT`` — drive a remote front door with the same
  seeded workload over HTTP/SSE; ``--fault-disconnect-p`` /
  ``--fault-stall-p`` then model misbehaving *clients* (hang-ups
  mid-stream, stalled reads) from the client side.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def _parse_range(spec: str):
    if ":" in spec:
        lo, hi = spec.split(":", 1)
        lo, hi = int(lo), int(hi)
    else:
        lo = hi = int(spec)
    if not 1 <= lo <= hi:
        raise argparse.ArgumentTypeError(f"bad range {spec!r}")
    return lo, hi


def make_workload(n, prompt_rng, new_rng, vocab, rate, *, seed=0,
                  shared_prefix=0.0, prefix_pool=4):
    """[(arrival_s, prompt, max_new)] with exponential inter-arrivals.

    Owns its generator: the trace (Poisson arrivals, lengths, prefix
    assignment) is a pure function of ``seed`` — reproducible
    percentiles run-to-run regardless of other RNG consumers.  With
    ``shared_prefix > 0`` each prompt's first ``shared_prefix`` fraction
    of tokens comes from one of ``prefix_pool`` fixed token arrays.
    """
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab, prompt_rng[1]).astype(np.int32)
                for _ in range(prefix_pool)] if shared_prefix > 0 else []
    t = 0.0
    out = []
    for _ in range(n):
        if rate > 0:
            t += rng.exponential(1.0 / rate)
        p_len = int(rng.integers(prompt_rng[0], prompt_rng[1] + 1))
        m_new = int(rng.integers(new_rng[0], new_rng[1] + 1))
        if prefixes:
            k = min(int(round(shared_prefix * p_len)), p_len - 1)
            pre = prefixes[int(rng.integers(len(prefixes)))][:k]
            prompt = np.concatenate(
                [pre, rng.integers(0, vocab, p_len - k).astype(np.int32)])
        else:
            prompt = rng.integers(0, vocab, p_len).astype(np.int32)
        out.append((t, prompt, m_new))
    return out


def serve_continuous(sched, workload, *, deadline_s=None, slo_s=None):
    """Drive the scheduler against timed arrivals; returns (results, report).

    Requests become visible to the queue only once their arrival time has
    passed; the loop idles (sleeps to the next arrival) when the engine
    drains before the stream does.

    Every terminal outcome flows through the report: completions feed
    the latency/TTFT percentiles, while shed / timed-out / cancelled
    requests are counted in their own status buckets (a shed ``submit``
    returns a typed ``Shed`` — its rid still lands in ``results``).
    ``deadline_s`` attaches a TTL to every submitted request; ``slo_s``
    (default: the deadline) defines **goodput** — completions finishing
    within the SLO per second of wall time — the number that matters at
    overload, where raw throughput stays high while every request is
    late (ISSUE: goodput-under-SLO, ``benchmarks/overload.py``).
    """
    from ..serve import Shed

    t0 = time.perf_counter()
    pending = list(workload)
    finished_at = {}
    submitted_at = {}
    results = {}
    queue_peak = 0
    while pending or sched.pending:
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            arr, prompt, max_new = pending.pop(0)
            rid = sched.submit(prompt, max_new=max_new,
                               deadline_s=deadline_s)
            if isinstance(rid, Shed):
                rid = rid.rid       # terminal Completion arrives below
            submitted_at[rid] = arr
        queue_peak = max(queue_peak, sched.pending)
        busy = sched.step()
        for rid, comp in sched.pop_results().items():
            results[rid] = comp
            finished_at[rid] = time.perf_counter() - t0
        if not busy and pending:
            time.sleep(max(0.0, pending[0][0] - (time.perf_counter() - t0)))
    wall = time.perf_counter() - t0
    by_status = {}
    for comp in results.values():
        by_status[comp.status] = by_status.get(comp.status, 0) + 1
    done = {r: c for r, c in results.items() if c.status == "completed"}
    lat = np.asarray([finished_at[r] - submitted_at[r] for r in done])
    ttft = np.asarray([c.ttft_s for c in done.values()])
    toks = sum(c.tokens.size for c in results.values())
    slo = slo_s if slo_s is not None else deadline_s
    good = (sum(1 for r in done
                if finished_at[r] - submitted_at[r] <= slo)
            if slo is not None else len(done))
    report = {
        "wall_s": wall,
        "tokens": toks,
        "tokens_per_s": toks / max(wall, 1e-9),
        "by_status": by_status,
        "queue_peak": queue_peak,
        "goodput_rps": good / max(wall, 1e-9),
        "lat_p50_s": float(np.percentile(lat, 50)) if lat.size else 0.0,
        "lat_p95_s": float(np.percentile(lat, 95)) if lat.size else 0.0,
        "lat_max_s": float(lat.max()) if lat.size else 0.0,
        "ttft_p50_s": float(np.percentile(ttft, 50)) if ttft.size else 0.0,
        "ttft_p95_s": float(np.percentile(ttft, 95)) if ttft.size else 0.0,
    }
    return results, report


def serve_static(api, params, workload, max_batch, temperature=0.0):
    """Static-batching baseline: waves of ``max_batch`` requests, each wave
    padded to its longest prompt and longest max_new (the cost the
    scheduler exists to avoid).  Returns the same report keys."""
    from ..serve import generate
    import jax.numpy as jnp

    t0 = time.perf_counter()
    useful = 0
    for i in range(0, len(workload), max_batch):
        wave = workload[i:i + max_batch]
        p_max = max(p.size for _, p, _ in wave)
        n_max = max(m for _, _, m in wave)
        batch = np.zeros((len(wave), p_max), np.int32)
        for j, (_, p, _) in enumerate(wave):
            batch[j, :p.size] = p
        out = generate(api, params, jnp.asarray(batch), max_new=n_max,
                       temperature=temperature)
        out["tokens"].block_until_ready()
        useful += sum(m for _, _, m in wave)
    wall = time.perf_counter() - t0
    return {"wall_s": wall, "tokens": useful,
            "tokens_per_s": useful / max(wall, 1e-9)}


def add_fault_flags(ap) -> None:
    """CLI twin of ``REPRO_FAULTS``: a seeded, per-hook-configurable
    injector (satellite of the resilient-front-door issue)."""
    g = ap.add_argument_group("fault injection")
    g.add_argument("--faults-seed", type=int, default=None, metavar="N",
                   help="arm a FaultInjector seeded N (schedule is a "
                        "pure function of the seed); required for any "
                        "--fault-* rate below")
    g.add_argument("--fault-delay-p", type=float, default=0.0)
    g.add_argument("--fault-max-delay", type=float, default=0.05,
                   metavar="S")
    g.add_argument("--fault-preempt-p", type=float, default=0.0)
    g.add_argument("--fault-expire-p", type=float, default=0.0)
    g.add_argument("--fault-drop-p", type=float, default=0.0)
    g.add_argument("--fault-max-drop", type=int, default=2)
    g.add_argument("--fault-crash-p", type=float, default=0.0)
    g.add_argument("--fault-disconnect-p", type=float, default=0.0)
    g.add_argument("--fault-max-disconnect-tokens", type=int, default=8)
    g.add_argument("--fault-stall-p", type=float, default=0.0)
    g.add_argument("--fault-max-stall", type=float, default=0.5,
                   metavar="S")
    g.add_argument("--fault-kill-p", type=float, default=0.0,
                   help="per-pump-step probability of SIGKILLing the "
                        "whole process (--listen only; recovery is the "
                        "next process replaying --journal-dir)")


def injector_from_args(args):
    """A ``FaultInjector`` from ``--faults-seed`` + rates, or None when
    unarmed (the scheduler then falls back to the REPRO_FAULTS env
    default)."""
    rates = (args.fault_delay_p, args.fault_preempt_p,
             args.fault_expire_p, args.fault_drop_p, args.fault_crash_p,
             args.fault_disconnect_p, args.fault_stall_p,
             args.fault_kill_p)
    if args.faults_seed is None:
        if any(r > 0 for r in rates):
            raise SystemExit("--fault-* rates need --faults-seed")
        return None
    from ..serve import FaultInjector
    return FaultInjector(
        args.faults_seed,
        delay_p=args.fault_delay_p, max_delay_s=args.fault_max_delay,
        preempt_p=args.fault_preempt_p,
        expire_p=args.fault_expire_p,
        drop_p=args.fault_drop_p, max_drop=args.fault_max_drop,
        crash_p=args.fault_crash_p,
        disconnect_p=args.fault_disconnect_p,
        max_disconnect_tokens=args.fault_max_disconnect_tokens,
        stall_p=args.fault_stall_p, max_stall_s=args.fault_max_stall,
        kill_p=args.fault_kill_p)


def run_listen(api, params, args, faults) -> None:
    """``--listen``: the supervised HTTP/SSE front door, draining
    gracefully on SIGINT/SIGTERM.  With ``--journal-dir`` every
    submit/token-panel/terminal is logged to a write-ahead journal and
    replayed on cold start, so a restart on the same directory resumes
    outstanding streams token-identically (DESIGN.md §5.1)."""
    import asyncio

    from ..serve import Journal, RequestLog, Scheduler, SSEServer, Supervisor

    journal = (Journal(args.journal_dir, fsync=args.fsync)
               if args.journal_dir else None)
    sched = Scheduler(api, params, max_batch=args.max_batch,
                      cache_len=args.cache_len, horizon=args.horizon,
                      prefix_cache=not args.no_prefix_cache,
                      block_size=args.block_size,
                      pool_blocks=args.pool_blocks,
                      temperature=args.temperature,
                      max_queue=args.max_queue,
                      preempt_after_steps=args.preempt_after,
                      rng=jax.random.PRNGKey(args.seed),
                      stream_tokens=True,
                      faults=faults,
                      journal=journal)
    rlog = RequestLog(args.log_jsonl) if args.log_jsonl else None
    sup = Supervisor(sched, request_log=rlog).start()
    if journal is not None:
        print(f"[serve] journal {args.journal_dir} (fsync={args.fsync}): "
              f"replayed {sup.replayed} outstanding request(s) in "
              f"{sup.replay_ms:.1f}ms")
    srv = SSEServer(sup, host=args.host, port=args.port)
    loop = asyncio.new_event_loop()
    asyncio.set_event_loop(loop)
    loop.run_until_complete(srv.start())
    srv.install_signal_handlers()
    print(f"[serve] listening on http://{srv.host}:{srv.port} "
          f"(SSE: POST /v1/generate; health: /healthz /readyz /metrics)")
    print("[serve] SIGINT/SIGTERM drains gracefully; repeat to force")
    try:
        loop.run_forever()
    finally:
        sup.stop(drain=False)
        if journal is not None:
            journal.close()
        if rlog is not None:
            rlog.close()
        m = sched.metrics
        print(f"[serve] done: {m.completed} completed, {m.cancelled} "
              f"cancelled, {m.shed} shed; {sup.recoveries} recoveries")


def run_connect(args, vocab, faults) -> None:
    """``--connect HOST:PORT``: replay the seeded workload over the
    wire, with client-side disconnect/stall chaos from the injector."""
    import threading

    from ..serve.client import get_json, stream_generate

    host, port = args.connect.rsplit(":", 1)
    port = int(port)
    ready = get_json(host, port, "/readyz")
    print(f"[serve] target http://{host}:{port} readyz -> "
          f"{ready['status']}")
    workload = make_workload(args.requests, args.prompt_len,
                             args.max_new, vocab, args.rate,
                             seed=args.seed,
                             shared_prefix=args.shared_prefix,
                             prefix_pool=args.prefix_pool)
    plans = []
    for i, (arr, prompt, m_new) in enumerate(workload):
        disc = faults.disconnect_after(i) if faults is not None else None
        stall = faults.client_stall() if faults is not None else 0.0
        plans.append((arr, prompt, m_new, disc, stall))
    results = [None] * len(plans)
    t0 = time.perf_counter()

    def _one(i, arr, prompt, m_new, disc, stall):
        time.sleep(max(0.0, arr - (time.perf_counter() - t0)))
        results[i] = stream_generate(
            host, port, prompt, max_new=m_new,
            deadline_s=args.deadline, disconnect_after=disc,
            stall_s=stall)

    threads = [threading.Thread(target=_one, args=(i, *plan))
               for i, plan in enumerate(plans)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    wall = time.perf_counter() - t0
    by = {}
    toks = 0
    for r in results:
        toks += len(r["tokens"])
        key = (r["done"]["status"] if r["done"] else
               ("hangup" if r["disconnected"] else f"http-{r['http_status']}"))
        by[key] = by.get(key, 0) + 1
    print(f"[serve] {len(results)} reqs over the wire in {wall:.2f}s: "
          f"{by}  {toks} token frames "
          f"({toks / max(wall, 1e-9):.1f} frames/s)")
    if faults is not None:
        chaos = [h for h, *_ in faults.trace]
        print(f"[serve] client chaos injected: "
              f"{ {h: chaos.count(h) for h in set(chaos)} }")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--crew", action="store_true")
    ap.add_argument("--autotune", action="store_true",
                    help="warm the CREW strategy cache before serving")
    ap.add_argument("--ppa-thr", type=float, default=None)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate, requests/sec (0 = all at t=0)")
    ap.add_argument("--prompt-len", type=_parse_range, default=(4, 24),
                    metavar="LO:HI")
    ap.add_argument("--max-new", type=_parse_range, default=(4, 32),
                    metavar="LO:HI")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--horizon", type=int, default=8,
                    help="decode steps per fused device program (1 = "
                         "token-synchronous host loop)")
    ap.add_argument("--shared-prefix", type=float, default=0.0,
                    metavar="FRAC",
                    help="fraction of each prompt drawn from a fixed "
                         "shared prefix (0 = fully independent prompts)")
    ap.add_argument("--prefix-pool", type=int, default=4,
                    help="number of distinct shared prefixes in the mix")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable the radix-tree prefix cache (cold "
                         "prefill for every admit)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="prefix-cache block granularity (tokens)")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="KV pool capacity in blocks (default: two full "
                         "batches' worth)")
    ap.add_argument("--deadline", type=float, default=None, metavar="S",
                    help="per-request TTL in seconds (enforced at horizon "
                         "boundaries; expired requests report timed_out)")
    ap.add_argument("--slo", type=float, default=None, metavar="S",
                    help="latency SLO for the goodput report (default: "
                         "--deadline)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound on queued requests; over it, submits are "
                         "shed (default: unbounded)")
    ap.add_argument("--preempt-after", type=int, default=None,
                    metavar="STEPS",
                    help="preempt the longest decode to the prefix pool "
                         "after this many queue-starved steps")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--compare-static", action="store_true",
                    help="replay the workload through static-batched "
                         "generate waves and report both throughputs")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    g = ap.add_argument_group("wire modes")
    g.add_argument("--listen", action="store_true",
                   help="serve the model over HTTP/SSE instead of "
                        "driving the seeded workload in-process")
    g.add_argument("--host", default="127.0.0.1")
    g.add_argument("--port", type=int, default=8777)
    g.add_argument("--journal-dir", default=None, metavar="DIR",
                   help="durable request journal for --listen: log every "
                        "submit/token-panel/terminal to a WAL in DIR and "
                        "replay it on startup, resuming outstanding "
                        "streams across process death (docs/serving.md)")
    g.add_argument("--fsync", choices=("record", "horizon", "none"),
                   default="horizon",
                   help="journal durability policy: fsync every record, "
                        "once per horizon flush (default; submits are "
                        "always synced), or never")
    g.add_argument("--log-jsonl", default=None, metavar="PATH",
                   help="append one structured JSON line per terminal "
                        "(rid, tenant, status, reason, ttft_s, tokens, "
                        "queue_s) to PATH")
    g.add_argument("--connect", default=None, metavar="HOST:PORT",
                   help="replay the seeded workload against a running "
                        "--listen server (no model is built)")
    add_fault_flags(ap)
    args = ap.parse_args()
    faults = injector_from_args(args)

    from .. import ckpt as ckptlib
    from ..configs import get_config
    from ..models import build_model
    from ..serve import Scheduler, autotune_crew_params, crewize_params

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.connect:
        run_connect(args, cfg.vocab, faults)
        return
    api = build_model(cfg)
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.arch_id} is encoder-only: nothing to serve")

    params = api.init(jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        from ..train import adamw, init_state
        state_like = init_state(api, adamw(1e-3), jax.random.PRNGKey(args.seed))
        restored, _ = ckptlib.resume_latest(args.ckpt_dir, state_like)
        if restored is not None:
            params = restored.params
            print("[serve] loaded checkpoint params")

    if args.crew:
        t0 = time.perf_counter()
        params, report = crewize_params(params, ppa_thr=args.ppa_thr)
        agg = report.aggregate()
        print(f"[serve] CREW conversion ({time.perf_counter()-t0:.1f}s): "
              f"{report.n_converted} matrices converted, "
              f"{report.n_skipped} left dense")
        print(f"[serve] CREW stats: {agg.row()}")
        if args.autotune:
            t0 = time.perf_counter()
            winners = autotune_crew_params(params)
            print(f"[serve] autotune warmup ({time.perf_counter()-t0:.1f}s): "
                  f"{len(winners)} apply shapes measured")

    if args.listen:
        run_listen(api, params, args, faults)
        return

    workload = make_workload(args.requests, args.prompt_len, args.max_new,
                             cfg.vocab, args.rate, seed=args.seed,
                             shared_prefix=args.shared_prefix,
                             prefix_pool=args.prefix_pool)
    sched = Scheduler(api, params, max_batch=args.max_batch,
                      cache_len=args.cache_len, horizon=args.horizon,
                      prefix_cache=not args.no_prefix_cache,
                      block_size=args.block_size,
                      pool_blocks=args.pool_blocks,
                      temperature=args.temperature,
                      max_queue=args.max_queue,
                      preempt_after_steps=args.preempt_after,
                      rng=jax.random.PRNGKey(args.seed),
                      faults=faults)
    results, rep = serve_continuous(sched, workload,
                                    deadline_s=args.deadline,
                                    slo_s=args.slo)
    print(f"[serve] continuous: {len(results)} reqs, "
          f"{rep['tokens']} tokens in {rep['wall_s']:.2f}s "
          f"-> {rep['tokens_per_s']:.1f} tok/s (incl. compile)")
    print(f"[serve] outcomes {rep['by_status']}  queue peak "
          f"{rep['queue_peak']}  goodput {rep['goodput_rps']:.1f} req/s"
          + (f" (SLO {args.slo or args.deadline}s)"
             if (args.slo or args.deadline) else " (no SLO)"))
    print(f"[serve] latency p50 {rep['lat_p50_s']:.3f}s  "
          f"p95 {rep['lat_p95_s']:.3f}s  max {rep['lat_max_s']:.3f}s  "
          f"ttft p50 {rep['ttft_p50_s']:.3f}s p95 {rep['ttft_p95_s']:.3f}s")
    m = sched.metrics
    print(f"[serve] prefix reuse: {m.prefill_tokens_saved} prefill tokens "
          f"saved ({m.prefix_hit_tokens} matched), {m.chunks} chunks, "
          f"{m.pool_evictions} evictions")
    print(f"[serve] programs {sched.program_counts()}  "
          f"metrics {m.to_dict()}")

    if args.compare_static:
        srep = serve_static(api, params, workload, args.max_batch,
                            temperature=args.temperature)
        print(f"[serve] static: {srep['tokens']} useful tokens in "
              f"{srep['wall_s']:.2f}s -> {srep['tokens_per_s']:.1f} tok/s "
              f"(incl. compile)")
        print(f"[serve] continuous/static speedup: "
              f"{rep['tokens_per_s'] / max(srep['tokens_per_s'], 1e-9):.2f}x")

    if results:
        some = min(results)
        print(f"[serve] sample tokens (rid {some}):",
              results[some].tokens[:16])


if __name__ == "__main__":
    main()
