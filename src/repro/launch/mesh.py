"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and everything else (smoke tests, benches) must keep seeing the one
real CPU device.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is the slow inter-pod (DCN-ish) dimension: batch shards over it,
weights replicate across it (FSDP stays intra-pod), so the only cross-pod
collective in training is the gradient all-reduce.
"""
from __future__ import annotations

from typing import Tuple


__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """jax.make_mesh with Auto axis types (GSPMD propagation)."""
    from ..dist.compat import make_mesh as _compat_make_mesh
    return _compat_make_mesh(shape, axes)
