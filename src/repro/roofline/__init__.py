"""Roofline analysis from compiled dry-run artifacts.

Hardware model: TPU v5e-like — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (constants below).  The three terms per §Roofline:

    compute    = FLOPs_per_device / peak_FLOPs
    memory     = bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

`cost_analysis()` on a GSPMD-partitioned module is **per-device** (verified
empirically: a 4-way sharded matmul reports ~1/4 of the dense FLOPs), so no
further division by chip count is needed.  Collective bytes are not in
cost_analysis; ``collective_bytes`` parses the optimized HLO text and sums
the result-shape bytes of every collective op (per-device shard sizes —
the bytes that actually cross that device's links, matching the
per-chip-link denominator).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

__all__ = [
    "HW", "TPU_V5E", "collective_bytes", "RooflineTerms", "roofline_terms",
    "model_flops",
]


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float        # per chip, bf16
    hbm_bw: float            # bytes/s per chip
    link_bw: float           # bytes/s per ICI link
    hbm_bytes: float         # capacity per chip


TPU_V5E = HW(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
             link_bw=50e9, hbm_bytes=16e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# one result shape like  bf16[8,128,14336]{2,1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum per-device result bytes of every collective op, by op kind.

    Handles plain and variadic results:
        %ar = f32[4,8]{1,0} all-reduce(...)
        %ar = (f32[4]{0}, f32[8]{0}) all-reduce(...)
    ``*-start`` variants (async collectives) are counted; their ``*-done``
    twins are skipped to avoid double counting.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, _, rhs = s.partition("=")
        rhs = rhs.strip()
        for kind in _COLLECTIVES:
            # match "<shape> <kind>(" or "<shape> <kind>-start("
            m = re.match(r"((?:\([^)]*\)|\S+))\s+" + kind + r"(-start)?\(", rhs)
            if m:
                out[kind] += _shape_bytes(m.group(1))
                break
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per device
    bytes_hbm: float             # per device
    bytes_collective: float      # per device
    hw: HW = TPU_V5E

    @property
    def t_compute(self) -> float:
        return self.flops / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.bytes_collective / self.hw.link_bw

    @property
    def bound(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops_per_dev": self.flops,
            "bytes_hbm_per_dev": self.bytes_hbm,
            "bytes_coll_per_dev": self.bytes_collective,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bound": self.bound,
        }


def roofline_terms(cost: Dict[str, float], hlo_text: str,
                   hw: HW = TPU_V5E) -> RooflineTerms:
    """Trip-count-aware terms from the optimized per-device HLO.

    ``cost_analysis`` visits while bodies once, so scanned models
    under-report by the trip count; the hlo.account parser re-multiplies
    (see roofline/hlo.py).  The raw cost dict is kept by the dry-run
    record for cross-checking.
    """
    from .hlo import account
    acc = account(hlo_text)
    return RooflineTerms(
        flops=acc.flops,
        bytes_hbm=acc.bytes_hbm,
        bytes_collective=acc.bytes_collective,
        hw=hw,
    )


def model_flops(cfg, shape, *, backward: bool) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N_active*D (inference) — the
    'useful FLOPs' yardstick for the HLO-vs-model ratio (§Roofline)."""
    n_active = cfg.active_param_count()
    if shape.kind == "decode":
        tokens = shape.global_batch  # one token per sequence per step
    else:
        tokens = shape.global_batch * shape.seq_len
    mult = 6.0 if backward else 2.0
    return mult * n_active * tokens
