"""Trip-count-aware HLO accounting for the roofline.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``lax.scan`` over 88 layers contributes its body cost a single time, so a
scanned model under-reports FLOPs/bytes by the trip count (verified: the
qwen2 train cell's raw 'flops' x n_layers exactly equals MODEL_FLOPS).
Production JAX models are scan-stacked precisely to keep HLO small, so an
honest roofline MUST re-multiply loop bodies.

This module parses the optimized (post-SPMD, per-device) HLO text into
computations + instructions, discovers each ``while`` op's trip count from
its condition computation (the loop-bound constant), propagates execution
multipliers ENTRY -> callees (while bodies x trip, fusions/calls x 1), and
accounts per instruction at fusion granularity:

  * FLOPs:  dot = 2 * prod(output dims) * prod(lhs contracting dims)
            (+ convolutions if present); counted inside fusions too.
  * HBM bytes: for every *materialized* top-level op — sum of operand
            sizes + result size (fusion operands/results are exactly the
            HBM-level buffers; intra-fusion traffic stays in
            registers/VMEM).  parameter/constant/tuple/get-tuple-element/
            bitcast are free.
  * Collective bytes: result bytes of all-reduce / all-gather /
            reduce-scatter / all-to-all / collective-permute (+ async
            ``*-start`` forms; ``*-done`` skipped).

The parser is validated against cost_analysis on scan-free modules (exact
FLOPs match) and against hand-counted scanned toys in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["parse_hlo", "HloAccounting", "account", "cost_analysis_dict"]


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized to one flat dict.

    New jax returns the properties dict directly; 0.4.x returns a
    one-element list of dicts (one per partition, pre-merged by XLA), so
    indexing the raw result by string key there is a TypeError.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# instruction:  %name = <shape> opcode(...operands...) , attrs
# tuple shapes may contain /*index=N*/ comments (hence '=' inside) but no
# nested parens (layouts are braces), so \([^()]*\) is safe for them.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\((.*)$"
)
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_elems_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        bpe = _DTYPE_BYTES.get(dtype)
        if bpe is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * bpe
    return total


def _shape_dims(shape_str: str) -> Optional[Tuple[str, List[int]]]:
    """First (dtype, dims) in a shape string (None for pure tuples)."""
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str       # operand list + attrs (raw tail of the line)
    is_root: bool = False

    def operands(self) -> List[str]:
        # operands live before the closing paren of the op call; attrs follow
        depth = 0
        end = len(self.rest)
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        return _OPERAND_RE.findall(self.rest[:end])

    def attr(self, key: str) -> Optional[str]:
        m = re.search(key + r"=\{([^}]*)\}", self.rest)
        if m:
            return m.group(1)
        m = re.search(key + r"=%?([\w.\-]+)", self.rest)
        return m.group(1) if m else None


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: Dict[str, Instr]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                cur = Computation(name=m.group(2), is_entry=bool(m.group(1)),
                                  instrs={})
            continue
        s = line.strip()
        if s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, shape, opcode, rest = m.groups()
            cur.instrs[name] = Instr(name=name, shape=shape, opcode=opcode,
                                     rest=rest,
                                     is_root=s.startswith("ROOT"))
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _trip_count(while_ins: Instr, cond: Optional[Computation]) -> int:
    """Trip count of a while op.  XLA annotates scan-style loops with
    ``backend_config={"known_trip_count":{"n":"8"}, ...}`` — authoritative.
    Fallback: the loop-bound constant in the condition computation."""
    m = re.search(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"', while_ins.rest)
    if m:
        return int(m.group(1))
    best = 1
    if cond is not None:
        for ins in cond.instrs.values():
            if ins.opcode == "constant":
                mm = re.search(r"constant\((-?\d+)\)", "constant(" + ins.rest)
                if mm:
                    best = max(best, int(mm.group(1)))
    return best


_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
_SLICE_OPS = {"slice", "dynamic-slice", "gather"}


def _fusion_operand_bytes(body: "Computation", idx: int,
                          full_bytes: int) -> int:
    """Bytes a fusion actually reads from operand `idx`.

    If every body use of parameter(idx) is a slice-like op, only the
    sliced regions cross HBM (the scan-xs pattern: fusion(stacked, iter)
    wrapping a dynamic-slice reads ONE layer slice per iteration, not the
    whole stack).  Otherwise the full operand is read.
    """
    pname = None
    for ins in body.instrs.values():
        if ins.opcode == "parameter" and f"parameter({idx})" in \
                "parameter(" + ins.rest:
            pname = ins.name
            break
    if pname is None:
        return full_bytes
    touched = 0
    for ins in body.instrs.values():
        if pname in ins.operands():
            if ins.opcode in _SLICE_OPS:
                touched += _shape_elems_bytes(ins.shape)
            elif ins.opcode == "dynamic-update-slice":
                # operand 0 of a DUS is the aliased full buffer; only the
                # update region is written
                ops_ = ins.operands()
                if ops_ and ops_[0] == pname:
                    continue
                return full_bytes
            else:
                return full_bytes
    return min(touched, full_bytes)


def _fusion_root_out_bytes(body: "Computation", out_bytes: int) -> int:
    """Bytes a fusion actually writes: a DUS-root fusion updates only the
    slice region of its (aliased) output buffer."""
    for ins in body.instrs.values():
        if ins.is_root and ins.opcode == "dynamic-update-slice":
            ops_ = ins.operands()
            if len(ops_) > 1 and ops_[1] in body.instrs:
                return min(2 * _shape_elems_bytes(body.instrs[ops_[1]].shape),
                           out_bytes)
    return out_bytes
_CONTROL_OPS = {"while", "conditional", "call", "fusion", "async-start",
                "async-update", "async-done", "custom-call"}


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out = _shape_dims(ins.shape)
    if out is None:
        return 0.0
    _, out_dims = out
    n_out = 1
    for d in out_dims:
        n_out *= d
    ops = ins.operands()
    contract = ins.attr("lhs_contracting_dims")
    csize = 1
    if contract and ops:
        lhs = comp.instrs.get(ops[0])
        if lhs is not None:
            ls = _shape_dims(lhs.shape)
            if ls is not None:
                for idx in contract.split(","):
                    idx = idx.strip()
                    if idx:
                        i = int(idx)
                        if i < len(ls[1]):
                            csize *= ls[1][i]
    return 2.0 * n_out * csize


@dataclasses.dataclass
class HloAccounting:
    flops: float
    bytes_hbm: float
    bytes_collective: float
    collectives: Dict[str, float]
    trip_counts: Dict[str, int]
    # per-computation (multiplier, flops, bytes, collective bytes) — lets
    # the §Perf analysis attribute cost to loop nests (e.g. "all bytes in
    # computations with multiplier > n_layers are attention-chunk traffic")
    per_comp: Dict[str, Tuple[float, float, float, float]] = \
        dataclasses.field(default_factory=dict)

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "bytes_hbm": self.bytes_hbm,
            "bytes_collective": self.bytes_collective,
            "collectives": dict(self.collectives),
            "n_loops": len(self.trip_counts),
        }


def account(text: str) -> HloAccounting:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # execution multiplier per computation, propagated from ENTRY
    mult: Dict[str, float] = {entry.name: 1.0}
    trip_counts: Dict[str, int] = {}
    order = [entry.name]
    seen = {entry.name}
    while order:
        cname = order.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for ins in comp.instrs.values():
            callees: List[Tuple[str, float]] = []
            if ins.opcode == "while":
                body = ins.attr("body")
                cond = ins.attr("condition")
                trip = _trip_count(ins, comps.get(cond) if cond else None)
                if body:
                    trip_counts[body] = trip
                    callees.append((body, m * trip))
                if cond:
                    callees.append((cond, m * (trip + 1)))
            elif ins.opcode == "fusion":
                callee = ins.attr("calls")
                if callee:
                    callees.append((callee, m))
            elif ins.opcode in ("call", "async-start", "custom-call"):
                callee = ins.attr("to_apply") or ins.attr("calls")
                if callee:
                    callees.append((callee, m))
            elif ins.opcode == "conditional":
                for key in ("true_computation", "false_computation"):
                    callee = ins.attr(key)
                    if callee:
                        callees.append((callee, m))
                bc = re.search(r"branch_computations=\{([^}]*)\}", ins.rest)
                if bc:
                    for name in _OPERAND_RE.findall(bc.group(1)):
                        callees.append((name, m))
            for callee, cm in callees:
                if callee in mult:
                    mult[callee] = max(mult[callee], cm)
                else:
                    mult[callee] = cm
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)

    flops = 0.0
    bytes_hbm = 0.0
    coll: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    per_comp: Dict[str, Tuple[float, float, float, float]] = {}

    # computations reachable only as fusion bodies: FLOPs counted, bytes not
    fusion_bodies = set()
    for comp in comps.values():
        for ins in comp.instrs.values():
            if ins.opcode == "fusion":
                callee = ins.attr("calls")
                if callee:
                    fusion_bodies.add(callee)
    # reduce/scatter/sort/... to_apply scalar computations: negligible, skip
    scalar_helpers = set()
    for comp in comps.values():
        for ins in comp.instrs.values():
            if ins.opcode not in ("fusion", "while", "conditional", "call"):
                ta = ins.attr("to_apply")
                if ta:
                    scalar_helpers.add(ta)

    for comp in comps.values():
        m = mult.get(comp.name)
        if m is None or comp.name in scalar_helpers:
            continue
        in_fusion = comp.name in fusion_bodies
        f0, b0, cl0 = flops, bytes_hbm, sum(coll.values())
        for ins in comp.instrs.values():
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, comp)
                if not in_fusion:
                    bytes_hbm += m * (_shape_elems_bytes(ins.shape) + sum(
                        _shape_elems_bytes(comp.instrs[o].shape)
                        for o in ins.operands() if o in comp.instrs))
                continue
            if in_fusion:
                continue  # intra-fusion ops: VMEM/registers, not HBM
            base = ins.opcode[:-6] if ins.opcode.endswith("-start") else ins.opcode
            if base in _COLLECTIVES:
                b = m * _shape_elems_bytes(ins.shape)
                coll[base] += b
                bytes_hbm += m * (_shape_elems_bytes(ins.shape) + sum(
                    _shape_elems_bytes(comp.instrs[o].shape)
                    for o in ins.operands() if o in comp.instrs))
                continue
            if ins.opcode.endswith("-done") or ins.opcode in _FREE_OPS:
                continue
            if ins.opcode in ("while", "conditional", "call", "async-start",
                              "async-update", "async-done"):
                continue  # their bodies are accounted directly
            # slice-like ops touch only the sliced region, NOT the full
            # operand (a dynamic-slice in a grid/scan loop would otherwise
            # bill the whole source array per iteration):
            out_b = _shape_elems_bytes(ins.shape)
            if ins.opcode in ("slice", "dynamic-slice", "gather"):
                bytes_hbm += m * 2 * out_b  # region read + result write
                continue
            if ins.opcode in ("dynamic-update-slice", "scatter"):
                ops_ = ins.operands()
                upd = (_shape_elems_bytes(comp.instrs[ops_[1]].shape)
                       if len(ops_) > 1 and ops_[1] in comp.instrs else out_b)
                bytes_hbm += m * 2 * upd    # region write (+ read-modify)
                continue
            if ins.opcode == "fusion":
                body = comps.get(ins.attr("calls") or "")
                ops_ = ins.operands()
                b = 0
                for i, o in enumerate(ops_):
                    full = (_shape_elems_bytes(comp.instrs[o].shape)
                            if o in comp.instrs else 0)
                    b += (_fusion_operand_bytes(body, i, full)
                          if body is not None else full)
                b += (_fusion_root_out_bytes(body, out_b)
                      if body is not None else out_b)
                bytes_hbm += m * b
                continue
            # materialized top-level op (incl. custom-call — operands and
            # result are exactly the HBM-level buffers):
            bytes_hbm += m * (out_b + sum(
                _shape_elems_bytes(comp.instrs[o].shape)
                for o in ins.operands() if o in comp.instrs))
        per_comp[comp.name] = (m, flops - f0, bytes_hbm - b0,
                               sum(coll.values()) - cl0)

    return HloAccounting(
        flops=flops,
        bytes_hbm=bytes_hbm,
        bytes_collective=sum(coll.values()),
        collectives={k: v for k, v in coll.items() if v},
        trip_counts=trip_counts,
        per_comp=per_comp,
    )
