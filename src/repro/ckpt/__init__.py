"""Fault-tolerant checkpointing: atomic, mesh-independent, elastic.

Layout per checkpoint:

    <dir>/step_000123/
        arrays.npz        # flattened pytree, host-numpy (mesh-independent)
        manifest.json     # step, tree structure, config hash, extra metadata
    <dir>/LATEST          # atomically-renamed pointer file

Write protocol (crash-safe at every point):
  1. write into ``step_N.tmp/``, fsync files,
  2. rename ``step_N.tmp -> step_N``     (atomic on POSIX),
  3. rewrite ``LATEST`` via tmp+rename   (atomic pointer swap).

A run killed mid-save leaves only a ``.tmp`` dir, which ``resume_latest``
ignores and the next save garbage-collects.  Arrays are saved as host
numpy, so restore works onto **any** mesh/topology/device count — the
elastic-restart path (tests/test_ckpt.py) reshards on load via
``device_put`` with the new mesh's NamedShardings.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "resume_latest", "latest_step", "tree_hash"]

_SEP = "/"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def tree_hash(tree) -> str:
    """Structure hash — guards restore against config drift."""
    paths = sorted(
        f"{_SEP.join(_path_str(q) for q in path)}:{tuple(leaf.shape)}:{leaf.dtype}"
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    )
    return hashlib.sha256("\n".join(paths).encode()).hexdigest()[:16]


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Atomic checkpoint write; returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    final = os.path.join(ckpt_dir, name)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(tree)
    arr_path = os.path.join(tmp, "arrays.npz")
    with open(arr_path, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    manifest = {
        "step": step,
        "tree_hash": tree_hash(tree),
        "n_arrays": len(flat),
        "extra": extra or {},
    }
    man_path = os.path.join(tmp, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _fsync_dir(ckpt_dir)

    # atomic LATEST pointer
    ptr_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.rename(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))

    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    for d in os.listdir(ckpt_dir):
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, step: int, like, *, shardings=None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (optional matching tree of
    NamedShardings) reshards on load — the elastic-restart path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(final, "arrays.npz"))
    want_hash = tree_hash(like)
    if manifest["tree_hash"] != want_hash:
        raise ValueError(
            f"checkpoint tree hash {manifest['tree_hash']} != expected {want_hash}"
            " (config drift?)")
    paths = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = data[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree, manifest


def resume_latest(ckpt_dir: str, like, *, shardings=None):
    """Returns (tree, manifest) or (None, None) when no checkpoint exists."""
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    return restore(ckpt_dir, step, like, shardings=shardings)
