"""The paper's five evaluation networks (Table IV), built from the substrate.

These drive the paper-reproduction benchmarks (Tables I/II, Figs 1/3/5/6,
Fig 11/12 via the perfmodel): the CREW offline analysis consumes their FC
weight matrices exactly as the paper's static pass does.

Dims are set so the FC parameter volume matches Table IV's model sizes
(FP32 FC params only):
  DS2    144 MB — 5 bidirectional GRU layers, hidden 800          (~36 M)
  GNMT   518 MB — 8+8 encoder/decoder LSTM layers, hidden 1024    (~130 M)
  Transf 336 MB — 6+6 encoder/decoder, d=704 ff=2816 (WMT16 base+) (~84 M)
  Kaldi   18 MB — MLP 440 -> 3x1024 -> 1953 senones               (~4.5 M)
  PTBLM  137 MB — 2-layer LSTM, hidden 1500 (Zaremba large)       (~34 M)

Weights are synthesized heavy-tailed ("trained-like", student-t mixture) by
default — no pretrained checkpoints exist offline, and the UW statistics
depend on the weight distribution's kurtosis; EXPERIMENTS.md reports the
sensitivity and cross-checks against a small actually-trained LM.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import numpy as np

__all__ = ["PAPER_MODELS", "PaperModel", "fc_matrices", "synth_weights"]


def _t4_quantile(u: np.ndarray) -> np.ndarray:
    """Exact inverse CDF of Student's t with nu=4 (Shaw 2006 closed form):
    with a = 4u(1-u),  q = sign(u - 1/2) * 2 * sqrt(cos(arccos(sqrt(a))/3)
    / sqrt(a) - 1).  One uniform draw per sample — much cheaper than the
    normal/chi-square ratio for large matrices."""
    a = 4.0 * u * (1.0 - u)
    ra = np.sqrt(a, out=a)
    c = np.cos(np.arccos(ra) / np.float32(3.0))
    np.divide(c, ra, out=c)
    c -= 1.0
    np.maximum(c, 0.0, out=c)  # float32 roundoff can dip below 0 at u ~ 1/2
    q = np.sqrt(c, out=c)
    q *= 2.0
    return np.copysign(q, u - np.float32(0.5), out=q)


def synth_weights(rng: np.random.Generator, n: int, m: int,
                  kind: str = "trained") -> np.ndarray:
    """Synthesize an FC weight matrix with a trained-network-like histogram.

    "trained": student-t(4) body + a sparse outlier tail — heavy-tailed like
    post-training weight matrices (outliers stretch the quantization scale,
    collapsing the body onto few levels: the effect CREW measures).
    "gaussian": control distribution for the sensitivity study.

    The t(4) body is sampled through its closed-form quantile from a single
    float32 uniform draw, and the 1e-4 outlier mask through a binomial count
    plus positions — the same distributions the per-element samplers drew
    from, at a fraction of the RNG cost (the stream, and hence the exact
    realization, changed in PR 2; all consumers are statistical).
    """
    if kind == "gaussian":
        return (rng.standard_normal((n, m), dtype=np.float32)
                * np.float32(0.05))
    u = rng.random((n, m), dtype=np.float32)
    np.clip(u, np.float32(2.0 ** -25), np.float32(1 - 2.0 ** -25), out=u)
    w = _t4_quantile(u)
    w *= np.float32(0.02)
    n_out = rng.binomial(n * m, 1e-4)
    pos = rng.choice(n * m, size=n_out, replace=False)
    w.ravel()[pos] *= np.float32(8.0)
    return w


@dataclasses.dataclass(frozen=True)
class PaperModel:
    name: str
    kind: str          # gru | lstm | transformer | mlp
    accuracy_metric: str
    # list of (layer_name, n_in, n_out) for every FC matrix in the model
    fc_shapes: Tuple[Tuple[str, int, int], ...]

    def fc_param_count(self) -> int:
        return sum(n * m for _, n, m in self.fc_shapes)

    def size_mb_fp32(self) -> float:
        return self.fc_param_count() * 4 / 2 ** 20


def _gru_shapes(name, d_in, hidden, bidir=False):
    """GRU gate matrices: wx [d_in, 3h], wh [h, 3h] (per direction)."""
    dirs = ("fwd", "bwd") if bidir else ("fwd",)
    out = []
    for d in dirs:
        out.append((f"{name}/{d}/wx", d_in, 3 * hidden))
        out.append((f"{name}/{d}/wh", hidden, 3 * hidden))
    return out


def _lstm_shapes(name, d_in, hidden):
    return [(f"{name}/wx", d_in, 4 * hidden), (f"{name}/wh", hidden, 4 * hidden)]


def _transformer_layer(name, d, ff, dec=False):
    out = [(f"{name}/q", d, d), (f"{name}/k", d, d), (f"{name}/v", d, d),
           (f"{name}/o", d, d)]
    if dec:
        out += [(f"{name}/xq", d, d), (f"{name}/xk", d, d),
                (f"{name}/xv", d, d), (f"{name}/xo", d, d)]
    out += [(f"{name}/ff1", d, ff), (f"{name}/ff2", ff, d)]
    return out


def _ds2() -> PaperModel:
    # deepspeech.pytorch: 5 bidirectional GRU layers, hidden 800, with the
    # two directions SUMMED (not concatenated) -> layer input stays 800.
    shapes: List[Tuple[str, int, int]] = []
    h = 800
    shapes += _gru_shapes("gru0", h, h, bidir=True)
    for i in range(1, 5):
        shapes += _gru_shapes(f"gru{i}", h, h, bidir=True)
    shapes.append(("fc_out", h, 29))  # char CTC head
    return PaperModel("DS2", "gru", "WER", tuple(shapes))


def _gnmt() -> PaperModel:
    shapes: List[Tuple[str, int, int]] = []
    h = 1024
    for i in range(8):
        shapes += _lstm_shapes(f"enc{i}", 2 * h if i == 0 else h, h)
    for i in range(8):
        shapes += _lstm_shapes(f"dec{i}", 2 * h if i == 0 else h, h)
    shapes.append(("attn/w", h, h))
    return PaperModel("GNMT", "lstm", "BLEU", tuple(shapes))


def _transformer() -> PaperModel:
    d, ff = 704, 2816
    shapes: List[Tuple[str, int, int]] = []
    for i in range(6):
        shapes += _transformer_layer(f"enc{i}", d, ff)
    for i in range(6):
        shapes += _transformer_layer(f"dec{i}", d, ff, dec=True)
    return PaperModel("Transformer", "transformer", "BLEU", tuple(shapes))


def _kaldi() -> PaperModel:
    dims = [440, 1024, 1024, 1024, 1953]
    shapes = tuple((f"affine{i}", dims[i], dims[i + 1]) for i in range(len(dims) - 1))
    return PaperModel("Kaldi", "mlp", "WER", shapes)


def _ptblm() -> PaperModel:
    h = 1500
    shapes: List[Tuple[str, int, int]] = []
    for i in range(2):
        shapes += _lstm_shapes(f"lstm{i}", h, h)
    return PaperModel("PTBLM", "lstm", "Perplexity", tuple(shapes))


PAPER_MODELS: Dict[str, PaperModel] = {
    m.name: m for m in (_ds2(), _gnmt(), _transformer(), _kaldi(), _ptblm())
}


# Materialized paper models are pure functions of (model, seed, kind) and
# several benchmark modules walk the same models back to back, so a small
# LRU keeps the biggest cost of a benchmark run — synthesizing hundreds of
# MB of weights — paid once per process.  Entries are shared: callers must
# treat the returned arrays as read only (every consumer copies on write:
# quantization, conversion and the perf model never mutate their input).
FC_CACHE_MAX = 3


@functools.lru_cache(maxsize=FC_CACHE_MAX)
def _fc_matrices_cached(model: PaperModel, seed: int, kind: str):
    rng = np.random.default_rng(seed)
    return [(name, synth_weights(rng, n, m, kind))
            for name, n, m in model.fc_shapes]


def fc_matrices(model: PaperModel, seed: int = 0,
                kind: str = "trained") -> List[Tuple[str, np.ndarray]]:
    """Materialize every FC matrix of a paper model (synthesized weights,
    LRU-memoized per (model, seed, kind) — treat the arrays as read only).
    The wrapper pins the cached call to positional form so keyword and
    positional call sites share one cache entry."""
    return _fc_matrices_cached(model, seed, kind)
