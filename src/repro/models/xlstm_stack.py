"""xLSTM LM stack: alternating (mLSTM, sLSTM) block pairs.

n_layers must be even; the stack scans over n_layers/2 pairs with stacked
params.  Both cells carry O(1)-size recurrent state, which is what
qualifies xlstm-125m for the long_500k decode cell.

Prefill is the recurrent sweep (lax.scan over time inside each cell) —
honest but sequential; a chunked-parallel mLSTM is the recorded §Perf
iteration candidate for this family.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..dist.ctx import constrain
from ..layers import embed, norms, xlstm

__all__ = [
    "init", "param_spec", "forward", "decode_step",
    "init_cache", "cache_spec",
]


def _pairs(cfg: ModelConfig) -> int:
    if cfg.n_layers % 2 != 0:
        raise ValueError("xLSTM stack needs an even layer count")
    return cfg.n_layers // 2


def init(rng, cfg: ModelConfig, *, dtype=jnp.float32) -> Dict[str, Any]:
    p = _pairs(cfg)
    ks = jax.random.split(rng, 4)
    xc = cfg.xlstm
    return {
        "embed": embed.init(ks[0], cfg.vocab, cfg.d_model,
                            tie=cfg.tie_embeddings, dtype=dtype),
        "pairs": {
            "mn": norms.rms_init(cfg.d_model, dtype=dtype, stack=(p,)),
            "m": xlstm.mlstm_init(ks[1], cfg.d_model, cfg.n_heads,
                                  pf=xc.mlstm_pf, dtype=dtype, stack=(p,)),
            "sn": norms.rms_init(cfg.d_model, dtype=dtype, stack=(p,)),
            "s": xlstm.slstm_init(ks[2], cfg.d_model, cfg.n_heads,
                                  pf=xc.slstm_pf, dtype=dtype, stack=(p,)),
        },
        "final_norm": norms.rms_init(cfg.d_model, dtype=dtype),
    }


def param_spec(cfg: ModelConfig) -> Dict[str, Any]:
    sa = (None,)
    return {
        "embed": embed.spec(tie=cfg.tie_embeddings),
        "pairs": {
            "mn": norms.rms_spec(stack_axes=sa),
            "m": xlstm.mlstm_spec(stack_axes=sa),
            "sn": norms.rms_spec(stack_axes=sa),
            "s": xlstm.slstm_spec(stack_axes=sa),
        },
        "final_norm": norms.rms_spec(),
    }


def _pair_apply(cfg: ModelConfig, pp, x, m_state, s_state, crew_strategy):
    xc = cfg.xlstm
    x = constrain(x, "batch", None, None)
    h = norms.rms_apply(pp["mn"], x)
    y, m_new = xlstm.mlstm_apply(pp["m"], h, m_state, n_heads=cfg.n_heads,
                                 pf=xc.mlstm_pf, crew_strategy=crew_strategy)
    x = x + y
    h = norms.rms_apply(pp["sn"], x)
    y, s_new = xlstm.slstm_apply(pp["s"], h, s_state, n_heads=cfg.n_heads,
                                 crew_strategy=crew_strategy)
    return x + y, m_new, s_new


def forward(
    params,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    *,
    dtype=jnp.bfloat16,
    remat: bool = False,
    crew_strategy: str = "auto",
    logits_mode: str = "all",
    **_unused,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    x = embed.embed(params["embed"], batch["tokens"], dtype=dtype)

    def pair(x, pp):
        x, _, _ = _pair_apply(cfg, pp, x, None, None, crew_strategy)
        return x, None

    if remat:
        pair = jax.checkpoint(pair)
    x, _ = jax.lax.scan(pair, x, params["pairs"])
    x = norms.rms_apply(params["final_norm"], x)
    if logits_mode == "last":
        x = x[:, -1:]
    logits = embed.logits(params["embed"], x)
    return logits, {"moe_aux": jnp.zeros(())}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    # seq_len is irrelevant: recurrent state is O(1) in sequence length.
    p = _pairs(cfg)
    return {
        "m": xlstm.mlstm_state(batch, cfg.d_model, cfg.n_heads,
                               pf=cfg.xlstm.mlstm_pf, stack=(p,)),
        "s": xlstm.slstm_state(batch, cfg.d_model, stack=(p,)),
        "len": jnp.zeros((), dtype=jnp.int32),
    }


def cache_spec(cfg: ModelConfig) -> Dict[str, Any]:
    from jax.sharding import PartitionSpec as P
    return {
        "m": xlstm.mlstm_state_spec(stack_axes=(None,)),
        "s": xlstm.slstm_state_spec(stack_axes=(None,)),
        "len": P(),
    }


def decode_step(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    cache: Dict[str, Any],
    *,
    dtype=jnp.bfloat16,
    crew_strategy: str = "auto",
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    x = embed.embed(params["embed"], tokens, dtype=dtype)  # [B, 1, d]

    def pair(x, inp):
        pp, m_st, s_st = inp
        x, m_new, s_new = _pair_apply(cfg, pp, x, m_st, s_st, crew_strategy)
        return x, (m_new, s_new)

    x, (m_new, s_new) = jax.lax.scan(
        pair, x, (params["pairs"], cache["m"], cache["s"]))
    x = norms.rms_apply(params["final_norm"], x)
    logits = embed.logits(params["embed"], x)[:, 0]
    return logits, {"m": m_new, "s": s_new, "len": cache["len"] + 1}
