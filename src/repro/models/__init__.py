"""Unified model API: ``build_model(cfg) -> ModelApi``.

Every family exposes the same functional surface so train/serve/launch code
is family-agnostic:

    api.init(rng, dtype)                  -> params            (real arrays)
    api.abstract_params(dtype)            -> ShapeDtypeStructs (no allocation)
    api.param_spec()                      -> logical PartitionSpec tree
    api.forward(params, batch, **kw)      -> (logits, aux)     (train/prefill)
    api.init_cache(batch, seq_len, dtype) -> decode state
    api.abstract_cache(batch, seq_len)    -> ShapeDtypeStructs
    api.cache_spec()                      -> logical PartitionSpec tree
    api.decode_step(params, tok, cache)   -> (logits, cache)
    api.input_specs(shape)                -> abstract batch for the cell
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import hybrid, transformer, xlstm_stack
from . import paper  # noqa: F401  (re-export)

__all__ = ["ModelApi", "build_model", "paper"]


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    _mod: Any

    # ---- params ----
    def init(self, rng, dtype=jnp.float32):
        return self._mod.init(rng, self.cfg, dtype=dtype)

    def abstract_params(self, dtype=jnp.float32):
        rng = jax.random.PRNGKey(0)
        return jax.eval_shape(lambda r: self._mod.init(r, self.cfg, dtype=dtype), rng)

    def param_spec(self):
        return self._mod.param_spec(self.cfg)

    # ---- compute ----
    def forward(self, params, batch, **kw):
        return self._mod.forward(params, self.cfg, batch, **kw)

    def decode_step(self, params, tokens, cache, **kw):
        return self._mod.decode_step(params, self.cfg, tokens, cache, **kw)

    def prefill(self, params, batch, cache_len: int, **kw):
        if not hasattr(self._mod, "prefill"):
            raise NotImplementedError(
                f"{self.cfg.family} has no prefill-with-cache path")
        return self._mod.prefill(params, self.cfg, batch, cache_len, **kw)

    def prefill_chunk(self, params, tokens, cache, **kw):
        """One prompt chunk against a partially filled cache (DESIGN.md §5)."""
        if not hasattr(self._mod, "prefill_chunk"):
            raise NotImplementedError(
                f"{self.cfg.family} has no chunked-prefill path")
        return self._mod.prefill_chunk(params, self.cfg, tokens, cache, **kw)

    # ---- decode state ----
    def init_cache(self, batch: int, seq_len: int, dtype=jnp.bfloat16):
        return self._mod.init_cache(self.cfg, batch, seq_len, dtype=dtype)

    def abstract_cache(self, batch: int, seq_len: int, dtype=jnp.bfloat16):
        return jax.eval_shape(
            lambda: self._mod.init_cache(self.cfg, batch, seq_len, dtype=dtype))

    def cache_spec(self):
        return self._mod.cache_spec(self.cfg)

    # ---- abstract inputs per (arch x shape) cell ----
    def input_specs(self, shape: ShapeConfig, *, dtype=jnp.bfloat16) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for the batch of a given cell.

        train/prefill: the full-sequence batch (tokens+labels / frames /
        tokens+patches).  decode: the one-token step input; the KV/SSM cache
        comes from ``abstract_cache`` (sized to shape.seq_len).
        """
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
        if shape.kind == "decode":
            if not cfg.has_decode:
                raise ValueError(f"{cfg.arch_id} is encoder-only: no decode")
            return {"tokens": i32((b, 1))}
        if cfg.family == "encoder":
            batch = {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype)}
            if shape.kind == "train":
                batch["labels"] = i32((b, s))
            return batch
        if cfg.family == "vlm":
            p = cfg.vision_patches
            batch = {
                "tokens": i32((b, s - p)),
                "patches": jax.ShapeDtypeStruct((b, p, cfg.d_model), dtype),
            }
            if shape.kind == "train":
                batch["labels"] = i32((b, s - p))
            return batch
        batch = {"tokens": i32((b, s))}
        if shape.kind == "train":
            batch["labels"] = i32((b, s))
        return batch


_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "encoder": transformer,
    "hybrid": hybrid,
    "ssm_xlstm": xlstm_stack,
}


def build_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family not in _FAMILY_MODULES:
        raise KeyError(f"unknown family {cfg.family!r}")
    return ModelApi(cfg=cfg, _mod=_FAMILY_MODULES[cfg.family])
