"""Decoder / encoder transformer stacks (dense, MoE, VLM, encoder families).

All layer params are stacked on a leading [L] axis and the stack runs as a
``lax.scan`` — constant-depth HLO regardless of layer count, which keeps
512-device dry-run compiles tractable and matches how production JAX LM
frameworks (MaxText et al.) structure deep models.

Families:
  dense   — causal LM, SwiGLU FFN, (GQA/MQA) attention, RoPE.
  moe     — causal LM with a top-k MoE FFN per layer (EP-shardable).
  vlm     — dense causal LM consuming [patch embeddings ; token embeddings].
  encoder — bidirectional, LayerNorm + GELU FFN, continuous frame inputs,
            CTC-style head (no decode path).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..dist.ctx import constrain
from ..layers import attention, embed, mlp, moe, norms

__all__ = [
    "init", "param_spec", "forward", "prefill", "prefill_chunk",
    "decode_step", "init_cache", "cache_spec",
]


def _is_encoder(cfg: ModelConfig) -> bool:
    return cfg.family == "encoder"


def _shard_kv(cfg: ModelConfig) -> bool:
    # MQA (kv=1) cannot split one KV head across the TP axis.
    return cfg.n_kv > 1


# --------------------------------------------------------------------------
# Init / specs
# --------------------------------------------------------------------------

def init(rng, cfg: ModelConfig, *, dtype=jnp.float32) -> Dict[str, Any]:
    l = cfg.n_layers
    ks = jax.random.split(rng, 5)
    blocks: Dict[str, Any] = {
        "attn": attention.init(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, dtype=dtype, stack=(l,)),
    }
    if _is_encoder(cfg):
        blocks["n1"] = norms.ln_init(cfg.d_model, dtype=dtype, stack=(l,))
        blocks["n2"] = norms.ln_init(cfg.d_model, dtype=dtype, stack=(l,))
        blocks["ffn"] = mlp.gelu_init(ks[1], cfg.d_model, cfg.d_ff,
                                      dtype=dtype, stack=(l,))
    else:
        blocks["n1"] = norms.rms_init(cfg.d_model, dtype=dtype, stack=(l,))
        blocks["n2"] = norms.rms_init(cfg.d_model, dtype=dtype, stack=(l,))
        if cfg.moe is not None:
            blocks["moe"] = moe.init(ks[1], cfg.d_model, cfg.d_ff,
                                     cfg.moe.n_experts, dtype=dtype, stack=(l,))
        elif cfg.mlp == "gelu":
            blocks["ffn"] = mlp.gelu_init(ks[1], cfg.d_model, cfg.d_ff,
                                          dtype=dtype, stack=(l,))
        else:
            blocks["ffn"] = mlp.swiglu_init(ks[1], cfg.d_model, cfg.d_ff,
                                            dtype=dtype, stack=(l,))
    params: Dict[str, Any] = {"blocks": blocks}
    if _is_encoder(cfg):
        # continuous frame inputs; output head is a CTC-style projection
        params["head"] = {
            "w": jax.random.normal(ks[2], (cfg.d_model, cfg.vocab)).astype(dtype)
            * cfg.d_model ** -0.5
        }
        params["final_norm"] = norms.ln_init(cfg.d_model, dtype=dtype)
    else:
        params["embed"] = embed.init(ks[2], cfg.vocab, cfg.d_model,
                                     tie=cfg.tie_embeddings, dtype=dtype)
        params["final_norm"] = norms.rms_init(cfg.d_model, dtype=dtype)
    return params


def param_spec(cfg: ModelConfig) -> Dict[str, Any]:
    sa = (None,)  # layer-stack axis is never sharded
    blocks: Dict[str, Any] = {
        "attn": attention.spec(qkv_bias=cfg.qkv_bias, stack_axes=sa,
                               shard_kv=_shard_kv(cfg)),
    }
    if _is_encoder(cfg):
        blocks["n1"] = norms.ln_spec(stack_axes=sa)
        blocks["n2"] = norms.ln_spec(stack_axes=sa)
        blocks["ffn"] = mlp.gelu_spec(stack_axes=sa)
    else:
        blocks["n1"] = norms.rms_spec(stack_axes=sa)
        blocks["n2"] = norms.rms_spec(stack_axes=sa)
        if cfg.moe is not None:
            blocks["moe"] = moe.spec(stack_axes=sa)
        elif cfg.mlp == "gelu":
            blocks["ffn"] = mlp.gelu_spec(stack_axes=sa)
        else:
            blocks["ffn"] = mlp.swiglu_spec(stack_axes=sa)
    spec: Dict[str, Any] = {"blocks": blocks}
    if _is_encoder(cfg):
        spec["head"] = {"w": P("embed", "vocab")}
        spec["final_norm"] = norms.ln_spec()
    else:
        spec["embed"] = embed.spec(tie=cfg.tie_embeddings)
        spec["final_norm"] = norms.rms_spec()
    return spec


# --------------------------------------------------------------------------
# Forward (train / prefill)
# --------------------------------------------------------------------------

def _ffn_apply(cfg: ModelConfig, blk, h, crew_strategy, crew_state=None):
    """Returns (y, aux_loss, new_ffn_state).  ``crew_state`` is the decode
    product-buffer mirror of the FFN params (None when stateless; MoE
    expert stacks carry no state — their mirror passes through)."""
    if _is_encoder(cfg) or cfg.mlp == "gelu":
        if crew_state is None:
            return (mlp.gelu_apply(blk["ffn"], h,
                                   crew_strategy=crew_strategy), 0.0, None)
        y, st = mlp.gelu_apply(blk["ffn"], h, crew_strategy=crew_strategy,
                               crew_state=crew_state)
        return y, 0.0, st
    if cfg.moe is not None:
        y, stats = moe.apply(blk["moe"], h, top_k=cfg.moe.top_k,
                             capacity_factor=cfg.moe.capacity_factor,
                             group_size=cfg.moe.group_size,
                             crew_strategy=crew_strategy)
        return y, stats.aux_loss, crew_state
    if crew_state is None:
        return (mlp.swiglu_apply(blk["ffn"], h,
                                 crew_strategy=crew_strategy), 0.0, None)
    y, st = mlp.swiglu_apply(blk["ffn"], h, crew_strategy=crew_strategy,
                             crew_state=crew_state)
    return y, 0.0, st


def _norm(cfg: ModelConfig, p, x):
    return norms.ln_apply(p, x) if _is_encoder(cfg) else norms.rms_apply(p, x)


def forward(
    params,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    *,
    dtype=jnp.bfloat16,
    remat: bool = False,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    crew_strategy: str = "auto",
    logits_mode: str = "all",
    attn_impl: str = "chunked",
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence forward -> (logits [B, S, vocab] f32, aux dict).

    batch: {"tokens": [B, S]} (dense/moe), plus {"patches": [B, P, d]} (vlm),
    or {"frames": [B, S, d]} (encoder).

    logits_mode="last" slices the final hidden state to the last position
    *before* the LM head matmul — the serving-prefill path, which avoids
    materializing [B, S, vocab].
    """
    causal = not _is_encoder(cfg)
    if _is_encoder(cfg):
        x = batch["frames"].astype(dtype)
    else:
        x = embed.embed(params["embed"], batch["tokens"], dtype=dtype)
        if cfg.family == "vlm":
            patches = batch["patches"].astype(dtype)
            x = jnp.concatenate([patches, x], axis=1)

    def block(x, blk):
        x = constrain(x, "batch", None, None)
        h = _norm(cfg, blk["n1"], x)
        y, _ = attention.attend(
            blk["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            d_head=cfg.head_dim, rope_theta=cfg.rope_theta, causal=causal,
            q_chunk=q_chunk, kv_chunk=kv_chunk, crew_strategy=crew_strategy,
            impl=attn_impl)
        x = x + y
        h = _norm(cfg, blk["n2"], x)
        y, aux, _ = _ffn_apply(cfg, blk, h, crew_strategy)
        return constrain(x + y, "batch", None, None), aux

    if remat:
        block = jax.checkpoint(block)

    def step(x, blk):
        x, aux = block(x, blk)
        return x, aux

    x, auxs = jax.lax.scan(step, x, params["blocks"])
    x = _norm(cfg, params["final_norm"], x)
    if logits_mode == "last":
        x = x[:, -1:]
    if _is_encoder(cfg):
        from ..layers import linear as _linear  # CREW-dispatching head
        logits = _linear.apply(params["head"], x.astype(jnp.float32),
                               plan=crew_strategy)
        logits = constrain(logits, "batch", None, "vocab")
    else:
        logits = embed.logits(params["embed"], x)
    aux = {"moe_aux": jnp.sum(auxs) if cfg.moe is not None else jnp.zeros(())}
    return logits, aux


def prefill(
    params,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    cache_len: int,
    *,
    dtype=jnp.bfloat16,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    crew_strategy: str = "auto",
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Full-sequence forward that also fills a decode cache of ``cache_len``.

    Returns (logits [B, S, vocab] f32, cache).  The prompt occupies cache
    positions [0, S); ``len`` is set to S so decode continues from there.
    """
    if _is_encoder(cfg):
        raise ValueError("encoder family has no decode cache")
    x = embed.embed(params["embed"], batch["tokens"], dtype=dtype)
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["patches"].astype(dtype), x], axis=1)
    b, s, _ = x.shape

    def step(x, blk):
        h = _norm(cfg, blk["n1"], x)
        y, (k, v) = attention.attend(
            blk["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            d_head=cfg.head_dim, rope_theta=cfg.rope_theta, causal=True,
            q_chunk=q_chunk, kv_chunk=kv_chunk, crew_strategy=crew_strategy)
        x = x + y
        h = _norm(cfg, blk["n2"], x)
        y, _, _ = _ffn_apply(cfg, blk, h, crew_strategy)
        pad = ((0, 0), (0, cache_len - s), (0, 0), (0, 0))
        return x + y, (jnp.pad(k, pad).astype(dtype), jnp.pad(v, pad).astype(dtype))

    x, (k_all, v_all) = jax.lax.scan(step, x, params["blocks"])
    x = _norm(cfg, params["final_norm"], x)
    logits = embed.logits(params["embed"], x)
    cache = {"k": k_all, "v": v_all, "len": jnp.asarray(s, jnp.int32)}
    return logits, cache


def prefill_chunk(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    cache: Dict[str, Any],
    *,
    dtype=jnp.bfloat16,
    crew_strategy: str = "auto",
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One prefill chunk against a partially filled cache (DESIGN.md §5).

    tokens [B, C] are C consecutive prompt tokens starting at cache
    position ``cache["len"]`` (scalar or per-lane [B]); positions before
    the offset hold reused KV state — a prefix-cache hit or earlier
    chunks — that is attended, never recomputed.  Returns
    (logits [B, C, vocab] f32, cache with ``len`` advanced by C).
    Chunk-by-chunk prefill is token- and cache-bitwise-identical to the
    monolithic :func:`prefill` (pinned by tests/test_prefix_cache.py).
    """
    if _is_encoder(cfg):
        raise ValueError("encoder family has no decode cache")
    if cfg.family == "vlm":
        raise NotImplementedError("vlm prefill is not chunkable (patches)")
    x = embed.embed(params["embed"], tokens, dtype=dtype)
    off = cache["len"]
    tbl = cache.get("table")    # [B, NB] block table -> paged pool layout

    def step(x, inp):
        blk, k_c, v_c = inp
        h = _norm(cfg, blk["n1"], x)
        if tbl is None:
            y, new = attention.attend_prefill_cached(
                blk["attn"], h, {"k": k_c, "v": v_c, "len": off},
                n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.head_dim,
                rope_theta=cfg.rope_theta, crew_strategy=crew_strategy)
        else:
            y, new = attention.attend_prefill_cached_paged(
                blk["attn"], h,
                {"k": k_c, "v": v_c, "len": off, "table": tbl},
                n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.head_dim,
                rope_theta=cfg.rope_theta, crew_strategy=crew_strategy)
        x = x + y
        h = _norm(cfg, blk["n2"], x)
        y, _, _ = _ffn_apply(cfg, blk, h, crew_strategy)
        return x + y, (new["k"], new["v"])

    x, (k_new, v_new) = jax.lax.scan(
        step, x, (params["blocks"], cache["k"], cache["v"]))
    x = _norm(cfg, params["final_norm"], x)
    logits = embed.logits(params["embed"], x)
    new_cache = {"k": k_new, "v": v_new, "len": off + tokens.shape[1]}
    if tbl is not None:
        new_cache["table"] = tbl
    return logits, new_cache


# --------------------------------------------------------------------------
# Decode (one token against a static KV cache)
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    kv = attention.init_kv_cache(batch, seq_len, cfg.n_kv, cfg.head_dim,
                                 dtype=dtype, stack=(cfg.n_layers,))
    return {"k": kv["k"], "v": kv["v"], "len": kv["len"]}


def cache_spec(cfg: ModelConfig) -> Dict[str, Any]:
    s = attention.cache_spec(stack_axes=(None,), shard_kv=_shard_kv(cfg))
    return {"k": s["k"], "v": s["v"], "len": s["len"]}


def decode_step(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    cache: Dict[str, Any],
    *,
    dtype=jnp.bfloat16,
    crew_strategy: str = "auto",
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """tokens [B, 1] -> (logits [B, vocab] f32, new cache).

    ``cache`` may carry a ``"crew"`` entry — the decode product-buffer
    state tree ``repro.serve.decode_state_for_params`` builds (DESIGN.md
    §3): its ``"blocks"`` mirror rides the layer scan as an extra
    xs/ys pair, so each layer's CREW projections run the VMEM-resident
    decode kernel against their own carried buffer, and the returned
    cache carries the updated tree for the next step's carry.  Without
    it the step is the historical stateless path, bit for bit.
    """
    if _is_encoder(cfg):
        raise ValueError("encoder family has no decode step")
    x = embed.embed(params["embed"], tokens, dtype=dtype)
    ln = cache["len"]
    cs = cache.get("crew")
    tbl = cache.get("table")    # [B, NB] block table -> paged pool layout
    ffn_key = "moe" if cfg.moe is not None else "ffn"

    def _attend(blk, h, k_c, v_c, crew_state=None):
        if tbl is None:
            return attention.attend_decode(
                blk["attn"], h, {"k": k_c, "v": v_c, "len": ln},
                n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.head_dim,
                rope_theta=cfg.rope_theta, crew_strategy=crew_strategy,
                crew_state=crew_state)
        return attention.attend_decode_paged(
            blk["attn"], h, {"k": k_c, "v": v_c, "len": ln, "table": tbl},
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.head_dim,
            rope_theta=cfg.rope_theta, crew_strategy=crew_strategy,
            crew_state=crew_state)

    def step(x, inp):
        blk, k_c, v_c = inp
        h = _norm(cfg, blk["n1"], x)
        y, new = _attend(blk, h, k_c, v_c)
        x = x + y
        h = _norm(cfg, blk["n2"], x)
        y, _, _ = _ffn_apply(cfg, blk, h, crew_strategy)
        return x + y, (new["k"], new["v"])

    def step_crew(x, inp):
        blk, k_c, v_c, st = inp
        h = _norm(cfg, blk["n1"], x)
        y, new = _attend(blk, h, k_c, v_c, crew_state=st["attn"])
        x = x + y
        h = _norm(cfg, blk["n2"], x)
        y, _, st_ffn = _ffn_apply(cfg, blk, h, crew_strategy,
                                  crew_state=st.get(ffn_key))
        st_new = {**st, "attn": new["crew"], ffn_key: st_ffn}
        return x + y, (new["k"], new["v"], st_new)

    if cs is None:
        x, (k_new, v_new) = jax.lax.scan(
            step, x, (params["blocks"], cache["k"], cache["v"]))
    else:
        x, (k_new, v_new, cs_blocks) = jax.lax.scan(
            step_crew, x,
            (params["blocks"], cache["k"], cache["v"], cs["blocks"]))
    x = _norm(cfg, params["final_norm"], x)
    logits = embed.logits(params["embed"], x)[:, 0]
    new_cache = {"k": k_new, "v": v_new, "len": ln + 1}
    if cs is not None:
        new_cache["crew"] = {**cs, "blocks": cs_blocks}
    if tbl is not None:
        new_cache["table"] = tbl
    return logits, new_cache
