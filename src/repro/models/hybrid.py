"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block.

The backbone is ``n_layers`` Mamba2 (SSD) blocks; after every
``hybrid.attn_every`` of them, a single shared transformer block (attention
+ SwiGLU, one set of weights reused at every application) runs — Zamba2's
parameter-efficient global-attention design.  CREW compounds here: the
shared block's weights are CREW-ized once and their partial-product reuse
applies at every one of the L/attn_every applications.

Layer scan structure: outer scan over G = n_layers/attn_every groups; inner
scan over the attn_every Mamba2 layers of the group; the shared block
(closure-captured, no scan axis) closes each group.

Decode state: per-layer Mamba2 (conv tail + SSD state) stacked [G, per, ...]
plus one KV cache per shared-block application, stacked [G, ...].  The KV
cache shards its sequence axis over "data" in the long_500k cell (SP) —
batch=1 gives DP nothing to do.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..dist.ctx import constrain
from ..layers import attention, embed, mamba2, mlp, norms

__all__ = [
    "init", "param_spec", "forward", "decode_step",
    "init_cache", "cache_spec",
]


def _groups(cfg: ModelConfig) -> Tuple[int, int]:
    per = cfg.hybrid.attn_every
    if cfg.n_layers % per != 0:
        raise ValueError(f"n_layers {cfg.n_layers} not divisible by attn_every {per}")
    return cfg.n_layers // per, per


def init(rng, cfg: ModelConfig, *, dtype=jnp.float32) -> Dict[str, Any]:
    g, per = _groups(cfg)
    ks = jax.random.split(rng, 6)
    s = cfg.ssm
    return {
        "embed": embed.init(ks[0], cfg.vocab, cfg.d_model,
                            tie=cfg.tie_embeddings, dtype=dtype),
        "mamba": {
            "norm": norms.rms_init(cfg.d_model, dtype=dtype, stack=(g, per)),
            "mixer": mamba2.init(ks[1], cfg.d_model, expand=s.expand,
                                 head_dim=s.head_dim, state=s.state,
                                 dtype=dtype, stack=(g, per)),
        },
        "shared": {
            "n1": norms.rms_init(cfg.d_model, dtype=dtype),
            "attn": attention.init(ks[2], cfg.d_model, cfg.n_heads, cfg.n_kv,
                                   cfg.head_dim, dtype=dtype),
            "n2": norms.rms_init(cfg.d_model, dtype=dtype),
            "ffn": mlp.swiglu_init(ks[3], cfg.d_model, cfg.d_ff, dtype=dtype),
        },
        "final_norm": norms.rms_init(cfg.d_model, dtype=dtype),
    }


def param_spec(cfg: ModelConfig) -> Dict[str, Any]:
    sa = (None, None)  # (group, layer-in-group) scan axes
    return {
        "embed": embed.spec(tie=cfg.tie_embeddings),
        "mamba": {
            "norm": norms.rms_spec(stack_axes=sa),
            "mixer": mamba2.spec(stack_axes=sa),
        },
        "shared": {
            "n1": norms.rms_spec(),
            "attn": attention.spec(shard_kv=cfg.n_kv > 1),
            "n2": norms.rms_spec(),
            "ffn": mlp.swiglu_spec(),
        },
        "final_norm": norms.rms_spec(),
    }


# --------------------------------------------------------------------------
# Forward (train / prefill)
# --------------------------------------------------------------------------

def forward(
    params,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    *,
    dtype=jnp.bfloat16,
    remat: bool = False,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    crew_strategy: str = "auto",
    logits_mode: str = "all",
    attn_impl: str = "chunked",
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    s = cfg.ssm
    x = embed.embed(params["embed"], batch["tokens"], dtype=dtype)
    shared = params["shared"]

    def mamba_layer(x, lp):
        x = constrain(x, "batch", None, None)
        h = norms.rms_apply(lp["norm"], x)
        y, _ = mamba2.apply_chunked(lp["mixer"], h, head_dim=s.head_dim,
                                    state=s.state, chunk=s.chunk,
                                    crew_strategy=crew_strategy)
        return constrain(x + y, "batch", None, None), None

    if remat:
        mamba_layer = jax.checkpoint(mamba_layer)

    def group(x, gp):
        x, _ = jax.lax.scan(mamba_layer, x, gp)
        h = norms.rms_apply(shared["n1"], x)
        y, _ = attention.attend(shared["attn"], h, n_heads=cfg.n_heads,
                                n_kv=cfg.n_kv, d_head=cfg.head_dim,
                                rope_theta=cfg.rope_theta, causal=True,
                                q_chunk=q_chunk, kv_chunk=kv_chunk,
                                crew_strategy=crew_strategy, impl=attn_impl)
        x = x + y
        h = norms.rms_apply(shared["n2"], x)
        x = x + mlp.swiglu_apply(shared["ffn"], h, crew_strategy=crew_strategy)
        return x, None

    x, _ = jax.lax.scan(group, x, params["mamba"])
    x = norms.rms_apply(params["final_norm"], x)
    if logits_mode == "last":
        x = x[:, -1:]
    logits = embed.logits(params["embed"], x)
    return logits, {"moe_aux": jnp.zeros(())}


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    g, per = _groups(cfg)
    s = cfg.ssm
    ssm = mamba2.init_state(batch, cfg.d_model, expand=s.expand,
                            head_dim=s.head_dim, state=s.state,
                            dtype=dtype, stack=(g, per))
    kv = attention.init_kv_cache(batch, seq_len, cfg.n_kv, cfg.head_dim,
                                 dtype=dtype, stack=(g,))
    return {"ssm": ssm, "k": kv["k"], "v": kv["v"], "len": kv["len"]}


def cache_spec(cfg: ModelConfig) -> Dict[str, Any]:
    ssm = mamba2.state_spec(stack_axes=(None, None))
    kv = attention.cache_spec(stack_axes=(None,), shard_kv=cfg.n_kv > 1)
    return {"ssm": ssm, "k": kv["k"], "v": kv["v"], "len": kv["len"]}


def decode_step(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    cache: Dict[str, Any],
    *,
    dtype=jnp.bfloat16,
    crew_strategy: str = "auto",
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    s = cfg.ssm
    x = embed.embed(params["embed"], tokens, dtype=dtype)
    shared = params["shared"]
    ln = cache["len"]

    def mamba_layer(x, inp):
        lp, st = inp
        h = norms.rms_apply(lp["norm"], x)
        y, st_new = mamba2.apply_decode(lp["mixer"], h, st, head_dim=s.head_dim,
                                        state=s.state,
                                        crew_strategy=crew_strategy)
        return x + y, st_new

    def group(x, inp):
        gp, g_ssm, k_c, v_c = inp
        x, ssm_new = jax.lax.scan(mamba_layer, x, (gp, g_ssm))
        h = norms.rms_apply(shared["n1"], x)
        y, new = attention.attend_decode(
            shared["attn"], h, {"k": k_c, "v": v_c, "len": ln},
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.head_dim,
            rope_theta=cfg.rope_theta, crew_strategy=crew_strategy)
        x = x + y
        h = norms.rms_apply(shared["n2"], x)
        x = x + mlp.swiglu_apply(shared["ffn"], h, crew_strategy=crew_strategy)
        return x, (ssm_new, new["k"], new["v"])

    x, (ssm_new, k_new, v_new) = jax.lax.scan(
        group, x, (params["mamba"], cache["ssm"], cache["k"], cache["v"]))
    x = norms.rms_apply(params["final_norm"], x)
    logits = embed.logits(params["embed"], x)[:, 0]
    return logits, {"ssm": ssm_new, "k": k_new, "v": v_new, "len": ln + 1}
