"""Runnable JAX versions of the paper's RNN/MLP workloads.

`paper.py` carries the exact published FC dims for the offline CREW
analysis; this module makes the same architectures *executable* so the
paper's workloads run end-to-end through the framework's CREW-dispatching
layers (every gate projection is a `layers.linear` leaf, so
`serve.crewize_params` converts them like any other checkpoint):

  * PTBLM  — embedding + N-layer LSTM + tied-dim softmax head (Zaremba).
  * DS2    — bidirectional-GRU stack over precomputed audio features with
             a CTC-style character head (conv frontend stubbed, like the
             assignment's audio frontends).
  * Kaldi  — plain MLP over acoustic features -> senone posteriors.

Scaled-down by default (`width=` multiplier) so they train/serve on CPU;
`width=1.0` gives the paper's dims.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..layers import embed, linear, recurrent

__all__ = [
    "ptblm_init", "ptblm_apply",
    "ds2_init", "ds2_apply",
    "kaldi_init", "kaldi_apply",
]


# --------------------------------------------------------------------------
# PTBLM — word-level LSTM LM (Zaremba et al.)
# --------------------------------------------------------------------------

def ptblm_init(rng, *, vocab: int = 10_000, hidden: int = 1500,
               n_layers: int = 2, width: float = 1.0, dtype=jnp.float32):
    h = max(8, int(hidden * width))
    ks = jax.random.split(rng, n_layers + 2)
    return {
        "embed": embed.init(ks[0], vocab, h, tie=True, dtype=dtype),
        "lstm": [recurrent.lstm_init(ks[1 + i], h, h, dtype=dtype)
                 for i in range(n_layers)],
    }


def ptblm_apply(params, tokens: jnp.ndarray, crew_strategy: str = "auto"):
    """tokens [B, S] -> logits [B, S, vocab] (tied head)."""
    x = embed.embed(params["embed"], tokens, dtype=jnp.float32)
    for lp in params["lstm"]:
        y, _ = recurrent.lstm_apply(lp, x)
        x = x + y  # residual keeps deep variants trainable
    return embed.logits(params["embed"], x)


# --------------------------------------------------------------------------
# DS2 — bidirectional GRU stack over audio features (CTC head)
# --------------------------------------------------------------------------

def _bigru_init(rng, d_in, h, dtype):
    k1, k2 = jax.random.split(rng)
    return {"fwd": recurrent.gru_init(k1, d_in, h, dtype=dtype),
            "bwd": recurrent.gru_init(k2, d_in, h, dtype=dtype)}


def _bigru_apply(params, x):
    # deepspeech.pytorch sums the two directions (keeps layer width at h)
    yf, _ = recurrent.gru_apply(params["fwd"], x)
    yb, _ = recurrent.gru_apply(params["bwd"], x[:, ::-1])
    return yf + yb[:, ::-1]


def ds2_init(rng, *, n_features: int = 161, hidden: int = 800,
             n_layers: int = 5, n_chars: int = 29, width: float = 1.0,
             dtype=jnp.float32):
    h = max(8, int(hidden * width))
    ks = jax.random.split(rng, n_layers + 1)
    layers = [_bigru_init(ks[0], n_features, h, dtype)]
    layers += [_bigru_init(ks[i], h, h, dtype) for i in range(1, n_layers)]
    return {
        "gru": layers,
        "head": linear.init(ks[-1], h, n_chars, bias=True, dtype=dtype),
    }


def ds2_apply(params, features: jnp.ndarray, crew_strategy: str = "auto"):
    """features [B, T, F] (precomputed frames; conv frontend stubbed)
    -> CTC logits [B, T, n_chars]."""
    x = features
    for lp in params["gru"]:
        x = _bigru_apply(lp, x)
    return linear.apply(params["head"], x, plan=crew_strategy)


# --------------------------------------------------------------------------
# Kaldi — acoustic-scoring MLP
# --------------------------------------------------------------------------

def kaldi_init(rng, *, dims=(440, 1024, 1024, 1024, 1953),
               width: float = 1.0, dtype=jnp.float32):
    dims = [max(8, int(d * width)) for d in dims]
    ks = jax.random.split(rng, len(dims) - 1)
    return {"affine": [
        linear.init(ks[i], dims[i], dims[i + 1], bias=True, dtype=dtype)
        for i in range(len(dims) - 1)
    ]}


def kaldi_apply(params, feats: jnp.ndarray, crew_strategy: str = "auto"):
    """feats [B, F] -> senone logits."""
    x = feats
    for i, lp in enumerate(params["affine"]):
        x = linear.apply(lp, x, plan=crew_strategy)
        if i < len(params["affine"]) - 1:
            x = jax.nn.relu(x)
    return x
