"""Deterministic fault injection for the serving stack (DESIGN.md §5).

A robustness layer is only as good as the recovery paths it actually
exercises, and the interesting failures — a request preempted mid-decode,
a deadline expiring while its slot idles in a horizon, a pool block
evicted between preemption and resume — occur on schedules real traffic
produces rarely and irreproducibly.  :class:`FaultInjector` makes those
schedules a **pure function of a seed**: each hook draws from its own
``numpy`` generator stream (seeded from ``(seed, hook index)``), so the
decision sequence per hook depends only on the seed and the call order —
and the call order is fixed by the scheduler's deterministic host loop.
Same seed + same workload → same schedule of injected faults → same
terminal statuses (``tests/test_faults.py`` pins this end to end).

Hooks, and where :class:`~repro.serve.Scheduler` calls them:

* ``horizon_delay()`` — seconds to stall before a horizon dispatch
  (once per dispatched horizon).  Simulates a slow device / noisy
  neighbor; with deadlines set, drives requests into ``TIMED_OUT``.
* ``should_preempt()`` — force a preemption this step even without
  queue pressure (once per step).  Exercises preempt-to-prefix-pool →
  resume; greedy outputs must be unchanged.
* ``should_expire(rid)`` — treat this request's deadline as already
  exceeded (once per deadline-bearing request per step).  Exercises the
  timeout path without wall-clock sleeps.
* ``pool_drop(trie)`` — evict LRU leaf blocks from the prefix pool
  (once per step).  Exercises resume and warm admits with missing
  blocks; matches just shorten, outputs must be unchanged.

Hooks called by the supervision layer (``serve.supervisor`` /
``serve.server``), same purity contract:

* ``should_crash()`` — simulate an engine crash at this pump step
  (once per step attempt).  The supervisor must snapshot, rebuild via
  ``Scheduler.reset(force=True)``, restore, and resume every stream
  greedy-token-identically.
* ``disconnect_after(rid)`` — token count after which this client
  connection vanishes mid-stream, or None to stay (once per accepted
  stream).  Exercises disconnect → ``cancel(rid)`` propagation.
* ``client_stall()`` — seconds a client stops reading its socket
  (once per stream).  Exercises per-connection write timeouts and
  send-queue backpressure.
* ``should_kill()`` — SIGKILL the whole process at this pump step
  (once per step attempt).  Exercises the *durability* story: the
  next process must replay the request journal (``serve.journal``)
  and resume every stream token-identically.

``trace`` records every *injected* fault as ``(hook, call_index, ...)``
tuples — the schedule two same-seed runs must agree on.

``default_injector()`` is the suite-wide chaos switch: with
``REPRO_FAULTS`` set (CI runs the tier-1 suite a second time under it),
every ``Scheduler`` that was not given an explicit ``faults=`` argument
gets a *benign* injector — forced preemptions and pool drops, whose
recovery is output-preserving, but no delays or expiries, which are not.
The whole parity suite then doubles as a chaos suite.
"""
from __future__ import annotations

import os
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["FaultInjector", "default_injector"]


class FaultInjector:
    """Seeded chaos layer over the scheduler's recovery paths.

    All probabilities default to 0 — an injector injects only what it is
    asked to.  ``seed`` fully determines every decision (see module
    docstring); two injectors with the same seed and config produce the
    same decisions for the same call sequence.
    """

    # append-only: each hook's RNG stream is seeded from its index
    # here, so reordering or inserting would silently reshuffle every
    # existing seeded schedule the tests pin
    _HOOKS = ("delay", "preempt", "expire", "drop",
              "crash", "disconnect", "stall", "kill")

    def __init__(self, seed: int = 0, *,
                 delay_p: float = 0.0, max_delay_s: float = 0.0,
                 preempt_p: float = 0.0,
                 expire_p: float = 0.0,
                 drop_p: float = 0.0, max_drop: int = 1,
                 crash_p: float = 0.0,
                 disconnect_p: float = 0.0,
                 max_disconnect_tokens: int = 8,
                 stall_p: float = 0.0, max_stall_s: float = 0.0,
                 kill_p: float = 0.0):
        self.seed = int(seed)
        self.delay_p = float(delay_p)
        self.max_delay_s = float(max_delay_s)
        self.preempt_p = float(preempt_p)
        self.expire_p = float(expire_p)
        self.drop_p = float(drop_p)
        self.max_drop = int(max_drop)
        self.crash_p = float(crash_p)
        self.disconnect_p = float(disconnect_p)
        self.max_disconnect_tokens = int(max_disconnect_tokens)
        self.stall_p = float(stall_p)
        self.max_stall_s = float(max_stall_s)
        self.kill_p = float(kill_p)
        self._rng = {
            hook: np.random.default_rng(
                np.random.SeedSequence(entropy=self.seed, spawn_key=(i,)))
            for i, hook in enumerate(self._HOOKS)
        }
        self._calls = {hook: 0 for hook in self._HOOKS}
        self.trace: List[Tuple] = []

    # ------------------------------------------------------------------

    def _tick(self, hook: str) -> int:
        n = self._calls[hook]
        self._calls[hook] = n + 1
        return n

    def horizon_delay(self) -> float:
        """Seconds to sleep before the next horizon dispatch (0 = none)."""
        n = self._tick("delay")
        rng = self._rng["delay"]
        hit = rng.random() < self.delay_p
        dt = float(rng.random()) * self.max_delay_s  # drawn either way:
        if not hit or dt <= 0.0:                     # stream advances at a
            return 0.0                               # fixed rate per call
        self.trace.append(("delay", n, round(dt, 6)))
        return dt

    def should_preempt(self) -> bool:
        """Force a preemption this scheduler step."""
        n = self._tick("preempt")
        hit = self._rng["preempt"].random() < self.preempt_p
        if hit:
            self.trace.append(("preempt", n))
        return hit

    def should_expire(self, rid: int) -> bool:
        """Treat request ``rid``'s deadline as already exceeded."""
        n = self._tick("expire")
        hit = self._rng["expire"].random() < self.expire_p
        if hit:
            self.trace.append(("expire", n, rid))
        return hit

    def pool_drop(self, trie) -> int:
        """Evict up to ``max_drop`` LRU leaf blocks from ``trie``; returns
        the number actually dropped (matches afterwards just shorten —
        recovery must be output-preserving)."""
        n = self._tick("drop")
        rng = self._rng["drop"]
        hit = rng.random() < self.drop_p
        k = int(rng.integers(1, self.max_drop + 1))  # fixed stream rate
        if not hit or trie is None:
            return 0
        dropped = trie.drop_lru_leaves(k)
        if dropped:
            self.trace.append(("drop", n, dropped))
        return dropped

    def should_crash(self) -> bool:
        """Simulate an engine crash before this supervisor pump step."""
        n = self._tick("crash")
        hit = self._rng["crash"].random() < self.crash_p
        if hit:
            self.trace.append(("crash", n))
        return hit

    def disconnect_after(self, rid: int) -> Optional[int]:
        """Token count after which the client for ``rid`` drops its
        connection mid-stream (0 = before the first token), or None to
        stay connected for the whole stream."""
        n = self._tick("disconnect")
        rng = self._rng["disconnect"]
        hit = rng.random() < self.disconnect_p
        k = int(rng.integers(0, self.max_disconnect_tokens + 1))
        if not hit:                                  # both drawn either
            return None                              # way: fixed stream
        self.trace.append(("disconnect", n, rid, k))  # rate per call
        return k

    def should_kill(self) -> bool:
        """Kill the whole process at this pump step — ``SIGKILL``, not
        an in-process crash (once per step attempt).  Unlike
        ``should_crash`` there is nothing to snapshot: recovery is the
        *next* process replaying the journal.  Only armed explicitly
        (never by ``default_injector``); the supervisor hosts the
        actual ``os.kill``."""
        n = self._tick("kill")
        hit = self._rng["kill"].random() < self.kill_p
        if hit:
            self.trace.append(("kill", n))
        return hit

    def client_stall(self) -> float:
        """Seconds this stream's client stops reading (0 = never)."""
        n = self._tick("stall")
        rng = self._rng["stall"]
        hit = rng.random() < self.stall_p
        dt = float(rng.random()) * self.max_stall_s  # fixed stream rate
        if not hit or dt <= 0.0:
            return 0.0
        self.trace.append(("stall", n, round(dt, 6)))
        return dt


def default_injector() -> Optional["FaultInjector"]:
    """The suite-wide benign injector, or None when ``REPRO_FAULTS`` is
    unset/0.  The value seeds the schedule (``REPRO_FAULTS=7`` → seed 7),
    so CI can sweep schedules by changing one env var.  Only
    output-preserving faults are enabled: forced preemptions, pool
    drops, and supervised crashes (recovery resumes every greedy stream
    token-identically) — never delays or client stalls (slow), expiries
    (change terminal statuses), or disconnects (cancel streams).
    """
    raw = os.environ.get("REPRO_FAULTS", "").strip()
    if not raw or raw == "0":
        return None
    try:
        seed = int(raw)
    except ValueError:
        seed = 1
    return FaultInjector(seed, preempt_p=0.05, drop_p=0.05, max_drop=2,
                         crash_p=0.05)
