"""Radix-tree prefix index over a block-granular KV pool (DESIGN.md §5).

CREW's thesis one level up: admitted prompts recompute the same prefill
products over and over whenever they share a prefix (system prompts,
few-shot templates, retries).  Caching the unique prefixes' KV blocks and
*indexing* into them beats recomputation exactly the way the paper's
unique-weight tables beat redundant multiplies.

This module is the pure host-side bookkeeping half: a token trie whose
edges are fixed-size token blocks, mapping every cached prefix to the
pool block ids that hold its KV state.  The device half — the pool
tensors themselves and the gather/scatter programs that move blocks
between the pool and a request's slot stripe — lives in
``serve.scheduler``; nothing here touches jax, so the eviction and
ref-count logic is unit-testable in microseconds
(tests/test_prefix_cache.py).

Semantics:

* **match** — walk the prompt block-by-block down the trie; returns the
  pool block ids of the longest cached prefix.  Matching bumps each
  node's LRU tick.
* **insert** — walk the same way, allocating a pool block for every
  block-aligned prompt prefix not yet cached.  Because a trie walk
  misses monotonically, the new blocks are always a contiguous tail; the
  caller copies those KV rows from the request's slot into the returned
  block ids.
* **eviction** — allocation under pool pressure evicts the
  least-recently-used *leaf* (a node with no children; interior nodes
  are pinned by their descendants' refcount).  Recency is an
  insertion-ordered map (every touch re-appends the node), so the victim
  is found by popping from the stale end — O(1) amortized, instead of a
  linear scan over every cached node per eviction.  Requests never pin
  blocks: a match is immediately *copied* into the request's own slot
  stripe, so an evicted block can never be read by a live request.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["PrefixTrie", "TrieNode"]


@dataclasses.dataclass
class TrieNode:
    """One cached token block: trie edge key + its pool block id."""
    block: int                       # pool block id holding this KV block
    key: bytes                       # the block's tokens (trie edge label)
    parent: Optional["TrieNode"]
    children: Dict[bytes, "TrieNode"] = dataclasses.field(default_factory=dict)
    last_use: int = 0                # LRU tick (monotonic)

    @property
    def refcount(self) -> int:
        """Pins against eviction: one per child subtree."""
        return len(self.children)


class PrefixTrie:
    """Token trie over ``n_blocks`` pool blocks of ``block_size`` tokens."""

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 1:
            raise ValueError("need at least one pool block")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.block_size = int(block_size)
        self.n_blocks = int(n_blocks)
        self.root = TrieNode(block=-1, key=b"", parent=None)
        self._free: List[int] = list(range(n_blocks))
        self._nodes: Dict[int, TrieNode] = {}   # block id -> node
        # LRU order: stale end first.  Touch = move_to_end, so ordering
        # tracks last_use without comparisons; eviction pops from the
        # front past the (rare) pinned interior / protected entries.
        self._lru: "collections.OrderedDict[int, TrieNode]" = \
            collections.OrderedDict()
        self._tick = itertools.count(1)
        self.evictions = 0

    # ------------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._nodes)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def _keys(self, tokens: np.ndarray):
        bs = self.block_size
        for h in range(0, (tokens.size // bs) * bs, bs):
            yield np.ascontiguousarray(tokens[h:h + bs]).tobytes()

    # ------------------------------------------------------------------

    def match(self, tokens: np.ndarray) -> Tuple[List[int], int]:
        """Longest cached prefix of ``tokens`` -> (pool block ids, length).

        The returned length is block-aligned.  Matched nodes get their
        LRU tick bumped (root to leaf, so a prefix chain ages together).
        """
        node = self.root
        ids: List[int] = []
        tick = next(self._tick)
        for key in self._keys(tokens):
            child = node.children.get(key)
            if child is None:
                break
            child.last_use = tick
            self._lru.move_to_end(child.block)
            ids.append(child.block)
            node = child
        return ids, len(ids) * self.block_size

    def insert(self, tokens: np.ndarray) -> Tuple[List[int], int]:
        """Cache every block-aligned prefix of ``tokens`` not yet present.

        Returns (new pool block ids, start token offset of the first new
        block) — a contiguous tail of the prompt's block sequence; the
        caller owns copying those KV rows into the pool.  Allocation
        evicts LRU leaves under pressure (never a node on the path being
        inserted); when the pool is exhausted by the path itself the
        insert stops early — the cache simply holds a shorter prefix.
        """
        node = self.root
        tick = next(self._tick)
        new_ids: List[int] = []
        start = -1
        h = 0
        path = set()
        for key in self._keys(tokens):
            path.add(id(node))
            child = node.children.get(key)
            if child is None:
                bid = self._alloc(path)
                if bid is None:
                    break
                child = TrieNode(block=bid, key=key, parent=node)
                node.children[key] = child
                self._nodes[bid] = child
                self._lru[bid] = child          # newest at the MRU end
                new_ids.append(bid)
                if start < 0:
                    start = h
            child.last_use = tick
            self._lru.move_to_end(child.block)
            node = child
            h += self.block_size
        return new_ids, start

    # ------------------------------------------------------------------

    def _alloc(self, protected: set) -> Optional[int]:
        if self._free:
            return self._free.pop()
        victim = next(
            (n for n in self._lru.values()
             if not n.children and id(n) not in protected), None)
        if victim is None:
            return None
        self._evict(victim)
        return self._free.pop()

    def _evict(self, node: TrieNode) -> None:
        assert not node.children, "only leaves are evictable"
        del node.parent.children[node.key]
        del self._nodes[node.block]
        del self._lru[node.block]
        self._free.append(node.block)
        self.evictions += 1

    def drop_lru_leaves(self, n: int) -> int:
        """Evict up to ``n`` least-recently-used leaves; returns the count.

        The fault-injection hook (``serve.faults``): losing pool blocks
        must never change outputs — a later ``match`` just returns a
        shorter prefix and the admitting request prefills the difference.
        Same victim-selection order as pressure eviction, so a dropped
        block is always one the next allocation would have taken anyway.
        """
        dropped = 0
        while dropped < n:
            victim = next(
                (nd for nd in self._lru.values() if not nd.children), None)
            if victim is None:
                break
            self._evict(victim)
            dropped += 1
        return dropped

    def check_invariants(self) -> List[str]:
        """Structural audit -> list of violations (empty = healthy).

        Pinned by the chaos property test (tests/test_faults.py): after a
        faulted run drains, every block is either free or reachable from
        the root, the LRU index mirrors the node table, and refcounts
        (child counts) are consistent — i.e. no pool block leaked and no
        request left a pin behind.
        """
        errs: List[str] = []
        if len(self._free) + len(self._nodes) != self.n_blocks:
            errs.append(
                f"block leak: {len(self._free)} free + {len(self._nodes)} "
                f"cached != {self.n_blocks} pool blocks")
        if set(self._lru) != set(self._nodes):
            errs.append("LRU index out of sync with node table")
        if set(self._nodes) & set(self._free):
            errs.append("block both free and cached")
        reachable = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            for key, child in node.children.items():
                reachable += 1
                if child.parent is not node:
                    errs.append(f"block {child.block}: bad parent link")
                if child.key != key:
                    errs.append(f"block {child.block}: edge key mismatch")
                if self._nodes.get(child.block) is not child:
                    errs.append(f"block {child.block}: not in node table")
                stack.append(child)
        if reachable != len(self._nodes):
            errs.append(
                f"{len(self._nodes) - reachable} cached blocks unreachable "
                "from root")
        return errs
