"""Radix-tree prefix index over a refcounted KV block pool (DESIGN.md §5).

CREW's thesis one level up: admitted prompts recompute the same prefill
products over and over whenever they share a prefix (system prompts,
few-shot templates, retries).  Caching the unique prefixes' KV blocks and
*indexing* into them beats recomputation exactly the way the paper's
unique-weight tables beat redundant multiplies.

This module is the pure host-side bookkeeping half: a token trie whose
edges are fixed-size token blocks, mapping every cached prefix to the
pool block ids that hold its KV state.  The device half — the pool
tensors themselves and the paged block tables that index them — lives in
``serve.scheduler``; nothing here touches jax, so the eviction and
ref-count logic is unit-testable in microseconds
(tests/test_prefix_cache.py).

Semantics:

* **match** — walk the prompt block-by-block down the trie; returns the
  pool block ids of the longest cached prefix.  Matching bumps each
  node's LRU tick.  A hit is *zero-copy*: the admitting slot's block
  table references the matched blocks directly (the scheduler bumps
  their pool refcount), no gather program runs.
* **insert / insert_owned** — walk the same way, caching every
  block-aligned prefix not yet present.  ``insert`` allocates fresh
  blocks (the standalone spelling); ``insert_owned`` *adopts* the
  caller's already-written slot blocks by reference — completion never
  copies KV back into the pool, it just hands the trie a share of the
  blocks the slot prefilled.
* **eviction** — allocation under pool pressure evicts the
  least-recently-used *leaf* whose block has no live reader
  (``pool.refcount == 1``: the trie's own reference and nobody else's;
  interior nodes are pinned by their descendants, shared blocks by the
  slots or parked requests reading them).  Recency is an
  insertion-ordered map (every touch re-appends the node), so the victim
  is found by popping from the stale end — O(1) amortized, instead of a
  linear scan over every cached node per eviction.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve.pool import BlockPool

__all__ = ["PrefixTrie", "TrieNode"]


@dataclasses.dataclass
class TrieNode:
    """One cached token block: trie edge key + its pool block id."""
    block: int                       # pool block id holding this KV block
    key: bytes                       # the block's tokens (trie edge label)
    parent: Optional["TrieNode"]
    children: Dict[bytes, "TrieNode"] = dataclasses.field(default_factory=dict)
    last_use: int = 0                # LRU tick (monotonic)

    @property
    def refcount(self) -> int:
        """Pins against eviction: one per child subtree."""
        return len(self.children)


class PrefixTrie:
    """Token trie over ``n_blocks`` pool blocks of ``block_size`` tokens.

    Pass ``pool=`` to share a :class:`BlockPool` with other block owners
    (live slot tables, parked requests); the default builds a private
    pool, which keeps the standalone trie semantics — and allocation /
    eviction order — identical to the pre-paged implementation.
    """

    def __init__(self, n_blocks: int, block_size: int,
                 pool: Optional[BlockPool] = None):
        if n_blocks < 1:
            raise ValueError("need at least one pool block")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.block_size = int(block_size)
        self.n_blocks = int(n_blocks)
        self._owns_pool = pool is None
        self.pool = BlockPool(n_blocks) if pool is None else pool
        if self.pool.n_blocks != self.n_blocks:
            raise ValueError("shared pool size mismatch")
        self.root = TrieNode(block=-1, key=b"", parent=None)
        self._nodes: Dict[int, TrieNode] = {}   # block id -> node
        # LRU order: stale end first.  Touch = move_to_end, so ordering
        # tracks last_use without comparisons; eviction pops from the
        # front past the (rare) pinned interior / protected entries.
        self._lru: "collections.OrderedDict[int, TrieNode]" = \
            collections.OrderedDict()
        self._tick = itertools.count(1)
        self.evictions = 0

    # ------------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._nodes)

    @property
    def free_blocks(self) -> int:
        return self.pool.free_blocks

    def _keys(self, tokens: np.ndarray):
        bs = self.block_size
        for h in range(0, (tokens.size // bs) * bs, bs):
            yield np.ascontiguousarray(tokens[h:h + bs]).tobytes()

    # ------------------------------------------------------------------

    def match(self, tokens: np.ndarray) -> Tuple[List[int], int]:
        """Longest cached prefix of ``tokens`` -> (pool block ids, length).

        The returned length is block-aligned.  Matched nodes get their
        LRU tick bumped (root to leaf, so a prefix chain ages together).
        The caller must ``pool.ref`` any id it intends to keep reading.
        """
        node = self.root
        ids: List[int] = []
        tick = next(self._tick)
        for key in self._keys(tokens):
            child = node.children.get(key)
            if child is None:
                break
            child.last_use = tick
            self._lru.move_to_end(child.block)
            ids.append(child.block)
            node = child
        return ids, len(ids) * self.block_size

    def insert(self, tokens: np.ndarray) -> Tuple[List[int], int]:
        """Cache every block-aligned prefix of ``tokens`` not yet present.

        Returns (new pool block ids, start token offset of the first new
        block) — a contiguous tail of the prompt's block sequence; the
        caller owns writing those KV rows into the pool.  Allocation
        evicts LRU leaves under pressure (never a node on the path being
        inserted, never a block with live readers); when the pool is
        exhausted by the path itself the insert stops early — the cache
        simply holds a shorter prefix.
        """
        node = self.root
        tick = next(self._tick)
        new_ids: List[int] = []
        start = -1
        h = 0
        path = set()
        for key in self._keys(tokens):
            path.add(id(node))
            child = node.children.get(key)
            if child is None:
                bid = self._alloc(path)
                if bid is None:
                    break
                child = TrieNode(block=bid, key=key, parent=node)
                node.children[key] = child
                self._nodes[bid] = child
                self._lru[bid] = child          # newest at the MRU end
                new_ids.append(bid)
                if start < 0:
                    start = h
            child.last_use = tick
            self._lru.move_to_end(child.block)
            node = child
            h += self.block_size
        return new_ids, start

    def insert_owned(self, tokens: np.ndarray,
                     blocks: List[int]) -> Tuple[List[int], List[int]]:
        """Cache ``tokens``'s aligned prefixes by *adopting* slot blocks.

        ``blocks[i]`` is the caller-owned pool block already holding KV
        for tokens ``[i*bs, (i+1)*bs)``.  Where the trie lacks a node the
        block is adopted by reference (``pool.ref`` — zero copy); where a
        node already exists (a prefix hit at admission, or a concurrent
        insert of the same prefix) the trie keeps its canonical block.

        Returns ``(path_ids, adopted)``: the trie's canonical block id
        for every aligned prefix block (what a future ``match`` will
        return — the ids to pin when parking a preempted request), and
        the subset newly adopted from the caller.
        """
        node = self.root
        tick = next(self._tick)
        path_ids: List[int] = []
        adopted: List[int] = []
        for i, key in enumerate(self._keys(tokens)):
            child = node.children.get(key)
            if child is None:
                bid = blocks[i]
                assert bid not in self._nodes, \
                    f"adopting block {bid} already cached"
                self.pool.ref(bid)
                child = TrieNode(block=bid, key=key, parent=node)
                node.children[key] = child
                self._nodes[bid] = child
                self._lru[bid] = child
                adopted.append(bid)
            child.last_use = tick
            self._lru.move_to_end(child.block)
            path_ids.append(child.block)
            node = child
        return path_ids, adopted

    # ------------------------------------------------------------------

    def _evictable(self, node: TrieNode) -> bool:
        """Leaf with no live reader beyond the trie's own reference."""
        return not node.children and self.pool.refcount(node.block) == 1

    def _alloc(self, protected: set) -> Optional[int]:
        bid = self.pool.alloc()
        if bid is not None:
            return bid
        victim = next(
            (n for n in self._lru.values()
             if self._evictable(n) and id(n) not in protected), None)
        if victim is None:
            return None
        self._evict(victim)
        return self.pool.alloc()

    def _evict(self, node: TrieNode) -> None:
        assert not node.children, "only leaves are evictable"
        assert self.pool.refcount(node.block) == 1, \
            f"evicting block {node.block} with live readers"
        del node.parent.children[node.key]
        del self._nodes[node.block]
        del self._lru[node.block]
        self.pool.deref(node.block)
        self.evictions += 1

    def drop_lru_leaves(self, n: int) -> int:
        """Evict up to ``n`` least-recently-used leaves; returns the count.

        The fault-injection hook (``serve.faults``): losing pool blocks
        must never change outputs — a later ``match`` just returns a
        shorter prefix and the admitting request prefills the difference.
        Same victim-selection order (and the same live-reader skip) as
        pressure eviction, so a dropped block is always one the next
        allocation would have taken anyway — never one a live slot or
        parked request still reads.
        """
        dropped = 0
        while dropped < n:
            victim = next(
                (nd for nd in self._lru.values() if self._evictable(nd)),
                None)
            if victim is None:
                break
            self._evict(victim)
            dropped += 1
        return dropped

    def check_invariants(self) -> List[str]:
        """Structural audit -> list of violations (empty = healthy).

        Pinned by the chaos property test (tests/test_faults.py) and the
        paged fuzz harness (tests/test_paged_prop.py): after a faulted
        run drains, every block is either free or reachable from the
        root, the LRU index mirrors the node table, and refcounts are
        consistent — i.e. no pool block leaked and no request left a pin
        behind.
        """
        errs: List[str] = []
        errs += self.pool.check_invariants()
        if self._owns_pool and \
                self.pool.free_blocks + len(self._nodes) != self.n_blocks:
            errs.append(
                f"block leak: {self.pool.free_blocks} free + "
                f"{len(self._nodes)} cached != {self.n_blocks} pool blocks")
        for bid in self._nodes:
            want = 1 if self._owns_pool else None
            have = self.pool.refcount(bid)
            if have < 1 or (want is not None and have != want):
                errs.append(f"block {bid}: cached with refcount {have}")
        if set(self._lru) != set(self._nodes):
            errs.append("LRU index out of sync with node table")
        reachable = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            for key, child in node.children.items():
                reachable += 1
                if child.parent is not node:
                    errs.append(f"block {child.block}: bad parent link")
                if child.key != key:
                    errs.append(f"block {child.block}: edge key mismatch")
                if self._nodes.get(child.block) is not child:
                    errs.append(f"block {child.block}: not in node table")
                stack.append(child)
        if reachable != len(self._nodes):
            errs.append(
                f"{len(self._nodes) - reachable} cached blocks unreachable "
                "from root")
        return errs
