"""Durable request journal: a write-ahead log for the front door.

PR 9's supervisor survives engine stalls and injected crashes, but only
while the process lives — a ``kill -9`` (OOM, node reboot, deploy) used
to lose every in-flight request.  :class:`Journal` closes that gap with
the smallest durable thing that works: an append-only log of the three
facts the scheduler needs to rebuild its outstanding set —

* ``submit``  — the request descriptor (prompt, limits, tenant,
  idempotency key) the moment admission accepts it,
* ``tokens``  — the ``[nb, H]`` token panels each horizon boundary
  emitted, recorded per rid with their absolute start index,
* ``terminal`` — the final :class:`~repro.serve.Completion` (status,
  reason, full token stream).

Replaying submissions minus terminals yields exactly the outstanding
rids with their generated-so-far tokens — the same host descriptors
``Scheduler.snapshot_requests`` captures — so cold-restart recovery
(:meth:`Supervisor.start`) rides the existing ``restore`` path and
greedy streams resume token-identically across full process death.

On-disk format (per record)::

    [u32 payload length][u32 crc32(payload)][payload: compact JSON]

Records append to numbered segment files (``wal-00000001.log``, …)
inside the journal directory; segments rotate at ``segment_bytes`` and
the whole directory is compacted (truncated to empty) once nothing is
outstanding, so the journal's steady-state size tracks in-flight work,
not lifetime traffic.  Opening a journal replays every segment in
order and **truncates the torn tail**: the first record whose length
prefix, CRC, or JSON fails to check marks the kill point — the file is
cut back to the last good record and any later segments are dropped.
A crash can therefore lose at most the record being appended
(``tests/test_journal.py`` pins this at every byte offset).

Durability knobs (``fsync=``) and their napkin math (DESIGN.md §5.1):

* ``"record"``  — fsync after every append.  Nothing acknowledged is
  ever lost, but at ~0.5–5 ms per fsync a horizon emitting dozens of
  tokens spends 10–100 ms on durability alone — more than the horizon's
  own compute.
* ``"horizon"`` (default) — one fsync per :meth:`commit` (the scheduler
  calls it once per step).  At-risk window: one horizon's panels, which
  replay re-decodes anyway from the durable submit — decode is
  deterministic, so nothing client-visible is lost.
* ``"none"``    — leave it to the OS writeback window (~5 s on ext4).
  Submissions accepted in that window can vanish; clients must retry
  (their ``Idempotency-Key`` makes the retry safe).

Submit records fsync under both ``"record"`` and ``"horizon"``: they are
rare relative to tokens, and a durable submit is what makes every other
loss recoverable.

The writer side is wired into :class:`~repro.serve.Scheduler` (pass
``journal=``); the reader side is consumed by
:class:`~repro.serve.Supervisor` at startup.  File discipline follows
``repro.ckpt``: write → flush → ``os.fsync`` → (for renames) fsync the
directory.

:class:`RequestLog` rides along as the per-request JSONL observability
sink (ROADMAP item 5): one line per terminal with rid, tenant, status,
reason, ttft_s, token count, and queue wait.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
import time
import zlib
from typing import Dict, IO, List, Optional, Tuple

__all__ = ["Journal", "JournalReplay", "RequestLog"]

_HDR = struct.Struct("<II")         # payload length, crc32(payload)
_SEG_FMT = "wal-%08d.log"
_FSYNC_POLICIES = ("record", "horizon", "none")


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


@dataclasses.dataclass(frozen=True)
class JournalReplay:
    """What a journal directory contained at open time.

    ``outstanding`` maps rid → the submit-record dict augmented with
    ``tokens``/``logprobs`` accumulated from token records (requests
    with no terminal yet); ``terminals`` maps rid → its terminal-record
    dict.  ``truncated_bytes`` counts torn-tail bytes cut on open.
    """
    next_rid: int
    outstanding: Dict[int, dict]
    terminals: Dict[int, dict]
    idempotency: Dict[str, int]
    records: int
    truncated_bytes: int
    replay_ms: float


class Journal:
    """Append-only write-ahead journal over one directory.

    Construction opens (creating if needed) the directory, replays all
    segments (see :attr:`replay`), truncates any torn tail, and positions
    the writer at the end of the last segment.  All appends go through
    module-level record framing; readers never need the writer.
    """

    def __init__(self, path: str, *, fsync: str = "horizon",
                 segment_bytes: int = 4 << 20):
        if fsync not in _FSYNC_POLICIES:
            raise ValueError(
                f"fsync must be one of {_FSYNC_POLICIES}, got {fsync!r}")
        self.path = str(path)
        self.fsync = fsync
        self._segment_bytes = int(segment_bytes)
        os.makedirs(self.path, exist_ok=True)
        self._fh: Optional[IO[bytes]] = None
        self._seg_index = 0
        self._dirty = False
        self.appended = 0           # records appended by this writer
        self.replay = self._open_and_replay()

    # ------------------------------------------------------------------
    # Open / replay / torn-tail truncation
    # ------------------------------------------------------------------

    def _segments(self) -> List[str]:
        names = sorted(n for n in os.listdir(self.path)
                       if n.startswith("wal-") and n.endswith(".log"))
        return [os.path.join(self.path, n) for n in names]

    @staticmethod
    def _scan_segment(seg: str) -> Tuple[List[dict], int, int]:
        """Read records from one segment; returns ``(records,
        good_bytes, total_bytes)`` where ``good_bytes`` is the offset of
        the first unreadable record (== total when the tail is clean)."""
        with open(seg, "rb") as f:
            blob = f.read()
        records: List[dict] = []
        off = 0
        while off + _HDR.size <= len(blob):
            ln, crc = _HDR.unpack_from(blob, off)
            end = off + _HDR.size + ln
            if end > len(blob):
                break                           # torn: partial payload
            payload = blob[off + _HDR.size:end]
            if zlib.crc32(payload) != crc:
                break                           # torn or corrupt
            try:
                rec = json.loads(payload.decode())
            except (UnicodeDecodeError, json.JSONDecodeError):
                break
            records.append(rec)
            off = end
        return records, off, len(blob)

    def _open_and_replay(self) -> JournalReplay:
        t0 = time.perf_counter()
        outstanding: Dict[int, dict] = {}
        terminals: Dict[int, dict] = {}
        idem: Dict[str, int] = {}
        next_rid = 0
        n_records = 0
        truncated = 0
        segs = self._segments()
        keep: List[str] = []
        for si, seg in enumerate(segs):
            records, good, total = self._scan_segment(seg)
            n_records += len(records)
            for rec in records:
                next_rid = max(next_rid, int(rec.get("rid", -1)) + 1)
                self._apply(rec, outstanding, terminals, idem)
            keep.append(seg)
            if good < total:
                # torn tail: cut this segment back to its last good
                # record and drop everything after the kill point
                truncated += total - good
                with open(seg, "r+b") as f:
                    f.truncate(good)
                    f.flush()
                    os.fsync(f.fileno())
                for later in segs[si + 1:]:
                    truncated += os.path.getsize(later)
                    os.remove(later)
                _fsync_dir(self.path)
                break
        if keep:
            last = keep[-1]
            self._seg_index = int(os.path.basename(last)[4:-4])
            self._fh = open(last, "ab")
        else:
            self._roll_segment()
        return JournalReplay(
            next_rid=next_rid,
            outstanding=outstanding,
            terminals=terminals,
            idempotency=idem,
            records=n_records,
            truncated_bytes=truncated,
            replay_ms=(time.perf_counter() - t0) * 1e3,
        )

    @staticmethod
    def _apply(rec: dict, outstanding: Dict[int, dict],
               terminals: Dict[int, dict], idem: Dict[str, int]) -> None:
        kind = rec.get("type")
        rid = int(rec.get("rid", -1))
        if kind == "submit":
            rec = dict(rec, tokens=[], logprobs=[])
            outstanding[rid] = rec
            if rec.get("idem_key"):
                idem[rec["idem_key"]] = rid
        elif kind == "tokens":
            req = outstanding.get(rid)
            if req is None:
                return              # tokens for an unknown/terminal rid
            start = int(rec["start"])
            toks, lps = req["tokens"], req["logprobs"]
            del toks[start:], lps[start:]   # overwrite semantics: a
            toks.extend(rec["tokens"])      # resume re-decodes the same
            lps.extend(rec["logprobs"])     # indices deterministically
        elif kind == "terminal":
            outstanding.pop(rid, None)
            terminals[rid] = rec
            if rec.get("idem_key"):
                idem[rec["idem_key"]] = rid

    # ------------------------------------------------------------------
    # Writer
    # ------------------------------------------------------------------

    def _roll_segment(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._fh.close()
        self._seg_index += 1
        seg = os.path.join(self.path, _SEG_FMT % self._seg_index)
        self._fh = open(seg, "ab")
        _fsync_dir(self.path)

    def _append(self, rec: dict, *, force_sync: bool = False) -> None:
        assert self._fh is not None, "journal is closed"
        payload = json.dumps(rec, separators=(",", ":")).encode()
        self._fh.write(_HDR.pack(len(payload), zlib.crc32(payload)))
        self._fh.write(payload)
        self.appended += 1
        self._dirty = True
        if self.fsync == "record" or (force_sync and self.fsync != "none"):
            self._sync()

    def _sync(self) -> None:
        if self._fh is not None and self._dirty:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._dirty = False

    def append_submit(self, rid: int, prompt, *, max_new: int,
                      eos_id: Optional[int], deadline_s: Optional[float],
                      priority: int, tenant: Optional[str],
                      submitted_s: float,
                      idem_key: Optional[str] = None) -> None:
        """Log one accepted submission.  Fsyncs under ``"record"`` *and*
        ``"horizon"`` — a durable submit is what makes every downstream
        loss re-decodable."""
        self._append({
            "type": "submit", "rid": int(rid),
            "prompt": [int(t) for t in prompt],
            "max_new": int(max_new),
            "eos_id": None if eos_id is None else int(eos_id),
            "deadline_s": None if deadline_s is None else float(deadline_s),
            "priority": int(priority),
            "tenant": tenant,
            "submitted_s": float(submitted_s),
            "idem_key": idem_key,
        }, force_sync=True)

    def append_tokens(self, rid: int, start: int, tokens, logprobs) -> None:
        """Log one rid's slice of a horizon panel: tokens
        ``[start, start+len)`` of its generated stream."""
        self._append({
            "type": "tokens", "rid": int(rid), "start": int(start),
            "tokens": [int(t) for t in tokens],
            "logprobs": [round(float(x), 6) for x in logprobs],
        })

    def append_terminal(self, rid: int, *, status: str, reason: str,
                        prompt_len: int, tokens, logprobs,
                        ttft_s: float, queue_s: float = 0.0,
                        tenant: Optional[str] = None,
                        idem_key: Optional[str] = None) -> None:
        """Log one terminal Completion (carries the full final stream,
        so replay never needs earlier token records for finished rids)."""
        self._append({
            "type": "terminal", "rid": int(rid),
            "status": status, "reason": reason,
            "prompt_len": int(prompt_len),
            "tokens": [int(t) for t in tokens],
            "logprobs": [round(float(x), 6) for x in logprobs],
            "ttft_s": round(float(ttft_s), 6),
            "queue_s": round(float(queue_s), 6),
            "tenant": tenant,
            "idem_key": idem_key,
        })

    def commit(self, *, idle: bool = False) -> None:
        """Horizon-boundary commit: fsync (policy ``"horizon"``), rotate
        an oversized segment, and — when the caller reports the engine
        idle (nothing outstanding) — compact the directory so the
        journal never grows with lifetime traffic."""
        if self.fsync != "none":
            self._sync()
        if idle:
            if self.total_bytes() > self._segment_bytes:
                self.compact()
        elif self._tell() > self._segment_bytes:
            self._roll_segment()

    def _tell(self) -> int:
        return 0 if self._fh is None else self._fh.tell()

    def total_bytes(self) -> int:
        return sum(os.path.getsize(s) for s in self._segments())

    def segments(self) -> int:
        return len(self._segments())

    def compact(self) -> None:
        """Drop every segment and start fresh.  Only valid when nothing
        is outstanding (every journaled rid has its terminal) — replay
        of an empty journal is trivially consistent.  Terminal records
        for finished rids are dropped too: reconnects for them are
        served from the living process, not the journal."""
        if self._fh is not None:
            self._fh.close()
            self._fh = None
        for seg in self._segments():
            os.remove(seg)
        _fsync_dir(self.path)
        self._seg_index = 0
        self._dirty = False
        self._roll_segment()

    def stats(self) -> dict:
        return {
            "fsync": self.fsync,
            "records_replayed": self.replay.records,
            "records_appended": self.appended,
            "truncated_bytes": self.replay.truncated_bytes,
            "replay_ms": round(self.replay.replay_ms, 3),
            "segments": self.segments(),
            "bytes": self.total_bytes(),
        }

    def close(self) -> None:
        if self._fh is not None:
            self._sync()
            self._fh.close()
            self._fh = None


class RequestLog:
    """Structured per-request JSONL log (one line per terminal).

    Append-only and line-buffered; each line carries the fields the
    ROADMAP's observability item names: rid, tenant, status, reason,
    ttft_s, tokens (count generated), queue_s (submit → first
    admission wait).  Crash-safety matters less than for the journal
    (logs are observability, not state), so lines are flushed but not
    fsynced.
    """

    def __init__(self, path: str):
        self.path = str(path)
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        self._fh = open(self.path, "a", encoding="utf-8")
        self.lines = 0

    def log(self, comp) -> None:
        """Append one terminal :class:`~repro.serve.Completion`."""
        rec = {
            "ts": time.time(),
            "rid": int(comp.rid),
            "tenant": comp.tenant,
            "status": comp.status,
            "reason": comp.reason,
            "ttft_s": round(float(comp.ttft_s), 6),
            "tokens": int(comp.tokens.size),
            "queue_s": round(float(comp.queue_s), 6),
        }
        self._fh.write(json.dumps(rec, separators=(",", ":")) + "\n")
        self._fh.flush()
        self.lines += 1

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
