"""Continuous-batching serve scheduler — DESIGN.md §5.

``serve.generate`` is one static jit'd batch: every request shares one
prompt length and one ``max_new``, so mixed traffic either pads to the
worst case or serializes.  :class:`Scheduler` instead owns a request
queue, a **paged KV block pool**, and a cross-request **prefix cache**,
and interleaves chunked prefill with decode:

* **paged KV** — all KV lives in one pool tensor of fixed-size blocks
  (``[L, blocks+1, block_size, KV, D]``; device block 0 is scratch).
  Each slot holds a *block table* — the list of pool block ids backing
  its sequence — and every program gathers K/V through a ``[B, NB]``
  table index (``layers.attention.attend_decode_paged`` /
  ``attend_prefill_cached_paged``).  There is no per-slot dense stripe
  and no block-mover program: blocks are owned by reference counts
  (``serve.pool.BlockPool``) shared between live slots, parked
  (preempted) requests, and the prefix trie.
* **admission + zero-copy prefix reuse** — at each horizon boundary,
  queued prompts are admitted into free slots.  The prompt first
  matches its longest cached prefix in a radix tree over pool blocks
  (``serve.prefix.PrefixTrie``); the hit blocks go straight into the
  slot's table with a refcount bump — **no KV moves** — and only the
  suffix is prefilled.  Prefill work is O(new tokens), not O(prompt),
  when traffic shares system prompts / few-shot templates / retries
  (CREW's cache-unique-products-and-index insight one level up,
  PAPER.md), and a hit now costs O(blocks) host bookkeeping instead of
  a gather program over the hit KV.
* **batched chunked prefill** — suffixes advance through
  ``api.prefill_chunk`` in bucket-sized chunks; all prefilling slots
  with the same (chunk bucket, table-width bucket) advance in **one
  dispatch** (lanes padded to ``max_batch`` with dead scratch-table
  lanes).  One program per (chunk, width) bucket pair — prompts longer
  than the largest bucket are admissible, and a prefilling prompt
  advances one chunk per engine step while other slots keep decoding.
  Chunk-by-chunk prefill is token-identical to the monolithic prefill
  (the single-pass softmax in ``cached_chunk_attention`` reproduces
  ``chunked_attention`` exactly; width padding past the true length is
  masked dead), so greedy outputs stay token-identical to cold-cache
  ``serve.generate`` with or without prefix hits.
* **horizon decode** — one fused program runs ``horizon`` decode steps
  (``lax.scan``, default H=8) across all decode-active slots.  Each
  scan iteration decodes one token per lane at its own cache position,
  reading and writing KV through the lane's block table.  EOS /
  per-request ``max_new`` exhaustion is masked *on device* (dead lanes
  step against the scratch block at a pinned position); the host syncs
  **once per horizon**, not once per token.
* **retire + backfill + pool adopt** — at the horizon boundary the host
  replays the emitted-token mask, retires requests that hit EOS or
  ``max_new``, and backfills freed slots from the queue.  When a
  prompt's prefill completes, the trie **adopts** its block-aligned
  blocks by reference (``PrefixTrie.insert_owned`` — completion never
  copies KV back); pool pressure evicts least-recently-used trie
  leaves, and refcounts guarantee an evicted block is never one a live
  slot or parked request still reads.

The hot loop is a fixed set of XLA programs: one chunk-prefill program
per (chunk bucket x table-width bucket) and one horizon program per
batch bucket — no per-request retracing and no copy/insert movers
(``program_counts()`` exposes the live compile counts; tests pin them,
including the zero-copy ``copy == 0`` pin).  The pool KV tensors — the
only multi-megabyte state threaded between programs — are **donated**
through every dispatch, so they update in place instead of being
copied (the [nb]-sized lane vectors and [nb, NB] tables are cheap and
passed by value).

Slot state (last tokens, lengths, prefill cursors, done mask,
per-request RNG keys, generated counts, block tables) is carried
host-side; CREW params flow through the same ``crew_strategy="auto"``
autotuned dispatch as the one-shot engine; under an active mesh the
programs trace inside ``sharding_ctx(mesh, SERVE_RULES)`` so
``constrain`` calls bind.

On top of the data path sits the **request lifecycle** (DESIGN.md §5
"request lifecycle"): every submitted request walks an explicit state
machine — QUEUED → PREFILLING → DECODING → one of the terminal states
{COMPLETED, CANCELLED, TIMED_OUT, SHED}, or PREEMPTED → QUEUED and
around again — and every rid gets **exactly one** terminal
:class:`Completion` whose ``status``/``reason`` say how it ended.
Admission is bounded (priority lanes + per-tenant token buckets; over
the bound ``submit`` returns a typed :class:`Shed` instead of growing
the queue), deadlines and cancellation are enforced at horizon
boundaries, and under pressure the scheduler **preempts to the prefix
pool**: the victim's block-aligned blocks are adopted by the trie and
**pinned** (an extra reference held per parked block, so eviction can
never free them before resume), the request re-queues, and resume is a
zero-copy prefix hit that re-prefills only the unaligned tail —
preemption costs one chunk, not a full re-prefill, which is the
paper's reuse insight applied to scheduling.  A seeded chaos layer
(``serve.faults``) can force every one of those paths
deterministically; greedy outputs are token-identical under benign
faults, pinned by tests and by the property harness
(tests/test_paged_prop.py), whose conservation law ``audit_blocks``
checks: every pool block's refcount equals its owner count across
free list ∪ trie ∪ live tables ∪ parked pins.

Requires the transformer-family cache contract ``{"k","v","len"}`` with
``[L, B, S, KV, D]`` KV tensors (dense / MoE configs; families without a
chunked-prefill path are rejected at construction).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import enum
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.ctx import sharding_ctx
from ..dist.sharding import SERVE_RULES
from ..kernels.plan import warn_deprecated
from ..models import ModelApi
from .convert import decode_state_for_params
from .faults import FaultInjector, default_injector
from .journal import Journal
from .pool import BlockPool
from .prefix import PrefixTrie

__all__ = ["Scheduler", "SchedulerMetrics", "Request", "Completion",
           "RequestState", "Shed", "SchedulerStalledError",
           "RequestSnapshot", "SchedulerSnapshot",
           "DEFAULT_BUCKETS", "DEFAULT_HORIZON", "DEFAULT_BLOCK_SIZE"]

DEFAULT_BUCKETS: Tuple[int, ...] = (16, 32, 64, 128)
DEFAULT_HORIZON = 8
DEFAULT_BLOCK_SIZE = 16

_KEEP = object()     # reset(faults=...) sentinel: keep the current injector


def _pow2_ladder(top: int) -> Tuple[int, ...]:
    """Powers of two up to ``top`` (``top`` included even when not one)."""
    out = []
    p = 1
    while p < top:
        out.append(p)
        p *= 2
    out.append(top)
    return tuple(out)


def _bucket_for(ladder: Tuple[int, ...], n: int) -> int:
    """Smallest ladder entry >= n (the ladder's top for anything larger)."""
    for b in ladder:
        if n <= b:
            return b
    return ladder[-1]


class RequestState(enum.Enum):
    """Lifecycle states.  QUEUED/PREFILLING/DECODING are transient;
    COMPLETED/CANCELLED/TIMED_OUT/SHED are terminal (each produces the
    request's single :class:`Completion`).  PREEMPTED is instantaneous —
    a preempted request re-enters QUEUED in the same step, its KV parked
    in the prefix pool (``Request.preemptions`` counts the round trips).
    """
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    COMPLETED = "completed"
    CANCELLED = "cancelled"
    TIMED_OUT = "timed_out"
    SHED = "shed"
    PREEMPTED = "preempted"


TERMINAL_STATES = frozenset({
    RequestState.COMPLETED, RequestState.CANCELLED,
    RequestState.TIMED_OUT, RequestState.SHED,
})


@dataclasses.dataclass(frozen=True)
class Shed:
    """Typed admission rejection returned by ``submit`` under overload.

    The rid is still real: a shed request gets its terminal
    ``Completion(status="shed")`` like every other outcome, so drivers
    can account for it without special-casing the return value beyond
    an ``isinstance`` check.
    """
    rid: int
    reason: str                 # "queue-full" | "tenant-rate" | "draining"


@dataclasses.dataclass(frozen=True)
class RequestSnapshot:
    """Host-side descriptor of one outstanding (queued or in-flight)
    request: everything needed to re-admit it after a crash so a greedy
    stream resumes token-identically (DESIGN.md §5 "wire protocol &
    supervision").  ``tokens``/``logprobs`` are what had been generated
    at snapshot time; on restore they seed the scheduler's resume path,
    so re-admission re-decodes (never re-prefills) anything a prefix-
    pool hit does not cover and the full stream stays the greedy
    stream."""
    rid: int
    prompt: Tuple[int, ...]
    max_new: int
    eos_id: Optional[int]
    deadline_s: Optional[float]
    priority: int
    tenant: Optional[str]
    submitted_s: float
    preemptions: int
    tokens: Tuple[int, ...] = ()
    logprobs: Tuple[float, ...] = ()
    ttft_s: Optional[float] = None
    idem_key: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class SchedulerSnapshot:
    """Outstanding requests plus the rid high-water mark, captured by
    :meth:`Scheduler.snapshot_requests` at a step boundary.  ``restore``
    after ``reset(force=True)`` re-queues every request under its
    original rid and keeps new rids from colliding with already-
    delivered ones."""
    next_rid: int
    requests: Tuple[RequestSnapshot, ...]


class SchedulerStalledError(RuntimeError):
    """``run()`` detected no forward progress (or blew its step budget).

    The message lists every live slot's state — rid, lifecycle phase,
    cache length, prefill cursor, generated count — plus queue depth
    and pool occupancy, so a wedged scheduler reports *what* is stuck
    instead of spinning.
    """


@dataclasses.dataclass
class Request:
    """One queued generation request (host-side)."""
    rid: int
    prompt: np.ndarray          # [S] int32, unpadded
    max_new: int
    eos_id: Optional[int]
    submitted_s: float = 0.0    # perf_counter at submit (TTFT accounting)
    deadline_s: Optional[float] = None  # TTL from submit; None = no deadline
    priority: int = 0           # lower value = more urgent (lane index)
    tenant: Optional[str] = None        # token-rate accounting bucket
    state: RequestState = RequestState.QUEUED
    preemptions: int = 0        # times preempted to the prefix pool
    idem_key: Optional[str] = None      # client idempotency key, if any


@dataclasses.dataclass
class Completion:
    """A finished request: generated tokens (EOS included if hit).

    Every submitted rid — completed, cancelled, timed out, or shed —
    produces exactly one Completion; ``status`` is the terminal
    :class:`RequestState` value and ``reason`` the human-readable cause.
    Non-completed outcomes keep whatever tokens were generated before
    the request ended (possibly none).
    """
    rid: int
    prompt_len: int
    tokens: np.ndarray          # [n_generated] int32
    logprobs: np.ndarray        # [n_generated] float32
    n_steps: int                # engine steps from admission to retirement
    ttft_s: float = 0.0         # submit -> first token wall time
    status: str = "completed"   # terminal RequestState value
    reason: str = ""            # why, for non-completed statuses
    tenant: Optional[str] = None    # the request's rate bucket, if any
    queue_s: float = 0.0        # submit -> first admission wait


@dataclasses.dataclass
class SchedulerMetrics:
    """Engine counters.  Read them as attributes (``m.steps``); the
    dict-style spellings (``m["steps"]``) from the pre-dataclass era
    still work for one release behind a DeprecationWarning
    (docs/api.md)."""
    steps: int = 0              # engine steps (admit + chunk + horizon)
    prefills: int = 0           # prompts admitted
    chunks: int = 0             # chunk prefills advanced (per slot-chunk)
    prefill_chunk_tokens: int = 0   # chunk tokens computed (incl. padding)
    prefix_hit_tokens: int = 0  # trie-matched tokens (pre-cap)
    prefill_tokens_saved: int = 0   # prompt tokens served from the pool
    pool_inserts: int = 0       # blocks adopted into the prefix trie
    pool_evictions: int = 0     # LRU leaf evictions under pool pressure
    horizons: int = 0           # fused H-step programs dispatched
    decode_steps: int = 0       # device decode steps (H per horizon)
    decode_lanes: int = 0       # useful (emitted) lane-steps
    padded_lanes: int = 0       # batch-bucket padding lane-steps
    wasted_lane_steps: int = 0  # dead-or-padding lane-steps per horizon
    # terminal-status counters (attributes only — new dict-style keys
    # would defeat the deprecation shim below; docs/api.md)
    completed: int = 0          # requests retired normally
    cancelled: int = 0          # requests cancelled (queued or in-flight)
    timed_out: int = 0          # requests past deadline_s
    shed: int = 0               # requests rejected at admission
    preempted: int = 0          # preempt-to-prefix-pool round trips
    resumed: int = 0            # preempted requests re-admitted
    resume_reprefill_tokens: int = 0  # tokens re-prefilled on resume
    queue_peak: int = 0         # high-water queued-request count
    # paged-pool occupancy (attributes only, like the status counters)
    zero_copy_hits: int = 0     # prefix-hit blocks referenced, not copied
    pool_blocks_in_use: int = 0     # gauge: blocks with refcount > 0
    pool_blocks_free: int = 0       # gauge: free-list depth
    pool_blocks_peak: int = 0       # high-water pool_blocks_in_use
    # per-tenant counters (attribute-only, like the status counters):
    # tenant name -> {submitted, completed, shed, tokens}; requests
    # without a tenant accumulate under "-"
    tenants: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)

    def tenant_bump(self, tenant: Optional[str], key: str,
                    n: int = 1) -> None:
        bucket = self.tenants.setdefault(
            tenant if tenant is not None else "-",
            {"submitted": 0, "completed": 0, "shed": 0, "tokens": 0})
        bucket[key] += n

    def __getitem__(self, key: str) -> int:
        warn_deprecated(
            "SchedulerMetrics:getitem",
            "dict-style SchedulerMetrics reads (metrics[...]) are "
            "deprecated; read the attribute (metrics.steps etc.) — see "
            "docs/api.md")
        if not hasattr(self, key):
            raise KeyError(key)
        return getattr(self, key)

    def __setitem__(self, key: str, value: int) -> None:
        warn_deprecated(
            "SchedulerMetrics:setitem",
            "dict-style SchedulerMetrics writes (metrics[...] = ...) are "
            "deprecated; set the attribute — see docs/api.md")
        if not hasattr(self, key):
            raise KeyError(key)
        setattr(self, key, value)

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class Scheduler:
    """Continuous-batching engine over paged chunked-prefill/horizon
    programs.

    Args:
      api / params: as for ``serve.generate`` (dense or CREW-converted).
      max_batch: number of concurrent decode slots.  Every slot holds a
        block table into the shared pool; the pool reserves
        ``max_batch * ceil(cache_len / block_size)`` blocks so a full
        batch always fits, plus one scratch block (device block 0) for
        padding lanes and mid-horizon-retired lanes.
      cache_len: per-slot KV capacity; every admitted request must fit
        ``prompt_len + max_new <= cache_len``.
      buckets: chunk sizes, ascending.  A prefilling prompt advances by
        the largest bucket per chunk; its tail compiles against the
        smallest bucket that holds it.  Prompts of any length up to
        ``cache_len - max_new`` are admissible (the monolithic-prefill
        cap on prompt length is gone).  None derives the default ladder
        clipped to ``cache_len``.
      horizon: decode steps per fused program dispatch (H).  The host
        syncs once per horizon; ``horizon=1`` is the token-synchronous
        baseline.  Retirement happens at horizon boundaries, so a lane
        whose request dies mid-horizon idles (masked, scratch-directed)
        until the boundary — ``metrics.wasted_lane_steps`` counts it.
      prefix_cache: enable the radix-tree prefix cache (default).  Off,
        every prompt prefills cold — the PR-4-equivalent baseline that
        ``benchmarks/prefix_reuse.py`` measures against — and the pool
        holds only the per-slot reservation.
      block_size: paged-KV granularity in tokens; only block-aligned
        prefixes are shared, and a hit is capped one block short of the
        prompt so at least one suffix token prefills (first-token logits
        must come from a live forward).
      pool_blocks: prefix-cache budget in blocks *beyond* the per-slot
        reservation (the reservation itself —
        ``max_batch * ceil(cache_len / block_size)`` blocks — is always
        allocated, so admission can never deadlock on cached prefixes).
        None sizes the budget to one full batch's worth of cache
        (``max_batch * cache_len // block_size``) — i.e. the prefix
        cache roughly doubles the scheduler's KV memory by default;
        pass an explicit budget when memory is tight or the hot prefix
        set is large.
      temperature / crew_strategy: static sampling and CREW dispatch
        knobs, shared by all programs (as in ``serve.generate``).
      decode_state: "auto" (default) resolves the CREW decode
        product-buffer state per batch bucket from the warmed autotune
        store (``serve.decode_state_for_params``) and threads it through
        the horizon scan carry with donated buffers; "off" disables it.
        A cold store resolves to no state — the historical stateless
        horizon, bit for bit.
      rng: base PRNG key; each request derives its own key stream via
        ``fold_in(fold_in(rng, rid), n_generated)``.
      mesh: optional device mesh; programs then trace under
        ``sharding_ctx(mesh, SERVE_RULES)``.
      max_queue: bound on *queued* (not in-flight) requests.  At the
        bound, ``submit`` sheds: a strictly-lower-priority queued victim
        if one exists (the newcomer takes its place), else the newcomer
        itself — returning a typed :class:`Shed`.  Preemption re-queues
        are exempt (they hold no new admission).  None = unbounded (the
        pre-lifecycle behavior).
      tenant_rate / tenant_burst: per-tenant token-bucket admission —
        ``tenant_rate`` tokens/s refill up to ``tenant_burst`` (default
        = rate); a submit whose worst-case cost (prompt + max_new
        tokens) exceeds the tenant's level is shed with reason
        "tenant-rate".  Requests without a tenant are never
        rate-limited.  None disables.
      preempt_after_steps: with a non-empty queue and no free slot for
        this many consecutive steps, preempt the longest-running decode
        to the prefix pool and re-queue it (aged-pressure trigger;
        higher-priority arrivals preempt immediately regardless).  None
        disables aged preemption.
      faults: a ``serve.faults.FaultInjector`` chaos layer, or None.
        With None the ``REPRO_FAULTS`` env var (when set) supplies the
        suite-wide benign injector; pass ``faults=False`` to force
        fault-free operation even under the env switch.
      stream_tokens: record every emitted ``(rid, index, token,
        logprob)`` in a buffer drained by :meth:`pop_tokens` — the feed
        the SSE front door streams from (``serve.supervisor``).  Off by
        default so batch drivers that only read Completions never grow
        the buffer.
    """

    def __init__(
        self,
        api: ModelApi,
        params,
        *,
        max_batch: int = 8,
        cache_len: int = 256,
        buckets: Optional[Sequence[int]] = None,
        horizon: int = DEFAULT_HORIZON,
        prefix_cache: bool = True,
        block_size: int = DEFAULT_BLOCK_SIZE,
        pool_blocks: Optional[int] = None,
        temperature: float = 0.0,
        crew_strategy: str = "auto",
        decode_state: str = "auto",
        rng: Optional[jnp.ndarray] = None,
        mesh=None,
        cache_dtype=jnp.bfloat16,
        max_queue: Optional[int] = None,
        tenant_rate: Optional[float] = None,
        tenant_burst: Optional[float] = None,
        preempt_after_steps: Optional[int] = None,
        faults: Union[FaultInjector, None, bool] = None,
        stream_tokens: bool = False,
        journal: Optional[Journal] = None,
    ):
        if not api.cfg.has_decode:
            raise ValueError(f"{api.cfg.arch_id} is encoder-only: no decode")
        if not hasattr(api._mod, "prefill_chunk"):
            raise NotImplementedError(
                f"{api.cfg.family} has no chunked-prefill path")
        if horizon < 1:
            raise ValueError("horizon must be >= 1")
        self._api = api
        self._params = params
        self._max_batch = int(max_batch)
        self._cache_len = int(cache_len)
        self._horizon = int(horizon)
        if buckets is None:
            buckets = ([b for b in DEFAULT_BUCKETS if b <= self._cache_len]
                       or [self._cache_len])
        self._buckets = tuple(sorted(int(b) for b in buckets))
        if not self._buckets:
            raise ValueError("need at least one chunk bucket")
        if self._buckets[-1] > self._cache_len:
            raise ValueError(
                f"largest bucket {self._buckets[-1]} exceeds cache_len "
                f"{self._cache_len}")
        self._temperature = float(temperature)
        self._crew_strategy = crew_strategy
        if decode_state not in ("auto", "off"):
            raise ValueError('decode_state must be "auto" or "off"')
        self._decode_state_mode = decode_state
        # per-batch-bucket CREW decode product-buffer state trees (None
        # when the bucket's shapes have no measured pallas-decode winner);
        # resolved lazily on first use of each bucket.
        self._crew_state: Dict[int, object] = {}
        self._base_key = rng if rng is not None else jax.random.PRNGKey(0)
        self._mesh = mesh

        # batch buckets: powers of two up to max_batch (max_batch included
        # even when not a power of two).
        self._batch_buckets = _pow2_ladder(self._max_batch)

        # the abstract cache supplies the KV contract and tensor dtypes;
        # the dense [B, S] slot stripes it describes are never allocated —
        # all KV lives in the paged pool below.
        abs_cache = api.abstract_cache(self._max_batch + 1, self._cache_len,
                                       dtype=cache_dtype)
        if not (isinstance(abs_cache, dict)
                and set(abs_cache) == {"k", "v", "len"}):
            raise NotImplementedError(
                f"{api.cfg.family} cache is not the {{k,v,len}} KV contract "
                "the slot scheduler manages")

        self._block_size = int(block_size)
        if self._block_size < 1:
            raise ValueError("block_size must be >= 1")
        # full table width: blocks per worst-case slot sequence
        self._nb_full = -(-self._cache_len // self._block_size)
        # default prefix budget = one full batch's worth of blocks, so
        # enabling the prefix cache costs at most ~2x the reservation KV
        # memory (stated in the arg docs; size it to the hot prefix set +
        # headroom in production — docs/serving.md "Sizing")
        if pool_blocks is None:
            pool_blocks = max(
                self._max_batch * (self._cache_len // self._block_size), 8)
        self._prefix_budget = int(pool_blocks) if prefix_cache else 0
        self._pool_blocks = (self._max_batch * self._nb_full
                             + self._prefix_budget)
        self._pool = BlockPool(self._pool_blocks)
        self._trie: Optional[PrefixTrie] = None
        if prefix_cache:
            self._trie = PrefixTrie(self._pool_blocks, self._block_size,
                                    pool=self._pool)
        # pool KV tensors: block ids are offset by 1 on device (0 is the
        # scratch block absorbing padded writes and dead-lane traffic)
        l, _, _, kv, d = abs_cache["k"].shape
        shape = (l, self._pool_blocks + 1, self._block_size, kv, d)
        self._pk = jnp.zeros(shape, abs_cache["k"].dtype)
        self._pv = jnp.zeros(shape, abs_cache["v"].dtype)
        # table-width buckets for the chunk programs (powers of two up to
        # a full table) — attention work scales with the chunk's position,
        # not cache_len
        self._tblw_buckets = _pow2_ladder(self._nb_full)

        # host-side slot state ("slot state carried as arrays")
        nb = self._max_batch
        self._slot_rid = np.full(nb, -1, np.int64)      # -1 == free
        self._slot_len = np.zeros(nb, np.int32)         # cache position
        self._slot_tok = np.zeros(nb, np.int32)         # last sampled token
        self._slot_ngen = np.zeros(nb, np.int32)        # tokens generated
        self._slot_done = np.ones(nb, bool)             # free/done mask
        self._slot_key = np.zeros((nb, 2), np.uint32)   # per-request key
        self._slot_pref_pos = np.zeros(nb, np.int32)    # next chunk offset
        self._slot_pref_end = np.zeros(nb, np.int32)    # prompt length

        # priority lanes: lane index = Request.priority (lower = more
        # urgent), FIFO within a lane; preemption re-queues at the front.
        self._lanes: Dict[int, Deque[Request]] = {}
        self._free: Deque[int] = collections.deque(range(nb))
        self._live: Dict[int, Request] = {}             # rid -> request
        # effective admission sequence per slot (prompt, or prompt + the
        # already-generated tokens for a preempt-resume)
        self._slot_seq: Dict[int, np.ndarray] = {}
        # per-slot block table (host ids; device id = host id + 1) and
        # parked pins: rid -> trie path blocks a preempted request holds
        # an extra reference on until resume or terminal
        self._slot_blocks: Dict[int, List[int]] = {}
        self._parked: Dict[int, List[int]] = {}
        self._out_toks: Dict[int, list] = {}
        self._out_lps: Dict[int, list] = {}
        self._admit_step: Dict[int, int] = {}
        self._ttft: Dict[int, float] = {}
        self._results: Dict[int, Completion] = {}
        self._terminal_state: Dict[int, RequestState] = {}
        self._next_rid = 0

        # lifecycle / admission-control state
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None)")
        self._max_queue = None if max_queue is None else int(max_queue)
        self._tenant_rate = None if tenant_rate is None else float(tenant_rate)
        if self._tenant_rate is not None and self._tenant_rate <= 0:
            raise ValueError("tenant_rate must be > 0 (or None)")
        self._tenant_burst = (self._tenant_rate if tenant_burst is None
                              else float(tenant_burst))
        self._preempt_after = (None if preempt_after_steps is None
                               else int(preempt_after_steps))
        self._tenant_level: Dict[str, float] = {}       # tokens available
        self._tenant_t: Dict[str, float] = {}           # last refill time
        self._cancel_pending: set = set()               # in-flight cancels
        self._starved_steps = 0     # consecutive full-slot steps w/ queue
        self._draining = False      # begin_drain(): submit sheds new work
        self._stream_tokens = bool(stream_tokens)
        self._stream: List[Tuple[int, int, int, float]] = []
        # durability hooks (serve.journal): submit records at admission,
        # per-rid token slices flushed once per horizon boundary,
        # terminal records at retirement
        self._journal = journal
        self._jstep: Dict[int, list] = {}   # rid -> [start, toks, lps]
        self._queue_s: Dict[int, float] = {}    # rid -> admission wait
        self._faults: Optional[FaultInjector] = (
            default_injector() if faults is None
            else (faults if isinstance(faults, FaultInjector) else None))

        self.metrics = SchedulerMetrics()
        self.metrics.pool_blocks_free = self._pool.free_blocks

        # Donation updates the pool KV tensors in place per dispatch
        # instead of copying them (the CPU jaxlib this repo pins aliases
        # the buffers too); tests/test_decode_horizon.py pins the
        # declared aliasing.
        self._chunk_fn = jax.jit(self._chunk_impl, donate_argnums=(0, 1))
        self._horizon_fn = jax.jit(self._horizon_impl, donate_argnums=(0, 1))
        self._horizon_crew_fn = jax.jit(self._horizon_crew_impl,
                                        donate_argnums=(0, 1, 2))

    # ------------------------------------------------------------------
    # Programs (one compile per chunk / batch / table-width bucket)
    # ------------------------------------------------------------------

    def _ctx(self):
        if self._mesh is None:
            return contextlib.nullcontext()
        return sharding_ctx(self._mesh, SERVE_RULES)

    def _chunk_impl(self, pk, pv, params, tokens, tables, offsets, true_cs,
                    req_keys, steps):
        """One batched prefill chunk -> (tokens, logprobs, pool KV).

        tokens [G, C] sit at per-lane cache positions
        [offsets[g], offsets[g] + C); each lane attends to its prior
        cache [0, offsets[g]) — a prefix-cache hit and/or earlier
        chunks — through its block table row (``tables`` [G, W], device
        ids, zero-padded with the scratch block).  W is the smallest
        table-width bucket covering ``offset + C`` blocks, so attention
        work scales with the chunk's position, not ``cache_len`` (rows
        past the width are all masked dead anyway; the truncation is
        exact).  Dead lanes (group smaller than G) carry all-scratch
        tables and ``true_c = 1``; their outputs are never read.  The
        tail chunk is right-padded to its bucket: causality makes the
        logits at ``true_c - 1`` independent of the padding, and padded
        rows land in dead cache positions (masked by the slot length,
        then overwritten as decode advances) or in the scratch block
        when they cross the table width — DESIGN.md §5.  ``steps`` is
        each request's generated-token count at sampling time — 0 for a
        fresh prompt (the historical key, bit for bit), ``len(gen)``
        for a preempt-resume, so sampled decoding continues the
        per-request ``fold_in`` stream exactly where the horizon
        program left it.
        """
        cache = {"k": pk, "v": pv, "len": offsets, "table": tables}
        logits, cache = self._api.prefill_chunk(
            params, tokens, cache, crew_strategy=self._crew_strategy)
        last = jnp.take_along_axis(
            logits, (true_cs - 1)[:, None, None], axis=1)[:, 0]  # [G, vocab]
        if self._temperature == 0.0:
            toks = jnp.argmax(last, axis=-1).astype(jnp.int32)
        else:
            keys = jax.vmap(jax.random.fold_in)(req_keys, steps)
            toks = jax.vmap(
                lambda k, l: jax.random.categorical(
                    k, l / self._temperature).astype(jnp.int32))(keys, last)
        # gather + logsumexp, not a full-vocab log_softmax read at [tok]
        lps = (jnp.take_along_axis(last, toks[:, None], axis=-1)[:, 0]
               - jax.scipy.special.logsumexp(last, axis=-1))
        return toks, lps, cache["k"], cache["v"]

    def _horizon_body(self, pk, pv, crew, params, tables, toks, lens,
                      req_keys, steps, rem, eos, alive):
        """H fused decode steps over the paged lanes — one host sync.

        tables is [nb, NB] (nb = the batch bucket, NB = the full table
        width); toks/lens/req_keys/steps/rem/eos/alive are [nb] lane
        vectors.  Per scan iteration each live lane decodes one token
        at its own cache position, reading and writing KV through its
        table row; a lane that samples EOS or exhausts ``rem`` (its
        remaining ``max_new`` budget) flips dead and keeps stepping
        against the scratch block at a pinned position — the program is
        fixed-shape for every iteration, and a dead lane can never
        touch a live block.  ``crew`` is this batch bucket's decode
        product-buffer state tree (or None): it rides the scan carry
        next to the KV pool, so the CREW projections' partial-product
        buffers stay resident across all H steps (DESIGN.md §3).
        Returns per-lane [nb, H] token/logprob/emitted-mask panels plus
        the updated (donated) pool and state.
        """
        def body(carry, _):
            pk, pv, crew, tok, lens, steps, rem, alive = carry
            tbl = jnp.where(alive[:, None], tables, 0)
            ln = jnp.where(alive, lens, 0)
            cache = {"k": pk, "v": pv, "len": ln, "table": tbl}
            if crew is not None:
                cache["crew"] = crew
            logits, new = self._api.decode_step(
                params, tok[:, None], cache,
                crew_strategy=self._crew_strategy)
            crew = new["crew"] if crew is not None else None
            pk, pv = new["k"], new["v"]
            if self._temperature == 0.0:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                keys = jax.vmap(jax.random.fold_in)(req_keys, steps)
                nxt = jax.vmap(
                    lambda k, l: jax.random.categorical(
                        k, l / self._temperature).astype(jnp.int32)
                )(keys, logits)
            lp = (jnp.take_along_axis(logits, nxt[:, None], axis=-1)[:, 0]
                  - jax.scipy.special.logsumexp(logits, axis=-1))
            emitted = alive
            step1 = emitted.astype(jnp.int32)
            rem = rem - step1
            alive = alive & (rem > 0) & jnp.where(eos >= 0, nxt != eos, True)
            tok = jnp.where(emitted, nxt, tok)
            lens = lens + step1
            steps = steps + step1
            return (pk, pv, crew, tok, lens, steps, rem, alive), \
                (nxt, lp, emitted)

        carry = (pk, pv, crew, toks, lens, steps, rem, alive)
        (pk, pv, crew, *_), (toks_h, lps_h, emit_h) = jax.lax.scan(
            body, carry, None, length=self._horizon)
        # [nb, H] panels
        return toks_h.T, lps_h.T, emit_h.T, pk, pv, crew

    def _horizon_impl(self, pk, pv, params, tables, toks, lens,
                      req_keys, steps, rem, eos, alive):
        """Stateless horizon program (no CREW decode state warmed)."""
        out = self._horizon_body(pk, pv, None, params, tables, toks,
                                 lens, req_keys, steps, rem, eos, alive)
        return out[:-1]

    def _horizon_crew_impl(self, pk, pv, crew, params, tables, toks,
                           lens, req_keys, steps, rem, eos, alive):
        """Horizon program with the bucket's carried CREW decode state —
        donated like the KV pool, so the product buffers update in
        place across dispatches."""
        return self._horizon_body(pk, pv, crew, params, tables, toks,
                                  lens, req_keys, steps, rem, eos, alive)

    def program_counts(self) -> Dict[str, int]:
        """Live XLA program counts — {bucket set} sized, not request sized.

        ``prefill`` counts chunk programs (one per used chunk-bucket x
        table-width-bucket pair — the width ladder is log-sized in the
        full table) and ``decode`` horizon programs (one per used batch
        bucket).  ``copy`` / ``insert`` are the retired prefix-cache
        block movers: paged admission references hit blocks in place
        and completion adopts slot blocks by reference, so both are
        **always 0** — the zero-copy pin (tests/test_decode_horizon.py).
        ``_cache_size`` is a private jax API (present on the pinned
        jax==0.4.37); -1 means this jax build no longer exposes it."""
        def size(fn):
            return getattr(fn, "_cache_size", lambda: -1)()
        hs = (size(self._horizon_fn), size(self._horizon_crew_fn))
        return {"prefill": size(self._chunk_fn),
                "decode": -1 if min(hs) < 0 else sum(hs),
                "copy": 0,
                "insert": 0}

    # ------------------------------------------------------------------
    # Queue API
    # ------------------------------------------------------------------

    def _queue_len(self) -> int:
        return sum(len(q) for q in self._lanes.values())

    def _queue_iter(self):
        """Queued requests in pop order (priority lanes, FIFO within)."""
        for pr in sorted(self._lanes):
            yield from self._lanes[pr]

    def _queue_push(self, req: Request, *, front: bool = False) -> None:
        lane = self._lanes.setdefault(req.priority, collections.deque())
        (lane.appendleft if front else lane.append)(req)
        self.metrics.queue_peak = max(self.metrics.queue_peak,
                                      self._queue_len())

    def _queue_pop(self) -> Optional[Request]:
        for pr in sorted(self._lanes):
            if self._lanes[pr]:
                return self._lanes[pr].popleft()
        return None

    def _queue_head(self) -> Optional[Request]:
        for pr in sorted(self._lanes):
            if self._lanes[pr]:
                return self._lanes[pr][0]
        return None

    def _queue_remove(self, rid: int) -> Optional[Request]:
        for lane in self._lanes.values():
            for req in lane:
                if req.rid == rid:
                    lane.remove(req)
                    return req
        return None

    def _tenant_admit(self, req: Request) -> bool:
        """Charge ``req``'s worst-case token cost against its tenant's
        bucket; False = insufficient budget (shed)."""
        if self._tenant_rate is None or req.tenant is None:
            return True
        now = time.perf_counter()
        last = self._tenant_t.get(req.tenant, now)
        level = min(self._tenant_burst,
                    self._tenant_level.get(req.tenant, self._tenant_burst)
                    + (now - last) * self._tenant_rate)
        self._tenant_t[req.tenant] = now
        cost = req.prompt.size + req.max_new
        if cost > level:
            self._tenant_level[req.tenant] = level
            return False
        self._tenant_level[req.tenant] = level - cost
        return True

    def _shed_victim(self, priority: int) -> Optional[Request]:
        """Last request of the lowest-priority non-empty lane, if that
        lane is *strictly* lower priority than ``priority``."""
        for pr in sorted(self._lanes, reverse=True):
            if pr > priority and self._lanes[pr]:
                return self._lanes[pr].pop()
        return None

    def submit(self, prompt, *, max_new: int = 32,
               eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None,
               priority: int = 0,
               tenant: Optional[str] = None,
               idem_key: Optional[str] = None) -> Union[int, Shed]:
        """Queue one request; returns its request id — or a typed
        :class:`Shed` when admission control rejects it (bounded queue
        full with no lower-priority victim, or the tenant's token bucket
        is empty).  A shed rid still receives its terminal Completion.

        ``deadline_s`` is a TTL from submit time, enforced at horizon
        boundaries; ``priority`` picks the queue lane (lower = more
        urgent; a higher-priority arrival may preempt a running decode
        when no slot is free); ``tenant`` names the token-rate bucket.
        Malformed requests (empty prompt, bad max_new, cache overflow)
        still raise ValueError — those are caller bugs, not overload.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if prompt.size + max_new > self._cache_len:
            raise ValueError(
                f"prompt {prompt.size} + max_new {max_new} exceeds "
                f"cache_len {self._cache_len}")
        if deadline_s is not None and deadline_s < 0:
            raise ValueError("deadline_s must be >= 0 (or None)")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, prompt, int(max_new), eos_id,
                      submitted_s=time.perf_counter(),
                      deadline_s=deadline_s, priority=int(priority),
                      tenant=tenant, idem_key=idem_key)
        self.metrics.tenant_bump(tenant, "submitted")
        if self._draining:
            # a draining scheduler admits nothing: the newcomer gets its
            # typed terminal immediately instead of queueing forever
            # behind a front door that will never run it
            self._terminal(req, RequestState.SHED,
                           "draining: not admitting new work")
            return Shed(rid, "draining")
        if not self._tenant_admit(req):
            self._terminal(req, RequestState.SHED,
                           f"tenant-rate: {tenant} over token budget")
            return Shed(rid, "tenant-rate")
        if (self._max_queue is not None
                and self._queue_len() >= self._max_queue):
            victim = self._shed_victim(req.priority)
            if victim is None:
                self._terminal(req, RequestState.SHED,
                               f"queue-full: {self._queue_len()} queued at "
                               f"bound {self._max_queue}")
                return Shed(rid, "queue-full")
            self._terminal(victim, RequestState.SHED,
                           "queue-full: displaced by higher-priority "
                           f"rid {rid}")
        if self._journal is not None:
            # write-ahead: the submit is durable before the request can
            # generate anything (shed requests are deliberately *not*
            # journaled — replaying one would resurrect work its client
            # already saw rejected)
            self._journal.append_submit(
                rid, prompt, max_new=req.max_new, eos_id=req.eos_id,
                deadline_s=req.deadline_s, priority=req.priority,
                tenant=req.tenant, submitted_s=req.submitted_s,
                idem_key=idem_key)
        self._queue_push(req)
        return rid

    def cancel(self, rid: int) -> bool:
        """Cancel a request; True if the cancellation took.

        Queued requests terminate immediately; in-flight requests
        terminate at the next step boundary (their lane may emit a few
        more tokens first — those are kept in the Completion).  Unknown
        or already-terminal rids return False.
        """
        req = self._queue_remove(rid)
        if req is not None:
            self._terminal(req, RequestState.CANCELLED,
                           "cancelled while queued")
            return True
        if rid in self._live and rid not in self._cancel_pending:
            self._cancel_pending.add(rid)
            return True
        return False

    def request_state(self, rid: int) -> Optional[RequestState]:
        """Current lifecycle state of ``rid`` — None for unknown rids
        and for terminal rids already drained by ``pop_results``."""
        if rid in self._live:
            return self._live[rid].state
        for req in self._queue_iter():
            if req.rid == rid:
                return RequestState.QUEUED
        if 0 <= rid < self._next_rid:
            return self._terminal_state.get(rid)
        return None

    @property
    def pending(self) -> int:
        """Queued + in-flight request count."""
        return self._queue_len() + len(self._live)

    # ------------------------------------------------------------------
    # Drain / snapshot / token-stream surface (serve.supervisor)
    # ------------------------------------------------------------------

    @property
    def draining(self) -> bool:
        """True once :meth:`begin_drain` stopped admission."""
        return self._draining

    def begin_drain(self) -> None:
        """Stop admitting: every subsequent ``submit`` returns a typed
        ``Shed(reason="draining")`` with its terminal Completion, while
        already-queued and in-flight work keeps running to completion.
        Survives ``reset(force=True)`` so crash recovery mid-drain
        stays draining; only a clean (idle) reset re-opens admission."""
        self._draining = True

    @property
    def faults(self) -> Optional[FaultInjector]:
        """The armed chaos injector (None when fault-free) — read by the
        supervisor for the ``should_crash`` hook."""
        return self._faults

    @property
    def stream_tokens(self) -> bool:
        """Whether per-token stream records are being collected."""
        return self._stream_tokens

    @property
    def journal(self) -> Optional[Journal]:
        """The attached write-ahead journal (None when not durable) —
        read by the supervisor for cold-restart replay and stats."""
        return self._journal

    def pop_tokens(self) -> List[Tuple[int, int, int, float]]:
        """Drain the per-token stream buffer: ``(rid, index, token,
        logprob)`` tuples in emission order since the last call
        (requires ``stream_tokens=True``).  ``index`` is the token's
        absolute position in the rid's generated stream — after a
        preempt-resume fallback or crash recovery re-decodes tokens, the
        same indices are re-emitted with (greedy) identical tokens, so a
        consumer that tracks a per-rid sent count dedups exactly."""
        out, self._stream = self._stream, []
        return out

    def _snap(self, req: Request) -> RequestSnapshot:
        rid = req.rid
        return RequestSnapshot(
            rid=rid,
            prompt=tuple(int(t) for t in req.prompt),
            max_new=req.max_new,
            eos_id=req.eos_id,
            deadline_s=req.deadline_s,
            priority=req.priority,
            tenant=req.tenant,
            submitted_s=req.submitted_s,
            preemptions=req.preemptions,
            tokens=tuple(int(t) for t in self._out_toks.get(rid, [])),
            logprobs=tuple(float(x) for x in self._out_lps.get(rid, [])),
            ttft_s=self._ttft.get(rid),
            idem_key=req.idem_key,
        )

    def snapshot_requests(self) -> SchedulerSnapshot:
        """Descriptors of every outstanding request — queued (parked
        preemptions included) in pop order, then in-flight by rid — plus
        the rid high-water mark.  Pure host bookkeeping: no device state
        is captured, because recovery rebuilds KV from the descriptors
        (re-prefill + re-decode is greedy-token-identical; DESIGN.md §5
        recovery napkin math)."""
        snaps = [self._snap(req) for req in self._queue_iter()]
        snaps += [self._snap(self._live[rid]) for rid in sorted(self._live)]
        return SchedulerSnapshot(self._next_rid, tuple(snaps))

    def restore(self, snapshot: SchedulerSnapshot) -> int:
        """Re-queue every snapshotted request under its original rid
        (typically right after ``reset(force=True)``).  Requests that
        had generated tokens re-enter through the scheduler's resume
        path: their kept tokens seed ``Completion.tokens``, the prompt
        re-prefills (as a prefix-pool hit when another recovered request
        re-cached it first), and anything a hit does not cover is
        re-decoded — bitwise the same tokens for greedy streams, so a
        consumer deduping on token index sees one continuous stream
        across the crash.  Returns the number of requests restored."""
        queued = {r.rid for r in self._queue_iter()}
        for snap in snapshot.requests:
            rid = snap.rid
            if (rid in self._live or rid in queued
                    or rid in self._terminal_state):
                raise ValueError(f"rid {rid} already present; restore "
                                 "expects a reset scheduler")
            req = Request(rid, np.asarray(snap.prompt, np.int32),
                          int(snap.max_new), snap.eos_id,
                          submitted_s=snap.submitted_s,
                          deadline_s=snap.deadline_s,
                          priority=int(snap.priority),
                          tenant=snap.tenant,
                          preemptions=snap.preemptions,
                          idem_key=snap.idem_key)
            if snap.tokens:
                self._out_toks[rid] = [int(t) for t in snap.tokens]
                self._out_lps[rid] = [float(x) for x in snap.logprobs]
            if snap.ttft_s is not None:
                self._ttft[rid] = snap.ttft_s
            self._queue_push(req)
            queued.add(rid)
        self._next_rid = max(self._next_rid, int(snapshot.next_rid))
        return len(snapshot.requests)

    def outstanding_rids(self) -> List[int]:
        """Queued + in-flight rids (queued in pop order, then in-flight
        by rid) — what a drain must retire before shutdown."""
        out = [req.rid for req in self._queue_iter()]
        out += sorted(self._live)
        return out

    def step_budget(self) -> int:
        """Watchdog step budget for draining the *current* outstanding
        work (see ``run``).  The supervisor uses it to bound a graceful
        drain: a drain that exceeds this budget is treated as wedged
        and the remaining requests are cancelled."""
        return self._step_budget()

    def progress_signature(self) -> tuple:
        """Opaque engine-state fingerprint; unchanged across many busy
        steps means no forward progress (the supervisor's out-of-band
        stall detector compares these, mirroring ``run``'s watchdog)."""
        return self._progress_sig()

    def _batch_bucket(self, n: int) -> int:
        return _bucket_for(self._batch_buckets, n)

    def _bucket_state(self, nb: int):
        """This batch bucket's CREW decode product-buffer state tree
        (resolved once per bucket; None with mode "off", a cold autotune
        store, or no pallas-decode winner at this batch)."""
        if self._decode_state_mode == "off":
            return None
        if nb not in self._crew_state:
            self._crew_state[nb] = decode_state_for_params(self._params, nb)
        return self._crew_state[nb]

    def _chunk_sizes(self, remaining: int) -> Tuple[int, int]:
        """(bucket, true) chunk sizes for a suffix of ``remaining`` tokens:
        full chunks advance by the largest bucket; the tail compiles
        against the smallest bucket that holds it."""
        if remaining >= self._buckets[-1]:
            return self._buckets[-1], self._buckets[-1]
        return _bucket_for(self._buckets, remaining), remaining

    # ------------------------------------------------------------------
    # Block accounting
    # ------------------------------------------------------------------

    def _blocks_needed(self, req: Request) -> int:
        """Worst-case blocks for ``req``'s full run (prompt + max_new),
        claimed up front at admission so decode never allocates —
        constant across preempt/resume cycles."""
        return -(-(req.prompt.size + req.max_new) // self._block_size)

    def _alloc_blocks(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` fresh blocks (O(1) free-list pops), evicting
        LRU refcount-1 trie leaves under pressure; None (all-or-nothing)
        when even eviction cannot cover the request."""
        got: List[int] = []
        for _ in range(n):
            bid = self._pool.alloc()
            while bid is None and self._trie is not None \
                    and self._trie.drop_lru_leaves(1):
                bid = self._pool.alloc()
            if bid is None:
                for b in got:
                    self._pool.deref(b)
                return None
            got.append(bid)
        if self._trie is not None:
            self.metrics.pool_evictions = self._trie.evictions
        return got

    def _release_parked(self, rid: int) -> None:
        for b in self._parked.pop(rid, ()):
            self._pool.deref(b)

    def _pool_gauges(self) -> None:
        free = self._pool.free_blocks
        used = self._pool.n_blocks - free
        self.metrics.pool_blocks_free = free
        self.metrics.pool_blocks_in_use = used
        self.metrics.pool_blocks_peak = max(
            self.metrics.pool_blocks_peak, used)

    def audit_blocks(self) -> List[str]:
        """Cross-owner refcount audit -> violations (empty = healthy).

        The conservation law the property harness pins
        (tests/test_paged_prop.py): every pool block's refcount equals
        the number of owners holding it — live slot tables, parked
        pins, trie nodes — and the free list is exactly the
        zero-reference blocks.  Includes the trie's own structural
        audit when the prefix cache is on.
        """
        expected: collections.Counter = collections.Counter()
        for blks in self._slot_blocks.values():
            expected.update(blks)
        for pins in self._parked.values():
            expected.update(pins)
        if self._trie is not None:
            expected.update(self._trie._nodes.keys())
        errs = list(self._pool.check_invariants())
        for bid in range(self._pool.n_blocks):
            want = expected.get(bid, 0)
            have = self._pool.refcount(bid)
            if want != have:
                errs.append(
                    f"block {bid}: refcount {have} but {want} owners")
        if self._trie is not None:
            errs += self._trie.check_invariants()
        return errs

    def reset(self, *, faults: object = _KEEP,
              force: bool = False) -> None:
        """Return an idle scheduler to its fresh-boot state, keeping the
        compiled programs (the jit caches live on bound methods, so a
        reset scheduler replays traffic with zero retracing — the
        property harness leans on this to run hundreds of workloads).
        Raises RuntimeError with work still queued or in flight, unless
        ``force=True`` — the crash-recovery path: outstanding requests
        are discarded *without* terminal Completions, on the contract
        that the caller captured them with :meth:`snapshot_requests`
        first and will :meth:`restore` them.  ``faults`` optionally
        swaps the chaos injector, with the same semantics as the
        constructor argument; by default the current injector is kept
        (its RNG streams are *not* rewound).  A drain in progress
        (:meth:`begin_drain`) survives the reset.
        """
        if not force and (self._live or self._queue_len()):
            raise RuntimeError("reset() with work queued or in flight")
        self._pk = jnp.zeros_like(self._pk)
        self._pv = jnp.zeros_like(self._pv)
        self._pool = BlockPool(self._pool_blocks)
        if self._trie is not None:
            self._trie = PrefixTrie(self._pool_blocks, self._block_size,
                                    pool=self._pool)
        self._slot_rid[:] = -1
        self._slot_len[:] = 0
        self._slot_tok[:] = 0
        self._slot_ngen[:] = 0
        self._slot_done[:] = True
        self._slot_key[:] = 0
        self._slot_pref_pos[:] = 0
        self._slot_pref_end[:] = 0
        self._lanes.clear()
        self._free = collections.deque(range(self._max_batch))
        self._live = {}
        self._slot_seq.clear()
        self._slot_blocks.clear()
        self._parked.clear()
        self._out_toks = {}
        self._out_lps = {}
        self._admit_step = {}
        self._ttft = {}
        self._jstep = {}
        self._queue_s = {}
        self._results = {}
        self._terminal_state = {}
        self._next_rid = 0
        self._tenant_level = {}
        self._tenant_t = {}
        self._cancel_pending = set()
        self._starved_steps = 0
        self._stream = []
        if not force:
            # a clean reset is a fresh boot and may admit again; a
            # forced (crash-recovery) reset keeps a drain in progress
            self._draining = False
        if faults is not _KEEP:
            self._faults = (
                default_injector() if faults is None
                else (faults if isinstance(faults, FaultInjector) else None))
        self.metrics = SchedulerMetrics()
        self.metrics.pool_blocks_free = self._pool.free_blocks

    # ------------------------------------------------------------------
    # Engine loop
    # ------------------------------------------------------------------

    def _terminal(self, req: Request, state: RequestState,
                  reason: str = "") -> None:
        """Record ``req``'s single terminal outcome (request not in a
        slot — slot holders go through ``_finish_slot``).  Non-completed
        outcomes keep any tokens generated before the end; a parked
        request's pinned blocks are released."""
        assert state in TERMINAL_STATES
        assert req.rid not in self._terminal_state, \
            f"rid {req.rid} terminated twice"
        self._release_parked(req.rid)
        req.state = state
        rid = req.rid
        admit = self._admit_step.pop(rid, None)
        comp = Completion(
            rid=rid,
            prompt_len=req.prompt.size,
            tokens=np.asarray(self._out_toks.pop(rid, []), np.int32),
            logprobs=np.asarray(self._out_lps.pop(rid, []), np.float32),
            n_steps=0 if admit is None else self.metrics.steps - admit + 1,
            ttft_s=self._ttft.pop(rid, 0.0),
            status=state.value,
            reason=reason,
            tenant=req.tenant,
            queue_s=self._queue_s.pop(rid, 0.0),
        )
        self._results[rid] = comp
        self._terminal_state[rid] = state
        counter = {RequestState.COMPLETED: "completed",
                   RequestState.CANCELLED: "cancelled",
                   RequestState.TIMED_OUT: "timed_out",
                   RequestState.SHED: "shed"}[state]
        setattr(self.metrics, counter, getattr(self.metrics, counter) + 1)
        if counter in ("completed", "shed"):
            self.metrics.tenant_bump(req.tenant, counter)
        self.metrics.tenant_bump(req.tenant, "tokens", int(comp.tokens.size))
        if self._journal is not None:
            # the terminal carries the full final stream, so replay
            # never needs this rid's earlier token records.  A shed
            # terminal does not bind its idempotency key: a shed is a
            # rejection, and re-enqueueing on retry is exactly what the
            # client wants.
            self._jstep.pop(rid, None)
            self._journal.append_terminal(
                rid, status=state.value, reason=reason,
                prompt_len=comp.prompt_len, tokens=comp.tokens,
                logprobs=comp.logprobs, ttft_s=comp.ttft_s,
                queue_s=comp.queue_s, tenant=req.tenant,
                idem_key=(None if state is RequestState.SHED
                          else req.idem_key))

    def _clear_slot(self, slot: int) -> None:
        for b in self._slot_blocks.pop(slot, ()):
            self._pool.deref(b)
        self._slot_rid[slot] = -1
        self._slot_done[slot] = True
        self._slot_len[slot] = 0
        self._slot_ngen[slot] = 0
        self._slot_pref_pos[slot] = 0
        self._slot_pref_end[slot] = 0
        self._slot_seq.pop(slot, None)
        self._free.append(slot)

    def _finish_slot(self, slot: int,
                     state: RequestState = RequestState.COMPLETED,
                     reason: str = "") -> None:
        rid = int(self._slot_rid[slot])
        req = self._live.pop(rid)
        self._cancel_pending.discard(rid)
        self._terminal(req, state, reason)
        self._clear_slot(slot)

    def _record(self, slot: int, tok: int, lp: float) -> bool:
        """Append one generated token; returns True if the slot retired."""
        rid = int(self._slot_rid[slot])
        req = self._live[rid]
        if not self._out_toks[rid]:
            self._ttft[rid] = time.perf_counter() - req.submitted_s
        self._out_toks[rid].append(tok)
        self._out_lps[rid].append(lp)
        if self._stream_tokens:
            self._stream.append((rid, len(self._out_toks[rid]) - 1,
                                 tok, lp))
        if self._journal is not None:
            # accumulate this rid's slice of the horizon panel; flushed
            # as one tokens record per rid at the step boundary
            ent = self._jstep.get(rid)
            if ent is None:
                ent = self._jstep[rid] = [
                    len(self._out_toks[rid]) - 1, [], []]
            ent[1].append(tok)
            ent[2].append(lp)
        self._slot_tok[slot] = tok
        self._slot_ngen[slot] += 1
        if ((req.eos_id is not None and tok == req.eos_id)
                or int(self._slot_ngen[slot]) >= req.max_new):
            self._finish_slot(slot)
            return True
        return False

    def _slot_of(self, rid: int) -> int:
        for s in range(self._max_batch):
            if int(self._slot_rid[s]) == rid:
                return s
        raise KeyError(rid)

    def _enforce_lifecycle(self) -> None:
        """Step-boundary lifecycle sweep: apply pending cancellations,
        expire deadlines (queued and in-flight), and let the chaos layer
        force expiries / drop pool blocks.  Runs before admission so a
        freed slot backfills in the same step."""
        for rid in sorted(self._cancel_pending):
            if rid in self._live:
                self._finish_slot(self._slot_of(rid),
                                  RequestState.CANCELLED,
                                  "cancelled mid-flight")
                continue
            # A cancel can land on a rid that was preempted back to the
            # queue (or retired) between cancel() and this boundary;
            # dropping it silently would orphan the request forever.
            req = self._queue_remove(rid)
            if req is not None:
                self._terminal(req, RequestState.CANCELLED,
                               "cancelled while parked")
            # else: retired on its own first — already terminal, no-op
        self._cancel_pending.clear()
        now = time.perf_counter()

        def expired(req: Request) -> bool:
            if req.deadline_s is None:
                return False
            if now - req.submitted_s > req.deadline_s:
                return True
            return (self._faults is not None
                    and self._faults.should_expire(req.rid))

        for req in [r for r in self._queue_iter() if expired(r)]:
            self._queue_remove(req.rid)
            self._terminal(req, RequestState.TIMED_OUT,
                           f"deadline {req.deadline_s}s exceeded in queue")
        for rid in [r for r in sorted(self._live) if expired(self._live[r])]:
            dl = self._live[rid].deadline_s
            self._finish_slot(self._slot_of(rid), RequestState.TIMED_OUT,
                              f"deadline {dl}s exceeded in flight")
        if self._faults is not None and self._trie is not None:
            if self._faults.pool_drop(self._trie):
                self.metrics.pool_evictions = self._trie.evictions

    def _preempt_slot(self, slot: int, reason: str) -> None:
        """Preempt-to-prefix-pool: the trie adopts the slot's
        block-aligned blocks (zero copy) and the request **pins** every
        block holding a written KV row — one extra reference each, held
        in ``_parked`` — before the slot's own references drop, so LRU
        eviction and fault-injected pool drops can never free the
        parked KV before resume.  The recorded sequence
        ``prompt + gen[:-1]`` is exactly the slot's valid KV rows
        (``slot_len = P + len(gen) - 1``: the last sampled token's KV is
        written by the *next* decode step, which never runs).  The pin
        covers the unaligned **tail block** the trie cannot adopt, so
        resume reattaches the pinned blocks wholesale and re-enters
        decode exactly where it left off — no recompute, no progress
        loss, and bitwise-identical KV (see :meth:`_admit_parked`).
        The pin works without a prefix cache too; only the trie
        *sharing* of the aligned part needs one."""
        rid = int(self._slot_rid[slot])
        req = self._live.pop(rid)
        gen = self._out_toks[rid]
        assert gen, "only decoding slots are preempted"
        seq = np.concatenate(
            [req.prompt, np.asarray(gen[:-1], np.int32)])
        assert seq.size == int(self._slot_len[slot]), \
            (seq.size, int(self._slot_len[slot]))
        self._pool_insert(slot, seq)
        # Pin the slot's OWN blocks, not the trie path: when another
        # request cached equivalent content first, the trie's canonical
        # block for a chunk differs from this slot's physical block —
        # but the slot's rows live in its own blocks, and those are
        # what resume must reattach.
        pinned = list(self._slot_blocks[slot][:-(-seq.size
                                                 // self._block_size)])
        for b in pinned:
            self._pool.ref(b)
        self._parked[rid] = pinned
        self._clear_slot(slot)
        req.state = RequestState.PREEMPTED
        req.preemptions += 1
        self.metrics.preempted += 1
        req.state = RequestState.QUEUED
        self._queue_push(req, front=True)

    def _maybe_preempt(self) -> None:
        """Preemption triggers, checked once per step (at most one
        preemption each): a fault-forced preempt, a queued request that
        strictly outranks a running decode when no slot is free, or
        aged starvation (``preempt_after_steps``)."""
        forced = (self._faults is not None
                  and self._faults.should_preempt())
        decoding = self._decoding()
        if not decoding:
            self._starved_steps = 0
            return
        # longest-running decode = most KV parked per chunk re-prefilled
        victim = max(decoding, key=lambda s: int(self._slot_ngen[s]))
        if forced:
            self._preempt_slot(victim, "fault-injected preemption")
            return
        head = self._queue_head()
        if head is None or self._free:
            self._starved_steps = 0
            return
        self._starved_steps += 1
        ranked = [s for s in decoding
                  if self._live[int(self._slot_rid[s])].priority
                  > head.priority]
        if ranked:
            victim = max(ranked, key=lambda s: int(self._slot_ngen[s]))
            self._preempt_slot(
                victim, f"preempted for priority-{head.priority} rid "
                f"{head.rid}")
            self._starved_steps = 0
        elif (self._preempt_after is not None
              and self._starved_steps >= self._preempt_after):
            self._preempt_slot(
                victim, f"aged pressure: queue starved {self._starved_steps} "
                "steps")
            self._starved_steps = 0

    def _admit(self) -> None:
        """Fill free slots from the queue: zero-copy prefix reference.

        Admission does *not* prefill and moves *no KV*: it resolves the
        effective sequence's longest cached prefix, bumps the hit
        blocks' refcounts straight into the slot's block table,
        allocates fresh blocks for the rest of the request's worst case
        (``prompt + max_new``, so decode never allocates), and parks
        the slot in the prefill phase with its chunk cursor at the hit
        length.  The chunk phase advances it.

        Hit references are taken *before* fresh allocation (so eviction
        under pressure can never free them).  If even trie eviction
        cannot cover the residual need —
        possible only when parked requests pin blocks — the request
        re-queues at the front and admission pauses until a retirement
        frees blocks; with nothing in flight to wait for, other parked
        requests are un-parked (stalest rid first) until the head fits,
        which costs them a cold re-prefill but never changes outputs.

        A request still holding its preemption pins short-circuits to
        :meth:`_admit_parked` — a wholesale reattach that skips the trie
        entirely.  The path below handles fresh requests and the rare
        resume whose pins the pressure valve released.  For the latter,
        generated tokens are **never re-prefilled**: a chunk-recomputed
        KV row is not bitwise identical to the decode-written row it
        would replace (different matmul shapes), and a near-tie argmax
        downstream would flip off the greedy stream.  Instead, kept
        tokens are exactly those whose decode-written KV the hit covers
        (rows ``[0, hit)`` plus the one fed-next token), and anything
        past the hit is discarded and re-decoded — bitwise the same
        tokens, since decode is batch-invariant.  When the hit covers
        at least the prompt the slot skips the prefill phase."""
        bs = self._block_size
        while self._free and self._queue_len():
            req = self._queue_pop()
            slot = self._free.popleft()
            gen = self._out_toks.get(req.rid, [])
            if req.rid in self._parked:
                if self._admit_parked(req, slot, gen):
                    continue
                break   # could not fund the reattach: requeued at front
            seq = (np.concatenate([req.prompt,
                                   np.asarray(gen, np.int32)])
                   if gen else req.prompt)
            raw = 0
            hit = 0
            hit_ids: List[int] = []
            if self._trie is not None:
                ids, raw = self._trie.match(seq)
                # keep >= 1 suffix token: first-token logits must come
                # from a live forward over the sequence's true tail
                hit = min(raw, ((seq.size - 1) // bs) * bs)
                hit_ids = ids[:hit // bs]
                for b in hit_ids:
                    self._pool.ref(b)
            fresh = self._alloc_blocks(self._blocks_needed(req)
                                       - len(hit_ids))
            if fresh is None and not self._live:
                # nothing in flight will ever free blocks: un-park other
                # requests (stalest first) until the head fits
                for orid in sorted(self._parked):
                    if orid == req.rid:
                        continue
                    self._release_parked(orid)
                    fresh = self._alloc_blocks(self._blocks_needed(req)
                                               - len(hit_ids))
                    if fresh is not None:
                        break
            if fresh is None:
                for b in hit_ids:
                    self._pool.deref(b)
                self._queue_push(req, front=True)
                self._free.appendleft(slot)
                break
            self._slot_blocks[slot] = hit_ids + fresh
            self.metrics.prefix_hit_tokens += raw
            if hit_ids:
                self.metrics.prefill_tokens_saved += hit
                self.metrics.zero_copy_hits += len(hit_ids)
            self.metrics.prefills += 1
            p_len = int(req.prompt.size)
            keep = max(0, hit - p_len + 1)
            if gen:
                self.metrics.resumed += 1
                self.metrics.resume_reprefill_tokens += \
                    max(0, p_len - hit) + len(gen) - keep
                # generated tokens past the hit re-decode, never re-chunk
                del self._out_toks[req.rid][keep:]
                del self._out_lps[req.rid][keep:]
            self._live[req.rid] = req
            self._out_toks.setdefault(req.rid, [])
            self._out_lps.setdefault(req.rid, [])
            # n_steps spans first admission -> terminal, across preempts
            self._admit_step.setdefault(req.rid, self.metrics.steps)
            self._queue_s.setdefault(
                req.rid, time.perf_counter() - req.submitted_s)
            self._slot_seq[slot] = req.prompt
            self._slot_rid[slot] = req.rid
            self._slot_done[slot] = False
            self._slot_len[slot] = hit
            self._slot_ngen[slot] = keep
            self._slot_key[slot] = np.asarray(
                jax.random.fold_in(self._base_key, req.rid))
            if keep:
                # rows [0, hit) already hold the exact decode-written KV
                # of prompt + gen[:keep-1]; resume decoding directly,
                # feeding the last kept token next
                req.state = RequestState.DECODING
                self._slot_tok[slot] = self._out_toks[req.rid][-1]
                self._slot_pref_pos[slot] = p_len
                self._slot_pref_end[slot] = p_len
            else:
                req.state = RequestState.PREFILLING
                self._slot_pref_pos[slot] = hit
                self._slot_pref_end[slot] = p_len

    def _admit_parked(self, req: Request, slot: int, gen: List[int]) -> bool:
        """Reattach a preempted request's pinned blocks wholesale.

        The pin taken at preemption covers *every* written KV row —
        including the unaligned tail block the trie cannot adopt — so
        resume transfers those references straight into the slot's
        block table and re-enters decode at the exact row it left off:
        nothing is recomputed, no generated token is discarded, and the
        KV is bitwise the original decode-written rows.  This keeps
        progress monotonic under arbitrarily aggressive preemption
        (preempt-every-step cannot livelock) where a truncate-and-
        re-decode resume would oscillate at a block boundary.  Only
        fresh blocks for the remaining decode need allocating; on
        failure the request requeues at the front with its pins intact.
        Returns True when the slot was filled."""
        parked = self._parked[req.rid]
        assert gen, "parked requests always have generated tokens"
        kv_len = int(req.prompt.size) + len(gen) - 1
        assert len(parked) == -(-kv_len // self._block_size), \
            (len(parked), kv_len)
        fresh = self._alloc_blocks(self._blocks_needed(req) - len(parked))
        if fresh is None and not self._live:
            for orid in sorted(self._parked):
                if orid == req.rid:
                    continue
                self._release_parked(orid)
                fresh = self._alloc_blocks(
                    self._blocks_needed(req) - len(parked))
                if fresh is not None:
                    break
        if fresh is None:
            self._queue_push(req, front=True)
            self._free.appendleft(slot)
            return False
        del self._parked[req.rid]   # pin references transfer to the slot
        self._slot_blocks[slot] = list(parked) + fresh
        self.metrics.prefix_hit_tokens += kv_len
        self.metrics.prefill_tokens_saved += kv_len
        self.metrics.zero_copy_hits += len(parked)
        self.metrics.prefills += 1
        self.metrics.resumed += 1
        self._live[req.rid] = req
        req.state = RequestState.DECODING
        self._admit_step.setdefault(req.rid, self.metrics.steps)
        self._queue_s.setdefault(
            req.rid, time.perf_counter() - req.submitted_s)
        self._slot_seq[slot] = req.prompt
        self._slot_rid[slot] = req.rid
        self._slot_done[slot] = False
        self._slot_len[slot] = kv_len
        self._slot_ngen[slot] = len(gen)
        self._slot_tok[slot] = gen[-1]
        self._slot_key[slot] = np.asarray(
            jax.random.fold_in(self._base_key, req.rid))
        self._slot_pref_pos[slot] = req.prompt.size
        self._slot_pref_end[slot] = req.prompt.size
        return True

    def _pool_insert(self, slot: int, tokens: np.ndarray) -> List[int]:
        """Adopt ``slot``'s block-aligned blocks for ``tokens`` into the
        trie by reference (prefill completion and preemption both land
        here — zero copy, no device program).  Returns the trie's
        canonical path ids (what a future match will return)."""
        if self._trie is None:
            return []
        path, adopted = self._trie.insert_owned(
            tokens, self._slot_blocks[slot])
        self.metrics.pool_inserts += len(adopted)
        self.metrics.pool_evictions = self._trie.evictions
        return path

    def _prefilling(self):
        return [s for s in range(self._max_batch)
                if not self._slot_done[s]
                and self._slot_pref_pos[s] < self._slot_pref_end[s]]

    def _decoding(self):
        return [s for s in range(self._max_batch)
                if not self._slot_done[s]
                and self._slot_pref_pos[s] >= self._slot_pref_end[s]]

    def _prefill_chunks(self) -> None:
        """Advance every prefilling slot by one chunk (co-scheduled with
        the decode horizon: a long prompt spreads its prefill over
        steps instead of stalling token emission).  Slots sharing a
        (chunk bucket, table-width bucket) advance in **one** batched
        dispatch — lanes padded to ``max_batch`` with dead scratch-table
        lanes, so the program set stays (chunk x width) sized while a
        warm wave of same-prefix prompts prefills in a single program
        launch.  With no decode-active lanes there is nothing to
        co-schedule against, so chunking rounds continue until a prompt
        completes and decode can start.  Sampled first tokens are read
        once per round, only for the chunks that completed a prompt."""
        bs = self._block_size
        while True:
            prefilling = self._prefilling()
            if not prefilling:
                return
            groups: Dict[Tuple[int, int], list] = {}
            for slot in prefilling:
                end = int(self._slot_pref_end[slot])
                pos = int(self._slot_pref_pos[slot])
                c_bkt, c_true = self._chunk_sizes(end - pos)
                w = _bucket_for(self._tblw_buckets,
                                -(-(pos + c_bkt) // bs))
                groups.setdefault((c_bkt, w), []).append(
                    (slot, pos, c_true, end))
            completed = []
            for (c_bkt, w), members in sorted(groups.items()):
                g = self._max_batch
                tokens = np.zeros((g, c_bkt), np.int32)
                tables = np.zeros((g, w), np.int32)
                offsets = np.zeros(g, np.int32)
                true_cs = np.ones(g, np.int32)
                keys = np.zeros((g, 2), np.uint32)
                steps = np.zeros(g, np.int32)
                for i, (slot, pos, c_true, _end) in enumerate(members):
                    seq = self._slot_seq[slot]
                    tokens[i, :c_true] = seq[pos:pos + c_true]
                    blks = self._slot_blocks[slot][:w]
                    tables[i, :len(blks)] = np.asarray(blks, np.int32) + 1
                    offsets[i] = pos
                    true_cs[i] = c_true
                    keys[i] = self._slot_key[slot]
                    steps[i] = int(self._slot_ngen[slot])
                with self._ctx():
                    toks, lps, self._pk, self._pv = self._chunk_fn(
                        self._pk, self._pv, self._params,
                        jnp.asarray(tokens), jnp.asarray(tables),
                        jnp.asarray(offsets), jnp.asarray(true_cs),
                        jnp.asarray(keys), jnp.asarray(steps))
                toks = np.asarray(toks)
                lps = np.asarray(lps)
                self.metrics.chunks += len(members)
                self.metrics.prefill_chunk_tokens += c_bkt * len(members)
                for i, (slot, pos, c_true, end) in enumerate(members):
                    self._slot_pref_pos[slot] = pos + c_true
                    self._slot_len[slot] = pos + c_true
                    if pos + c_true >= end:
                        completed.append((slot, self._slot_seq[slot],
                                          int(toks[i]), float(lps[i])))
            for slot, seq, tok, lp in completed:
                self._pool_insert(slot, seq)
                self._live[int(self._slot_rid[slot])].state = \
                    RequestState.DECODING
                self._record(slot, tok, lp)
            if self._decoding():
                return

    def step(self) -> bool:
        """One horizon boundary: enforce lifecycle (cancels, deadlines,
        injected faults), maybe preempt, admit, advance prefill chunks,
        run one fused H-step horizon, retire; True while busy.

        An empty queue with no active slots is an idle drain: returns
        False without launching any program.
        """
        self.metrics.steps += 1
        self._enforce_lifecycle()
        self._maybe_preempt()
        self._admit()
        self._prefill_chunks()
        self._pool_gauges()
        active = self._decoding()
        if not active:
            busy = bool(self._queue_len() or self._live)
            if not busy:
                self.metrics.steps -= 1  # nothing ran
            self._journal_flush()
            return busy
        nb = self._batch_bucket(len(active))
        tables = np.zeros((nb, self._nb_full), np.int32)
        toks = np.zeros(nb, np.int32)
        lens = np.zeros(nb, np.int32)
        keys = np.zeros((nb, 2), np.uint32)
        steps = np.zeros(nb, np.int32)
        rem = np.zeros(nb, np.int32)
        eos = np.full(nb, -1, np.int32)
        alive = np.zeros(nb, bool)
        for i, s in enumerate(active):
            req = self._live[int(self._slot_rid[s])]
            blks = self._slot_blocks[s]
            tables[i, :len(blks)] = np.asarray(blks, np.int32) + 1
            toks[i] = self._slot_tok[s]
            lens[i] = self._slot_len[s]
            keys[i] = self._slot_key[s]
            steps[i] = self._slot_ngen[s]
            rem[i] = req.max_new - int(self._slot_ngen[s])
            eos[i] = -1 if req.eos_id is None else int(req.eos_id)
            alive[i] = True
        crew = self._bucket_state(nb)
        if self._faults is not None:
            dt = self._faults.horizon_delay()
            if dt:
                time.sleep(dt)   # chaos: a slow device / noisy neighbor
        with self._ctx():
            if crew is None:
                toks_h, lps_h, emit_h, self._pk, self._pv = self._horizon_fn(
                    self._pk, self._pv, self._params, jnp.asarray(tables),
                    jnp.asarray(toks), jnp.asarray(lens), jnp.asarray(keys),
                    jnp.asarray(steps), jnp.asarray(rem), jnp.asarray(eos),
                    jnp.asarray(alive))
            else:
                (toks_h, lps_h, emit_h, self._pk, self._pv,
                 self._crew_state[nb]) = self._horizon_crew_fn(
                    self._pk, self._pv, crew, self._params,
                    jnp.asarray(tables), jnp.asarray(toks),
                    jnp.asarray(lens), jnp.asarray(keys),
                    jnp.asarray(steps), jnp.asarray(rem), jnp.asarray(eos),
                    jnp.asarray(alive))
        toks_h = np.asarray(toks_h)
        lps_h = np.asarray(lps_h)
        emit_h = np.asarray(emit_h)
        h = self._horizon
        emitted_total = int(emit_h[:len(active)].sum())
        self.metrics.horizons += 1
        self.metrics.decode_steps += h
        self.metrics.decode_lanes += emitted_total
        self.metrics.padded_lanes += (nb - len(active)) * h
        self.metrics.wasted_lane_steps += nb * h - emitted_total
        for i, s in enumerate(active):
            for t in range(h):
                if not emit_h[i, t]:
                    break
                self._slot_len[s] += 1  # step t wrote the prior token's KV
                if self._record(s, int(toks_h[i, t]), float(lps_h[i, t])):
                    break
        self._pool_gauges()
        self._journal_flush()
        return bool(self._queue_len() or self._live)

    def _journal_flush(self) -> None:
        """Horizon-boundary durability point: write one tokens record
        per rid that emitted this step, then commit (one fsync under the
        ``"horizon"`` policy — the napkin math in DESIGN.md §5.1)."""
        if self._journal is None:
            return
        for rid, (start, toks, lps) in self._jstep.items():
            self._journal.append_tokens(rid, start, toks, lps)
        self._jstep.clear()
        self._journal.commit(
            idle=not (self._queue_len() or self._live))

    def _step_budget(self) -> int:
        """Generous upper bound on the steps draining the current work
        could take — chunks plus horizons per request as if each ran
        alone, with slack for preempt/resume cycles and injected faults.
        A healthy scheduler finishes far under it; only a stall crosses
        it."""
        work = 0
        for req in list(self._queue_iter()) + list(self._live.values()):
            total = req.prompt.size + req.max_new
            chunks = -(-total // self._buckets[0])      # ceil, worst bucket
            horizons = -(-req.max_new // self._horizon)
            work += chunks + horizons
        return 64 + 8 * work

    def _stall_report(self, steps: int, budget: int) -> str:
        used = self._pool.n_blocks - self._pool.free_blocks
        lines = [f"scheduler stalled after {steps} steps "
                 f"(budget {budget}): no forward progress",
                 f"  queue: {self._queue_len()} waiting "
                 f"(rids {[r.rid for r in self._queue_iter()][:8]}), "
                 f"{len(self._free)} free slots",
                 f"  pool: {used}/{self._pool.n_blocks} blocks in use, "
                 f"{len(self._parked)} parked requests pinning blocks"]
        for s in range(self._max_batch):
            if self._slot_done[s]:
                continue
            rid = int(self._slot_rid[s])
            req = self._live.get(rid)
            lines.append(
                f"  slot {s}: rid {rid} "
                f"state={req.state.value if req else '?'} "
                f"len={int(self._slot_len[s])} "
                f"prefill={int(self._slot_pref_pos[s])}/"
                f"{int(self._slot_pref_end[s])} "
                f"ngen={int(self._slot_ngen[s])} "
                f"blocks={len(self._slot_blocks.get(s, ()))}"
                + (f"/{req.max_new}" if req else ""))
        return "\n".join(lines)

    def _progress_sig(self) -> tuple:
        return (self._queue_len(), tuple(sorted(self._live)),
                tuple(int(x) for x in self._slot_len),
                tuple(int(x) for x in self._slot_ngen),
                tuple(int(x) for x in self._slot_pref_pos),
                len(self._results))

    def run(self, max_steps: Optional[int] = None) -> Dict[int, Completion]:
        """Drain the queue to completion; returns {rid: Completion} for
        every terminal outcome (completed, cancelled, timed out, shed).

        A watchdog bounds the drain: ``max_steps`` caps the step count
        (default: a generous budget derived from the outstanding work,
        ``_step_budget``), and a no-progress detector trips when the
        scheduler state signature is unchanged across 16 consecutive
        busy steps.  Either raises :class:`SchedulerStalledError` with a
        per-slot diagnostic instead of spinning forever.
        """
        budget = int(max_steps) if max_steps is not None \
            else self._step_budget()
        steps = 0
        stalled = 0
        sig = self._progress_sig()
        while self.step():
            steps += 1
            new_sig = self._progress_sig()
            stalled = stalled + 1 if new_sig == sig else 0
            sig = new_sig
            if steps >= budget or stalled >= 16:
                raise SchedulerStalledError(
                    self._stall_report(steps, budget))
        return self.pop_results()

    def pop_results(self) -> Dict[int, Completion]:
        out, self._results = self._results, {}
        for rid in out:
            # a popped rid can never re-terminate (it left the queue and
            # the slots at terminal time), so its state entry can go —
            # keeps lifecycle bookkeeping bounded on a long-lived server
            self._terminal_state.pop(rid, None)
        return out
