"""Continuous-batching serve scheduler — DESIGN.md §5.

``serve.generate`` is one static jit'd batch: every request shares one
prompt length and one ``max_new``, so mixed traffic either pads to the
worst case or serializes.  :class:`Scheduler` instead owns a request
queue, a slot-based KV cache, and a cross-request **prefix cache**, and
interleaves chunked prefill with decode:

* **admission + prefix reuse** — at each horizon boundary, queued
  prompts are admitted into free slots.  The prompt first matches its
  longest cached prefix in a radix tree over block-granular pool KV
  (``serve.prefix.PrefixTrie``); the matched blocks are *copied* into
  the slot's stripe (one gather on the block axis, donated like the rest
  of the cache state) and only the **suffix** is prefilled — prefill
  work is O(new tokens), not O(prompt), when traffic shares system
  prompts / few-shot templates / retried requests (CREW's
  cache-unique-products-and-index insight one level up, PAPER.md).
* **chunked prefill** — the suffix runs through ``api.prefill_chunk`` in
  bucket-sized chunks against the already-populated slot cache
  (``layers.attention.attend_prefill_cached``: per-slot length offsets,
  chunk rows scattered at their own cache positions).  One program per
  chunk bucket — prompts longer than the largest bucket are now
  admissible, and a prefilling prompt advances one chunk per engine
  step while other slots keep decoding, so a long prefill no longer
  stalls token emission.  Chunk-by-chunk prefill is token- and
  cache-bitwise identical to the monolithic prefill (the single-pass
  softmax in ``cached_chunk_attention`` reproduces ``chunked_attention``
  exactly), so greedy outputs stay token-identical to cold-cache
  ``serve.generate`` with or without prefix hits.
* **horizon decode** — one fused program runs ``horizon`` decode steps
  (``lax.scan``, default H=8) across all decode-active slots.  Each scan
  iteration gathers the live lanes out of the slot cache, decodes one
  token per lane with a *per-slot* length vector, and scatters back.
  EOS / per-request ``max_new`` exhaustion is masked *on device* (dead
  lanes step against the scratch slot at a pinned position); the host
  syncs **once per horizon**, not once per token.
* **retire + backfill + pool insert** — at the horizon boundary the host
  replays the emitted-token mask, retires requests that hit EOS or
  ``max_new``, and backfills freed slots from the queue.  When a
  prompt's prefill completes, its block-aligned KV prefix is inserted
  into the pool (one scatter on the block axis) so the *next* request
  sharing it prefills only its own suffix; pool pressure evicts
  least-recently-used trie leaves — never state a live slot depends on,
  because matches are copied, not aliased.

The hot loop is a fixed set of XLA programs: one chunk-prefill program
per chunk bucket, one horizon program per batch bucket, and one
copy/insert program per block-count bucket — no per-request retracing
(``program_counts()`` exposes the live compile counts; tests pin them).
The slot KV cache and the block pool — the only multi-megabyte state
threaded between programs — are **donated** through every dispatch, so
they update in place instead of being copied (the [nb]-sized lane
vectors are cheap and passed by value).

Slot state (last tokens, lengths, prefill cursors, done mask,
per-request RNG keys, generated counts) is carried as arrays; CREW
params flow through the same ``crew_strategy="auto"`` autotuned dispatch
as the one-shot engine; under an active mesh the programs trace inside
``sharding_ctx(mesh, SERVE_RULES)`` so ``constrain`` calls bind.

Requires the transformer-family cache contract ``{"k","v","len"}`` with
``[L, B, S, KV, D]`` KV tensors (dense / MoE configs; families without a
chunked-prefill path are rejected at construction).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Deque, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.ctx import sharding_ctx
from ..dist.sharding import SERVE_RULES
from ..kernels.plan import warn_deprecated
from ..models import ModelApi
from .convert import decode_state_for_params
from .prefix import PrefixTrie

__all__ = ["Scheduler", "SchedulerMetrics", "Request", "Completion",
           "DEFAULT_BUCKETS", "DEFAULT_HORIZON", "DEFAULT_BLOCK_SIZE"]

DEFAULT_BUCKETS: Tuple[int, ...] = (16, 32, 64, 128)
DEFAULT_HORIZON = 8
DEFAULT_BLOCK_SIZE = 16


def _pow2_ladder(top: int) -> Tuple[int, ...]:
    """Powers of two up to ``top`` (``top`` included even when not one)."""
    out = []
    p = 1
    while p < top:
        out.append(p)
        p *= 2
    out.append(top)
    return tuple(out)


def _bucket_for(ladder: Tuple[int, ...], n: int) -> int:
    """Smallest ladder entry >= n (the ladder's top for anything larger)."""
    for b in ladder:
        if n <= b:
            return b
    return ladder[-1]


@dataclasses.dataclass
class Request:
    """One queued generation request (host-side)."""
    rid: int
    prompt: np.ndarray          # [S] int32, unpadded
    max_new: int
    eos_id: Optional[int]
    submitted_s: float = 0.0    # perf_counter at submit (TTFT accounting)


@dataclasses.dataclass
class Completion:
    """A finished request: generated tokens (EOS included if hit)."""
    rid: int
    prompt_len: int
    tokens: np.ndarray          # [n_generated] int32
    logprobs: np.ndarray        # [n_generated] float32
    n_steps: int                # engine steps from admission to retirement
    ttft_s: float = 0.0         # submit -> first token wall time


@dataclasses.dataclass
class SchedulerMetrics:
    """Engine counters.  Read them as attributes (``m.steps``); the
    dict-style spellings (``m["steps"]``) from the pre-dataclass era
    still work for one release behind a DeprecationWarning
    (docs/api.md)."""
    steps: int = 0              # engine steps (admit + chunk + horizon)
    prefills: int = 0           # prompts admitted
    chunks: int = 0             # chunk-prefill programs dispatched
    prefill_chunk_tokens: int = 0   # chunk tokens computed (incl. padding)
    prefix_hit_tokens: int = 0  # trie-matched tokens (pre-cap)
    prefill_tokens_saved: int = 0   # prompt tokens served from the pool
    pool_inserts: int = 0       # blocks written into the pool
    pool_evictions: int = 0     # LRU leaf evictions under pool pressure
    horizons: int = 0           # fused H-step programs dispatched
    decode_steps: int = 0       # device decode steps (H per horizon)
    decode_lanes: int = 0       # useful (emitted) lane-steps
    padded_lanes: int = 0       # batch-bucket padding lane-steps
    wasted_lane_steps: int = 0  # dead-or-padding lane-steps per horizon

    def __getitem__(self, key: str) -> int:
        warn_deprecated(
            "SchedulerMetrics:getitem",
            "dict-style SchedulerMetrics reads (metrics[...]) are "
            "deprecated; read the attribute (metrics.steps etc.) — see "
            "docs/api.md")
        if not hasattr(self, key):
            raise KeyError(key)
        return getattr(self, key)

    def __setitem__(self, key: str, value: int) -> None:
        warn_deprecated(
            "SchedulerMetrics:setitem",
            "dict-style SchedulerMetrics writes (metrics[...] = ...) are "
            "deprecated; set the attribute — see docs/api.md")
        if not hasattr(self, key):
            raise KeyError(key)
        setattr(self, key, value)

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class Scheduler:
    """Continuous-batching engine over chunked-prefill/horizon programs.

    Args:
      api / params: as for ``serve.generate`` (dense or CREW-converted).
      max_batch: number of concurrent decode slots (one extra scratch
        slot is allocated internally for batch-bucket padding and for
        mid-horizon-retired lanes).
      cache_len: per-slot KV capacity; every admitted request must fit
        ``prompt_len + max_new <= cache_len``.
      buckets: chunk sizes, ascending.  A prefilling prompt advances by
        the largest bucket per chunk; its tail compiles against the
        smallest bucket that holds it.  Prompts of any length up to
        ``cache_len - max_new`` are admissible (the monolithic-prefill
        cap on prompt length is gone).  None derives the default ladder
        clipped to ``cache_len``.
      horizon: decode steps per fused program dispatch (H).  The host
        syncs once per horizon; ``horizon=1`` is the token-synchronous
        baseline.  Retirement happens at horizon boundaries, so a lane
        whose request dies mid-horizon idles (masked, scratch-directed)
        until the boundary — ``metrics.wasted_lane_steps`` counts it.
      prefix_cache: enable the radix-tree prefix cache (default).  Off,
        every prompt prefills cold — the PR-4-equivalent baseline that
        ``benchmarks/prefix_reuse.py`` measures against.
      block_size: prefix-cache granularity in tokens; only block-aligned
        prefixes are shared, and a hit is capped one block short of the
        prompt so at least one suffix token prefills (first-token logits
        must come from a live forward).
      pool_blocks: KV pool capacity in blocks (+1 scratch block is
        allocated internally).  None sizes it to one full batch's worth
        of cache (``max_batch * cache_len // block_size``) — i.e. the
        prefix cache roughly doubles the scheduler's KV memory by
        default; pass an explicit budget when memory is tight or the
        hot prefix set is large.
      temperature / crew_strategy: static sampling and CREW dispatch
        knobs, shared by all programs (as in ``serve.generate``).
      decode_state: "auto" (default) resolves the CREW decode
        product-buffer state per batch bucket from the warmed autotune
        store (``serve.decode_state_for_params``) and threads it through
        the horizon scan carry with donated buffers; "off" disables it.
        A cold store resolves to no state — the historical stateless
        horizon, bit for bit.
      rng: base PRNG key; each request derives its own key stream via
        ``fold_in(fold_in(rng, rid), n_generated)``.
      mesh: optional device mesh; programs then trace under
        ``sharding_ctx(mesh, SERVE_RULES)``.
    """

    def __init__(
        self,
        api: ModelApi,
        params,
        *,
        max_batch: int = 8,
        cache_len: int = 256,
        buckets: Optional[Sequence[int]] = None,
        horizon: int = DEFAULT_HORIZON,
        prefix_cache: bool = True,
        block_size: int = DEFAULT_BLOCK_SIZE,
        pool_blocks: Optional[int] = None,
        temperature: float = 0.0,
        crew_strategy: str = "auto",
        decode_state: str = "auto",
        rng: Optional[jnp.ndarray] = None,
        mesh=None,
        cache_dtype=jnp.bfloat16,
    ):
        if not api.cfg.has_decode:
            raise ValueError(f"{api.cfg.arch_id} is encoder-only: no decode")
        if not hasattr(api._mod, "prefill_chunk"):
            raise NotImplementedError(
                f"{api.cfg.family} has no chunked-prefill path")
        if horizon < 1:
            raise ValueError("horizon must be >= 1")
        self._api = api
        self._params = params
        self._max_batch = int(max_batch)
        self._cache_len = int(cache_len)
        self._horizon = int(horizon)
        if buckets is None:
            buckets = ([b for b in DEFAULT_BUCKETS if b <= self._cache_len]
                       or [self._cache_len])
        self._buckets = tuple(sorted(int(b) for b in buckets))
        if not self._buckets:
            raise ValueError("need at least one chunk bucket")
        if self._buckets[-1] > self._cache_len:
            raise ValueError(
                f"largest bucket {self._buckets[-1]} exceeds cache_len "
                f"{self._cache_len}")
        self._temperature = float(temperature)
        self._crew_strategy = crew_strategy
        if decode_state not in ("auto", "off"):
            raise ValueError('decode_state must be "auto" or "off"')
        self._decode_state_mode = decode_state
        # per-batch-bucket CREW decode product-buffer state trees (None
        # when the bucket's shapes have no measured pallas-decode winner);
        # resolved lazily on first use of each bucket.
        self._crew_state: Dict[int, object] = {}
        self._base_key = rng if rng is not None else jax.random.PRNGKey(0)
        self._mesh = mesh

        # batch buckets: powers of two up to max_batch (max_batch included
        # even when not a power of two).
        self._batch_buckets = _pow2_ladder(self._max_batch)

        # slot cache: max_batch real slots + 1 scratch slot for padding
        # lanes and mid-horizon-retired lanes (duplicate scatter indices
        # must never hit a live slot).
        abs_cache = api.abstract_cache(self._max_batch + 1, self._cache_len,
                                       dtype=cache_dtype)
        if not (isinstance(abs_cache, dict)
                and set(abs_cache) == {"k", "v", "len"}):
            raise NotImplementedError(
                f"{api.cfg.family} cache is not the {{k,v,len}} KV contract "
                "the slot scheduler manages")
        self._k = jnp.zeros(abs_cache["k"].shape, abs_cache["k"].dtype)
        self._v = jnp.zeros(abs_cache["v"].shape, abs_cache["v"].dtype)

        # prefix-cache block pool: pool_blocks real blocks + scratch block
        # 0 (padding lanes of the bucketed copy/insert programs read and
        # write it, never a real block).
        self._block_size = int(block_size)
        if self._block_size < 1:
            raise ValueError("block_size must be >= 1")
        # default pool = one full batch's worth of stripes, so enabling
        # the prefix cache costs at most ~2x the slot-cache KV memory
        # (stated in the arg docs; size it to the hot prefix set +
        # headroom in production — docs/serving.md "Sizing")
        if pool_blocks is None:
            pool_blocks = max(
                self._max_batch * (self._cache_len // self._block_size), 8)
        self._pool_blocks = int(pool_blocks)
        self._trie: Optional[PrefixTrie] = None
        self._pk = self._pv = None
        if prefix_cache:
            # block ids are offset by 1 on device (0 is scratch)
            self._trie = PrefixTrie(self._pool_blocks, self._block_size)
            l, _, _, kv, d = abs_cache["k"].shape
            shape = (l, self._pool_blocks + 1, self._block_size, kv, d)
            self._pk = jnp.zeros(shape, abs_cache["k"].dtype)
            self._pv = jnp.zeros(shape, abs_cache["v"].dtype)
        # block-count buckets for the copy/insert programs (powers of two
        # up to a full stripe's worth of blocks)
        self._nblk_buckets = _pow2_ladder(
            max(self._cache_len // self._block_size, 1))

        # host-side slot state ("slot state carried as arrays")
        nb = self._max_batch
        self._slot_rid = np.full(nb, -1, np.int64)      # -1 == free
        self._slot_len = np.zeros(nb, np.int32)         # cache position
        self._slot_tok = np.zeros(nb, np.int32)         # last sampled token
        self._slot_ngen = np.zeros(nb, np.int32)        # tokens generated
        self._slot_done = np.ones(nb, bool)             # free/done mask
        self._slot_key = np.zeros((nb, 2), np.uint32)   # per-request key
        self._slot_pref_pos = np.zeros(nb, np.int32)    # next chunk offset
        self._slot_pref_end = np.zeros(nb, np.int32)    # prompt length

        self._queue: Deque[Request] = collections.deque()
        self._free: Deque[int] = collections.deque(range(nb))
        self._live: Dict[int, Request] = {}             # rid -> request
        self._out_toks: Dict[int, list] = {}
        self._out_lps: Dict[int, list] = {}
        self._admit_step: Dict[int, int] = {}
        self._ttft: Dict[int, float] = {}
        self._results: Dict[int, Completion] = {}
        self._next_rid = 0

        self.metrics = SchedulerMetrics()

        # Donation updates the slot KV cache / block pool in place per
        # dispatch instead of copying them (the CPU jaxlib this repo pins
        # aliases the buffers too); tests/test_decode_horizon.py pins the
        # declared aliasing.
        self._win_buckets = _pow2_ladder(self._cache_len)
        self._chunk_fn = jax.jit(self._chunk_impl, donate_argnums=(0, 1),
                                 static_argnums=(8,))
        self._horizon_fn = jax.jit(self._horizon_impl, donate_argnums=(0, 1))
        self._horizon_crew_fn = jax.jit(self._horizon_crew_impl,
                                        donate_argnums=(0, 1, 2))
        self._copy_fn = jax.jit(self._copy_impl, donate_argnums=(0, 1))
        self._insert_fn = jax.jit(self._insert_impl, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    # Programs (one compile per chunk / batch / block-count bucket)
    # ------------------------------------------------------------------

    def _ctx(self):
        if self._mesh is None:
            return contextlib.nullcontext()
        return sharding_ctx(self._mesh, SERVE_RULES)

    def _chunk_impl(self, k_all, v_all, params, tokens, offset, true_c, slot,
                    req_key, win):
        """One prefill chunk for one slot -> (token, logprob, cache).

        tokens [1, C] sit at slot cache positions [offset, offset + C);
        the chunk attends to the slot's prior cache [0, offset) — a
        prefix-cache hit and/or earlier chunks — via
        ``api.prefill_chunk``, never recomputing it.  ``win`` (static)
        is the KV *window* the chunk sees: the smallest window bucket
        covering ``offset + C``, so attention work scales with the
        chunk's position, not with ``cache_len`` — a 32-token prompt in
        a 4096-slot cache scores 32x32, not 32x4096 (rows past the
        window are all masked dead anyway; the truncation is exact).
        The tail chunk is right-padded to its bucket: causality makes
        the logits at ``true_c - 1`` independent of the padding, and
        padded cache rows are dead (masked by the slot length, then
        overwritten as decode advances) — DESIGN.md §5.  The sampled
        token/logprob are read by the host only for the chunk that
        completes a prompt.
        """
        cache = {"k": k_all[:, slot, :win][:, None],
                 "v": v_all[:, slot, :win][:, None], "len": offset}
        logits, cache = self._api.prefill_chunk(
            params, tokens, cache, crew_strategy=self._crew_strategy)
        last = jax.lax.dynamic_index_in_dim(
            logits, true_c - 1, axis=1, keepdims=False)[0]       # [vocab]
        if self._temperature == 0.0:
            tok = jnp.argmax(last).astype(jnp.int32)
        else:
            tok = jax.random.categorical(
                jax.random.fold_in(req_key, 0),
                last / self._temperature).astype(jnp.int32)
        # gather + logsumexp, not a full-vocab log_softmax read at [tok]
        lp = last[tok] - jax.scipy.special.logsumexp(last)
        k_all = k_all.at[:, slot, :win].set(cache["k"][:, 0])
        v_all = v_all.at[:, slot, :win].set(cache["v"][:, 0])
        return tok, lp, k_all, v_all

    def _copy_impl(self, k_all, v_all, pk, pv, ids, slot):
        """Prefix-cache hit: pool blocks ``ids`` -> slot positions [0, n·bs).

        One gather on the block axis; ``ids`` is padded to its
        block-count bucket with the scratch block 0, whose rows land
        beyond the hit length and are dead (overwritten by the first
        suffix chunk or masked).
        """
        bs = self._block_size
        n = ids.shape[0]
        blk_k = pk[:, ids]                  # [L, n, bs, KV, D]
        blk_v = pv[:, ids]
        l, _, _, kv, d = blk_k.shape
        k_all = k_all.at[:, slot, :n * bs].set(blk_k.reshape(l, n * bs, kv, d))
        v_all = v_all.at[:, slot, :n * bs].set(blk_v.reshape(l, n * bs, kv, d))
        return k_all, v_all

    def _insert_impl(self, pk, pv, k_all, v_all, ids, slot, start):
        """Pool insert: slot positions [start, start + n·bs) -> blocks ``ids``.

        One scatter on the block axis.  The rows are read by *index*,
        never ``dynamic_slice``: when the bucket-padded window crosses
        ``cache_len`` the padding rows must clamp individually (their
        garbage lands in the scratch block 0, never read as real data) —
        a dus start-clamp would instead shift the whole window back over
        earlier rows and poison the *real* blocks for every later hit.
        """
        bs = self._block_size
        n = ids.shape[0]
        pos = start + jnp.arange(n * bs)                # [n·bs], clamped get
        seg_k = k_all[:, slot, pos]
        seg_v = v_all[:, slot, pos]
        l, _, kv, d = seg_k.shape
        pk = pk.at[:, ids].set(seg_k.reshape(l, n, bs, kv, d))
        pv = pv.at[:, ids].set(seg_v.reshape(l, n, bs, kv, d))
        return pk, pv

    def _horizon_body(self, k_all, v_all, crew, params, slot_ids, toks, lens,
                      req_keys, steps, rem, eos, alive):
        """H fused decode steps over the gathered lanes — one host sync.

        slot_ids/toks/lens/req_keys/steps/rem/eos/alive are [nb] lane
        vectors (nb = the batch bucket); padding lanes point at the
        scratch slot with ``alive=False``.  Per scan iteration each live
        lane decodes one token at its own cache position; a lane that
        samples EOS or exhausts ``rem`` (its remaining ``max_new`` budget)
        flips dead and keeps stepping against the scratch slot at a
        pinned position — the program is fixed-shape for every iteration.
        ``crew`` is this batch bucket's decode product-buffer state tree
        (or None): it rides the scan carry next to the KV buffers, so the
        CREW projections' partial-product buffers stay resident across
        all H steps (DESIGN.md §3).  Returns per-lane [nb, H]
        token/logprob/emitted-mask panels plus the updated (donated)
        cache and state.
        """
        scratch = self._max_batch

        def body(carry, _):
            k_all, v_all, crew, tok, lens, steps, rem, alive = carry
            sid = jnp.where(alive, slot_ids, scratch)
            ln = jnp.where(alive, lens, 0)
            k_sel = k_all[:, sid]
            v_sel = v_all[:, sid]
            cache = {"k": k_sel, "v": v_sel, "len": ln}
            if crew is not None:
                cache["crew"] = crew
            logits, new = self._api.decode_step(
                params, tok[:, None], cache,
                crew_strategy=self._crew_strategy)
            crew = new["crew"] if crew is not None else None
            if self._temperature == 0.0:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                keys = jax.vmap(jax.random.fold_in)(req_keys, steps)
                nxt = jax.vmap(
                    lambda k, l: jax.random.categorical(
                        k, l / self._temperature).astype(jnp.int32)
                )(keys, logits)
            lp = (jnp.take_along_axis(logits, nxt[:, None], axis=-1)[:, 0]
                  - jax.scipy.special.logsumexp(logits, axis=-1))
            k_all = k_all.at[:, sid].set(new["k"])
            v_all = v_all.at[:, sid].set(new["v"])
            emitted = alive
            step1 = emitted.astype(jnp.int32)
            rem = rem - step1
            alive = alive & (rem > 0) & jnp.where(eos >= 0, nxt != eos, True)
            tok = jnp.where(emitted, nxt, tok)
            lens = lens + step1
            steps = steps + step1
            return (k_all, v_all, crew, tok, lens, steps, rem, alive), \
                (nxt, lp, emitted)

        carry = (k_all, v_all, crew, toks, lens, steps, rem, alive)
        (k_all, v_all, crew, *_), (toks_h, lps_h, emit_h) = jax.lax.scan(
            body, carry, None, length=self._horizon)
        # [nb, H] panels
        return toks_h.T, lps_h.T, emit_h.T, k_all, v_all, crew

    def _horizon_impl(self, k_all, v_all, params, slot_ids, toks, lens,
                      req_keys, steps, rem, eos, alive):
        """Stateless horizon program (no CREW decode state warmed)."""
        out = self._horizon_body(k_all, v_all, None, params, slot_ids, toks,
                                 lens, req_keys, steps, rem, eos, alive)
        return out[:-1]

    def _horizon_crew_impl(self, k_all, v_all, crew, params, slot_ids, toks,
                           lens, req_keys, steps, rem, eos, alive):
        """Horizon program with the bucket's carried CREW decode state —
        donated like the KV buffers, so the product buffers update in
        place across dispatches."""
        return self._horizon_body(k_all, v_all, crew, params, slot_ids,
                                  toks, lens, req_keys, steps, rem, eos,
                                  alive)

    def program_counts(self) -> Dict[str, int]:
        """Live XLA program counts — {bucket set} sized, not request sized.

        ``prefill`` counts chunk programs (one per used chunk-bucket x
        KV-window-bucket pair — the window ladder is log-sized in
        ``cache_len``), ``decode`` horizon programs (one per used batch
        bucket), and ``copy`` / ``insert`` the prefix-cache block movers
        (one per used block-count bucket).  ``_cache_size`` is a private jax API
        (present on the pinned jax==0.4.37); -1 means this jax build no
        longer exposes it."""
        def size(fn):
            return getattr(fn, "_cache_size", lambda: -1)()
        hs = (size(self._horizon_fn), size(self._horizon_crew_fn))
        return {"prefill": size(self._chunk_fn),
                "decode": -1 if min(hs) < 0 else sum(hs),
                "copy": size(self._copy_fn),
                "insert": size(self._insert_fn)}

    # ------------------------------------------------------------------
    # Queue API
    # ------------------------------------------------------------------

    def submit(self, prompt, *, max_new: int = 32,
               eos_id: Optional[int] = None) -> int:
        """Queue one request; returns its request id."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if prompt.size + max_new > self._cache_len:
            raise ValueError(
                f"prompt {prompt.size} + max_new {max_new} exceeds "
                f"cache_len {self._cache_len}")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, prompt, int(max_new), eos_id,
                                   submitted_s=time.perf_counter()))
        return rid

    @property
    def pending(self) -> int:
        """Queued + in-flight request count."""
        return len(self._queue) + len(self._live)

    def _batch_bucket(self, n: int) -> int:
        return _bucket_for(self._batch_buckets, n)

    def _bucket_state(self, nb: int):
        """This batch bucket's CREW decode product-buffer state tree
        (resolved once per bucket; None with mode "off", a cold autotune
        store, or no pallas-decode winner at this batch)."""
        if self._decode_state_mode == "off":
            return None
        if nb not in self._crew_state:
            self._crew_state[nb] = decode_state_for_params(self._params, nb)
        return self._crew_state[nb]

    def _chunk_sizes(self, remaining: int) -> Tuple[int, int]:
        """(bucket, true) chunk sizes for a suffix of ``remaining`` tokens:
        full chunks advance by the largest bucket; the tail compiles
        against the smallest bucket that holds it."""
        if remaining >= self._buckets[-1]:
            return self._buckets[-1], self._buckets[-1]
        return _bucket_for(self._buckets, remaining), remaining

    def _padded_block_ids(self, ids) -> jnp.ndarray:
        """Block-mover ids padded to their block-count bucket with the
        pool's scratch block 0 (host ids are 0-based; device block 0 is
        the scratch)."""
        padded = np.zeros(_bucket_for(self._nblk_buckets, len(ids)), np.int32)
        padded[:len(ids)] = np.asarray(ids, np.int32) + 1
        return jnp.asarray(padded)

    # ------------------------------------------------------------------
    # Engine loop
    # ------------------------------------------------------------------

    def _retire(self, slot: int) -> None:
        rid = int(self._slot_rid[slot])
        req = self._live.pop(rid)
        self._results[rid] = Completion(
            rid=rid,
            prompt_len=req.prompt.size,
            tokens=np.asarray(self._out_toks.pop(rid), np.int32),
            logprobs=np.asarray(self._out_lps.pop(rid), np.float32),
            n_steps=self.metrics.steps - self._admit_step.pop(rid) + 1,
            ttft_s=self._ttft.pop(rid, 0.0),
        )
        self._slot_rid[slot] = -1
        self._slot_done[slot] = True
        self._slot_len[slot] = 0
        self._slot_ngen[slot] = 0
        self._slot_pref_pos[slot] = 0
        self._slot_pref_end[slot] = 0
        self._free.append(slot)

    def _record(self, slot: int, tok: int, lp: float) -> bool:
        """Append one generated token; returns True if the slot retired."""
        rid = int(self._slot_rid[slot])
        req = self._live[rid]
        if not self._out_toks[rid]:
            self._ttft[rid] = time.perf_counter() - req.submitted_s
        self._out_toks[rid].append(tok)
        self._out_lps[rid].append(lp)
        self._slot_tok[slot] = tok
        self._slot_ngen[slot] += 1
        if ((req.eos_id is not None and tok == req.eos_id)
                or int(self._slot_ngen[slot]) >= req.max_new):
            self._retire(slot)
            return True
        return False

    def _admit(self) -> None:
        """Fill free slots from the queue: prefix match + block copy.

        Admission does *not* prefill: it resolves the prompt's longest
        cached prefix, copies those pool blocks into the slot stripe
        (one bucketed gather program, dead-padded with the scratch
        block), and parks the slot in the prefill phase with its chunk
        cursor at the hit length.  The chunk phase advances it."""
        while self._free and self._queue:
            slot = self._free.popleft()
            req = self._queue.popleft()
            hit = 0
            if self._trie is not None:
                ids, raw = self._trie.match(req.prompt)
                self.metrics.prefix_hit_tokens += raw
                # keep >= 1 suffix token: first-token logits must come
                # from a live forward over the prompt's true tail
                bs = self._block_size
                hit = min(raw, ((req.prompt.size - 1) // bs) * bs)
                ids = ids[:hit // bs]
                if ids:
                    with self._ctx():
                        self._k, self._v = self._copy_fn(
                            self._k, self._v, self._pk, self._pv,
                            self._padded_block_ids(ids), jnp.int32(slot))
                    self.metrics.prefill_tokens_saved += hit
            self.metrics.prefills += 1
            self._live[req.rid] = req
            self._out_toks[req.rid] = []
            self._out_lps[req.rid] = []
            self._admit_step[req.rid] = self.metrics.steps
            self._slot_rid[slot] = req.rid
            self._slot_done[slot] = False
            self._slot_len[slot] = hit
            self._slot_ngen[slot] = 0
            self._slot_key[slot] = np.asarray(
                jax.random.fold_in(self._base_key, req.rid))
            self._slot_pref_pos[slot] = hit
            self._slot_pref_end[slot] = req.prompt.size

    def _pool_insert(self, slot: int, req: Request) -> None:
        """Cache the completed prompt's block-aligned KV prefix."""
        if self._trie is None:
            return
        new_ids, start = self._trie.insert(req.prompt)
        if new_ids:
            with self._ctx():
                self._pk, self._pv = self._insert_fn(
                    self._pk, self._pv, self._k, self._v,
                    self._padded_block_ids(new_ids), jnp.int32(slot),
                    jnp.int32(start))
            self.metrics.pool_inserts += len(new_ids)
        self.metrics.pool_evictions = self._trie.evictions

    def _prefilling(self):
        return [s for s in range(self._max_batch)
                if not self._slot_done[s]
                and self._slot_pref_pos[s] < self._slot_pref_end[s]]

    def _decoding(self):
        return [s for s in range(self._max_batch)
                if not self._slot_done[s]
                and self._slot_pref_pos[s] >= self._slot_pref_end[s]]

    def _prefill_chunks(self) -> None:
        """Advance every prefilling slot by one chunk (co-scheduled with
        the decode horizon: a long prompt spreads its prefill over
        steps instead of stalling token emission).  With no decode-active
        lanes there is nothing to co-schedule against, so chunking rounds
        continue until a prompt completes and decode can start.  Chunk
        dispatches queue back-to-back; sampled first tokens are read once
        at the end, only for the chunks that completed a prompt."""
        while True:
            prefilling = self._prefilling()
            if not prefilling:
                return
            completed = []
            for slot in prefilling:
                req = self._live[int(self._slot_rid[slot])]
                pos = int(self._slot_pref_pos[slot])
                c_bkt, c_true = self._chunk_sizes(req.prompt.size - pos)
                win = _bucket_for(self._win_buckets, pos + c_bkt)
                tokens = np.zeros((1, c_bkt), np.int32)
                tokens[0, :c_true] = req.prompt[pos:pos + c_true]
                with self._ctx():
                    tok, lp, self._k, self._v = self._chunk_fn(
                        self._k, self._v, self._params, jnp.asarray(tokens),
                        jnp.int32(pos), jnp.int32(c_true), jnp.int32(slot),
                        jnp.asarray(self._slot_key[slot]), win)
                self.metrics.chunks += 1
                self.metrics.prefill_chunk_tokens += c_bkt
                self._slot_pref_pos[slot] = pos + c_true
                self._slot_len[slot] = pos + c_true
                if pos + c_true >= req.prompt.size:
                    completed.append((slot, req, tok, lp))
            for slot, req, tok, lp in completed:
                self._pool_insert(slot, req)
                self._record(slot, int(tok), float(lp))
            if self._decoding():
                return

    def step(self) -> bool:
        """Admit, advance prefill chunks, run one fused H-step horizon,
        retire; True while busy.

        An empty queue with no active slots is an idle drain: returns
        False without launching any program.
        """
        self.metrics.steps += 1
        self._admit()
        self._prefill_chunks()
        active = self._decoding()
        if not active:
            busy = bool(self._queue or self._live)
            if not busy:
                self.metrics.steps -= 1  # nothing ran
            return busy
        nb = self._batch_bucket(len(active))
        scratch = self._max_batch
        lanes = active + [scratch] * (nb - len(active))
        slot_ids = np.asarray(lanes, np.int32)
        toks = np.zeros(nb, np.int32)
        lens = np.zeros(nb, np.int32)
        keys = np.zeros((nb, 2), np.uint32)
        steps = np.zeros(nb, np.int32)
        rem = np.zeros(nb, np.int32)
        eos = np.full(nb, -1, np.int32)
        alive = np.zeros(nb, bool)
        for i, s in enumerate(active):
            req = self._live[int(self._slot_rid[s])]
            toks[i] = self._slot_tok[s]
            lens[i] = self._slot_len[s]
            keys[i] = self._slot_key[s]
            steps[i] = self._slot_ngen[s]
            rem[i] = req.max_new - int(self._slot_ngen[s])
            eos[i] = -1 if req.eos_id is None else int(req.eos_id)
            alive[i] = True
        crew = self._bucket_state(nb)
        with self._ctx():
            if crew is None:
                toks_h, lps_h, emit_h, self._k, self._v = self._horizon_fn(
                    self._k, self._v, self._params, jnp.asarray(slot_ids),
                    jnp.asarray(toks), jnp.asarray(lens), jnp.asarray(keys),
                    jnp.asarray(steps), jnp.asarray(rem), jnp.asarray(eos),
                    jnp.asarray(alive))
            else:
                (toks_h, lps_h, emit_h, self._k, self._v,
                 self._crew_state[nb]) = self._horizon_crew_fn(
                    self._k, self._v, crew, self._params,
                    jnp.asarray(slot_ids), jnp.asarray(toks),
                    jnp.asarray(lens), jnp.asarray(keys),
                    jnp.asarray(steps), jnp.asarray(rem), jnp.asarray(eos),
                    jnp.asarray(alive))
        toks_h = np.asarray(toks_h)
        lps_h = np.asarray(lps_h)
        emit_h = np.asarray(emit_h)
        h = self._horizon
        emitted_total = int(emit_h[:len(active)].sum())
        self.metrics.horizons += 1
        self.metrics.decode_steps += h
        self.metrics.decode_lanes += emitted_total
        self.metrics.padded_lanes += (nb - len(active)) * h
        self.metrics.wasted_lane_steps += nb * h - emitted_total
        for i, s in enumerate(active):
            for t in range(h):
                if not emit_h[i, t]:
                    break
                self._slot_len[s] += 1  # step t wrote the prior token's KV
                if self._record(s, int(toks_h[i, t]), float(lps_h[i, t])):
                    break
        return bool(self._queue or self._live)

    def run(self) -> Dict[int, Completion]:
        """Drain the queue to completion; returns {rid: Completion}."""
        while self.step():
            pass
        return self.pop_results()

    def pop_results(self) -> Dict[int, Completion]:
        out, self._results = self._results, {}
        return out
