"""Continuous-batching serve scheduler — DESIGN.md §5.

``serve.generate`` is one static jit'd batch: every request shares one
prompt length and one ``max_new``, so mixed traffic either pads to the
worst case or serializes.  :class:`Scheduler` instead owns a request
queue and a slot-based KV cache and interleaves prefill with decode:

* **admission** — at each horizon boundary, queued prompts are admitted
  into free slots.  A prompt is padded to the smallest configured
  *prefill bucket* that holds it, runs the ordinary ``api.prefill`` at
  batch 1, and its KV is written into the slot's stripe of the shared
  cache.  The sampled first token and the true (unpadded) length become
  the slot's state.  Prefill dispatches are queued back-to-back and
  synced once, so the host's admit bookkeeping overlaps the device work.
* **horizon decode** — one fused program runs ``horizon`` decode steps
  (``lax.scan``, default H=8) across all active slots.  Each scan
  iteration gathers the live lanes out of the slot cache, decodes one
  token per lane with a *per-slot* length vector (each lane RoPEs and
  scatters at its own position — see ``layers.attention.attend_decode``),
  and scatters back.  EOS / per-request ``max_new`` exhaustion is masked
  *on device*: a retired lane keeps stepping — fixed-shape program — but
  its reads and KV writes are redirected to the scratch slot at a pinned
  position, so it can neither corrupt a live slot nor overrun its own
  cache.  The host syncs **once per horizon**, not once per token.
* **retire + backfill** — at the horizon boundary the host replays the
  emitted-token mask, retires requests that hit EOS or ``max_new``, and
  backfills freed slots from the queue on the next admit, so short and
  long requests coexist without padding the whole batch to the longest.

The hot loop is therefore a fixed set of XLA programs: one prefill
program per prefill bucket and one horizon program per batch bucket —
no per-request retracing (``program_counts()`` exposes the live compile
counts; tests pin them).  The slot KV cache — the only multi-megabyte
state threaded between programs — is **donated** through every prefill
and horizon call, so it is updated in place instead of being copied per
dispatch (the [nb]-sized lane vectors are cheap and passed by value).
While a horizon is in flight the host pre-buckets the queue head (async
overlap); the request queue and the free-slot pool are O(1) deques.

Slot state (last tokens, lengths, done mask, per-request RNG keys,
generated counts) is carried as arrays; CREW params flow through the
same ``crew_strategy="auto"`` autotuned dispatch as the one-shot engine;
under an active mesh the programs trace inside
``sharding_ctx(mesh, SERVE_RULES)`` so ``constrain`` calls bind.

Requires the transformer-family cache contract ``{"k","v","len"}`` with
``[L, B, S, KV, D]`` KV tensors (dense / MoE configs; families without a
prefill-with-cache path are rejected at construction).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import itertools
from typing import Deque, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.ctx import sharding_ctx
from ..dist.sharding import SERVE_RULES
from ..models import ModelApi

__all__ = ["Scheduler", "Request", "Completion", "DEFAULT_BUCKETS",
           "DEFAULT_HORIZON"]

DEFAULT_BUCKETS: Tuple[int, ...] = (16, 32, 64, 128)
DEFAULT_HORIZON = 8


@dataclasses.dataclass
class Request:
    """One queued generation request (host-side)."""
    rid: int
    prompt: np.ndarray          # [S] int32, unpadded
    max_new: int
    eos_id: Optional[int]
    padded: Optional[np.ndarray] = None  # [1, bucket] admit-ready form


@dataclasses.dataclass
class Completion:
    """A finished request: generated tokens (EOS included if hit)."""
    rid: int
    prompt_len: int
    tokens: np.ndarray          # [n_generated] int32
    logprobs: np.ndarray        # [n_generated] float32
    n_steps: int                # engine steps from admission to retirement


class Scheduler:
    """Continuous-batching engine over bucketed prefill/horizon programs.

    Args:
      api / params: as for ``serve.generate`` (dense or CREW-converted).
      max_batch: number of concurrent decode slots (one extra scratch
        slot is allocated internally for batch-bucket padding and for
        mid-horizon-retired lanes).
      cache_len: per-slot KV capacity; every admitted request must fit
        ``prompt_len + max_new <= cache_len``.
      buckets: prefill pad lengths, ascending; a prompt compiles against
        the smallest bucket that holds it.  None derives the default set
        clipped to ``cache_len``.
      horizon: decode steps per fused program dispatch (H).  The host
        syncs once per horizon; ``horizon=1`` is the token-synchronous
        baseline.  Retirement happens at horizon boundaries, so a lane
        whose request dies mid-horizon idles (masked, scratch-directed)
        until the boundary — ``metrics["wasted_lane_steps"]`` counts it.
      temperature / crew_strategy: static sampling and CREW dispatch
        knobs, shared by all programs (as in ``serve.generate``).
      rng: base PRNG key; each request derives its own key stream via
        ``fold_in(fold_in(rng, rid), n_generated)``.
      mesh: optional device mesh; programs then trace under
        ``sharding_ctx(mesh, SERVE_RULES)``.
    """

    def __init__(
        self,
        api: ModelApi,
        params,
        *,
        max_batch: int = 8,
        cache_len: int = 256,
        buckets: Optional[Sequence[int]] = None,
        horizon: int = DEFAULT_HORIZON,
        temperature: float = 0.0,
        crew_strategy: str = "auto",
        rng: Optional[jnp.ndarray] = None,
        mesh=None,
        cache_dtype=jnp.bfloat16,
    ):
        if not api.cfg.has_decode:
            raise ValueError(f"{api.cfg.arch_id} is encoder-only: no decode")
        if not hasattr(api._mod, "prefill"):
            raise NotImplementedError(
                f"{api.cfg.family} has no prefill-with-cache path")
        if horizon < 1:
            raise ValueError("horizon must be >= 1")
        self._api = api
        self._params = params
        self._max_batch = int(max_batch)
        self._cache_len = int(cache_len)
        self._horizon = int(horizon)
        if buckets is None:
            buckets = ([b for b in DEFAULT_BUCKETS if b <= self._cache_len]
                       or [self._cache_len])
        self._buckets = tuple(sorted(int(b) for b in buckets))
        if not self._buckets:
            raise ValueError("need at least one prefill bucket")
        if self._buckets[-1] > self._cache_len:
            raise ValueError(
                f"largest bucket {self._buckets[-1]} exceeds cache_len "
                f"{self._cache_len}")
        self._temperature = float(temperature)
        self._crew_strategy = crew_strategy
        self._base_key = rng if rng is not None else jax.random.PRNGKey(0)
        self._mesh = mesh

        # batch buckets: powers of two up to max_batch (max_batch included
        # even when not a power of two).
        bb = []
        p = 1
        while p < self._max_batch:
            bb.append(p)
            p *= 2
        bb.append(self._max_batch)
        self._batch_buckets = tuple(bb)

        # slot cache: max_batch real slots + 1 scratch slot for padding
        # lanes and mid-horizon-retired lanes (duplicate scatter indices
        # must never hit a live slot).
        abs_cache = api.abstract_cache(self._max_batch + 1, self._cache_len,
                                       dtype=cache_dtype)
        if not (isinstance(abs_cache, dict)
                and set(abs_cache) == {"k", "v", "len"}):
            raise NotImplementedError(
                f"{api.cfg.family} cache is not the {{k,v,len}} KV contract "
                "the slot scheduler manages")
        self._k = jnp.zeros(abs_cache["k"].shape, abs_cache["k"].dtype)
        self._v = jnp.zeros(abs_cache["v"].shape, abs_cache["v"].dtype)

        # host-side slot state ("slot state carried as arrays")
        nb = self._max_batch
        self._slot_rid = np.full(nb, -1, np.int64)      # -1 == free
        self._slot_len = np.zeros(nb, np.int32)         # cache position
        self._slot_tok = np.zeros(nb, np.int32)         # last sampled token
        self._slot_ngen = np.zeros(nb, np.int32)        # tokens generated
        self._slot_done = np.ones(nb, bool)             # free/done mask
        self._slot_key = np.zeros((nb, 2), np.uint32)   # per-request key

        self._queue: Deque[Request] = collections.deque()
        self._free: Deque[int] = collections.deque(range(nb))
        self._live: Dict[int, Request] = {}             # rid -> request
        self._out_toks: Dict[int, list] = {}
        self._out_lps: Dict[int, list] = {}
        self._admit_step: Dict[int, int] = {}
        self._results: Dict[int, Completion] = {}
        self._next_rid = 0

        self.metrics = {"steps": 0, "prefills": 0, "horizons": 0,
                        "decode_steps": 0, "decode_lanes": 0,
                        "padded_lanes": 0, "wasted_lane_steps": 0}

        # Donation updates the slot KV cache in place per dispatch instead
        # of copying it (the CPU jaxlib this repo pins aliases the buffers
        # too); tests/test_decode_horizon.py pins the declared aliasing.
        self._prefill_fn = jax.jit(self._prefill_impl, donate_argnums=(0, 1))
        self._horizon_fn = jax.jit(self._horizon_impl, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    # Programs (one compile per prefill bucket / batch bucket)
    # ------------------------------------------------------------------

    def _ctx(self):
        if self._mesh is None:
            return contextlib.nullcontext()
        return sharding_ctx(self._mesh, SERVE_RULES)

    def _prefill_impl(self, k_all, v_all, params, prompt, true_len, slot,
                      req_key):
        """prompt [1, bucket] -> (first token, logprob, updated slot cache).

        The prompt is right-padded to its bucket; causality makes the
        logits at ``true_len - 1`` independent of the padding, and the
        padded cache positions are dead (masked by the slot length, then
        overwritten as decode advances) — DESIGN.md §5.
        """
        from ..layers.attention import _maybe_quant_kv

        logits, cache = self._api.prefill(
            params, {"tokens": prompt}, self._cache_len,
            crew_strategy=self._crew_strategy)
        last = jax.lax.dynamic_index_in_dim(
            logits, true_len - 1, axis=1, keepdims=False)[0]     # [vocab]
        if self._temperature == 0.0:
            tok = jnp.argmax(last).astype(jnp.int32)
        else:
            tok = jax.random.categorical(
                jax.random.fold_in(req_key, 0),
                last / self._temperature).astype(jnp.int32)
        # gather + logsumexp, not a full-vocab log_softmax read at [tok]
        lp = last[tok] - jax.scipy.special.logsumexp(last)
        # quantize on insert when the slot cache is int8 (prefill emits
        # bf16 KV; decode-time writes go through the same helper)
        k_all = k_all.at[:, slot].set(_maybe_quant_kv(cache["k"][:, 0], k_all))
        v_all = v_all.at[:, slot].set(_maybe_quant_kv(cache["v"][:, 0], v_all))
        return tok, lp, k_all, v_all

    def _horizon_impl(self, k_all, v_all, params, slot_ids, toks, lens,
                      req_keys, steps, rem, eos, alive):
        """H fused decode steps over the gathered lanes — one host sync.

        slot_ids/toks/lens/req_keys/steps/rem/eos/alive are [nb] lane
        vectors (nb = the batch bucket); padding lanes point at the
        scratch slot with ``alive=False``.  Per scan iteration each live
        lane decodes one token at its own cache position; a lane that
        samples EOS or exhausts ``rem`` (its remaining ``max_new`` budget)
        flips dead and keeps stepping against the scratch slot at a
        pinned position — the program is fixed-shape for every iteration.
        Returns per-lane [nb, H] token/logprob/emitted-mask panels plus
        the updated (donated) cache.
        """
        scratch = self._max_batch

        def body(carry, _):
            k_all, v_all, tok, lens, steps, rem, alive = carry
            sid = jnp.where(alive, slot_ids, scratch)
            ln = jnp.where(alive, lens, 0)
            k_sel = k_all[:, sid]
            v_sel = v_all[:, sid]
            logits, new = self._api.decode_step(
                params, tok[:, None], {"k": k_sel, "v": v_sel, "len": ln},
                crew_strategy=self._crew_strategy)
            if self._temperature == 0.0:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                keys = jax.vmap(jax.random.fold_in)(req_keys, steps)
                nxt = jax.vmap(
                    lambda k, l: jax.random.categorical(
                        k, l / self._temperature).astype(jnp.int32)
                )(keys, logits)
            lp = (jnp.take_along_axis(logits, nxt[:, None], axis=-1)[:, 0]
                  - jax.scipy.special.logsumexp(logits, axis=-1))
            k_all = k_all.at[:, sid].set(new["k"])
            v_all = v_all.at[:, sid].set(new["v"])
            emitted = alive
            step1 = emitted.astype(jnp.int32)
            rem = rem - step1
            alive = alive & (rem > 0) & jnp.where(eos >= 0, nxt != eos, True)
            tok = jnp.where(emitted, nxt, tok)
            lens = lens + step1
            steps = steps + step1
            return (k_all, v_all, tok, lens, steps, rem, alive), \
                (nxt, lp, emitted)

        carry = (k_all, v_all, toks, lens, steps, rem, alive)
        (k_all, v_all, *_), (toks_h, lps_h, emit_h) = jax.lax.scan(
            body, carry, None, length=self._horizon)
        return toks_h.T, lps_h.T, emit_h.T, k_all, v_all   # [nb, H] panels

    def program_counts(self) -> Dict[str, int]:
        """Live XLA program counts — {bucket set} sized, not request sized.

        ``_cache_size`` is a private jax API (present on the pinned
        jax==0.4.37); -1 means this jax build no longer exposes it."""
        def size(fn):
            return getattr(fn, "_cache_size", lambda: -1)()
        return {"prefill": size(self._prefill_fn),
                "decode": size(self._horizon_fn)}

    # ------------------------------------------------------------------
    # Queue API
    # ------------------------------------------------------------------

    def submit(self, prompt, *, max_new: int = 32,
               eos_id: Optional[int] = None) -> int:
        """Queue one request; returns its request id."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size > self._buckets[-1]:
            raise ValueError(
                f"prompt length {prompt.size} exceeds largest bucket "
                f"{self._buckets[-1]}")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if prompt.size + max_new > self._cache_len:
            raise ValueError(
                f"prompt {prompt.size} + max_new {max_new} exceeds "
                f"cache_len {self._cache_len}")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, prompt, int(max_new), eos_id))
        return rid

    @property
    def pending(self) -> int:
        """Queued + in-flight request count."""
        return len(self._queue) + len(self._live)

    def _bucket_for(self, n: int) -> int:
        for b in self._buckets:
            if n <= b:
                return b
        raise ValueError(f"no bucket holds prompt length {n}")

    def _batch_bucket(self, n: int) -> int:
        for b in self._batch_buckets:
            if n <= b:
                return b
        return self._max_batch

    def _pad_prompt(self, req: Request) -> np.ndarray:
        if req.padded is None:
            bucket = self._bucket_for(req.prompt.size)
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :req.prompt.size] = req.prompt
            req.padded = padded
        return req.padded

    def _prepare_queue_head(self) -> None:
        """Bucket/pad the prompts the next admit can possibly touch.

        Called right after a horizon dispatch: this host work runs while
        the device is still executing the in-flight program (async
        overlap), so the next boundary's admissions start from ready
        arrays."""
        for req in itertools.islice(self._queue, self._max_batch):
            self._pad_prompt(req)

    # ------------------------------------------------------------------
    # Engine loop
    # ------------------------------------------------------------------

    def _retire(self, slot: int) -> None:
        rid = int(self._slot_rid[slot])
        req = self._live.pop(rid)
        self._results[rid] = Completion(
            rid=rid,
            prompt_len=req.prompt.size,
            tokens=np.asarray(self._out_toks.pop(rid), np.int32),
            logprobs=np.asarray(self._out_lps.pop(rid), np.float32),
            n_steps=self.metrics["steps"] - self._admit_step.pop(rid) + 1,
        )
        self._slot_rid[slot] = -1
        self._slot_done[slot] = True
        self._slot_len[slot] = 0
        self._slot_ngen[slot] = 0
        self._free.append(slot)

    def _record(self, slot: int, tok: int, lp: float) -> bool:
        """Append one generated token; returns True if the slot retired."""
        rid = int(self._slot_rid[slot])
        req = self._live[rid]
        self._out_toks[rid].append(tok)
        self._out_lps[rid].append(lp)
        self._slot_tok[slot] = tok
        self._slot_ngen[slot] += 1
        if ((req.eos_id is not None and tok == req.eos_id)
                or int(self._slot_ngen[slot]) >= req.max_new):
            self._retire(slot)
            return True
        return False

    def _admit(self) -> None:
        """Fill free slots from the queue; one sync for all prefills.

        The prefill dispatches are queued back-to-back without reading
        their results, so the host's slot bookkeeping for request *i+1*
        overlaps the device running request *i*'s prefill; the sampled
        first tokens are read once at the end (a retirement there —
        prefill-sampled EOS — frees the slot for the *next* boundary,
        matching the pre-horizon semantics)."""
        admitted = []
        n_admit = min(len(self._free), len(self._queue))
        for _ in range(n_admit):
            slot = self._free.popleft()
            req = self._queue.popleft()
            padded = self._pad_prompt(req)
            req_key = np.asarray(jax.random.fold_in(self._base_key, req.rid))
            with self._ctx():
                tok, lp, self._k, self._v = self._prefill_fn(
                    self._k, self._v, self._params, jnp.asarray(padded),
                    jnp.int32(req.prompt.size), jnp.int32(slot),
                    jnp.asarray(req_key))
            self.metrics["prefills"] += 1
            self._live[req.rid] = req
            self._out_toks[req.rid] = []
            self._out_lps[req.rid] = []
            self._admit_step[req.rid] = self.metrics["steps"]
            self._slot_rid[slot] = req.rid
            self._slot_done[slot] = False
            self._slot_len[slot] = req.prompt.size
            self._slot_ngen[slot] = 0
            self._slot_key[slot] = req_key
            admitted.append((slot, tok, lp))
        for slot, tok, lp in admitted:
            self._record(slot, int(tok), float(lp))

    def step(self) -> bool:
        """Admit, run one fused H-step horizon, retire; True while busy.

        An empty queue with no active slots is an idle drain: returns
        False without launching any program.
        """
        self.metrics["steps"] += 1
        self._admit()
        active = [s for s in range(self._max_batch) if not self._slot_done[s]]
        if not active:
            busy = bool(self._queue)
            if not busy:
                self.metrics["steps"] -= 1  # nothing ran
            return busy
        nb = self._batch_bucket(len(active))
        scratch = self._max_batch
        lanes = active + [scratch] * (nb - len(active))
        slot_ids = np.asarray(lanes, np.int32)
        toks = np.zeros(nb, np.int32)
        lens = np.zeros(nb, np.int32)
        keys = np.zeros((nb, 2), np.uint32)
        steps = np.zeros(nb, np.int32)
        rem = np.zeros(nb, np.int32)
        eos = np.full(nb, -1, np.int32)
        alive = np.zeros(nb, bool)
        for i, s in enumerate(active):
            req = self._live[int(self._slot_rid[s])]
            toks[i] = self._slot_tok[s]
            lens[i] = self._slot_len[s]
            keys[i] = self._slot_key[s]
            steps[i] = self._slot_ngen[s]
            rem[i] = req.max_new - int(self._slot_ngen[s])
            eos[i] = -1 if req.eos_id is None else int(req.eos_id)
            alive[i] = True
        with self._ctx():
            toks_h, lps_h, emit_h, self._k, self._v = self._horizon_fn(
                self._k, self._v, self._params, jnp.asarray(slot_ids),
                jnp.asarray(toks), jnp.asarray(lens), jnp.asarray(keys),
                jnp.asarray(steps), jnp.asarray(rem), jnp.asarray(eos),
                jnp.asarray(alive))
        # async overlap: pre-bucket the queue head while the horizon
        # program is still executing on device, then sync once.
        self._prepare_queue_head()
        toks_h = np.asarray(toks_h)
        lps_h = np.asarray(lps_h)
        emit_h = np.asarray(emit_h)
        h = self._horizon
        emitted_total = int(emit_h[:len(active)].sum())
        self.metrics["horizons"] += 1
        self.metrics["decode_steps"] += h
        self.metrics["decode_lanes"] += emitted_total
        self.metrics["padded_lanes"] += (nb - len(active)) * h
        self.metrics["wasted_lane_steps"] += nb * h - emitted_total
        for i, s in enumerate(active):
            for t in range(h):
                if not emit_h[i, t]:
                    break
                self._slot_len[s] += 1  # step t wrote the prior token's KV
                if self._record(s, int(toks_h[i, t]), float(lps_h[i, t])):
                    break
        return bool(self._queue or self._live)

    def run(self) -> Dict[int, Completion]:
        """Drain the queue to completion; returns {rid: Completion}."""
        while self.step():
            pass
        return self.pop_results()

    def pop_results(self) -> Dict[int, Completion]:
        out, self._results = self._results, {}
        return out
