"""Continuous-batching serve scheduler — DESIGN.md §5.

``serve.generate`` is one static jit'd batch: every request shares one
prompt length and one ``max_new``, so mixed traffic either pads to the
worst case or serializes.  :class:`Scheduler` instead owns a request
queue, a slot-based KV cache, and a cross-request **prefix cache**, and
interleaves chunked prefill with decode:

* **admission + prefix reuse** — at each horizon boundary, queued
  prompts are admitted into free slots.  The prompt first matches its
  longest cached prefix in a radix tree over block-granular pool KV
  (``serve.prefix.PrefixTrie``); the matched blocks are *copied* into
  the slot's stripe (one gather on the block axis, donated like the rest
  of the cache state) and only the **suffix** is prefilled — prefill
  work is O(new tokens), not O(prompt), when traffic shares system
  prompts / few-shot templates / retried requests (CREW's
  cache-unique-products-and-index insight one level up, PAPER.md).
* **chunked prefill** — the suffix runs through ``api.prefill_chunk`` in
  bucket-sized chunks against the already-populated slot cache
  (``layers.attention.attend_prefill_cached``: per-slot length offsets,
  chunk rows scattered at their own cache positions).  One program per
  chunk bucket — prompts longer than the largest bucket are now
  admissible, and a prefilling prompt advances one chunk per engine
  step while other slots keep decoding, so a long prefill no longer
  stalls token emission.  Chunk-by-chunk prefill is token- and
  cache-bitwise identical to the monolithic prefill (the single-pass
  softmax in ``cached_chunk_attention`` reproduces ``chunked_attention``
  exactly), so greedy outputs stay token-identical to cold-cache
  ``serve.generate`` with or without prefix hits.
* **horizon decode** — one fused program runs ``horizon`` decode steps
  (``lax.scan``, default H=8) across all decode-active slots.  Each scan
  iteration gathers the live lanes out of the slot cache, decodes one
  token per lane with a *per-slot* length vector, and scatters back.
  EOS / per-request ``max_new`` exhaustion is masked *on device* (dead
  lanes step against the scratch slot at a pinned position); the host
  syncs **once per horizon**, not once per token.
* **retire + backfill + pool insert** — at the horizon boundary the host
  replays the emitted-token mask, retires requests that hit EOS or
  ``max_new``, and backfills freed slots from the queue.  When a
  prompt's prefill completes, its block-aligned KV prefix is inserted
  into the pool (one scatter on the block axis) so the *next* request
  sharing it prefills only its own suffix; pool pressure evicts
  least-recently-used trie leaves — never state a live slot depends on,
  because matches are copied, not aliased.

The hot loop is a fixed set of XLA programs: one chunk-prefill program
per chunk bucket, one horizon program per batch bucket, and one
copy/insert program per block-count bucket — no per-request retracing
(``program_counts()`` exposes the live compile counts; tests pin them).
The slot KV cache and the block pool — the only multi-megabyte state
threaded between programs — are **donated** through every dispatch, so
they update in place instead of being copied (the [nb]-sized lane
vectors are cheap and passed by value).

Slot state (last tokens, lengths, prefill cursors, done mask,
per-request RNG keys, generated counts) is carried as arrays; CREW
params flow through the same ``crew_strategy="auto"`` autotuned dispatch
as the one-shot engine; under an active mesh the programs trace inside
``sharding_ctx(mesh, SERVE_RULES)`` so ``constrain`` calls bind.

On top of the data path sits the **request lifecycle** (DESIGN.md §5
"request lifecycle"): every submitted request walks an explicit state
machine — QUEUED → PREFILLING → DECODING → one of the terminal states
{COMPLETED, CANCELLED, TIMED_OUT, SHED}, or PREEMPTED → QUEUED and
around again — and every rid gets **exactly one** terminal
:class:`Completion` whose ``status``/``reason`` say how it ended.
Admission is bounded (priority lanes + per-tenant token buckets; over
the bound ``submit`` returns a typed :class:`Shed` instead of growing
the queue), deadlines and cancellation are enforced at horizon
boundaries, and under pressure the scheduler **preempts to the prefix
pool**: the victim's block-aligned KV scatters into the pool through the
existing insert path, the request re-queues, and resume is just a prefix
hit that re-prefills the unaligned tail — preemption costs one chunk,
not a full re-prefill, which is the paper's reuse insight applied to
scheduling.  A seeded chaos layer (``serve.faults``) can force every one
of those paths deterministically; greedy outputs are token-identical
under benign faults, pinned by tests.

Requires the transformer-family cache contract ``{"k","v","len"}`` with
``[L, B, S, KV, D]`` KV tensors (dense / MoE configs; families without a
chunked-prefill path are rejected at construction).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import enum
import time
from typing import Deque, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.ctx import sharding_ctx
from ..dist.sharding import SERVE_RULES
from ..kernels.plan import warn_deprecated
from ..models import ModelApi
from .convert import decode_state_for_params
from .faults import FaultInjector, default_injector
from .prefix import PrefixTrie

__all__ = ["Scheduler", "SchedulerMetrics", "Request", "Completion",
           "RequestState", "Shed", "SchedulerStalledError",
           "DEFAULT_BUCKETS", "DEFAULT_HORIZON", "DEFAULT_BLOCK_SIZE"]

DEFAULT_BUCKETS: Tuple[int, ...] = (16, 32, 64, 128)
DEFAULT_HORIZON = 8
DEFAULT_BLOCK_SIZE = 16


def _pow2_ladder(top: int) -> Tuple[int, ...]:
    """Powers of two up to ``top`` (``top`` included even when not one)."""
    out = []
    p = 1
    while p < top:
        out.append(p)
        p *= 2
    out.append(top)
    return tuple(out)


def _bucket_for(ladder: Tuple[int, ...], n: int) -> int:
    """Smallest ladder entry >= n (the ladder's top for anything larger)."""
    for b in ladder:
        if n <= b:
            return b
    return ladder[-1]


class RequestState(enum.Enum):
    """Lifecycle states.  QUEUED/PREFILLING/DECODING are transient;
    COMPLETED/CANCELLED/TIMED_OUT/SHED are terminal (each produces the
    request's single :class:`Completion`).  PREEMPTED is instantaneous —
    a preempted request re-enters QUEUED in the same step, its KV parked
    in the prefix pool (``Request.preemptions`` counts the round trips).
    """
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    COMPLETED = "completed"
    CANCELLED = "cancelled"
    TIMED_OUT = "timed_out"
    SHED = "shed"
    PREEMPTED = "preempted"


TERMINAL_STATES = frozenset({
    RequestState.COMPLETED, RequestState.CANCELLED,
    RequestState.TIMED_OUT, RequestState.SHED,
})


@dataclasses.dataclass(frozen=True)
class Shed:
    """Typed admission rejection returned by ``submit`` under overload.

    The rid is still real: a shed request gets its terminal
    ``Completion(status="shed")`` like every other outcome, so drivers
    can account for it without special-casing the return value beyond
    an ``isinstance`` check.
    """
    rid: int
    reason: str                 # "queue-full" | "tenant-rate"


class SchedulerStalledError(RuntimeError):
    """``run()`` detected no forward progress (or blew its step budget).

    The message lists every live slot's state — rid, lifecycle phase,
    cache length, prefill cursor, generated count — plus queue depth,
    so a wedged scheduler reports *what* is stuck instead of spinning.
    """


@dataclasses.dataclass
class Request:
    """One queued generation request (host-side)."""
    rid: int
    prompt: np.ndarray          # [S] int32, unpadded
    max_new: int
    eos_id: Optional[int]
    submitted_s: float = 0.0    # perf_counter at submit (TTFT accounting)
    deadline_s: Optional[float] = None  # TTL from submit; None = no deadline
    priority: int = 0           # lower value = more urgent (lane index)
    tenant: Optional[str] = None        # token-rate accounting bucket
    state: RequestState = RequestState.QUEUED
    preemptions: int = 0        # times preempted to the prefix pool


@dataclasses.dataclass
class Completion:
    """A finished request: generated tokens (EOS included if hit).

    Every submitted rid — completed, cancelled, timed out, or shed —
    produces exactly one Completion; ``status`` is the terminal
    :class:`RequestState` value and ``reason`` the human-readable cause.
    Non-completed outcomes keep whatever tokens were generated before
    the request ended (possibly none).
    """
    rid: int
    prompt_len: int
    tokens: np.ndarray          # [n_generated] int32
    logprobs: np.ndarray        # [n_generated] float32
    n_steps: int                # engine steps from admission to retirement
    ttft_s: float = 0.0         # submit -> first token wall time
    status: str = "completed"   # terminal RequestState value
    reason: str = ""            # why, for non-completed statuses


@dataclasses.dataclass
class SchedulerMetrics:
    """Engine counters.  Read them as attributes (``m.steps``); the
    dict-style spellings (``m["steps"]``) from the pre-dataclass era
    still work for one release behind a DeprecationWarning
    (docs/api.md)."""
    steps: int = 0              # engine steps (admit + chunk + horizon)
    prefills: int = 0           # prompts admitted
    chunks: int = 0             # chunk-prefill programs dispatched
    prefill_chunk_tokens: int = 0   # chunk tokens computed (incl. padding)
    prefix_hit_tokens: int = 0  # trie-matched tokens (pre-cap)
    prefill_tokens_saved: int = 0   # prompt tokens served from the pool
    pool_inserts: int = 0       # blocks written into the pool
    pool_evictions: int = 0     # LRU leaf evictions under pool pressure
    horizons: int = 0           # fused H-step programs dispatched
    decode_steps: int = 0       # device decode steps (H per horizon)
    decode_lanes: int = 0       # useful (emitted) lane-steps
    padded_lanes: int = 0       # batch-bucket padding lane-steps
    wasted_lane_steps: int = 0  # dead-or-padding lane-steps per horizon
    # terminal-status counters (attributes only — new dict-style keys
    # would defeat the deprecation shim below; docs/api.md)
    completed: int = 0          # requests retired normally
    cancelled: int = 0          # requests cancelled (queued or in-flight)
    timed_out: int = 0          # requests past deadline_s
    shed: int = 0               # requests rejected at admission
    preempted: int = 0          # preempt-to-prefix-pool round trips
    resumed: int = 0            # preempted requests re-admitted
    resume_reprefill_tokens: int = 0  # tokens re-prefilled on resume
    queue_peak: int = 0         # high-water queued-request count

    def __getitem__(self, key: str) -> int:
        warn_deprecated(
            "SchedulerMetrics:getitem",
            "dict-style SchedulerMetrics reads (metrics[...]) are "
            "deprecated; read the attribute (metrics.steps etc.) — see "
            "docs/api.md")
        if not hasattr(self, key):
            raise KeyError(key)
        return getattr(self, key)

    def __setitem__(self, key: str, value: int) -> None:
        warn_deprecated(
            "SchedulerMetrics:setitem",
            "dict-style SchedulerMetrics writes (metrics[...] = ...) are "
            "deprecated; set the attribute — see docs/api.md")
        if not hasattr(self, key):
            raise KeyError(key)
        setattr(self, key, value)

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class Scheduler:
    """Continuous-batching engine over chunked-prefill/horizon programs.

    Args:
      api / params: as for ``serve.generate`` (dense or CREW-converted).
      max_batch: number of concurrent decode slots (one extra scratch
        slot is allocated internally for batch-bucket padding and for
        mid-horizon-retired lanes).
      cache_len: per-slot KV capacity; every admitted request must fit
        ``prompt_len + max_new <= cache_len``.
      buckets: chunk sizes, ascending.  A prefilling prompt advances by
        the largest bucket per chunk; its tail compiles against the
        smallest bucket that holds it.  Prompts of any length up to
        ``cache_len - max_new`` are admissible (the monolithic-prefill
        cap on prompt length is gone).  None derives the default ladder
        clipped to ``cache_len``.
      horizon: decode steps per fused program dispatch (H).  The host
        syncs once per horizon; ``horizon=1`` is the token-synchronous
        baseline.  Retirement happens at horizon boundaries, so a lane
        whose request dies mid-horizon idles (masked, scratch-directed)
        until the boundary — ``metrics.wasted_lane_steps`` counts it.
      prefix_cache: enable the radix-tree prefix cache (default).  Off,
        every prompt prefills cold — the PR-4-equivalent baseline that
        ``benchmarks/prefix_reuse.py`` measures against.
      block_size: prefix-cache granularity in tokens; only block-aligned
        prefixes are shared, and a hit is capped one block short of the
        prompt so at least one suffix token prefills (first-token logits
        must come from a live forward).
      pool_blocks: KV pool capacity in blocks (+1 scratch block is
        allocated internally).  None sizes it to one full batch's worth
        of cache (``max_batch * cache_len // block_size``) — i.e. the
        prefix cache roughly doubles the scheduler's KV memory by
        default; pass an explicit budget when memory is tight or the
        hot prefix set is large.
      temperature / crew_strategy: static sampling and CREW dispatch
        knobs, shared by all programs (as in ``serve.generate``).
      decode_state: "auto" (default) resolves the CREW decode
        product-buffer state per batch bucket from the warmed autotune
        store (``serve.decode_state_for_params``) and threads it through
        the horizon scan carry with donated buffers; "off" disables it.
        A cold store resolves to no state — the historical stateless
        horizon, bit for bit.
      rng: base PRNG key; each request derives its own key stream via
        ``fold_in(fold_in(rng, rid), n_generated)``.
      mesh: optional device mesh; programs then trace under
        ``sharding_ctx(mesh, SERVE_RULES)``.
      max_queue: bound on *queued* (not in-flight) requests.  At the
        bound, ``submit`` sheds: a strictly-lower-priority queued victim
        if one exists (the newcomer takes its place), else the newcomer
        itself — returning a typed :class:`Shed`.  Preemption re-queues
        are exempt (they hold no new admission).  None = unbounded (the
        pre-lifecycle behavior).
      tenant_rate / tenant_burst: per-tenant token-bucket admission —
        ``tenant_rate`` tokens/s refill up to ``tenant_burst`` (default
        = rate); a submit whose worst-case cost (prompt + max_new
        tokens) exceeds the tenant's level is shed with reason
        "tenant-rate".  Requests without a tenant are never
        rate-limited.  None disables.
      preempt_after_steps: with a non-empty queue and no free slot for
        this many consecutive steps, preempt the longest-running decode
        to the prefix pool and re-queue it (aged-pressure trigger;
        higher-priority arrivals preempt immediately regardless).  None
        disables aged preemption.
      faults: a ``serve.faults.FaultInjector`` chaos layer, or None.
        With None the ``REPRO_FAULTS`` env var (when set) supplies the
        suite-wide benign injector; pass ``faults=False`` to force
        fault-free operation even under the env switch.
    """

    def __init__(
        self,
        api: ModelApi,
        params,
        *,
        max_batch: int = 8,
        cache_len: int = 256,
        buckets: Optional[Sequence[int]] = None,
        horizon: int = DEFAULT_HORIZON,
        prefix_cache: bool = True,
        block_size: int = DEFAULT_BLOCK_SIZE,
        pool_blocks: Optional[int] = None,
        temperature: float = 0.0,
        crew_strategy: str = "auto",
        decode_state: str = "auto",
        rng: Optional[jnp.ndarray] = None,
        mesh=None,
        cache_dtype=jnp.bfloat16,
        max_queue: Optional[int] = None,
        tenant_rate: Optional[float] = None,
        tenant_burst: Optional[float] = None,
        preempt_after_steps: Optional[int] = None,
        faults: Union[FaultInjector, None, bool] = None,
    ):
        if not api.cfg.has_decode:
            raise ValueError(f"{api.cfg.arch_id} is encoder-only: no decode")
        if not hasattr(api._mod, "prefill_chunk"):
            raise NotImplementedError(
                f"{api.cfg.family} has no chunked-prefill path")
        if horizon < 1:
            raise ValueError("horizon must be >= 1")
        self._api = api
        self._params = params
        self._max_batch = int(max_batch)
        self._cache_len = int(cache_len)
        self._horizon = int(horizon)
        if buckets is None:
            buckets = ([b for b in DEFAULT_BUCKETS if b <= self._cache_len]
                       or [self._cache_len])
        self._buckets = tuple(sorted(int(b) for b in buckets))
        if not self._buckets:
            raise ValueError("need at least one chunk bucket")
        if self._buckets[-1] > self._cache_len:
            raise ValueError(
                f"largest bucket {self._buckets[-1]} exceeds cache_len "
                f"{self._cache_len}")
        self._temperature = float(temperature)
        self._crew_strategy = crew_strategy
        if decode_state not in ("auto", "off"):
            raise ValueError('decode_state must be "auto" or "off"')
        self._decode_state_mode = decode_state
        # per-batch-bucket CREW decode product-buffer state trees (None
        # when the bucket's shapes have no measured pallas-decode winner);
        # resolved lazily on first use of each bucket.
        self._crew_state: Dict[int, object] = {}
        self._base_key = rng if rng is not None else jax.random.PRNGKey(0)
        self._mesh = mesh

        # batch buckets: powers of two up to max_batch (max_batch included
        # even when not a power of two).
        self._batch_buckets = _pow2_ladder(self._max_batch)

        # slot cache: max_batch real slots + 1 scratch slot for padding
        # lanes and mid-horizon-retired lanes (duplicate scatter indices
        # must never hit a live slot).
        abs_cache = api.abstract_cache(self._max_batch + 1, self._cache_len,
                                       dtype=cache_dtype)
        if not (isinstance(abs_cache, dict)
                and set(abs_cache) == {"k", "v", "len"}):
            raise NotImplementedError(
                f"{api.cfg.family} cache is not the {{k,v,len}} KV contract "
                "the slot scheduler manages")
        self._k = jnp.zeros(abs_cache["k"].shape, abs_cache["k"].dtype)
        self._v = jnp.zeros(abs_cache["v"].shape, abs_cache["v"].dtype)

        # prefix-cache block pool: pool_blocks real blocks + scratch block
        # 0 (padding lanes of the bucketed copy/insert programs read and
        # write it, never a real block).
        self._block_size = int(block_size)
        if self._block_size < 1:
            raise ValueError("block_size must be >= 1")
        # default pool = one full batch's worth of stripes, so enabling
        # the prefix cache costs at most ~2x the slot-cache KV memory
        # (stated in the arg docs; size it to the hot prefix set +
        # headroom in production — docs/serving.md "Sizing")
        if pool_blocks is None:
            pool_blocks = max(
                self._max_batch * (self._cache_len // self._block_size), 8)
        self._pool_blocks = int(pool_blocks)
        self._trie: Optional[PrefixTrie] = None
        self._pk = self._pv = None
        if prefix_cache:
            # block ids are offset by 1 on device (0 is scratch)
            self._trie = PrefixTrie(self._pool_blocks, self._block_size)
            l, _, _, kv, d = abs_cache["k"].shape
            shape = (l, self._pool_blocks + 1, self._block_size, kv, d)
            self._pk = jnp.zeros(shape, abs_cache["k"].dtype)
            self._pv = jnp.zeros(shape, abs_cache["v"].dtype)
        # block-count buckets for the copy/insert programs (powers of two
        # up to a full stripe's worth of blocks)
        self._nblk_buckets = _pow2_ladder(
            max(self._cache_len // self._block_size, 1))

        # host-side slot state ("slot state carried as arrays")
        nb = self._max_batch
        self._slot_rid = np.full(nb, -1, np.int64)      # -1 == free
        self._slot_len = np.zeros(nb, np.int32)         # cache position
        self._slot_tok = np.zeros(nb, np.int32)         # last sampled token
        self._slot_ngen = np.zeros(nb, np.int32)        # tokens generated
        self._slot_done = np.ones(nb, bool)             # free/done mask
        self._slot_key = np.zeros((nb, 2), np.uint32)   # per-request key
        self._slot_pref_pos = np.zeros(nb, np.int32)    # next chunk offset
        self._slot_pref_end = np.zeros(nb, np.int32)    # prompt length

        # priority lanes: lane index = Request.priority (lower = more
        # urgent), FIFO within a lane; preemption re-queues at the front.
        self._lanes: Dict[int, Deque[Request]] = {}
        self._free: Deque[int] = collections.deque(range(nb))
        self._live: Dict[int, Request] = {}             # rid -> request
        # effective admission sequence per slot (prompt, or prompt + the
        # already-generated tokens for a preempt-resume)
        self._slot_seq: Dict[int, np.ndarray] = {}
        self._out_toks: Dict[int, list] = {}
        self._out_lps: Dict[int, list] = {}
        self._admit_step: Dict[int, int] = {}
        self._ttft: Dict[int, float] = {}
        self._results: Dict[int, Completion] = {}
        self._terminal_state: Dict[int, RequestState] = {}
        self._next_rid = 0

        # lifecycle / admission-control state
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None)")
        self._max_queue = None if max_queue is None else int(max_queue)
        self._tenant_rate = None if tenant_rate is None else float(tenant_rate)
        if self._tenant_rate is not None and self._tenant_rate <= 0:
            raise ValueError("tenant_rate must be > 0 (or None)")
        self._tenant_burst = (self._tenant_rate if tenant_burst is None
                              else float(tenant_burst))
        self._preempt_after = (None if preempt_after_steps is None
                               else int(preempt_after_steps))
        self._tenant_level: Dict[str, float] = {}       # tokens available
        self._tenant_t: Dict[str, float] = {}           # last refill time
        self._cancel_pending: set = set()               # in-flight cancels
        self._starved_steps = 0     # consecutive full-slot steps w/ queue
        self._faults: Optional[FaultInjector] = (
            default_injector() if faults is None
            else (faults if isinstance(faults, FaultInjector) else None))

        self.metrics = SchedulerMetrics()

        # Donation updates the slot KV cache / block pool in place per
        # dispatch instead of copying them (the CPU jaxlib this repo pins
        # aliases the buffers too); tests/test_decode_horizon.py pins the
        # declared aliasing.
        self._win_buckets = _pow2_ladder(self._cache_len)
        self._chunk_fn = jax.jit(self._chunk_impl, donate_argnums=(0, 1),
                                 static_argnums=(9,))
        self._horizon_fn = jax.jit(self._horizon_impl, donate_argnums=(0, 1))
        self._horizon_crew_fn = jax.jit(self._horizon_crew_impl,
                                        donate_argnums=(0, 1, 2))
        self._copy_fn = jax.jit(self._copy_impl, donate_argnums=(0, 1))
        self._insert_fn = jax.jit(self._insert_impl, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    # Programs (one compile per chunk / batch / block-count bucket)
    # ------------------------------------------------------------------

    def _ctx(self):
        if self._mesh is None:
            return contextlib.nullcontext()
        return sharding_ctx(self._mesh, SERVE_RULES)

    def _chunk_impl(self, k_all, v_all, params, tokens, offset, true_c, slot,
                    req_key, step, win):
        """One prefill chunk for one slot -> (token, logprob, cache).

        tokens [1, C] sit at slot cache positions [offset, offset + C);
        the chunk attends to the slot's prior cache [0, offset) — a
        prefix-cache hit and/or earlier chunks — via
        ``api.prefill_chunk``, never recomputing it.  ``win`` (static)
        is the KV *window* the chunk sees: the smallest window bucket
        covering ``offset + C``, so attention work scales with the
        chunk's position, not with ``cache_len`` — a 32-token prompt in
        a 4096-slot cache scores 32x32, not 32x4096 (rows past the
        window are all masked dead anyway; the truncation is exact).
        The tail chunk is right-padded to its bucket: causality makes
        the logits at ``true_c - 1`` independent of the padding, and
        padded cache rows are dead (masked by the slot length, then
        overwritten as decode advances) — DESIGN.md §5.  The sampled
        token/logprob are read by the host only for the chunk that
        completes a prompt.  ``step`` is the request's generated-token
        count at sampling time — 0 for a fresh prompt (the historical
        key, bit for bit), ``len(gen)`` for a preempt-resume, so sampled
        decoding continues the per-request ``fold_in`` stream exactly
        where the horizon program left it.
        """
        cache = {"k": k_all[:, slot, :win][:, None],
                 "v": v_all[:, slot, :win][:, None], "len": offset}
        logits, cache = self._api.prefill_chunk(
            params, tokens, cache, crew_strategy=self._crew_strategy)
        last = jax.lax.dynamic_index_in_dim(
            logits, true_c - 1, axis=1, keepdims=False)[0]       # [vocab]
        if self._temperature == 0.0:
            tok = jnp.argmax(last).astype(jnp.int32)
        else:
            tok = jax.random.categorical(
                jax.random.fold_in(req_key, step),
                last / self._temperature).astype(jnp.int32)
        # gather + logsumexp, not a full-vocab log_softmax read at [tok]
        lp = last[tok] - jax.scipy.special.logsumexp(last)
        k_all = k_all.at[:, slot, :win].set(cache["k"][:, 0])
        v_all = v_all.at[:, slot, :win].set(cache["v"][:, 0])
        return tok, lp, k_all, v_all

    def _copy_impl(self, k_all, v_all, pk, pv, ids, slot):
        """Prefix-cache hit: pool blocks ``ids`` -> slot positions [0, n·bs).

        One gather on the block axis; ``ids`` is padded to its
        block-count bucket with the scratch block 0, whose rows land
        beyond the hit length and are dead (overwritten by the first
        suffix chunk or masked).
        """
        bs = self._block_size
        n = ids.shape[0]
        blk_k = pk[:, ids]                  # [L, n, bs, KV, D]
        blk_v = pv[:, ids]
        l, _, _, kv, d = blk_k.shape
        k_all = k_all.at[:, slot, :n * bs].set(blk_k.reshape(l, n * bs, kv, d))
        v_all = v_all.at[:, slot, :n * bs].set(blk_v.reshape(l, n * bs, kv, d))
        return k_all, v_all

    def _insert_impl(self, pk, pv, k_all, v_all, ids, slot, start):
        """Pool insert: slot positions [start, start + n·bs) -> blocks ``ids``.

        One scatter on the block axis.  The rows are read by *index*,
        never ``dynamic_slice``: when the bucket-padded window crosses
        ``cache_len`` the padding rows must clamp individually (their
        garbage lands in the scratch block 0, never read as real data) —
        a dus start-clamp would instead shift the whole window back over
        earlier rows and poison the *real* blocks for every later hit.
        """
        bs = self._block_size
        n = ids.shape[0]
        pos = start + jnp.arange(n * bs)                # [n·bs], clamped get
        seg_k = k_all[:, slot, pos]
        seg_v = v_all[:, slot, pos]
        l, _, kv, d = seg_k.shape
        pk = pk.at[:, ids].set(seg_k.reshape(l, n, bs, kv, d))
        pv = pv.at[:, ids].set(seg_v.reshape(l, n, bs, kv, d))
        return pk, pv

    def _horizon_body(self, k_all, v_all, crew, params, slot_ids, toks, lens,
                      req_keys, steps, rem, eos, alive):
        """H fused decode steps over the gathered lanes — one host sync.

        slot_ids/toks/lens/req_keys/steps/rem/eos/alive are [nb] lane
        vectors (nb = the batch bucket); padding lanes point at the
        scratch slot with ``alive=False``.  Per scan iteration each live
        lane decodes one token at its own cache position; a lane that
        samples EOS or exhausts ``rem`` (its remaining ``max_new`` budget)
        flips dead and keeps stepping against the scratch slot at a
        pinned position — the program is fixed-shape for every iteration.
        ``crew`` is this batch bucket's decode product-buffer state tree
        (or None): it rides the scan carry next to the KV buffers, so the
        CREW projections' partial-product buffers stay resident across
        all H steps (DESIGN.md §3).  Returns per-lane [nb, H]
        token/logprob/emitted-mask panels plus the updated (donated)
        cache and state.
        """
        scratch = self._max_batch

        def body(carry, _):
            k_all, v_all, crew, tok, lens, steps, rem, alive = carry
            sid = jnp.where(alive, slot_ids, scratch)
            ln = jnp.where(alive, lens, 0)
            k_sel = k_all[:, sid]
            v_sel = v_all[:, sid]
            cache = {"k": k_sel, "v": v_sel, "len": ln}
            if crew is not None:
                cache["crew"] = crew
            logits, new = self._api.decode_step(
                params, tok[:, None], cache,
                crew_strategy=self._crew_strategy)
            crew = new["crew"] if crew is not None else None
            if self._temperature == 0.0:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                keys = jax.vmap(jax.random.fold_in)(req_keys, steps)
                nxt = jax.vmap(
                    lambda k, l: jax.random.categorical(
                        k, l / self._temperature).astype(jnp.int32)
                )(keys, logits)
            lp = (jnp.take_along_axis(logits, nxt[:, None], axis=-1)[:, 0]
                  - jax.scipy.special.logsumexp(logits, axis=-1))
            k_all = k_all.at[:, sid].set(new["k"])
            v_all = v_all.at[:, sid].set(new["v"])
            emitted = alive
            step1 = emitted.astype(jnp.int32)
            rem = rem - step1
            alive = alive & (rem > 0) & jnp.where(eos >= 0, nxt != eos, True)
            tok = jnp.where(emitted, nxt, tok)
            lens = lens + step1
            steps = steps + step1
            return (k_all, v_all, crew, tok, lens, steps, rem, alive), \
                (nxt, lp, emitted)

        carry = (k_all, v_all, crew, toks, lens, steps, rem, alive)
        (k_all, v_all, crew, *_), (toks_h, lps_h, emit_h) = jax.lax.scan(
            body, carry, None, length=self._horizon)
        # [nb, H] panels
        return toks_h.T, lps_h.T, emit_h.T, k_all, v_all, crew

    def _horizon_impl(self, k_all, v_all, params, slot_ids, toks, lens,
                      req_keys, steps, rem, eos, alive):
        """Stateless horizon program (no CREW decode state warmed)."""
        out = self._horizon_body(k_all, v_all, None, params, slot_ids, toks,
                                 lens, req_keys, steps, rem, eos, alive)
        return out[:-1]

    def _horizon_crew_impl(self, k_all, v_all, crew, params, slot_ids, toks,
                           lens, req_keys, steps, rem, eos, alive):
        """Horizon program with the bucket's carried CREW decode state —
        donated like the KV buffers, so the product buffers update in
        place across dispatches."""
        return self._horizon_body(k_all, v_all, crew, params, slot_ids,
                                  toks, lens, req_keys, steps, rem, eos,
                                  alive)

    def program_counts(self) -> Dict[str, int]:
        """Live XLA program counts — {bucket set} sized, not request sized.

        ``prefill`` counts chunk programs (one per used chunk-bucket x
        KV-window-bucket pair — the window ladder is log-sized in
        ``cache_len``), ``decode`` horizon programs (one per used batch
        bucket), and ``copy`` / ``insert`` the prefix-cache block movers
        (one per used block-count bucket).  ``_cache_size`` is a private jax API
        (present on the pinned jax==0.4.37); -1 means this jax build no
        longer exposes it."""
        def size(fn):
            return getattr(fn, "_cache_size", lambda: -1)()
        hs = (size(self._horizon_fn), size(self._horizon_crew_fn))
        return {"prefill": size(self._chunk_fn),
                "decode": -1 if min(hs) < 0 else sum(hs),
                "copy": size(self._copy_fn),
                "insert": size(self._insert_fn)}

    # ------------------------------------------------------------------
    # Queue API
    # ------------------------------------------------------------------

    def _queue_len(self) -> int:
        return sum(len(q) for q in self._lanes.values())

    def _queue_iter(self):
        """Queued requests in pop order (priority lanes, FIFO within)."""
        for pr in sorted(self._lanes):
            yield from self._lanes[pr]

    def _queue_push(self, req: Request, *, front: bool = False) -> None:
        lane = self._lanes.setdefault(req.priority, collections.deque())
        (lane.appendleft if front else lane.append)(req)
        self.metrics.queue_peak = max(self.metrics.queue_peak,
                                      self._queue_len())

    def _queue_pop(self) -> Optional[Request]:
        for pr in sorted(self._lanes):
            if self._lanes[pr]:
                return self._lanes[pr].popleft()
        return None

    def _queue_head(self) -> Optional[Request]:
        for pr in sorted(self._lanes):
            if self._lanes[pr]:
                return self._lanes[pr][0]
        return None

    def _queue_remove(self, rid: int) -> Optional[Request]:
        for lane in self._lanes.values():
            for req in lane:
                if req.rid == rid:
                    lane.remove(req)
                    return req
        return None

    def _tenant_admit(self, req: Request) -> bool:
        """Charge ``req``'s worst-case token cost against its tenant's
        bucket; False = insufficient budget (shed)."""
        if self._tenant_rate is None or req.tenant is None:
            return True
        now = time.perf_counter()
        last = self._tenant_t.get(req.tenant, now)
        level = min(self._tenant_burst,
                    self._tenant_level.get(req.tenant, self._tenant_burst)
                    + (now - last) * self._tenant_rate)
        self._tenant_t[req.tenant] = now
        cost = req.prompt.size + req.max_new
        if cost > level:
            self._tenant_level[req.tenant] = level
            return False
        self._tenant_level[req.tenant] = level - cost
        return True

    def _shed_victim(self, priority: int) -> Optional[Request]:
        """Last request of the lowest-priority non-empty lane, if that
        lane is *strictly* lower priority than ``priority``."""
        for pr in sorted(self._lanes, reverse=True):
            if pr > priority and self._lanes[pr]:
                return self._lanes[pr].pop()
        return None

    def submit(self, prompt, *, max_new: int = 32,
               eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None,
               priority: int = 0,
               tenant: Optional[str] = None) -> Union[int, Shed]:
        """Queue one request; returns its request id — or a typed
        :class:`Shed` when admission control rejects it (bounded queue
        full with no lower-priority victim, or the tenant's token bucket
        is empty).  A shed rid still receives its terminal Completion.

        ``deadline_s`` is a TTL from submit time, enforced at horizon
        boundaries; ``priority`` picks the queue lane (lower = more
        urgent; a higher-priority arrival may preempt a running decode
        when no slot is free); ``tenant`` names the token-rate bucket.
        Malformed requests (empty prompt, bad max_new, cache overflow)
        still raise ValueError — those are caller bugs, not overload.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if prompt.size + max_new > self._cache_len:
            raise ValueError(
                f"prompt {prompt.size} + max_new {max_new} exceeds "
                f"cache_len {self._cache_len}")
        if deadline_s is not None and deadline_s < 0:
            raise ValueError("deadline_s must be >= 0 (or None)")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, prompt, int(max_new), eos_id,
                      submitted_s=time.perf_counter(),
                      deadline_s=deadline_s, priority=int(priority),
                      tenant=tenant)
        if not self._tenant_admit(req):
            self._terminal(req, RequestState.SHED,
                           f"tenant-rate: {tenant} over token budget")
            return Shed(rid, "tenant-rate")
        if (self._max_queue is not None
                and self._queue_len() >= self._max_queue):
            victim = self._shed_victim(req.priority)
            if victim is None:
                self._terminal(req, RequestState.SHED,
                               f"queue-full: {self._queue_len()} queued at "
                               f"bound {self._max_queue}")
                return Shed(rid, "queue-full")
            self._terminal(victim, RequestState.SHED,
                           "queue-full: displaced by higher-priority "
                           f"rid {rid}")
        self._queue_push(req)
        return rid

    def cancel(self, rid: int) -> bool:
        """Cancel a request; True if the cancellation took.

        Queued requests terminate immediately; in-flight requests
        terminate at the next step boundary (their lane may emit a few
        more tokens first — those are kept in the Completion).  Unknown
        or already-terminal rids return False.
        """
        req = self._queue_remove(rid)
        if req is not None:
            self._terminal(req, RequestState.CANCELLED,
                           "cancelled while queued")
            return True
        if rid in self._live and rid not in self._cancel_pending:
            self._cancel_pending.add(rid)
            return True
        return False

    def request_state(self, rid: int) -> Optional[RequestState]:
        """Current lifecycle state of ``rid`` — None for unknown rids
        and for terminal rids already drained by ``pop_results``."""
        if rid in self._live:
            return self._live[rid].state
        for req in self._queue_iter():
            if req.rid == rid:
                return RequestState.QUEUED
        if 0 <= rid < self._next_rid:
            return self._terminal_state.get(rid)
        return None

    @property
    def pending(self) -> int:
        """Queued + in-flight request count."""
        return self._queue_len() + len(self._live)

    def _batch_bucket(self, n: int) -> int:
        return _bucket_for(self._batch_buckets, n)

    def _bucket_state(self, nb: int):
        """This batch bucket's CREW decode product-buffer state tree
        (resolved once per bucket; None with mode "off", a cold autotune
        store, or no pallas-decode winner at this batch)."""
        if self._decode_state_mode == "off":
            return None
        if nb not in self._crew_state:
            self._crew_state[nb] = decode_state_for_params(self._params, nb)
        return self._crew_state[nb]

    def _chunk_sizes(self, remaining: int) -> Tuple[int, int]:
        """(bucket, true) chunk sizes for a suffix of ``remaining`` tokens:
        full chunks advance by the largest bucket; the tail compiles
        against the smallest bucket that holds it."""
        if remaining >= self._buckets[-1]:
            return self._buckets[-1], self._buckets[-1]
        return _bucket_for(self._buckets, remaining), remaining

    def _padded_block_ids(self, ids) -> jnp.ndarray:
        """Block-mover ids padded to their block-count bucket with the
        pool's scratch block 0 (host ids are 0-based; device block 0 is
        the scratch)."""
        padded = np.zeros(_bucket_for(self._nblk_buckets, len(ids)), np.int32)
        padded[:len(ids)] = np.asarray(ids, np.int32) + 1
        return jnp.asarray(padded)

    # ------------------------------------------------------------------
    # Engine loop
    # ------------------------------------------------------------------

    def _terminal(self, req: Request, state: RequestState,
                  reason: str = "") -> None:
        """Record ``req``'s single terminal outcome (request not in a
        slot — slot holders go through ``_finish_slot``).  Non-completed
        outcomes keep any tokens generated before the end."""
        assert state in TERMINAL_STATES
        assert req.rid not in self._terminal_state, \
            f"rid {req.rid} terminated twice"
        req.state = state
        rid = req.rid
        admit = self._admit_step.pop(rid, None)
        self._results[rid] = Completion(
            rid=rid,
            prompt_len=req.prompt.size,
            tokens=np.asarray(self._out_toks.pop(rid, []), np.int32),
            logprobs=np.asarray(self._out_lps.pop(rid, []), np.float32),
            n_steps=0 if admit is None else self.metrics.steps - admit + 1,
            ttft_s=self._ttft.pop(rid, 0.0),
            status=state.value,
            reason=reason,
        )
        self._terminal_state[rid] = state
        counter = {RequestState.COMPLETED: "completed",
                   RequestState.CANCELLED: "cancelled",
                   RequestState.TIMED_OUT: "timed_out",
                   RequestState.SHED: "shed"}[state]
        setattr(self.metrics, counter, getattr(self.metrics, counter) + 1)

    def _clear_slot(self, slot: int) -> None:
        self._slot_rid[slot] = -1
        self._slot_done[slot] = True
        self._slot_len[slot] = 0
        self._slot_ngen[slot] = 0
        self._slot_pref_pos[slot] = 0
        self._slot_pref_end[slot] = 0
        self._slot_seq.pop(slot, None)
        self._free.append(slot)

    def _finish_slot(self, slot: int,
                     state: RequestState = RequestState.COMPLETED,
                     reason: str = "") -> None:
        rid = int(self._slot_rid[slot])
        req = self._live.pop(rid)
        self._cancel_pending.discard(rid)
        self._terminal(req, state, reason)
        self._clear_slot(slot)

    def _record(self, slot: int, tok: int, lp: float) -> bool:
        """Append one generated token; returns True if the slot retired."""
        rid = int(self._slot_rid[slot])
        req = self._live[rid]
        if not self._out_toks[rid]:
            self._ttft[rid] = time.perf_counter() - req.submitted_s
        self._out_toks[rid].append(tok)
        self._out_lps[rid].append(lp)
        self._slot_tok[slot] = tok
        self._slot_ngen[slot] += 1
        if ((req.eos_id is not None and tok == req.eos_id)
                or int(self._slot_ngen[slot]) >= req.max_new):
            self._finish_slot(slot)
            return True
        return False

    def _slot_of(self, rid: int) -> int:
        for s in range(self._max_batch):
            if int(self._slot_rid[s]) == rid:
                return s
        raise KeyError(rid)

    def _enforce_lifecycle(self) -> None:
        """Step-boundary lifecycle sweep: apply pending cancellations,
        expire deadlines (queued and in-flight), and let the chaos layer
        force expiries / drop pool blocks.  Runs before admission so a
        freed slot backfills in the same step."""
        for rid in sorted(self._cancel_pending & set(self._live)):
            self._finish_slot(self._slot_of(rid), RequestState.CANCELLED,
                              "cancelled mid-flight")
        self._cancel_pending.clear()
        now = time.perf_counter()

        def expired(req: Request) -> bool:
            if req.deadline_s is None:
                return False
            if now - req.submitted_s > req.deadline_s:
                return True
            return (self._faults is not None
                    and self._faults.should_expire(req.rid))

        for req in [r for r in self._queue_iter() if expired(r)]:
            self._queue_remove(req.rid)
            self._terminal(req, RequestState.TIMED_OUT,
                           f"deadline {req.deadline_s}s exceeded in queue")
        for rid in [r for r in sorted(self._live) if expired(self._live[r])]:
            dl = self._live[rid].deadline_s
            self._finish_slot(self._slot_of(rid), RequestState.TIMED_OUT,
                              f"deadline {dl}s exceeded in flight")
        if self._faults is not None and self._trie is not None:
            if self._faults.pool_drop(self._trie):
                self.metrics.pool_evictions = self._trie.evictions

    def _preempt_slot(self, slot: int, reason: str) -> None:
        """Preempt-to-prefix-pool: park the slot's block-aligned KV in
        the pool via the existing insert path and re-queue the request
        at the front of its lane.  The recorded sequence
        ``prompt + gen[:-1]`` is exactly the slot's valid KV rows
        (``slot_len = P + len(gen) - 1``: the last sampled token's KV is
        written by the *next* decode step, which never runs) — resume
        re-prefills only past the pool hit.  Without a prefix cache the
        request simply re-prefills from scratch; outputs are identical
        either way."""
        rid = int(self._slot_rid[slot])
        req = self._live.pop(rid)
        gen = self._out_toks[rid]
        assert gen, "only decoding slots are preempted"
        seq = np.concatenate(
            [req.prompt, np.asarray(gen[:-1], np.int32)])
        assert seq.size == int(self._slot_len[slot]), \
            (seq.size, int(self._slot_len[slot]))
        self._pool_insert(slot, seq)
        self._clear_slot(slot)
        req.state = RequestState.PREEMPTED
        req.preemptions += 1
        self.metrics.preempted += 1
        req.state = RequestState.QUEUED
        self._queue_push(req, front=True)

    def _maybe_preempt(self) -> None:
        """Preemption triggers, checked once per step (at most one
        preemption each): a fault-forced preempt, a queued request that
        strictly outranks a running decode when no slot is free, or
        aged starvation (``preempt_after_steps``)."""
        forced = (self._faults is not None
                  and self._faults.should_preempt())
        decoding = self._decoding()
        if not decoding:
            self._starved_steps = 0
            return
        # longest-running decode = most KV parked per chunk re-prefilled
        victim = max(decoding, key=lambda s: int(self._slot_ngen[s]))
        if forced:
            self._preempt_slot(victim, "fault-injected preemption")
            return
        head = self._queue_head()
        if head is None or self._free:
            self._starved_steps = 0
            return
        self._starved_steps += 1
        ranked = [s for s in decoding
                  if self._live[int(self._slot_rid[s])].priority
                  > head.priority]
        if ranked:
            victim = max(ranked, key=lambda s: int(self._slot_ngen[s]))
            self._preempt_slot(
                victim, f"preempted for priority-{head.priority} rid "
                f"{head.rid}")
            self._starved_steps = 0
        elif (self._preempt_after is not None
              and self._starved_steps >= self._preempt_after):
            self._preempt_slot(
                victim, f"aged pressure: queue starved {self._starved_steps} "
                "steps")
            self._starved_steps = 0

    def _admit(self) -> None:
        """Fill free slots from the queue: prefix match + block copy.

        Admission does *not* prefill: it resolves the effective
        sequence's longest cached prefix, copies those pool blocks into
        the slot stripe (one bucketed gather program, dead-padded with
        the scratch block), and parks the slot in the prefill phase with
        its chunk cursor at the hit length.  The chunk phase advances it.

        The effective sequence is the prompt — or, for a request
        preempted mid-decode, ``prompt + generated-so-far``: its first
        ``P + g - 1`` tokens' KV went to the pool at preemption, so the
        match covers everything block-aligned and only the unaligned
        tail (at most ``block_size`` tokens plus the one always-live
        suffix token) re-prefills.  The completing chunk's logits sit at
        the last generated token, so the sampled continuation is exactly
        token ``g + 1`` of the uninterrupted run."""
        while self._free and self._queue_len():
            req = self._queue_pop()
            slot = self._free.popleft()
            gen = self._out_toks.get(req.rid, [])
            seq = (np.concatenate([req.prompt,
                                   np.asarray(gen, np.int32)])
                   if gen else req.prompt)
            hit = 0
            if self._trie is not None:
                ids, raw = self._trie.match(seq)
                self.metrics.prefix_hit_tokens += raw
                # keep >= 1 suffix token: first-token logits must come
                # from a live forward over the sequence's true tail
                bs = self._block_size
                hit = min(raw, ((seq.size - 1) // bs) * bs)
                ids = ids[:hit // bs]
                if ids:
                    with self._ctx():
                        self._k, self._v = self._copy_fn(
                            self._k, self._v, self._pk, self._pv,
                            self._padded_block_ids(ids), jnp.int32(slot))
                    self.metrics.prefill_tokens_saved += hit
            self.metrics.prefills += 1
            if gen:
                self.metrics.resumed += 1
                self.metrics.resume_reprefill_tokens += seq.size - hit
            self._live[req.rid] = req
            req.state = RequestState.PREFILLING
            self._out_toks.setdefault(req.rid, [])
            self._out_lps.setdefault(req.rid, [])
            # n_steps spans first admission -> terminal, across preempts
            self._admit_step.setdefault(req.rid, self.metrics.steps)
            self._slot_seq[slot] = seq
            self._slot_rid[slot] = req.rid
            self._slot_done[slot] = False
            self._slot_len[slot] = hit
            self._slot_ngen[slot] = len(gen)
            self._slot_key[slot] = np.asarray(
                jax.random.fold_in(self._base_key, req.rid))
            self._slot_pref_pos[slot] = hit
            self._slot_pref_end[slot] = seq.size

    def _pool_insert(self, slot: int, tokens: np.ndarray) -> None:
        """Cache ``tokens``' block-aligned KV prefix from ``slot``'s
        stripe (prefill completion and preemption both land here)."""
        if self._trie is None:
            return
        new_ids, start = self._trie.insert(tokens)
        if new_ids:
            with self._ctx():
                self._pk, self._pv = self._insert_fn(
                    self._pk, self._pv, self._k, self._v,
                    self._padded_block_ids(new_ids), jnp.int32(slot),
                    jnp.int32(start))
            self.metrics.pool_inserts += len(new_ids)
        self.metrics.pool_evictions = self._trie.evictions

    def _prefilling(self):
        return [s for s in range(self._max_batch)
                if not self._slot_done[s]
                and self._slot_pref_pos[s] < self._slot_pref_end[s]]

    def _decoding(self):
        return [s for s in range(self._max_batch)
                if not self._slot_done[s]
                and self._slot_pref_pos[s] >= self._slot_pref_end[s]]

    def _prefill_chunks(self) -> None:
        """Advance every prefilling slot by one chunk (co-scheduled with
        the decode horizon: a long prompt spreads its prefill over
        steps instead of stalling token emission).  With no decode-active
        lanes there is nothing to co-schedule against, so chunking rounds
        continue until a prompt completes and decode can start.  Chunk
        dispatches queue back-to-back; sampled first tokens are read once
        at the end, only for the chunks that completed a prompt."""
        while True:
            prefilling = self._prefilling()
            if not prefilling:
                return
            completed = []
            for slot in prefilling:
                seq = self._slot_seq[slot]
                end = int(self._slot_pref_end[slot])
                pos = int(self._slot_pref_pos[slot])
                c_bkt, c_true = self._chunk_sizes(end - pos)
                win = _bucket_for(self._win_buckets, pos + c_bkt)
                tokens = np.zeros((1, c_bkt), np.int32)
                tokens[0, :c_true] = seq[pos:pos + c_true]
                step = int(self._slot_ngen[slot])    # 0 unless resuming
                with self._ctx():
                    tok, lp, self._k, self._v = self._chunk_fn(
                        self._k, self._v, self._params, jnp.asarray(tokens),
                        jnp.int32(pos), jnp.int32(c_true), jnp.int32(slot),
                        jnp.asarray(self._slot_key[slot]), jnp.int32(step),
                        win)
                self.metrics.chunks += 1
                self.metrics.prefill_chunk_tokens += c_bkt
                self._slot_pref_pos[slot] = pos + c_true
                self._slot_len[slot] = pos + c_true
                if pos + c_true >= end:
                    completed.append((slot, seq, tok, lp))
            for slot, seq, tok, lp in completed:
                self._pool_insert(slot, seq)
                self._live[int(self._slot_rid[slot])].state = \
                    RequestState.DECODING
                self._record(slot, int(tok), float(lp))
            if self._decoding():
                return

    def step(self) -> bool:
        """One horizon boundary: enforce lifecycle (cancels, deadlines,
        injected faults), maybe preempt, admit, advance prefill chunks,
        run one fused H-step horizon, retire; True while busy.

        An empty queue with no active slots is an idle drain: returns
        False without launching any program.
        """
        self.metrics.steps += 1
        self._enforce_lifecycle()
        self._maybe_preempt()
        self._admit()
        self._prefill_chunks()
        active = self._decoding()
        if not active:
            busy = bool(self._queue_len() or self._live)
            if not busy:
                self.metrics.steps -= 1  # nothing ran
            return busy
        nb = self._batch_bucket(len(active))
        scratch = self._max_batch
        lanes = active + [scratch] * (nb - len(active))
        slot_ids = np.asarray(lanes, np.int32)
        toks = np.zeros(nb, np.int32)
        lens = np.zeros(nb, np.int32)
        keys = np.zeros((nb, 2), np.uint32)
        steps = np.zeros(nb, np.int32)
        rem = np.zeros(nb, np.int32)
        eos = np.full(nb, -1, np.int32)
        alive = np.zeros(nb, bool)
        for i, s in enumerate(active):
            req = self._live[int(self._slot_rid[s])]
            toks[i] = self._slot_tok[s]
            lens[i] = self._slot_len[s]
            keys[i] = self._slot_key[s]
            steps[i] = self._slot_ngen[s]
            rem[i] = req.max_new - int(self._slot_ngen[s])
            eos[i] = -1 if req.eos_id is None else int(req.eos_id)
            alive[i] = True
        crew = self._bucket_state(nb)
        if self._faults is not None:
            dt = self._faults.horizon_delay()
            if dt:
                time.sleep(dt)   # chaos: a slow device / noisy neighbor
        with self._ctx():
            if crew is None:
                toks_h, lps_h, emit_h, self._k, self._v = self._horizon_fn(
                    self._k, self._v, self._params, jnp.asarray(slot_ids),
                    jnp.asarray(toks), jnp.asarray(lens), jnp.asarray(keys),
                    jnp.asarray(steps), jnp.asarray(rem), jnp.asarray(eos),
                    jnp.asarray(alive))
            else:
                (toks_h, lps_h, emit_h, self._k, self._v,
                 self._crew_state[nb]) = self._horizon_crew_fn(
                    self._k, self._v, crew, self._params,
                    jnp.asarray(slot_ids), jnp.asarray(toks),
                    jnp.asarray(lens), jnp.asarray(keys),
                    jnp.asarray(steps), jnp.asarray(rem), jnp.asarray(eos),
                    jnp.asarray(alive))
        toks_h = np.asarray(toks_h)
        lps_h = np.asarray(lps_h)
        emit_h = np.asarray(emit_h)
        h = self._horizon
        emitted_total = int(emit_h[:len(active)].sum())
        self.metrics.horizons += 1
        self.metrics.decode_steps += h
        self.metrics.decode_lanes += emitted_total
        self.metrics.padded_lanes += (nb - len(active)) * h
        self.metrics.wasted_lane_steps += nb * h - emitted_total
        for i, s in enumerate(active):
            for t in range(h):
                if not emit_h[i, t]:
                    break
                self._slot_len[s] += 1  # step t wrote the prior token's KV
                if self._record(s, int(toks_h[i, t]), float(lps_h[i, t])):
                    break
        return bool(self._queue_len() or self._live)

    def _step_budget(self) -> int:
        """Generous upper bound on the steps draining the current work
        could take — chunks plus horizons per request as if each ran
        alone, with slack for preempt/resume cycles and injected faults.
        A healthy scheduler finishes far under it; only a stall crosses
        it."""
        work = 0
        for req in list(self._queue_iter()) + list(self._live.values()):
            total = req.prompt.size + req.max_new
            chunks = -(-total // self._buckets[0])      # ceil, worst bucket
            horizons = -(-req.max_new // self._horizon)
            work += chunks + horizons
        return 64 + 8 * work

    def _stall_report(self, steps: int, budget: int) -> str:
        lines = [f"scheduler stalled after {steps} steps "
                 f"(budget {budget}): no forward progress",
                 f"  queue: {self._queue_len()} waiting "
                 f"(rids {[r.rid for r in self._queue_iter()][:8]}), "
                 f"{len(self._free)} free slots"]
        for s in range(self._max_batch):
            if self._slot_done[s]:
                continue
            rid = int(self._slot_rid[s])
            req = self._live.get(rid)
            lines.append(
                f"  slot {s}: rid {rid} "
                f"state={req.state.value if req else '?'} "
                f"len={int(self._slot_len[s])} "
                f"prefill={int(self._slot_pref_pos[s])}/"
                f"{int(self._slot_pref_end[s])} "
                f"ngen={int(self._slot_ngen[s])}"
                + (f"/{req.max_new}" if req else ""))
        return "\n".join(lines)

    def _progress_sig(self) -> tuple:
        return (self._queue_len(), tuple(sorted(self._live)),
                tuple(int(x) for x in self._slot_len),
                tuple(int(x) for x in self._slot_ngen),
                tuple(int(x) for x in self._slot_pref_pos),
                len(self._results))

    def run(self, max_steps: Optional[int] = None) -> Dict[int, Completion]:
        """Drain the queue to completion; returns {rid: Completion} for
        every terminal outcome (completed, cancelled, timed out, shed).

        A watchdog bounds the drain: ``max_steps`` caps the step count
        (default: a generous budget derived from the outstanding work,
        ``_step_budget``), and a no-progress detector trips when the
        scheduler state signature is unchanged across 16 consecutive
        busy steps.  Either raises :class:`SchedulerStalledError` with a
        per-slot diagnostic instead of spinning forever.
        """
        budget = int(max_steps) if max_steps is not None \
            else self._step_budget()
        steps = 0
        stalled = 0
        sig = self._progress_sig()
        while self.step():
            steps += 1
            new_sig = self._progress_sig()
            stalled = stalled + 1 if new_sig == sig else 0
            sig = new_sig
            if steps >= budget or stalled >= 16:
                raise SchedulerStalledError(
                    self._stall_report(steps, budget))
        return self.pop_results()

    def pop_results(self) -> Dict[int, Completion]:
        out, self._results = self._results, {}
        for rid in out:
            # a popped rid can never re-terminate (it left the queue and
            # the slots at terminal time), so its state entry can go —
            # keeps lifecycle bookkeeping bounded on a long-lived server
            self._terminal_state.pop(rid, None)
        return out
