"""Serving-time CREW conversion: dense checkpoint -> CREW param tree.

Walks the param tree and replaces every linear weight leaf ``{"w": W}``
(the framework-wide convention, including scan-stacked ``[L, N, M]`` and
MoE ``[L, E, N, M]`` leaves) with a ``CrewMatrixUniform`` whose leaves
carry the same leading stack axes — so ``lax.scan`` layer stacks and the
TP shardings keep working unchanged.

Stacked leaves share one index width (the max over the stack) so the
packed words tensor is rectangular; per-layer variable width would break
scan stacking.  The storage accounting for EXPERIMENTS.md still uses the
paper-faithful straddled format via repro.core.stats.

Embedding tables (gather, not matmul) and non-"w" leaves (norm scales,
conv kernels, xLSTM block-diagonal recurrent weights) are left dense —
CREW targets FC matmuls, exactly like the paper (§Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.convert import CrewMatrixUniform
from ..core.pack import elems_per_word, pack_rows_word_aligned
from ..core.ppa import force_max_unique, ppa_layout
from ..core.quant import QuantConfig, quantize_matrix
from ..core.stats import CrewStats, aggregate_stats, layout_stats
from ..core.unique import analyze_matrix, index_width

__all__ = ["crewize_params", "abstract_crew_params", "crewize_spec",
           "autotune_crew_params", "cache_decode_weights",
           "decode_state_for_params", "CrewReport"]


@dataclasses.dataclass
class CrewReport:
    n_converted: int
    n_skipped: int
    stats: List[Tuple[str, CrewStats]]

    def aggregate(self) -> CrewStats:
        return aggregate_stats([s for _, s in self.stats])


def _convert_matrix(w2d: np.ndarray, *, bits, width: int, max_unique,
                    ppa_thr, dtype):
    """One [N, M] matrix -> (words [N, W], uniq [N, 2^width], stats)."""
    qm = quantize_matrix(w2d, QuantConfig(bits=bits))
    layout = analyze_matrix(qm.q)
    if ppa_thr is not None:
        layout = ppa_layout(layout, ppa_thr).layout
    if max_unique is not None and layout.max_unique() > max_unique:
        layout = force_max_unique(layout, max_unique).layout
    k = 1 << width
    words = pack_rows_word_aligned(layout.idx, width)
    uniq = layout.padded_unique_table(k).astype(np.float32) * float(qm.scale)
    return words, uniq.astype(dtype), layout_stats(layout, bits)


def _max_width(w: np.ndarray, *, bits, max_unique, ppa_thr) -> int:
    """Max index width across all stacked [.., N, M] matrices."""
    flat = w.reshape(-1, *w.shape[-2:])
    width = 1
    for i in range(flat.shape[0]):
        qm = quantize_matrix(flat[i], QuantConfig(bits=bits))
        layout = analyze_matrix(qm.q)
        if ppa_thr is not None:
            layout = ppa_layout(layout, ppa_thr).layout
        mu = layout.max_unique()
        if max_unique is not None:
            mu = min(mu, max_unique)
        width = max(width, index_width(mu))
    return width


def crewize_params(
    params,
    *,
    bits: int = 8,
    max_unique: Optional[int] = None,
    ppa_thr: Optional[float] = None,
    dtype=jnp.bfloat16,
    min_cols: int = 128,
    skip_names: Tuple[str, ...] = ("router",),
    pad_words_to: int = 16,
) -> Tuple[Any, CrewReport]:
    """Convert every eligible linear weight in a param tree to CREW.

    min_cols: matrices with fewer output columns are left dense (index
    metadata would not amortize — e.g. MoE routers, tiny heads).
    pad_words_to: the packed-word dim is zero-padded to this multiple so it
    shards over the TP axis exactly like the dense [N, M] weight's M dim
    (padded words decode to indices past n_out and are sliced off).
    """
    report = CrewReport(n_converted=0, n_skipped=0, stats=[])

    def rec(path, node):
        if isinstance(node, dict):
            out = {}
            for key, val in node.items():
                if (
                    key == "w"
                    and hasattr(val, "ndim")
                    and val.ndim >= 2
                    and not any(s in path for s in skip_names)
                    and val.shape[-1] >= min_cols
                ):
                    out[key] = _crewize_leaf(path, np.asarray(val))
                else:
                    out[key] = rec(f"{path}/{key}", val)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(rec(f"{path}[{i}]", v)
                              for i, v in enumerate(node))
        return node

    def _crewize_leaf(path, w):
        stack = w.shape[:-2]
        n, m = w.shape[-2:]
        width = _max_width(w, bits=bits, max_unique=max_unique, ppa_thr=ppa_thr)
        k = 1 << width
        epw = elems_per_word(width)
        n_words = (m + epw - 1) // epw
        n_words = -(-n_words // pad_words_to) * pad_words_to
        flat = w.reshape(-1, n, m)
        words = np.empty((flat.shape[0], n, n_words), dtype=np.uint32)
        uniq = np.empty((flat.shape[0], n, k), dtype=np.float32)
        for i in range(flat.shape[0]):
            wi, ui, st = _convert_matrix(
                flat[i], bits=bits, width=width, max_unique=max_unique,
                ppa_thr=ppa_thr, dtype=np.float32)
            words[i, :, :wi.shape[1]] = wi
            words[i, :, wi.shape[1]:] = 0
            uniq[i] = ui
            report.stats.append((f"{path}[{i}]", st))
        report.n_converted += 1
        return CrewMatrixUniform(
            words=jnp.asarray(words.reshape(*stack, n, n_words)),
            uniq=jnp.asarray(uniq.reshape(*stack, n, k), dtype=dtype),
            width=width,
            n_out=m,
        )

    def count_skips(node):
        if isinstance(node, dict):
            for key, val in node.items():
                if key == "w" and hasattr(val, "ndim") and not isinstance(
                        val, CrewMatrixUniform):
                    report.n_skipped += 1
                count_skips(val)
        elif isinstance(node, (list, tuple)):
            for val in node:
                count_skips(val)

    new = rec("", params)
    count_skips(new)
    return new, report


def autotune_crew_params(
    params,
    *,
    batch_sizes: Tuple[int, ...] = (1, 8),
    activations: Tuple[Optional[str], ...] = (None,),
    decode_batch_sizes: Tuple[int, ...] = (),
    dtype=jnp.float32,
    interpret: bool = True,
    repeats: int = 2,
    store=None,
    seed: int = 0,
):
    """Warm the measured-dispatch cache for every CREW leaf in a param tree.

    Walks the converted tree, and for each *distinct* apply shape
    (B, N, M, K, width, epilogue) — stacked ``[L, N, W]`` leaves contribute
    one 2-D slice, since ``lax.scan`` applies the same shape per layer —
    times the candidate strategies via ``repro.perf.measure_crew_matmul``
    on a random activation of each requested batch size.  Subsequent
    ``crew_matmul(strategy="auto")`` calls (the serve engine's default) then
    dispatch on measurement instead of the analytical prior.  Returns
    {dispatch key: winning strategy}.

    Leaves whose parent carries a bias (``{"w", "b"}``) are measured with
    the fused bias epilogue, so the warmed key matches what
    ``layers.linear.apply`` dispatches at serve time; ``activations``
    optionally sweeps fused-activation variants (e.g. ``("silu",)`` for
    SwiGLU gate projections, ``(None, "gelu")`` for GELU FFNs).  Epilogue
    combinations not warmed here fall back to the analytical prior —
    never to a differently-epilogued measurement (repro.perf key tags).

    ``batch_sizes`` are *flattened token* batches: ``crew_matmul`` collapses
    every leading dim into the dispatch key's B, so decode steps key on the
    request batch but prefill keys on ``batch * prompt_len``.  To cover
    prefill, include those products (e.g. ``(1, 8, 8 * 512)``) — shapes not
    warmed here simply fall back to the analytical prior.

    ``decode_batch_sizes`` additionally warms *decode-shaped* keys
    (``kind="decode"``, epilogue-independent) via
    ``repro.perf.measure_crew_matmul_decode`` — the buffer-residency
    tournament between the carried-state VMEM decode kernel, the
    decompress-once cached GEMV, and the per-step strategies.  Those keys
    gate ``decode_state_for_params`` / :func:`cache_decode_weights`:
    with none warmed both are no-ops and decode behavior is unchanged.
    """
    from ..perf import autotune

    leaves: List[Tuple[CrewMatrixUniform, bool]] = []

    def walk(node):
        if isinstance(node, dict):
            w = node.get("w")
            if isinstance(w, CrewMatrixUniform):
                leaves.append((w, "b" in node))
            for key, val in node.items():
                if key != "w":
                    walk(val)
        elif isinstance(node, (list, tuple)):
            for val in node:
                walk(val)

    walk(params)
    rng = np.random.default_rng(seed)
    winners = {}
    for leaf, has_bias in leaves:
        words = np.asarray(leaf.words).reshape(-1, *leaf.words.shape[-2:])[0]
        uniq = np.asarray(leaf.uniq).reshape(-1, *leaf.uniq.shape[-2:])[0]
        cm = CrewMatrixUniform(
            words=jnp.asarray(words),
            uniq=jnp.asarray(uniq.astype(np.float32), dtype=dtype),
            width=leaf.width,
            n_out=leaf.n_out,
        )
        bias = jnp.zeros((cm.n_out,), dtype=dtype) if has_bias else None
        for b in batch_sizes:
            for act in activations:
                key = autotune.make_key(
                    b, cm.n_in, cm.n_out, cm.k, cm.width,
                    jax.default_backend(),
                    epilogue=autotune.epilogue_tag(has_bias, act))
                if key in winners:
                    continue
                x = jnp.asarray(
                    rng.standard_normal((b, cm.n_in)).astype(np.float32),
                    dtype=dtype)
                rec = autotune.measure_crew_matmul(
                    x, cm, repeats=repeats, interpret=interpret, store=store,
                    bias=bias, activation=act)
                winners[key] = rec.strategy
        for b in decode_batch_sizes:
            key = autotune.make_key(
                b, cm.n_in, cm.n_out, cm.k, cm.width,
                jax.default_backend(), kind="decode")
            if key in winners:
                continue
            x = jnp.asarray(
                rng.standard_normal((b, cm.n_in)).astype(np.float32),
                dtype=dtype)
            rec = autotune.measure_crew_matmul_decode(
                x, cm, repeats=repeats, interpret=interpret, store=store)
            winners[key] = rec.strategy
    return winners


def decode_state_for_params(params, batch: int, *, backend=None):
    """Build the decode product-buffer state tree for a CREW param tree.

    The returned tree mirrors ``params`` dict-for-dict; at each ``"w"``
    key holding a CREW leaf whose *measured* decode winner (see
    ``autotune_crew_params(decode_batch_sizes=...)``) is the VMEM-resident
    ``pallas-decode`` kernel, the mirror holds
    ``{"pbuf": f32[*stack, batch, N_pad, K]}`` — the carried
    partial-product buffer, zero-initialized (its content is a pure
    function of each step's activation).  Every other position is None.

    Attach it as ``cache["crew"]`` before the decode loop
    (``models.transformer.decode_step`` threads the ``"blocks"`` mirror
    through its layer scan; the serve engine/scheduler carry the whole
    tree through the H-step horizon scan with donated buffers).

    Returns None when no leaf qualifies — a cold autotune store, or every
    winner preferring the stateless strategies — in which case the decode
    program runs the historical stateless path bit for bit.  MoE expert
    stacks (two stack dims) never qualify: experts apply via vmap'd
    reconstruct, not ``linear.apply``.
    """
    from ..kernels.crew_matmul import decode_pbuf_rows
    from ..kernels.ops import resolve_decode_plan

    found = [False]

    def leaf_state(w):
        if not isinstance(w, CrewMatrixUniform):
            return None
        stack = w.words.shape[:-2]
        if len(stack) > 1:
            return None
        n = int(w.words.shape[-2])
        k = int(w.uniq.shape[-1])
        plan = resolve_decode_plan(batch, n, w.n_out, k, w.width,
                                   backend=backend)
        if plan is None or plan.strategy != "pallas-decode":
            return None
        found[0] = True
        return {"pbuf": jnp.zeros(
            (*stack, batch, decode_pbuf_rows(n), k), jnp.float32)}

    def rec(node):
        if isinstance(node, dict):
            return {key: (leaf_state(val) if key == "w" else rec(val))
                    for key, val in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v) for v in node)
        return None

    mirror = rec(params)
    return mirror if found[0] else None


def cache_decode_weights(params, *, batch_sizes: Tuple[int, ...] = (1,),
                         backend=None):
    """Wrap CREW leaves whose measured decode winner is the
    decompress-once strategy in :class:`~repro.core.CrewMatrixCached`.

    For each CREW ``"w"`` leaf, probes the decode-shaped autotune keys for
    ``batch_sizes``; when any winner is ``"xla-cached"`` the leaf is
    replaced by ``CrewMatrixCached(cm, wbuf)`` with the weight buffer
    reconstructed **once** here (vmapped over the layer-stack axes) —
    decode applies then skip the per-dispatch decompress.  The wrapped
    leaf lives in the params tree (shared, never donated) and its apply
    is bitwise the ``xla-dense`` strategy on the same leaf, so wrapping
    never changes tokens.  Leaves with no measurement (cold store) are
    left untouched.
    """
    from ..core.convert import CrewMatrixCached, crew_reconstruct_uniform
    from ..kernels.ops import resolve_decode_plan

    def wrap(w):
        stack = w.words.shape[:-2]
        n = int(w.words.shape[-2])
        k = int(w.uniq.shape[-1])
        plans = [resolve_decode_plan(b, n, w.n_out, k, w.width,
                                     backend=backend) for b in batch_sizes]
        if not any(p is not None and p.strategy == "xla-cached"
                   for p in plans):
            return w
        rec_fn = crew_reconstruct_uniform
        for _ in stack:
            rec_fn = jax.vmap(rec_fn)
        return CrewMatrixCached(cm=w, wbuf=rec_fn(w))

    def rec(node):
        if isinstance(node, dict):
            return {key: (wrap(val) if key == "w"
                          and isinstance(val, CrewMatrixUniform)
                          else rec(val))
                    for key, val in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v) for v in node)
        return node

    return rec(params)


def crewize_spec(spec_tree, crew_params):
    """Mirror a logical PartitionSpec tree onto a CREW-converted param tree.

    A converted weight's spec P(*stack, in, out) carries over directly:
    packed words shard exactly like the dense [N, M] weight (the word dim
    follows M — packing is per-row, word-aligned, and padded to a
    TP-divisible word count); unique tables shard on N and replicate over
    the TP axis (they are small, and every shard needs the full row table
    to form its partial products).  Column-parallel layers therefore
    compute step-1 partial products redundantly per shard — cheap — and
    row-parallel layers end in the usual single all-reduce: CREW adds no
    collectives over dense TP (DESIGN.md §3.7).
    """
    from jax.sharding import PartitionSpec as P

    def one(spec, val):
        if isinstance(val, CrewMatrixUniform):
            parts = tuple(spec)
            in_axis = parts[-2] if len(parts) >= 2 else None
            out_axis = parts[-1] if len(parts) >= 2 else None
            stack = parts[:-2]
            return CrewMatrixUniform(
                words=P(*stack, in_axis, out_axis),
                uniq=P(*stack, in_axis, None),
                width=val.width,
                n_out=val.n_out,
            )
        return spec

    return jax.tree.map(
        one, spec_tree, crew_params,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def abstract_crew_params(abstract_params, *, width: int = 6,
                         dtype=jnp.bfloat16, min_cols: int = 128,
                         skip_names: Tuple[str, ...] = ("router",),
                         pad_words_to: int = 16):
    """ShapeDtypeStruct version of ``crewize_params`` for dry-runs.

    Replaces each eligible ``{"w": SDS[..., N, M]}`` with a
    CrewMatrixUniform of abstract words/uniq at an assumed index width
    (the measured network-wide max is 6-7 for 8-bit quantization).
    No data is touched — suitable for full-size 512-device lowering.
    """
    k = 1 << width
    epw = elems_per_word(width)

    def rec(path, node):
        if isinstance(node, dict):
            out = {}
            for key, val in node.items():
                if (
                    key == "w"
                    and hasattr(val, "ndim")
                    and val.ndim >= 2
                    and not any(s in path for s in skip_names)
                    and val.shape[-1] >= min_cols
                ):
                    stack = val.shape[:-2]
                    n, m = val.shape[-2:]
                    n_words = (m + epw - 1) // epw
                    n_words = -(-n_words // pad_words_to) * pad_words_to
                    out[key] = CrewMatrixUniform(
                        words=jax.ShapeDtypeStruct((*stack, n, n_words),
                                                   jnp.uint32),
                        uniq=jax.ShapeDtypeStruct((*stack, n, k), dtype),
                        width=width,
                        n_out=m,
                    )
                else:
                    out[key] = rec(f"{path}/{key}", val)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(rec(f"{path}[{i}]", v)
                              for i, v in enumerate(node))
        return node

    return rec("", abstract_params)
