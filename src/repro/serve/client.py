"""Blocking SSE client for the front door (std-lib ``http.client``).

The reference consumer of the wire protocol (docs/serving.md): the
chaos benchmarks, the CI smokes, and ``launch/serve.py --connect`` all
speak through :func:`stream_generate`, which doubles as the chaos
*instrument* — ``disconnect_after=k`` hangs up after ``k`` token frames
(k=0: before the first) and ``stall_s`` stops reading mid-stream to
exercise the server's write timeout and send-queue backpressure.

Resumable consumption (``resume=True``): the client tracks the SSE
``id:`` of the last frame it saw and, when the connection drops before
the ``done`` frame — network blip, server restart, SIGKILL — reconnects
to ``GET /v1/stream/<rid>`` with ``Last-Event-ID``, sleeping a jittered
exponential backoff between attempts (seeded, so chaos runs replay).
Replayed frames are deduplicated on the absolute token index, so the
assembled stream is exactly the uninterrupted stream.
"""
from __future__ import annotations

import http.client
import json
import random
import time
from typing import Optional

__all__ = ["stream_generate", "resume_stream", "get_json"]


def get_json(host: str, port: int, path: str,
             timeout: float = 10.0) -> dict:
    """GET ``path`` and decode the JSON body; ``status`` and
    ``retry_after`` (header, if present) are merged into the result."""
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        body = resp.read()
        out = json.loads(body.decode() or "{}")
        out["status"] = resp.status
        retry = resp.getheader("Retry-After")
        if retry is not None:
            out["retry_after"] = int(retry)
        return out
    finally:
        conn.close()


def _read_sse(resp, out: dict, disconnect_after: Optional[int],
              stall_s: float, stall_at: int) -> str:
    """Consume SSE frames into ``out`` until done/EOF/planned hangup.
    Frames at or below ``out``'s high-water index are dropped (replay
    dedup on the absolute output index).  Returns ``"done"``,
    ``"eof"`` (server closed early) or ``"disconnected"``."""
    event = None
    while True:
        line = resp.readline()
        if not line:
            return "eof"            # server closed (or died) mid-stream
        line = line.strip()
        if line.startswith(b"event:"):
            event = line.split(b":", 1)[1].strip().decode()
        elif line.startswith(b"id:"):
            out["last_event_id"] = line.split(b":", 1)[1].strip().decode()
        elif line.startswith(b"data:"):
            data = json.loads(line.split(b":", 1)[1].decode())
            if event == "token":
                if data["i"] <= out["_hw"]:
                    continue        # replayed frame: already consumed
                out["_hw"] = data["i"]
                out["_n_tok"] += 1
                if stall_s > 0.0 and out["_n_tok"] == stall_at:
                    time.sleep(stall_s)
                out["indices"].append(data["i"])
                out["tokens"].append(data["token"])
                out["logprobs"].append(data["logprob"])
                if (disconnect_after is not None
                        and out["_n_tok"] >= disconnect_after):
                    out["disconnected"] = True
                    return "disconnected"
            elif event == "done":
                out["done"] = data
                return "done"


def _new_out() -> dict:
    return {"http_status": 0, "rid": -1, "tokens": [], "logprobs": [],
            "indices": [], "done": None, "disconnected": False,
            "reconnects": 0, "_hw": -1, "_n_tok": 0}


def _finalize(out: dict) -> dict:
    out.pop("_hw", None)
    out.pop("_n_tok", None)
    return out


def _reconnect_loop(host: str, port: int, out: dict, *,
                    max_reconnects: int, backoff_s: float,
                    backoff_cap_s: float, timeout: float,
                    rng: random.Random) -> dict:
    """Re-attach to ``out['rid']`` until done or attempts exhausted.
    Jittered exponential backoff between attempts; a refused connection
    (server restarting) just burns an attempt and backs off again."""
    attempts = 0
    while out["done"] is None and attempts < max_reconnects:
        attempts += 1
        # full jitter: sleep U(0, min(cap, base * 2^k)) — decorrelates
        # a thundering herd of reconnecting clients after a restart
        delay = rng.uniform(0.0, min(backoff_cap_s,
                                     backoff_s * (2 ** attempts)))
        time.sleep(delay)
        conn = http.client.HTTPConnection(host, port, timeout=timeout)
        try:
            headers = {"Connection": "close"}
            if out["_hw"] >= 0:
                headers["Last-Event-ID"] = f"{out['rid']}:{out['_hw']}"
            conn.request("GET", f"/v1/stream/{out['rid']}",
                         headers=headers)
            resp = conn.getresponse()
            if resp.status == 404:
                out["error"] = "stream gone"
                break               # journal compacted / unknown rid
            if resp.status != 200:
                continue            # 503 while booting: back off again
            out["reconnects"] += 1
            if _read_sse(resp, out, None, 0.0, 0) == "done":
                break
        except (ConnectionError, OSError, http.client.HTTPException):
            continue                # refused/reset mid-restart: retry
        finally:
            conn.close()
    return _finalize(out)


def resume_stream(host: str, port: int, rid: int, *,
                  last_index: int = -1,
                  max_reconnects: int = 1,
                  backoff_s: float = 0.05,
                  backoff_cap_s: float = 2.0,
                  backoff_seed: Optional[int] = None,
                  timeout: float = 60.0) -> dict:
    """Attach to an existing stream (``GET /v1/stream/<rid>`` with
    ``Last-Event-ID``) and consume it to the done frame.  The result
    dict matches :func:`stream_generate`; tokens before ``last_index+1``
    are not re-collected."""
    out = _new_out()
    out["rid"] = int(rid)
    out["_hw"] = int(last_index)
    rng = random.Random(rid if backoff_seed is None else backoff_seed)
    return _reconnect_loop(host, port, out,
                           max_reconnects=max_reconnects,
                           backoff_s=backoff_s,
                           backoff_cap_s=backoff_cap_s,
                           timeout=timeout, rng=rng)


def stream_generate(host: str, port: int, prompt, *,
                    max_new: int = 32,
                    eos_id: Optional[int] = None,
                    deadline_s: Optional[float] = None,
                    priority: int = 0,
                    tenant: Optional[str] = None,
                    idempotency_key: Optional[str] = None,
                    resume: bool = False,
                    max_reconnects: int = 8,
                    backoff_s: float = 0.05,
                    backoff_cap_s: float = 2.0,
                    backoff_seed: Optional[int] = None,
                    disconnect_after: Optional[int] = None,
                    stall_s: float = 0.0,
                    stall_at: int = 1,
                    timeout: float = 60.0) -> dict:
    """POST one generation and consume its SSE stream.

    Returns a dict: ``http_status``, ``rid`` (from ``X-Request-Id``,
    or the error body's rid for typed sheds, or -1 when rejected before
    admission assigned one),
    ``tokens`` / ``logprobs`` / ``indices`` (token frames received, in
    order), ``done`` (the final done-frame payload or None),
    ``disconnected`` (True when this client hung up on purpose),
    ``reconnects`` (successful re-attaches), ``last_event_id`` (the
    last SSE ``id:`` seen), and ``retry_after`` when the server sent
    the header.

    ``resume=True`` marks the stream resumable server-side (disconnects
    get a grace window instead of an instant cancel) and turns on
    client-side auto-reconnect: up to ``max_reconnects`` attempts with
    seeded full-jitter exponential backoff (base ``backoff_s``, cap
    ``backoff_cap_s``), deduplicating replayed frames on the absolute
    token index.  ``idempotency_key`` is sent as the
    ``Idempotency-Key`` header — retrying the POST with the same key
    re-attaches instead of double-enqueueing.

    ``disconnect_after=k`` closes the socket after ``k`` token frames
    (0 = immediately after the response headers); ``stall_s`` sleeps
    that long before reading the ``stall_at``-th token frame, modelling
    a client that stops draining its socket.
    """
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    out = _new_out()
    outcome = "eof"
    try:
        body = {"prompt": [int(t) for t in prompt], "max_new": max_new,
                "eos_id": eos_id, "deadline_s": deadline_s,
                "priority": priority, "tenant": tenant,
                "resumable": bool(resume)}
        headers = {"Content-Type": "application/json",
                   "Connection": "close"}
        if idempotency_key is not None:
            headers["Idempotency-Key"] = idempotency_key
        conn.request("POST", "/v1/generate", body=json.dumps(body),
                     headers=headers)
        resp = conn.getresponse()
        out["http_status"] = resp.status
        retry = resp.getheader("Retry-After")
        if retry is not None:
            out["retry_after"] = int(retry)
        if resp.status != 200:
            payload = json.loads(resp.read().decode() or "{}")
            out["error"] = payload.get("error")
            if "rid" in payload:
                out["rid"] = int(payload["rid"])
            return _finalize(out)
        out["rid"] = int(resp.getheader("X-Request-Id", "-1"))
        if resp.getheader("X-Idempotent-Replay"):
            out["idempotent_replay"] = True

        if disconnect_after == 0:
            out["disconnected"] = True
            return _finalize(out)

        outcome = _read_sse(resp, out, disconnect_after, stall_s, stall_at)
    except (ConnectionError, OSError, http.client.HTTPException) as e:
        if not (resume and out["rid"] >= 0):
            out["error"] = str(e)
            return _finalize(out)
    finally:
        conn.close()
    if (resume and outcome == "eof" and out["done"] is None
            and out["rid"] >= 0):
        rng = random.Random(out["rid"] if backoff_seed is None
                            else backoff_seed)
        return _reconnect_loop(host, port, out,
                               max_reconnects=max_reconnects,
                               backoff_s=backoff_s,
                               backoff_cap_s=backoff_cap_s,
                               timeout=timeout, rng=rng)
    return _finalize(out)
