"""Blocking SSE client for the front door (std-lib ``http.client``).

The reference consumer of the wire protocol (docs/serving.md): the
chaos benchmark, the CI smoke test, and ``launch/serve.py --connect``
all speak through :func:`stream_generate`, which doubles as the chaos
*instrument* — ``disconnect_after=k`` hangs up after ``k`` token frames
(k=0: before the first) and ``stall_s`` stops reading mid-stream to
exercise the server's write timeout and send-queue backpressure.
"""
from __future__ import annotations

import http.client
import json
import time
from typing import Optional

__all__ = ["stream_generate", "get_json"]


def get_json(host: str, port: int, path: str,
             timeout: float = 10.0) -> dict:
    """GET ``path`` and decode the JSON body; ``status`` and
    ``retry_after`` (header, if present) are merged into the result."""
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        body = resp.read()
        out = json.loads(body.decode() or "{}")
        out["status"] = resp.status
        retry = resp.getheader("Retry-After")
        if retry is not None:
            out["retry_after"] = int(retry)
        return out
    finally:
        conn.close()


def stream_generate(host: str, port: int, prompt, *,
                    max_new: int = 32,
                    eos_id: Optional[int] = None,
                    deadline_s: Optional[float] = None,
                    priority: int = 0,
                    tenant: Optional[str] = None,
                    disconnect_after: Optional[int] = None,
                    stall_s: float = 0.0,
                    stall_at: int = 1,
                    timeout: float = 60.0) -> dict:
    """POST one generation and consume its SSE stream.

    Returns a dict: ``http_status``, ``rid`` (from ``X-Request-Id``,
    or the error body's rid for typed sheds, or -1 when rejected before
    admission assigned one),
    ``tokens`` / ``logprobs`` / ``indices`` (token frames received, in
    order), ``done`` (the final done-frame payload or None),
    ``disconnected`` (True when this client hung up on purpose), and
    ``retry_after`` when the server sent the header.

    ``disconnect_after=k`` closes the socket after ``k`` token frames
    (0 = immediately after the response headers); ``stall_s`` sleeps
    that long before reading the ``stall_at``-th token frame, modelling
    a client that stops draining its socket.
    """
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    out = {"http_status": 0, "rid": -1, "tokens": [], "logprobs": [],
           "indices": [], "done": None, "disconnected": False}
    try:
        body = {"prompt": [int(t) for t in prompt], "max_new": max_new,
                "eos_id": eos_id, "deadline_s": deadline_s,
                "priority": priority, "tenant": tenant}
        conn.request("POST", "/v1/generate", body=json.dumps(body),
                     headers={"Content-Type": "application/json",
                              "Connection": "close"})
        resp = conn.getresponse()
        out["http_status"] = resp.status
        retry = resp.getheader("Retry-After")
        if retry is not None:
            out["retry_after"] = int(retry)
        if resp.status != 200:
            payload = json.loads(resp.read().decode() or "{}")
            out["error"] = payload.get("error")
            if "rid" in payload:
                out["rid"] = int(payload["rid"])
            return out
        out["rid"] = int(resp.getheader("X-Request-Id", "-1"))

        if disconnect_after == 0:
            out["disconnected"] = True
            return out

        event = None
        n_tok = 0
        while True:
            line = resp.readline()
            if not line:
                break               # server closed (end of stream)
            line = line.strip()
            if line.startswith(b"event:"):
                event = line.split(b":", 1)[1].strip().decode()
            elif line.startswith(b"data:"):
                data = json.loads(line.split(b":", 1)[1].decode())
                if event == "token":
                    n_tok += 1
                    if stall_s > 0.0 and n_tok == stall_at:
                        time.sleep(stall_s)
                    out["indices"].append(data["i"])
                    out["tokens"].append(data["token"])
                    out["logprobs"].append(data["logprob"])
                    if (disconnect_after is not None
                            and n_tok >= disconnect_after):
                        out["disconnected"] = True
                        return out
                elif event == "done":
                    out["done"] = data
                    return out
    finally:
        conn.close()
    return out
