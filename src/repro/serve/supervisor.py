"""Supervision layer over the synchronous scheduler (DESIGN.md §5).

The :class:`~repro.serve.Scheduler` is a synchronous host loop: someone
must pump ``step()``, route the ``[nb, H]`` horizon panels to whoever is
waiting on each rid, and decide what happens when the engine stalls or a
client vanishes.  :class:`Supervisor` is that someone — a worker thread
that owns the scheduler and turns it into a long-lived service with
three robustness guarantees:

* **Disconnect propagation** — ``cancel(rid)`` routes to
  ``Scheduler.cancel`` at the next step boundary; a dropped client can
  never orphan a slot (the conservation audit stays clean).
* **Graceful drain** — ``begin_drain()`` stops admission (the scheduler
  sheds newcomers with a typed ``reason="draining"`` terminal) and the
  pump finishes in-flight work, bounded by the scheduler's own watchdog
  step budget; a drain that exceeds the budget cancels what remains
  rather than hanging shutdown.
* **Crash recovery** — on :class:`SchedulerStalledError`, an injected
  crash fault (``FaultInjector.should_crash``), a supervisor-detected
  stall, or an explicit :meth:`inject_crash`, the supervisor snapshots
  every outstanding request descriptor, rebuilds the engine with
  ``reset(force=True)`` (compiled programs are reused — no retracing),
  and ``restore``s the snapshot.  Recovered requests re-enter through
  the scheduler's resume path, so their streams continue
  greedy-token-identically and consumers deduplicate on the absolute
  token index (see :meth:`Scheduler.pop_tokens`).

Subscribers attach per-rid callbacks at :meth:`submit`; each receives
:class:`StreamEvent` values — ``kind="token"`` per generated token (in
order, exactly once per index) and a final ``kind="done"`` carrying the
terminal :class:`Completion`.  Callbacks run on the pump thread and must
not block (the SSE server's callback just enqueues to an asyncio queue).
"""
from __future__ import annotations

import dataclasses
import math
import os
import signal
import threading
import time
from typing import Callable, Dict, List, Optional, Set, Tuple, Union

import numpy as np

from .journal import RequestLog
from .scheduler import (
    Completion,
    RequestSnapshot,
    Scheduler,
    SchedulerSnapshot,
    SchedulerStalledError,
    Shed,
)

__all__ = ["Duplicate", "StreamEvent", "Supervisor"]


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One per-request event delivered to a subscriber callback.

    ``kind="token"`` carries ``index`` (absolute position in the rid's
    generated stream), ``token`` and ``logprob``; ``kind="done"``
    carries the terminal :class:`Completion`.  Every rid sees its token
    events in index order exactly once, then exactly one done event —
    across disconnects, preemptions, and supervised crash recoveries.
    """
    kind: str                   # "token" | "done"
    rid: int
    index: int = -1
    token: int = -1
    logprob: float = 0.0
    completion: Optional[Completion] = None


@dataclasses.dataclass(frozen=True)
class Duplicate:
    """:meth:`Supervisor.submit` saw an already-bound
    ``Idempotency-Key``: the work exists under ``rid`` — attach to its
    stream (:meth:`Supervisor.attach`) instead of double-enqueueing."""
    rid: int


class _InjectedCrash(RuntimeError):
    """Raised inside the pump to simulate an engine crash."""


class Supervisor:
    """Own a :class:`Scheduler` on a pump thread; supervise its faults.

    The scheduler must have been built with ``stream_tokens=True`` (the
    supervisor routes the per-token buffer to subscribers).  All public
    methods are thread-safe; scheduler access is serialized by one lock,
    so ``submit``/``cancel`` interleave with ``step()`` only at step
    boundaries — the same atomicity the scheduler's own lifecycle sweep
    assumes.

    ``max_recoveries`` bounds *consecutive* recoveries with no forward
    progress (a delivered token or terminal resets the counter): past
    it the supervisor stops restoring and cancels the survivors instead
    of crash-looping forever.
    """

    def __init__(self, sched: Scheduler, *,
                 max_recoveries: int = 8,
                 stall_steps: int = 16,
                 idle_poll_s: float = 0.05,
                 yield_s: float = 0.001,
                 request_log: Optional[RequestLog] = None,
                 resume_grace_s: float = 10.0):
        if not sched.stream_tokens:
            raise ValueError("Supervisor requires a Scheduler built "
                             "with stream_tokens=True")
        self._sched = sched
        self._max_recoveries = int(max_recoveries)
        self._stall_steps = int(stall_steps)
        self._idle_poll_s = float(idle_poll_s)
        self._yield_s = float(yield_s)
        self._request_log = request_log
        self._resume_grace_s = float(resume_grace_s)
        self._lock = threading.RLock()
        self._wake = threading.Event()
        self._idle = threading.Event()
        self._idle.set()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._subs: Dict[int, Callable[[StreamEvent], None]] = {}
        self._sent: Dict[int, int] = {}
        self._cancelled: Set[int] = set()
        self._crash_cause: Optional[str] = None
        self._drain_budget: Optional[int] = None
        self._drain_steps = 0
        self._drain_cancelled = False
        self._last_sig: Optional[tuple] = None
        self._stalled = 0
        self._consecutive = 0
        # resumable-stream state: full delivered history per live rid
        # (reconnects replay from it), idempotency-key bindings, and
        # the grace deadlines for disconnected-but-resumable streams
        self._hist: Dict[int, List[Tuple[int, int, float]]] = {}
        self._idem: Dict[str, int] = {}
        self._disc: Dict[int, float] = {}
        self._step_ewma: Optional[float] = None
        self._cold_replayed = False
        self.results: Dict[int, Completion] = {}
        self.recoveries = 0
        self.recovery_log: List[dict] = []
        self.replayed = 0           # requests re-admitted from the journal
        self.replay_ms = 0.0        # journal scan + restore wall time

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------

    def start(self) -> "Supervisor":
        """Start the pump thread (idempotent).  With a journal attached
        to the scheduler, the first start replays it: outstanding rids
        re-enter through the same ``restore`` path crash recovery uses
        (greedy streams resume token-identically across full process
        death), finished rids repopulate :attr:`results` so late
        reconnects still get their terminal."""
        with self._lock:
            self._cold_replay_locked()
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._pump, name="scheduler-pump", daemon=True)
            self._thread.start()
        return self

    def _cold_replay_locked(self) -> None:
        j = self._sched.journal
        if j is None or self._cold_replayed:
            return
        self._cold_replayed = True
        rep = j.replay
        if not rep.records:
            return
        t0 = time.perf_counter()
        self._idem.update(rep.idempotency)
        for rid, rec in rep.terminals.items():
            self.results[rid] = Completion(
                rid=rid,
                prompt_len=int(rec.get("prompt_len", 0)),
                tokens=np.asarray(rec.get("tokens", []), np.int32),
                logprobs=np.asarray(rec.get("logprobs", []), np.float32),
                n_steps=0,
                ttft_s=float(rec.get("ttft_s", 0.0)),
                status=rec.get("status", "completed"),
                reason=rec.get("reason", ""),
                tenant=rec.get("tenant"),
                queue_s=float(rec.get("queue_s", 0.0)),
            )
        snaps = []
        for rid in sorted(rep.outstanding):
            rec = rep.outstanding[rid]
            snaps.append(RequestSnapshot(
                rid=rid,
                prompt=tuple(int(t) for t in rec["prompt"]),
                max_new=int(rec["max_new"]),
                eos_id=rec.get("eos_id"),
                deadline_s=rec.get("deadline_s"),
                priority=int(rec.get("priority", 0)),
                tenant=rec.get("tenant"),
                submitted_s=float(rec["submitted_s"]),
                preemptions=0,
                tokens=tuple(int(t) for t in rec["tokens"]),
                logprobs=tuple(float(x) for x in rec["logprobs"]),
                ttft_s=0.0 if rec["tokens"] else None,
                idem_key=rec.get("idem_key"),
            ))
        # restore even with nothing outstanding: the rid high-water
        # mark must advance past every journaled rid, or fresh submits
        # would collide with already-delivered results
        self.replayed = self._sched.restore(
            SchedulerSnapshot(rep.next_rid, tuple(snaps)))
        for snap in snaps:
            self._sent[snap.rid] = len(snap.tokens)
            self._hist[snap.rid] = [
                (i, int(t), float(lp))
                for i, (t, lp) in enumerate(zip(snap.tokens,
                                                snap.logprobs))]
        self.replay_ms = (rep.replay_ms
                          + (time.perf_counter() - t0) * 1e3)
        if snaps:
            self._idle.clear()
            self._wake.set()

    def stop(self, *, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop the pump; with ``drain`` (default) finish outstanding
        work first (bounded by the watchdog budget), else abandon it."""
        if drain and self._thread is not None and self._thread.is_alive():
            self.begin_drain()
            self.wait_idle(timeout)
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None

    @property
    def scheduler(self) -> Scheduler:
        """The supervised engine (for metrics / audit reads; mutate it
        only through the supervisor)."""
        return self._sched

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    @property
    def draining(self) -> bool:
        return self._sched.draining

    @property
    def accepting(self) -> bool:
        """True while new submissions will be admitted."""
        return (self.running and not self._stop.is_set()
                and not self._sched.draining)

    # ------------------------------------------------------------------
    # Client surface
    # ------------------------------------------------------------------

    def submit(self, prompt, *, max_new: int = 32,
               eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None,
               priority: int = 0,
               tenant: Optional[str] = None,
               on_event: Optional[Callable[[StreamEvent], None]] = None,
               idempotency_key: Optional[str] = None,
               ) -> Union[int, Shed, Duplicate]:
        """Submit one request; subscription is atomic with admission, so
        no token can be emitted before ``on_event`` is attached.  A shed
        request (typed :class:`Shed` return) still delivers its terminal
        done event to ``on_event`` before this returns.

        ``idempotency_key`` makes retries safe: a key already bound to
        a rid (in this process, or replayed from the journal) returns
        :class:`Duplicate` without enqueueing anything — the caller
        attaches to the existing stream instead.  Keys bind only on
        acceptance; a shed does not consume its key."""
        with self._lock:
            if idempotency_key:
                known = self._idem.get(idempotency_key)
                if known is not None:
                    return Duplicate(known)
            res = self._sched.submit(prompt, max_new=max_new,
                                     eos_id=eos_id, deadline_s=deadline_s,
                                     priority=priority, tenant=tenant,
                                     idem_key=idempotency_key)
            rid = res if isinstance(res, int) else res.rid
            if idempotency_key and isinstance(res, int):
                self._idem[idempotency_key] = rid
            if on_event is not None:
                self._subs[rid] = on_event
            self._sent.setdefault(rid, 0)
            if not isinstance(res, int):
                self._deliver_locked()   # shed: terminal already exists
            self._idle.clear()
        self._wake.set()
        return res

    def attach(self, rid: int, on_event: Callable[[StreamEvent], None],
               *, from_index: int = 0) -> bool:
        """(Re)attach a subscriber to an existing rid, replaying history
        from absolute token index ``from_index`` (the ``Last-Event-ID``
        reconnect path).  Replayed and live events share the same
        exactly-once-per-index contract the original stream had.  For a
        finished rid the terminal tokens + done replay immediately from
        its :class:`Completion`.  Returns False for unknown rids
        (never journaled, or compacted away)."""
        def _safe(ev: StreamEvent) -> bool:
            try:
                on_event(ev)
                return True
            except Exception:
                return False
        with self._lock:
            comp = self.results.get(rid)
            if comp is not None:
                for i in range(max(0, from_index), comp.tokens.size):
                    if not _safe(StreamEvent(
                            "token", rid, index=i,
                            token=int(comp.tokens[i]),
                            logprob=float(comp.logprobs[i]))):
                        return True
                _safe(StreamEvent("done", rid, completion=comp))
                return True
            if rid not in set(self._sched.outstanding_rids()):
                return False
            for i, tok, lp in self._hist.get(rid, [])[max(0, from_index):]:
                if not _safe(StreamEvent("token", rid, index=i,
                                         token=tok, logprob=lp)):
                    return True
            self._subs[rid] = on_event
            self._sent.setdefault(rid, 0)
            self._disc.pop(rid, None)   # reattached within the grace
            self._idle.clear()
        self._wake.set()
        return True

    def release(self, rid: int) -> None:
        """A resumable stream's client disconnected: detach the
        subscriber but keep the request running for ``resume_grace_s``
        seconds.  A reconnect within the grace (:meth:`attach`) keeps
        it alive; otherwise the pump cancels it — disconnects still
        cannot orphan a slot, they just do it on a timer."""
        with self._lock:
            self._subs.pop(rid, None)
            if rid not in self.results:
                self._disc[rid] = time.perf_counter() + self._resume_grace_s
        self._wake.set()

    def retry_after_s(self) -> int:
        """Derived ``Retry-After`` hint: the remaining drain step budget
        times the observed per-step wall time (EWMA) — an upper bound on
        when a draining server will have finished its in-flight work.
        Falls back to 1 s before a drain began or a step has run.  Reads
        only plain attributes (GIL-atomic), so it is safe to call from
        the event loop without contending on the supervisor lock."""
        ewma = self._step_ewma
        budget = self._drain_budget
        if ewma is None or budget is None:
            return 1
        remaining = max(1, budget - self._drain_steps)
        return int(max(1, min(600, math.ceil(remaining * ewma))))

    def idempotent_rid(self, key: Optional[str]) -> Optional[int]:
        """The rid bound to ``key``, or None (unknown key / no key)."""
        if not key:
            return None
        with self._lock:
            return self._idem.get(key)

    def journal_stats(self) -> Optional[dict]:
        """Journal counters for ``/metrics`` (None when not durable)."""
        j = self._sched.journal
        if j is None:
            return None
        with self._lock:
            stats = j.stats()
        stats["replayed_requests"] = self.replayed
        stats["restore_replay_ms"] = round(self.replay_ms, 3)
        return stats

    def audit_clean(self) -> bool:
        """Run the block-conservation audit at a step boundary (the
        lock serializes against the pump)."""
        with self._lock:
            return not self._sched.audit_blocks()

    def metrics_payload(self) -> dict:
        """The ``/metrics`` document: scheduler counters (per-tenant
        included) + supervision + durability state, assembled under the
        lock so gauges are step-boundary-consistent.  Call from a worker
        thread, not the event loop."""
        with self._lock:
            payload = dataclasses.asdict(self._sched.metrics)
            payload.update(
                pending=self._sched.pending,
                draining=self.draining,
                recoveries=self.recoveries,
                audit_clean=int(not self._sched.audit_blocks()),
            )
        stats = self.journal_stats()
        if stats is not None:
            payload["journal"] = stats
        payload["retry_after_s"] = self.retry_after_s()
        return payload

    def cancel(self, rid: int) -> bool:
        """Cancel ``rid`` (disconnect propagation).  Remembered across a
        crash recovery: a restored request that was cancelled before the
        crash is re-cancelled after restore, never resurrected.
        Idempotent — unknown and already-terminal rids are a no-op."""
        with self._lock:
            self._cancelled.add(rid)
            took = self._sched.cancel(rid)
        self._wake.set()
        return took

    def begin_drain(self) -> None:
        """Stop admitting (newcomers shed with ``reason="draining"``)
        and let the pump finish in-flight work, bounded by the
        scheduler's watchdog step budget captured now."""
        # flip the flag before taking the lock: a plain bool write is
        # atomic, and readiness probes must flip to 503 immediately —
        # not after the pump finishes a (possibly compiling) step
        self._sched.begin_drain()
        with self._lock:
            if self._drain_budget is None:
                self._drain_budget = max(64, self._sched.step_budget())
                self._drain_steps = 0
        self._wake.set()

    def drain(self, timeout: float = 60.0) -> bool:
        """``begin_drain`` + wait for outstanding work to finish."""
        self.begin_drain()
        return self.wait_idle(timeout)

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Block until nothing is queued or in flight (and every
        terminal has been delivered); False on timeout."""
        return self._idle.wait(timeout)

    def inject_crash(self, reason: str = "operator-injected crash") -> None:
        """Force one supervised crash/recovery cycle at the next pump
        step (deterministic hook for tests and the chaos benchmark)."""
        with self._lock:
            self._crash_cause = reason
        self._wake.set()

    # ------------------------------------------------------------------
    # Pump internals (all _locked methods require self._lock held)
    # ------------------------------------------------------------------

    def _emit(self, rid: int, ev: StreamEvent) -> None:
        cb = self._subs.get(rid)
        if cb is not None:
            try:
                cb(ev)
            except Exception:
                # a broken subscriber must not take the pump down; its
                # connection-level handler owns client-visible errors
                self._subs.pop(rid, None)

    def _emit_token_locked(self, rid: int, idx: int, tok: int,
                           lp: float) -> None:
        """Deliver one token and record it in the per-rid history the
        reconnect path replays from."""
        self._hist.setdefault(rid, []).append((idx, tok, lp))
        self._emit(rid, StreamEvent("token", rid, index=idx,
                                    token=tok, logprob=lp))
        self._sent[rid] = idx + 1

    def _deliver_locked(self) -> None:
        """Route buffered tokens (deduplicated on absolute index) and
        terminal Completions to subscribers."""
        progressed = False
        for rid, idx, tok, lp in self._sched.pop_tokens():
            sent = self._sent.get(rid, 0)
            if idx < sent:
                continue            # recovery re-decode: already delivered
            progressed = True
            self._emit_token_locked(rid, idx, tok, lp)
        for rid, comp in self._sched.pop_results().items():
            progressed = True
            sent = self._sent.get(rid, 0)
            for i in range(sent, comp.tokens.size):
                self._emit_token_locked(rid, i, int(comp.tokens[i]),
                                        float(comp.logprobs[i]))
            self.results[rid] = comp
            self._emit(rid, StreamEvent("done", rid, completion=comp))
            if self._request_log is not None:
                try:
                    self._request_log.log(comp)
                except OSError:
                    pass    # observability must not take the pump down
            self._subs.pop(rid, None)
            self._sent.pop(rid, None)
            self._hist.pop(rid, None)   # reconnects now replay from comp
            self._disc.pop(rid, None)
            self._cancelled.discard(rid)
        if progressed:
            self._consecutive = 0

    def _recover_locked(self, cause: str) -> None:
        """Snapshot → reset(force) → restore → re-apply cancels."""
        self._deliver_locked()      # flush whatever already made it out
        t0 = time.perf_counter()
        self.recoveries += 1
        self._consecutive += 1
        snap = self._sched.snapshot_requests()
        self._sched.reset(force=True)
        give_up = self._consecutive > self._max_recoveries
        restored = 0
        if not give_up:
            restored = self._sched.restore(snap)
            # a subscriber may have seen fewer tokens than the engine
            # had generated (crash between decode and delivery) — or,
            # after restore, a prefix hit may keep more tokens than the
            # truncate-and-re-decode path will re-emit.  Top up from
            # the snapshot now; the dedup index keeps re-decoded tokens
            # from double-delivering.
            for rs in snap.requests:
                sent = self._sent.get(rs.rid, 0)
                for i in range(sent, len(rs.tokens)):
                    self._emit_token_locked(rs.rid, i, int(rs.tokens[i]),
                                            float(rs.logprobs[i]))
            for rid in sorted(self._cancelled):
                self._sched.cancel(rid)
        else:
            # crash loop: stop restoring, terminate the survivors so
            # every rid still gets its exactly-one terminal Completion
            self._sched.restore(snap)
            for rs in snap.requests:
                self._sched.cancel(rs.rid)
        self._last_sig = None
        self._stalled = 0
        self.recovery_log.append({
            "cause": cause,
            "requests": len(snap.requests),
            "restored": restored,
            "gave_up": give_up,
            "wall_s": time.perf_counter() - t0,
        })

    def _pump(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                if self._crash_cause is not None:
                    # explicit inject_crash fires even on an idle engine
                    # (an empty-snapshot recovery), never lies in wait
                    # for an unrelated later request
                    cause, self._crash_cause = self._crash_cause, None
                    self._recover_locked(cause)
                self._deliver_locked()
                idle = self._sched.pending == 0
                if idle:
                    if not self._idle.is_set():
                        j = self._sched.journal
                        if j is not None:
                            # idle transition: make pending terminals
                            # durable and let the journal compact
                            j.commit(idle=True)
                    self._idle.set()
            if idle:
                self._wake.wait(self._idle_poll_s)
                self._wake.clear()
                continue
            with self._lock:
                if self._sched.pending == 0:
                    continue
                self._idle.clear()
                now = time.perf_counter()
                for rid, deadline in list(self._disc.items()):
                    if now >= deadline and rid not in self._subs:
                        # resumable stream's grace expired unreclaimed:
                        # disconnect propagation, on a timer
                        self._disc.pop(rid, None)
                        self._cancelled.add(rid)
                        self._sched.cancel(rid)
                try:
                    faults = self._sched.faults
                    if faults is not None and faults.should_kill():
                        # chaos: full process death — no snapshot, no
                        # goodbye; only the journal survives this
                        os.kill(os.getpid(), signal.SIGKILL)
                    if faults is not None and faults.should_crash():
                        raise _InjectedCrash("fault-injected crash")
                    t_step = time.perf_counter()
                    self._sched.step()
                    dt = time.perf_counter() - t_step
                    self._step_ewma = (dt if self._step_ewma is None
                                       else 0.8 * self._step_ewma + 0.2 * dt)
                    sig = self._sched.progress_signature()
                    self._stalled = (self._stalled + 1
                                     if sig == self._last_sig else 0)
                    self._last_sig = sig
                    if self._stalled >= self._stall_steps:
                        raise SchedulerStalledError(
                            f"supervisor: no progress across "
                            f"{self._stalled} busy steps")
                    if self._drain_budget is not None:
                        self._drain_steps += 1
                        if (self._drain_steps > self._drain_budget
                                and not self._drain_cancelled):
                            # wedged drain: cancel survivors instead of
                            # hanging shutdown forever
                            self._drain_cancelled = True
                            for rid in self._sched.outstanding_rids():
                                self._sched.cancel(rid)
                except (_InjectedCrash, SchedulerStalledError) as e:
                    self._recover_locked(str(e))
                self._deliver_locked()
            # hold the lock open for a beat: the pump re-acquires it
            # within microseconds otherwise, starving client threads
            # (submit / cancel / inject_crash) until the engine idles
            time.sleep(self._yield_s)
        with self._lock:
            self._deliver_locked()
