"""Asyncio HTTP/SSE front door over the supervised scheduler.

Std-lib only: a hand-rolled HTTP/1.1 responder on
``asyncio.start_server`` — the endpoint surface is four routes
(docs/serving.md), not a framework's worth of them, and owning the
socket is what makes the robustness story testable: disconnects are
*observed* (EOF on the request socket), backpressure is a bounded
per-connection send queue, and a slow client hits an explicit write
timeout instead of wedging the pump.

Routes:

* ``GET /healthz`` — liveness: the process is up (200 always).
* ``GET /readyz`` — readiness: 200 while accepting, 503 +
  ``Retry-After`` once draining or stopped (the value is derived from
  the remaining drain budget × observed step latency, not a constant).
* ``GET /metrics`` — scheduler counters (per-tenant included) +
  supervisor recovery stats + durability state (``audit_clean``,
  journal replay/fsync counters).
* ``POST /v1/generate`` — submit ``{"prompt": [ints], "max_new": n,
  "eos_id": …, "deadline_s": …, "priority": …, "tenant": …,
  "resumable": bool}``; the
  response is an SSE stream (``X-Request-Id`` header carries the rid):
  one ``event: token`` frame per generated token, then exactly one
  ``event: done`` frame with the terminal Completion.  Every frame
  carries an SSE ``id:`` of the form ``<rid>:<index>`` (``done`` for
  the terminal), so a client can resume after a dropped connection.
  An ``Idempotency-Key`` header makes retries safe: a key already
  bound to a rid re-attaches to that stream instead of enqueueing a
  second copy.  Admission rejections map to HTTP: draining /
  queue-full → 503 + ``Retry-After``, tenant-rate → 429; malformed
  bodies → 400.
* ``GET /v1/stream/<rid>`` — reconnect to an existing stream.
  ``Last-Event-ID: <rid>:<k>`` (standard SSE reconnect header) replays
  from absolute token index ``k+1`` — from supervisor history for live
  rids, from the terminal Completion (journal-backed across restarts)
  for finished ones — then continues live.  Unknown rids → 404.

Disconnects on a plain stream cancel the request immediately; on a
``resumable`` stream the request keeps running for a grace window
(``Supervisor.resume_grace_s``) awaiting a reconnect.

Threading model: the asyncio loop runs the sockets; the supervisor's
pump thread runs the engine and delivers :class:`StreamEvent` callbacks,
which hop onto the loop via ``call_soon_threadsafe`` into a bounded
``asyncio.Queue`` per connection.  ``submit`` happens in a worker thread
(``asyncio.to_thread``) because the supervisor lock can be held for a
whole engine step.
"""
from __future__ import annotations

import asyncio
import json
import threading
from typing import Optional, Tuple

from .scheduler import Shed
from .supervisor import Duplicate, StreamEvent, Supervisor

__all__ = ["SSEServer"]

_MAX_HEADER_BYTES = 16384
_MAX_BODY_BYTES = 1 << 20


def _json_bytes(obj) -> bytes:
    return json.dumps(obj, separators=(",", ":")).encode()


def _response(status: str, body: bytes,
              content_type: str = "application/json",
              extra: Tuple[Tuple[str, str], ...] = ()) -> bytes:
    head = [f"HTTP/1.1 {status}",
            f"Content-Type: {content_type}",
            f"Content-Length: {len(body)}",
            "Connection: close"]
    head += [f"{k}: {v}" for k, v in extra]
    return ("\r\n".join(head) + "\r\n\r\n").encode() + body


class SSEServer:
    """Serve a :class:`Supervisor` over HTTP/SSE (see module docstring).

    ``port=0`` binds an ephemeral port; read ``server.port`` after
    :meth:`start`.  ``send_queue`` bounds the per-connection event
    queue: a client that stops reading long enough to overflow it (or
    to trip ``write_timeout_s`` on a single write) is treated as
    disconnected and its request cancelled — backpressure never reaches
    the pump thread.
    """

    def __init__(self, supervisor: Supervisor, *,
                 host: str = "127.0.0.1", port: int = 0,
                 write_timeout_s: float = 10.0,
                 send_queue: int = 256,
                 retry_after_s: int = 1):
        self._sup = supervisor
        self.host = host
        self.port = int(port)
        self._write_timeout_s = float(write_timeout_s)
        self._send_queue = int(send_queue)
        self._retry_after_s = int(retry_after_s)
        self._server: Optional[asyncio.AbstractServer] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._started = threading.Event()
        self._drain_signals = 0
        self._conns: set = set()

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------

    async def start(self) -> "SSEServer":
        """Bind the listener on the current event loop."""
        self._loop = asyncio.get_running_loop()
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._started.set()
        return self

    async def aclose(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    def install_signal_handlers(self) -> None:
        """SIGINT/SIGTERM → graceful drain; a second signal → hard stop
        (CLI mode only; background/test servers skip this)."""
        import signal
        assert self._loop is not None
        for sig in (signal.SIGINT, signal.SIGTERM):
            self._loop.add_signal_handler(sig, self._on_signal)

    def _on_signal(self) -> None:
        self._drain_signals += 1
        if self._drain_signals == 1:
            # readiness flips to 503 immediately; in-flight work drains
            # on the pump thread, bounded by the watchdog budget
            asyncio.ensure_future(self._stop_when_idle())
        else:
            asyncio.get_event_loop().stop()

    async def _stop_when_idle(self) -> None:
        # begin_drain can contend on the supervisor lock (held across
        # whole engine steps) — keep that wait off the event loop so
        # health probes stay responsive throughout the drain
        await asyncio.to_thread(self._sup.begin_drain)
        await asyncio.to_thread(self._sup.wait_idle, 60.0)
        # the engine is idle but open streams may still hold queued
        # frames (the final tokens + done); let them flush before the
        # loop dies or the client sees EOF instead of a done event
        if self._conns:
            await asyncio.wait(set(self._conns),
                               timeout=self._write_timeout_s)
        await self.aclose()
        assert self._loop is not None
        self._loop.stop()

    def start_background(self) -> "SSEServer":
        """Run the loop + listener on a daemon thread (tests and the
        chaos benchmark); returns once the port is bound."""
        def _run() -> None:
            loop = asyncio.new_event_loop()
            self._loop = loop
            asyncio.set_event_loop(loop)
            loop.run_until_complete(self.start())
            loop.run_forever()
            loop.close()

        self._thread = threading.Thread(target=_run, name="sse-server",
                                        daemon=True)
        self._thread.start()
        if not self._started.wait(timeout=10.0):
            raise RuntimeError("SSE server failed to bind")
        return self

    def stop_background(self) -> None:
        loop, self._thread = self._loop, None
        if loop is None:
            return

        def _shutdown() -> None:
            task = asyncio.ensure_future(self.aclose())
            task.add_done_callback(lambda _: loop.stop())

        loop.call_soon_threadsafe(_shutdown)

    # ------------------------------------------------------------------
    # HTTP plumbing
    # ------------------------------------------------------------------

    async def _write(self, writer: asyncio.StreamWriter,
                     data: bytes) -> None:
        writer.write(data)
        await asyncio.wait_for(writer.drain(), self._write_timeout_s)

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        task = asyncio.current_task()
        self._conns.add(task)
        try:
            await self._handle_inner(reader, writer)
        finally:
            self._conns.discard(task)

    async def _handle_inner(self, reader: asyncio.StreamReader,
                            writer: asyncio.StreamWriter) -> None:
        try:
            head = await asyncio.wait_for(
                reader.readuntil(b"\r\n\r\n"), timeout=30.0)
        except (asyncio.IncompleteReadError, asyncio.TimeoutError,
                asyncio.LimitOverrunError, ConnectionError):
            writer.close()
            return
        if len(head) > _MAX_HEADER_BYTES:
            await self._finish(writer, _response(
                "431 Request Header Fields Too Large", b"{}"))
            return
        lines = head.decode("latin-1").split("\r\n")
        try:
            method, path, _ = lines[0].split(" ", 2)
        except ValueError:
            await self._finish(writer, _response("400 Bad Request", b"{}"))
            return
        headers = {}
        for ln in lines[1:]:
            if ":" in ln:
                k, v = ln.split(":", 1)
                headers[k.strip().lower()] = v.strip()
        body = b""
        clen = int(headers.get("content-length", 0) or 0)
        if clen:
            if clen > _MAX_BODY_BYTES:
                await self._finish(writer, _response(
                    "413 Payload Too Large", b"{}"))
                return
            try:
                body = await asyncio.wait_for(
                    reader.readexactly(clen), timeout=30.0)
            except (asyncio.IncompleteReadError, asyncio.TimeoutError):
                writer.close()
                return
        try:
            await self._route(method, path, headers, body, reader, writer)
        except (ConnectionError, asyncio.TimeoutError):
            writer.close()
        except Exception:
            try:
                await self._finish(writer, _response(
                    "500 Internal Server Error", b"{}"))
            except Exception:
                writer.close()

    async def _finish(self, writer: asyncio.StreamWriter,
                      payload: bytes) -> None:
        try:
            await self._write(writer, payload)
        finally:
            writer.close()

    def _retry_after(self) -> int:
        """``Retry-After`` seconds: the supervisor's drain estimate
        (remaining budget × observed step latency), floored by the
        configured constant."""
        return max(self._retry_after_s, self._sup.retry_after_s())

    def _unavailable(self, reason: str) -> bytes:
        retry = self._retry_after()
        return _response(
            "503 Service Unavailable",
            _json_bytes({"error": reason, "retry_after_s": retry}),
            extra=(("Retry-After", str(retry)),))

    async def _route(self, method: str, path: str, headers: dict,
                     body: bytes,
                     reader: asyncio.StreamReader,
                     writer: asyncio.StreamWriter) -> None:
        if method == "GET" and path == "/healthz":
            await self._finish(writer, _response(
                "200 OK", _json_bytes({"ok": True})))
        elif method == "GET" and path == "/readyz":
            if self._sup.accepting:
                await self._finish(writer, _response(
                    "200 OK", _json_bytes({"ready": True})))
            else:
                reason = ("draining" if self._sup.draining
                          else "not accepting")
                await self._finish(writer, self._unavailable(reason))
        elif method == "GET" and path == "/metrics":
            # assembled under the supervisor lock off the event loop
            payload = await asyncio.to_thread(self._sup.metrics_payload)
            await self._finish(writer, _response(
                "200 OK", _json_bytes(payload)))
        elif method == "POST" and path == "/v1/generate":
            await self._generate(body, headers, reader, writer)
        elif method == "GET" and path.startswith("/v1/stream/"):
            await self._resume(path, headers, reader, writer)
        else:
            await self._finish(writer, _response(
                "404 Not Found", _json_bytes({"error": "no such route"})))

    # ------------------------------------------------------------------
    # The SSE stream
    # ------------------------------------------------------------------

    def _event_queue(self):
        """A bounded per-connection event queue plus the pump-thread →
        loop bridge callback."""
        loop = asyncio.get_running_loop()
        queue: asyncio.Queue = asyncio.Queue(maxsize=self._send_queue)
        overflow = asyncio.Event()

        def _enqueue(ev: StreamEvent) -> None:
            try:
                queue.put_nowait(ev)
            except asyncio.QueueFull:
                overflow.set()

        def on_event(ev: StreamEvent) -> None:
            # pump thread → loop; bounded queue is the backpressure
            loop.call_soon_threadsafe(_enqueue, ev)

        return queue, overflow, on_event

    @staticmethod
    def _parse_last_event_id(headers: dict) -> Optional[int]:
        """``Last-Event-ID: <rid>:<k>`` (or bare ``<k>``) → resume from
        absolute index ``k + 1``; None/garbage → replay from 0."""
        raw = headers.get("last-event-id", "").strip()
        if not raw:
            return None
        tail = raw.rsplit(":", 1)[-1]
        try:
            return int(tail) + 1
        except ValueError:
            return None

    async def _generate(self, body: bytes, headers: dict,
                        reader: asyncio.StreamReader,
                        writer: asyncio.StreamWriter) -> None:
        try:
            spec = json.loads(body.decode() or "{}")
            prompt = [int(t) for t in spec["prompt"]]
            resumable = bool(spec.get("resumable", False))
            kwargs = dict(
                max_new=int(spec.get("max_new", 32)),
                eos_id=(None if spec.get("eos_id") is None
                        else int(spec["eos_id"])),
                deadline_s=(None if spec.get("deadline_s") is None
                            else float(spec["deadline_s"])),
                priority=int(spec.get("priority", 0)),
                tenant=spec.get("tenant"),
            )
            if not prompt:
                raise ValueError("empty prompt")
        except (KeyError, TypeError, ValueError, json.JSONDecodeError) as e:
            await self._finish(writer, _response(
                "400 Bad Request", _json_bytes({"error": str(e)})))
            return
        idem_key = headers.get("idempotency-key") or None
        if not self._sup.accepting:
            # a duplicate of already-accepted work streams even while
            # draining (it is not new admission); everything else 503s
            known = await asyncio.to_thread(
                self._sup.idempotent_rid, idem_key)
            if known is None:
                await self._finish(writer, self._unavailable(
                    "draining" if self._sup.draining else "not accepting"))
                return

        queue, overflow, on_event = self._event_queue()
        # the supervisor lock can be held for a full engine step, so
        # submit from a worker thread instead of blocking the loop
        try:
            res = await asyncio.to_thread(
                self._sup.submit, prompt, on_event=on_event,
                idempotency_key=idem_key, **kwargs)
        except ValueError as e:
            await self._finish(writer, _response(
                "400 Bad Request", _json_bytes({"error": str(e)})))
            return
        if isinstance(res, Shed):
            if res.reason == "tenant-rate":
                await self._finish(writer, _response(
                    "429 Too Many Requests",
                    _json_bytes({"error": res.reason, "rid": res.rid}),
                    extra=(("Retry-After", str(self._retry_after())),)))
            else:        # "draining" | "queue-full"
                await self._finish(writer, self._unavailable(res.reason))
            return
        if isinstance(res, Duplicate):
            # idempotent retry: re-attach to the existing stream instead
            # of double-enqueueing; Last-Event-ID still dedups replay
            rid = res.rid
            from_index = self._parse_last_event_id(headers) or 0
            ok = await asyncio.to_thread(
                self._sup.attach, rid, on_event, from_index=from_index)
            if not ok:
                await self._finish(writer, _response(
                    "404 Not Found",
                    _json_bytes({"error": "unknown rid for key",
                                 "rid": rid})))
                return
            await self._stream_events(rid, queue, overflow, reader,
                                      writer, resumable=True,
                                      duplicate=True)
            return
        await self._stream_events(res, queue, overflow, reader, writer,
                                  resumable=resumable)

    async def _resume(self, path: str, headers: dict,
                      reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        """``GET /v1/stream/<rid>`` — the Last-Event-ID reconnect."""
        try:
            rid = int(path[len("/v1/stream/"):].split("?", 1)[0])
        except ValueError:
            await self._finish(writer, _response(
                "400 Bad Request", _json_bytes({"error": "bad rid"})))
            return
        from_index = self._parse_last_event_id(headers) or 0
        queue, overflow, on_event = self._event_queue()
        ok = await asyncio.to_thread(
            self._sup.attach, rid, on_event, from_index=from_index)
        if not ok:
            await self._finish(writer, _response(
                "404 Not Found",
                _json_bytes({"error": "unknown rid (never journaled, "
                             "or compacted away)", "rid": rid})))
            return
        await self._stream_events(rid, queue, overflow, reader, writer,
                                  resumable=True)

    async def _stream_events(self, rid: int, queue: "asyncio.Queue",
                             overflow: asyncio.Event,
                             reader: asyncio.StreamReader,
                             writer: asyncio.StreamWriter, *,
                             resumable: bool,
                             duplicate: bool = False) -> None:
        headers = (
            "HTTP/1.1 200 OK\r\n"
            "Content-Type: text/event-stream\r\n"
            "Cache-Control: no-store\r\n"
            "Connection: close\r\n"
            f"X-Request-Id: {rid}\r\n")
        if duplicate:
            headers += "X-Idempotent-Replay: 1\r\n"
        await self._write(writer, (headers + "\r\n").encode())

        def _gone() -> None:
            # a resumable client gets a reconnect grace window; a plain
            # disconnect propagates as an immediate cancel
            if resumable:
                self._sup.release(rid)
            else:
                self._sup.cancel(rid)

        # the request is fully read, so any data/EOF now means the
        # client went away
        eof_task = asyncio.ensure_future(reader.read(1))
        try:
            while True:
                get_task = asyncio.ensure_future(queue.get())
                done, _ = await asyncio.wait(
                    {get_task, eof_task},
                    return_when=asyncio.FIRST_COMPLETED)
                if eof_task in done:
                    get_task.cancel()
                    _gone()
                    break
                if overflow.is_set():
                    get_task.cancel()
                    _gone()
                    break
                ev = get_task.result()
                try:
                    await self._write(writer, self._frame(ev))
                except (ConnectionError, asyncio.TimeoutError, OSError):
                    # reset or write-timeout: same as a disconnect
                    _gone()
                    break
                if ev.kind == "done":
                    break
        finally:
            eof_task.cancel()
            writer.close()

    @staticmethod
    def _frame(ev: StreamEvent) -> bytes:
        if ev.kind == "token":
            eid = f"{ev.rid}:{ev.index}"
            data = {"i": ev.index, "token": ev.token,
                    "logprob": round(ev.logprob, 6)}
        else:
            eid = f"{ev.rid}:done"
            comp = ev.completion
            data = {"rid": ev.rid, "status": comp.status,
                    "reason": comp.reason,
                    "prompt_len": comp.prompt_len,
                    "n_tokens": int(comp.tokens.size),
                    "tokens": [int(t) for t in comp.tokens],
                    "ttft_s": round(float(comp.ttft_s), 6)}
        return (f"event: {ev.kind}\r\n"
                f"id: {eid}\r\n"
                f"data: {json.dumps(data, separators=(',', ':'))}"
                "\r\n\r\n").encode()
