"""Asyncio HTTP/SSE front door over the supervised scheduler.

Std-lib only: a hand-rolled HTTP/1.1 responder on
``asyncio.start_server`` — the endpoint surface is four routes
(docs/serving.md), not a framework's worth of them, and owning the
socket is what makes the robustness story testable: disconnects are
*observed* (EOF on the request socket), backpressure is a bounded
per-connection send queue, and a slow client hits an explicit write
timeout instead of wedging the pump.

Routes:

* ``GET /healthz`` — liveness: the process is up (200 always).
* ``GET /readyz`` — readiness: 200 while accepting, 503 +
  ``Retry-After`` once draining or stopped.
* ``GET /metrics`` — scheduler counters + supervisor recovery stats.
* ``POST /v1/generate`` — submit ``{"prompt": [ints], "max_new": n,
  "eos_id": …, "deadline_s": …, "priority": …, "tenant": …}``; the
  response is an SSE stream (``X-Request-Id`` header carries the rid):
  one ``event: token`` frame per generated token, then exactly one
  ``event: done`` frame with the terminal Completion.  Admission
  rejections map to HTTP: draining / queue-full → 503 + ``Retry-After``,
  tenant-rate → 429; malformed bodies → 400.

Threading model: the asyncio loop runs the sockets; the supervisor's
pump thread runs the engine and delivers :class:`StreamEvent` callbacks,
which hop onto the loop via ``call_soon_threadsafe`` into a bounded
``asyncio.Queue`` per connection.  ``submit`` happens in a worker thread
(``asyncio.to_thread``) because the supervisor lock can be held for a
whole engine step.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import threading
from typing import Optional, Tuple

from .scheduler import Shed
from .supervisor import StreamEvent, Supervisor

__all__ = ["SSEServer"]

_MAX_HEADER_BYTES = 16384
_MAX_BODY_BYTES = 1 << 20


def _json_bytes(obj) -> bytes:
    return json.dumps(obj, separators=(",", ":")).encode()


def _response(status: str, body: bytes,
              content_type: str = "application/json",
              extra: Tuple[Tuple[str, str], ...] = ()) -> bytes:
    head = [f"HTTP/1.1 {status}",
            f"Content-Type: {content_type}",
            f"Content-Length: {len(body)}",
            "Connection: close"]
    head += [f"{k}: {v}" for k, v in extra]
    return ("\r\n".join(head) + "\r\n\r\n").encode() + body


class SSEServer:
    """Serve a :class:`Supervisor` over HTTP/SSE (see module docstring).

    ``port=0`` binds an ephemeral port; read ``server.port`` after
    :meth:`start`.  ``send_queue`` bounds the per-connection event
    queue: a client that stops reading long enough to overflow it (or
    to trip ``write_timeout_s`` on a single write) is treated as
    disconnected and its request cancelled — backpressure never reaches
    the pump thread.
    """

    def __init__(self, supervisor: Supervisor, *,
                 host: str = "127.0.0.1", port: int = 0,
                 write_timeout_s: float = 10.0,
                 send_queue: int = 256,
                 retry_after_s: int = 1):
        self._sup = supervisor
        self.host = host
        self.port = int(port)
        self._write_timeout_s = float(write_timeout_s)
        self._send_queue = int(send_queue)
        self._retry_after_s = int(retry_after_s)
        self._server: Optional[asyncio.AbstractServer] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._started = threading.Event()
        self._drain_signals = 0
        self._conns: set = set()

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------

    async def start(self) -> "SSEServer":
        """Bind the listener on the current event loop."""
        self._loop = asyncio.get_running_loop()
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._started.set()
        return self

    async def aclose(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    def install_signal_handlers(self) -> None:
        """SIGINT/SIGTERM → graceful drain; a second signal → hard stop
        (CLI mode only; background/test servers skip this)."""
        import signal
        assert self._loop is not None
        for sig in (signal.SIGINT, signal.SIGTERM):
            self._loop.add_signal_handler(sig, self._on_signal)

    def _on_signal(self) -> None:
        self._drain_signals += 1
        if self._drain_signals == 1:
            # readiness flips to 503 immediately; in-flight work drains
            # on the pump thread, bounded by the watchdog budget
            asyncio.ensure_future(self._stop_when_idle())
        else:
            asyncio.get_event_loop().stop()

    async def _stop_when_idle(self) -> None:
        # begin_drain can contend on the supervisor lock (held across
        # whole engine steps) — keep that wait off the event loop so
        # health probes stay responsive throughout the drain
        await asyncio.to_thread(self._sup.begin_drain)
        await asyncio.to_thread(self._sup.wait_idle, 60.0)
        # the engine is idle but open streams may still hold queued
        # frames (the final tokens + done); let them flush before the
        # loop dies or the client sees EOF instead of a done event
        if self._conns:
            await asyncio.wait(set(self._conns),
                               timeout=self._write_timeout_s)
        await self.aclose()
        assert self._loop is not None
        self._loop.stop()

    def start_background(self) -> "SSEServer":
        """Run the loop + listener on a daemon thread (tests and the
        chaos benchmark); returns once the port is bound."""
        def _run() -> None:
            loop = asyncio.new_event_loop()
            self._loop = loop
            asyncio.set_event_loop(loop)
            loop.run_until_complete(self.start())
            loop.run_forever()
            loop.close()

        self._thread = threading.Thread(target=_run, name="sse-server",
                                        daemon=True)
        self._thread.start()
        if not self._started.wait(timeout=10.0):
            raise RuntimeError("SSE server failed to bind")
        return self

    def stop_background(self) -> None:
        loop, self._thread = self._loop, None
        if loop is None:
            return

        def _shutdown() -> None:
            task = asyncio.ensure_future(self.aclose())
            task.add_done_callback(lambda _: loop.stop())

        loop.call_soon_threadsafe(_shutdown)

    # ------------------------------------------------------------------
    # HTTP plumbing
    # ------------------------------------------------------------------

    async def _write(self, writer: asyncio.StreamWriter,
                     data: bytes) -> None:
        writer.write(data)
        await asyncio.wait_for(writer.drain(), self._write_timeout_s)

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        task = asyncio.current_task()
        self._conns.add(task)
        try:
            await self._handle_inner(reader, writer)
        finally:
            self._conns.discard(task)

    async def _handle_inner(self, reader: asyncio.StreamReader,
                            writer: asyncio.StreamWriter) -> None:
        try:
            head = await asyncio.wait_for(
                reader.readuntil(b"\r\n\r\n"), timeout=30.0)
        except (asyncio.IncompleteReadError, asyncio.TimeoutError,
                asyncio.LimitOverrunError, ConnectionError):
            writer.close()
            return
        if len(head) > _MAX_HEADER_BYTES:
            await self._finish(writer, _response(
                "431 Request Header Fields Too Large", b"{}"))
            return
        lines = head.decode("latin-1").split("\r\n")
        try:
            method, path, _ = lines[0].split(" ", 2)
        except ValueError:
            await self._finish(writer, _response("400 Bad Request", b"{}"))
            return
        headers = {}
        for ln in lines[1:]:
            if ":" in ln:
                k, v = ln.split(":", 1)
                headers[k.strip().lower()] = v.strip()
        body = b""
        clen = int(headers.get("content-length", 0) or 0)
        if clen:
            if clen > _MAX_BODY_BYTES:
                await self._finish(writer, _response(
                    "413 Payload Too Large", b"{}"))
                return
            try:
                body = await asyncio.wait_for(
                    reader.readexactly(clen), timeout=30.0)
            except (asyncio.IncompleteReadError, asyncio.TimeoutError):
                writer.close()
                return
        try:
            await self._route(method, path, body, reader, writer)
        except (ConnectionError, asyncio.TimeoutError):
            writer.close()
        except Exception:
            try:
                await self._finish(writer, _response(
                    "500 Internal Server Error", b"{}"))
            except Exception:
                writer.close()

    async def _finish(self, writer: asyncio.StreamWriter,
                      payload: bytes) -> None:
        try:
            await self._write(writer, payload)
        finally:
            writer.close()

    def _unavailable(self, reason: str) -> bytes:
        return _response(
            "503 Service Unavailable",
            _json_bytes({"error": reason,
                         "retry_after_s": self._retry_after_s}),
            extra=(("Retry-After", str(self._retry_after_s)),))

    async def _route(self, method: str, path: str, body: bytes,
                     reader: asyncio.StreamReader,
                     writer: asyncio.StreamWriter) -> None:
        if method == "GET" and path == "/healthz":
            await self._finish(writer, _response(
                "200 OK", _json_bytes({"ok": True})))
        elif method == "GET" and path == "/readyz":
            if self._sup.accepting:
                await self._finish(writer, _response(
                    "200 OK", _json_bytes({"ready": True})))
            else:
                reason = ("draining" if self._sup.draining
                          else "not accepting")
                await self._finish(writer, self._unavailable(reason))
        elif method == "GET" and path == "/metrics":
            sched = self._sup.scheduler
            payload = dataclasses.asdict(sched.metrics)
            payload.update(
                pending=sched.pending,
                draining=self._sup.draining,
                recoveries=self._sup.recoveries,
            )
            await self._finish(writer, _response(
                "200 OK", _json_bytes(payload)))
        elif method == "POST" and path == "/v1/generate":
            await self._generate(body, reader, writer)
        else:
            await self._finish(writer, _response(
                "404 Not Found", _json_bytes({"error": "no such route"})))

    # ------------------------------------------------------------------
    # The SSE stream
    # ------------------------------------------------------------------

    async def _generate(self, body: bytes,
                        reader: asyncio.StreamReader,
                        writer: asyncio.StreamWriter) -> None:
        try:
            spec = json.loads(body.decode() or "{}")
            prompt = [int(t) for t in spec["prompt"]]
            kwargs = dict(
                max_new=int(spec.get("max_new", 32)),
                eos_id=(None if spec.get("eos_id") is None
                        else int(spec["eos_id"])),
                deadline_s=(None if spec.get("deadline_s") is None
                            else float(spec["deadline_s"])),
                priority=int(spec.get("priority", 0)),
                tenant=spec.get("tenant"),
            )
            if not prompt:
                raise ValueError("empty prompt")
        except (KeyError, TypeError, ValueError, json.JSONDecodeError) as e:
            await self._finish(writer, _response(
                "400 Bad Request", _json_bytes({"error": str(e)})))
            return
        if not self._sup.accepting:
            await self._finish(writer, self._unavailable(
                "draining" if self._sup.draining else "not accepting"))
            return

        loop = asyncio.get_running_loop()
        queue: asyncio.Queue = asyncio.Queue(maxsize=self._send_queue)
        overflow = asyncio.Event()

        def _enqueue(ev: StreamEvent) -> None:
            try:
                queue.put_nowait(ev)
            except asyncio.QueueFull:
                overflow.set()

        def on_event(ev: StreamEvent) -> None:
            # pump thread → loop; bounded queue is the backpressure
            loop.call_soon_threadsafe(_enqueue, ev)

        # the supervisor lock can be held for a full engine step, so
        # submit from a worker thread instead of blocking the loop
        try:
            res = await asyncio.to_thread(
                self._sup.submit, prompt, on_event=on_event, **kwargs)
        except ValueError as e:
            await self._finish(writer, _response(
                "400 Bad Request", _json_bytes({"error": str(e)})))
            return
        if isinstance(res, Shed):
            if res.reason == "tenant-rate":
                await self._finish(writer, _response(
                    "429 Too Many Requests",
                    _json_bytes({"error": res.reason, "rid": res.rid}),
                    extra=(("Retry-After", str(self._retry_after_s)),)))
            else:        # "draining" | "queue-full"
                await self._finish(writer, self._unavailable(res.reason))
            return
        rid = res

        await self._write(writer, (
            "HTTP/1.1 200 OK\r\n"
            "Content-Type: text/event-stream\r\n"
            "Cache-Control: no-store\r\n"
            "Connection: close\r\n"
            f"X-Request-Id: {rid}\r\n\r\n").encode())

        # the request is fully read, so any data/EOF now means the
        # client went away → propagate as a cancel
        eof_task = asyncio.ensure_future(reader.read(1))
        try:
            while True:
                get_task = asyncio.ensure_future(queue.get())
                done, _ = await asyncio.wait(
                    {get_task, eof_task},
                    return_when=asyncio.FIRST_COMPLETED)
                if eof_task in done:
                    get_task.cancel()
                    self._sup.cancel(rid)
                    break
                if overflow.is_set():
                    get_task.cancel()
                    self._sup.cancel(rid)
                    break
                ev = get_task.result()
                try:
                    await self._write(writer, self._frame(ev))
                except (ConnectionError, asyncio.TimeoutError, OSError):
                    # reset or write-timeout: same as a disconnect
                    self._sup.cancel(rid)
                    break
                if ev.kind == "done":
                    break
        finally:
            eof_task.cancel()
            writer.close()

    @staticmethod
    def _frame(ev: StreamEvent) -> bytes:
        if ev.kind == "token":
            data = {"i": ev.index, "token": ev.token,
                    "logprob": round(ev.logprob, 6)}
        else:
            comp = ev.completion
            data = {"rid": ev.rid, "status": comp.status,
                    "reason": comp.reason,
                    "prompt_len": comp.prompt_len,
                    "n_tokens": int(comp.tokens.size),
                    "tokens": [int(t) for t in comp.tokens],
                    "ttft_s": round(float(comp.ttft_s), 6)}
        return (f"event: {ev.kind}\r\n"
                f"data: {json.dumps(data, separators=(',', ':'))}"
                "\r\n\r\n").encode()
