"""Batched serving engine: prefill + decode loop with sampling.

Jit'd programs with the KV cache *donated* between them:

* ``_prefill_program`` — full-sequence forward that fills the cache and
  samples the first token.  Keyed on ``(batch, prompt_len, cache_len)``
  only, so sweeping ``max_new`` (e.g. static-wave baselines with
  per-wave lengths) re-uses one compiled prefill.
* ``_chunk_prefill_program`` — the prefill-from-cache split
  (``generate(chunk=...)``): the prompt fills the cache in chunk-sized
  pieces against the already-written positions, keyed on the *chunk*
  shape, so sweeping prompt lengths re-uses one program per chunk size.
  Bitwise-identical outputs to the monolithic path (DESIGN.md §5
  "chunked prefill").
* ``_decode_program`` — ``lax.scan`` over the generated positions, so
  the whole decode loop is a single XLA program with no host round-trip
  per token.  The cache argument is donated (``donate_argnums``): the
  prefill's output buffers are reused in place instead of being copied
  when the scan's first cache update would otherwise force a fresh
  allocation while the caller still holds the reference.

Per-token logprobs gather the sampled logit and subtract a logsumexp —
never materializing a full-vocab ``log_softmax`` per step just to read
one column.

Works with dense or CREW-converted params interchangeably (linear.apply
dispatches on the weight leaf type) — the quickstart example serves both
and diffs the outputs token-by-token.

The default ``crew_strategy="auto"`` resolves per apply shape at trace
time via the repro.perf autotune store (measured winners, analytical prior
on a cold cache); run ``serve.convert.autotune_crew_params`` on the
converted tree before the first ``generate`` to warm it.

This is the *one-shot* path: every request in the batch shares one prompt
length and one ``max_new``.  Mixed traffic belongs on
``serve.scheduler.Scheduler`` (continuous batching, DESIGN.md §5), which
reuses the same prefill/decode model surface and yields token-identical
greedy outputs; docs/serving.md compares the two.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..models import ModelApi

__all__ = ["Engine", "generate"]


def _sample(key, logits, temperature: float):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


def _sampled_logprob(logits: jnp.ndarray, tok: jnp.ndarray) -> jnp.ndarray:
    """log p(tok) from [B, vocab] logits without a full-vocab log_softmax:
    one gather + one logsumexp reduction (log_softmax materializes — and
    XLA keeps live — a [B, vocab] f32 tensor per step just to read one
    column per lane)."""
    picked = jnp.take_along_axis(logits, tok[:, None], axis=-1)[:, 0]
    return picked - jax.scipy.special.logsumexp(logits, axis=-1)


@functools.partial(
    jax.jit,
    static_argnames=("api", "cache_len", "temperature", "crew_strategy"),
)
def _prefill_program(api: ModelApi, params, prompts, key, cache_len: int,
                     temperature: float, crew_strategy: str):
    logits, cache = api.prefill(params, {"tokens": prompts}, cache_len,
                                crew_strategy=crew_strategy)
    first = _sample(key, logits[:, -1], temperature)
    return first, cache


@functools.partial(
    jax.jit,
    static_argnames=("api", "temperature", "crew_strategy"),
    donate_argnums=(2,),  # the partially filled KV cache
)
def _chunk_prefill_program(api: ModelApi, params, cache, tokens, key,
                           true_c, temperature: float, crew_strategy: str):
    """One prefill chunk against prior cache — the prefill-from-cache
    split of ``_prefill_program``: keyed on the *chunk* shape only, so
    sweeping prompt lengths reuses one compiled program per chunk size
    instead of one monolithic prefill per prompt length.  ``true_c`` is
    the chunk's unpadded length (traced; padded tail rows are dead).
    Returns the token sampled at the chunk's last true position — read
    by the caller only for the final chunk."""
    logits, cache = api.prefill_chunk(params, tokens, cache,
                                      crew_strategy=crew_strategy)
    last = jax.lax.dynamic_index_in_dim(logits, true_c - 1, axis=1,
                                        keepdims=False)
    first = _sample(key, last, temperature)
    return first, cache


def _chunked_prefill(api, params, prompts, key, cache_len: int, chunk: int,
                     temperature: float, crew_strategy: str):
    """Fill a fresh cache chunk-by-chunk; bitwise-identical to the
    monolithic prefill (tests/test_serve.py pins the token parity)."""
    b, s = prompts.shape
    cache = api.init_cache(b, cache_len)
    s_pad = -(-s // chunk) * chunk
    padded = jnp.pad(prompts, ((0, 0), (0, s_pad - s)))
    first = None
    for pos in range(0, s, chunk):
        true_c = min(chunk, s - pos)
        first, cache = _chunk_prefill_program(
            api, params, cache, jax.lax.dynamic_slice_in_dim(
                padded, pos, chunk, axis=1),
            key, jnp.asarray(true_c, jnp.int32), temperature, crew_strategy)
    # padded tail rows advanced ``len`` past the prompt; decode must
    # continue from the true length (the overshoot rows are dead)
    cache = {**cache, "len": jnp.asarray(s, jnp.int32)}
    return first, cache


@functools.partial(
    jax.jit,
    static_argnames=("api", "temperature", "crew_strategy"),
    donate_argnums=(2,),  # the prefill-filled KV cache
)
def _decode_program(api: ModelApi, params, cache, first, keys,
                    temperature: float, crew_strategy: str):
    def step(carry, key):
        tok, cache = carry
        logits, cache = api.decode_step(params, tok[:, None], cache,
                                        crew_strategy=crew_strategy)
        nxt = _sample(key, logits, temperature)
        return (nxt, cache), (nxt, _sampled_logprob(logits, nxt))

    (_, cache), (toks, lps) = jax.lax.scan(step, (first, cache), keys)
    # the final cache is returned (and discarded by generate) so the
    # donated input cache has an output to alias — without it XLA has
    # nothing to wire the donation to and the buffers copy.
    return toks, lps, cache


def generate(
    api: ModelApi,
    params,
    prompts: jnp.ndarray,
    *,
    max_new: int = 32,
    cache_len: Optional[int] = None,
    temperature: float = 0.0,
    rng: Optional[jnp.ndarray] = None,
    crew_strategy: str = "auto",
    chunk: Optional[int] = None,
    decode_state: str = "auto",
) -> Dict[str, jnp.ndarray]:
    """prompts [B, S] int32 -> {"tokens": [B, max_new], "logprobs": ...}.

    ``chunk`` switches the prefill to the prefill-from-cache split: the
    prompt fills the cache in ``chunk``-sized pieces through one program
    keyed on the chunk shape (not the prompt length), with the cache
    donated between pieces.  Outputs are bitwise-identical to the
    monolithic default — use it when sweeping many prompt lengths, where
    the monolithic path compiles one prefill per length.

    ``decode_state="auto"`` resolves the CREW decode product-buffer
    state tree for this batch from the warmed autotune store
    (``serve.decode_state_for_params``) and attaches it to the cache: the
    decode scan then carries the VMEM-resident partial-product buffers
    across all ``max_new`` steps inside the donated cache.  A cold store
    (or dense params, or ``"off"``) resolves to no state — the
    historical stateless decode program, bit for bit.
    """
    if chunk is not None and chunk < 1:
        raise ValueError("chunk must be >= 1")
    if decode_state not in ("auto", "off"):
        raise ValueError('decode_state must be "auto" or "off"')
    b, s = prompts.shape
    cache_len = cache_len or (s + max_new)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    # One split up front: key 0 samples the first token, keys 1..max_new-1
    # drive the scan.  (Never reuse `rng` itself after splitting — the old
    # code consumed it in _sample and then re-split it for the scan keys.)
    keys = jax.random.split(rng, max_new)

    if chunk is None:
        first, cache = _prefill_program(api, params, prompts, keys[0],
                                        cache_len, temperature, crew_strategy)
    else:
        first, cache = _chunked_prefill(api, params, prompts, keys[0],
                                        cache_len, int(chunk), temperature,
                                        crew_strategy)
    if decode_state == "auto":
        from .convert import decode_state_for_params
        state = decode_state_for_params(params, b)
        if state is not None:
            cache = {**cache, "crew": state}
    toks, lps, _ = _decode_program(api, params, cache, first, keys[1:],
                                   temperature, crew_strategy)
    tokens = jnp.concatenate([first[None], toks], axis=0).T  # [B, max_new]
    return {"tokens": tokens, "logprobs": lps.T}


class Engine:
    """Stable one-shot serving facade (``repro.serve.Engine``).

    Binds ``(api, params)`` and the static sampling/dispatch knobs once;
    each :meth:`generate` call is the module-level :func:`generate` with
    those bindings.  Dense and CREW-converted params are interchangeable
    (``layers.linear.apply`` dispatches on the weight leaf type), and the
    same instance can serve any batch/prompt shape — programs are cached
    per shape by jit.  Mixed traffic with admission/retirement belongs on
    :class:`~repro.serve.Scheduler`; docs/serving.md compares the two.
    """

    def __init__(self, api: ModelApi, params, *, temperature: float = 0.0,
                 crew_strategy: str = "auto", decode_state: str = "auto"):
        if decode_state not in ("auto", "off"):
            raise ValueError('decode_state must be "auto" or "off"')
        self.api = api
        self.params = params
        self.temperature = float(temperature)
        self.crew_strategy = crew_strategy
        self.decode_state = decode_state

    def generate(self, prompts: jnp.ndarray, *, max_new: int = 32,
                 cache_len: Optional[int] = None,
                 rng: Optional[jnp.ndarray] = None,
                 chunk: Optional[int] = None) -> Dict[str, jnp.ndarray]:
        """prompts [B, S] int32 -> {"tokens", "logprobs"} (see
        :func:`generate`)."""
        return generate(self.api, self.params, prompts, max_new=max_new,
                        cache_len=cache_len, temperature=self.temperature,
                        rng=rng, crew_strategy=self.crew_strategy,
                        chunk=chunk, decode_state=self.decode_state)
