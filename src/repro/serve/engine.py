"""Batched serving engine: prefill + decode loop with sampling.

One jit'd prefill and one jit'd decode step per (batch, prompt_len,
cache_len) bucket; the decode loop runs as ``lax.scan`` over generated
positions so the whole generation is a single XLA program.  Works with
dense or CREW-converted params interchangeably (linear.apply dispatches on
the weight leaf type) — the quickstart example serves both and diffs the
outputs token-by-token.

The default ``crew_strategy="auto"`` resolves per apply shape at trace
time via the repro.perf autotune store (measured winners, analytical prior
on a cold cache); run ``serve.convert.autotune_crew_params`` on the
converted tree before the first ``generate`` to warm it.

This is the *one-shot* path: every request in the batch shares one prompt
length and one ``max_new``.  Mixed traffic belongs on
``serve.scheduler.Scheduler`` (continuous batching, DESIGN.md §5), which
reuses the same prefill/decode model surface and yields token-identical
greedy outputs; docs/serving.md compares the two.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..models import ModelApi

__all__ = ["generate"]


def _sample(key, logits, temperature: float):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("api", "max_new", "cache_len", "temperature",
                     "crew_strategy"),
)
def generate(
    api: ModelApi,
    params,
    prompts: jnp.ndarray,
    *,
    max_new: int = 32,
    cache_len: Optional[int] = None,
    temperature: float = 0.0,
    rng: Optional[jnp.ndarray] = None,
    crew_strategy: str = "auto",
) -> Dict[str, jnp.ndarray]:
    """prompts [B, S] int32 -> {"tokens": [B, max_new], "logprobs": ...}."""
    b, s = prompts.shape
    cache_len = cache_len or (s + max_new)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    # One split up front: key 0 samples the first token, keys 1..max_new-1
    # drive the scan.  (Never reuse `rng` itself after splitting — the old
    # code consumed it in _sample and then re-split it for the scan keys.)
    keys = jax.random.split(rng, max_new)

    logits, cache = api.prefill(params, {"tokens": prompts}, cache_len,
                                crew_strategy=crew_strategy)
    first = _sample(keys[0], logits[:, -1], temperature)

    def step(carry, key):
        tok, cache = carry
        logits, cache = api.decode_step(params, tok[:, None], cache,
                                        crew_strategy=crew_strategy)
        nxt = _sample(key, logits, temperature)
        lp = jax.nn.log_softmax(logits, axis=-1)
        lp_tok = jnp.take_along_axis(lp, nxt[:, None], axis=-1)[:, 0]
        return (nxt, cache), (nxt, lp_tok)

    (_, _), (toks, lps) = jax.lax.scan(step, (first, cache), keys[1:])
    tokens = jnp.concatenate([first[None], toks], axis=0).T  # [B, max_new]
    return {"tokens": tokens, "logprobs": lps.T}
