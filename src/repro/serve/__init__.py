"""Serving: CREW checkpoint conversion, one-shot generate engine, and the
continuous-batching scheduler (docs/serving.md walks the full path)."""
from .convert import (crewize_params, abstract_crew_params,
                      autotune_crew_params, crewize_spec, CrewReport)
from .engine import generate
from .prefix import PrefixTrie
from .scheduler import Scheduler, SchedulerMetrics, Request, Completion

__all__ = ["crewize_params", "abstract_crew_params", "autotune_crew_params",
           "crewize_spec", "CrewReport", "generate", "PrefixTrie",
           "Scheduler", "SchedulerMetrics", "Request", "Completion"]
