"""Serving: CREW checkpoint conversion, one-shot generate engine, and the
continuous-batching scheduler (docs/serving.md walks the full path)."""
from .convert import (crewize_params, abstract_crew_params,
                      autotune_crew_params, crewize_spec, CrewReport)
from .engine import generate
from .scheduler import Scheduler, Request, Completion

__all__ = ["crewize_params", "abstract_crew_params", "autotune_crew_params",
           "crewize_spec", "CrewReport", "generate",
           "Scheduler", "Request", "Completion"]
