"""Serving: CREW checkpoint conversion + batched generate engine."""
from .convert import (crewize_params, abstract_crew_params,
                      autotune_crew_params, crewize_spec, CrewReport)
from .engine import generate

__all__ = ["crewize_params", "abstract_crew_params", "autotune_crew_params",
           "crewize_spec", "CrewReport", "generate"]
