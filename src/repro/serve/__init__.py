"""repro.serve — the stable serving surface (docs/api.md).

Two entry points generate tokens:

* :class:`Engine` / :func:`generate` — one-shot batched serving: every
  request shares one prompt length and one ``max_new``.
* :class:`Scheduler` — continuous batching over mixed traffic
  (``submit`` requests, ``step``/``run`` the engine loop, read
  :class:`SchedulerMetrics` / :class:`Completion` results), with the
  radix-tree prefix cache (:class:`PrefixTrie`) underneath.  Requests
  walk an explicit lifecycle (:class:`RequestState`): they can carry
  deadlines and priorities, be cancelled (``Scheduler.cancel``), be
  preempted to the prefix pool and resumed, or be shed at admission
  (typed :class:`Shed` return) — every rid ends in exactly one terminal
  :class:`Completion`.  ``run()`` is watchdogged
  (:class:`SchedulerStalledError`), and :class:`FaultInjector`
  (``serve.faults``) drives every recovery path deterministically from
  a seed.

Checkpoint preparation: :func:`crewize_params` converts a dense tree to
CREW, :func:`autotune_crew_params` warms the measured-dispatch store
(including the decode-shaped keys), :func:`cache_decode_weights` /
:func:`decode_state_for_params` materialize the decode-time weight and
product-buffer residency those measurements select.

Everything in ``__all__`` is covered by the deprecation policy (one
release of DeprecationWarning before a breaking change); other names are
internal.  docs/serving.md walks the full path.
"""
from .convert import (
    CrewReport,
    abstract_crew_params,
    autotune_crew_params,
    cache_decode_weights,
    crewize_params,
    crewize_spec,
    decode_state_for_params,
)
from .engine import Engine, generate
from .faults import FaultInjector
from .journal import Journal, JournalReplay, RequestLog
from .pool import BlockPool
from .prefix import PrefixTrie
from .scheduler import (
    Completion,
    Request,
    RequestSnapshot,
    RequestState,
    Scheduler,
    SchedulerMetrics,
    SchedulerSnapshot,
    SchedulerStalledError,
    Shed,
)
from .server import SSEServer
from .supervisor import Duplicate, StreamEvent, Supervisor

__all__ = [
    # engines
    "Engine",
    "generate",
    "Scheduler",
    "SchedulerMetrics",
    "Request",
    "Completion",
    # request lifecycle
    "RequestState",
    "Shed",
    "SchedulerStalledError",
    "FaultInjector",
    # supervision + wire protocol (DESIGN.md §5)
    "Supervisor",
    "StreamEvent",
    "Duplicate",
    "SSEServer",
    "RequestSnapshot",
    "SchedulerSnapshot",
    # durability (DESIGN.md §5.1)
    "Journal",
    "JournalReplay",
    "RequestLog",
    # checkpoint preparation
    "crewize_params",
    "abstract_crew_params",
    "crewize_spec",
    "CrewReport",
    "autotune_crew_params",
    "cache_decode_weights",
    "decode_state_for_params",
    # paged KV substrate
    "BlockPool",
    "PrefixTrie",
]
