"""Reference-counted KV block pool — the single owner of every block id.

The paged-KV substrate (DESIGN.md §5): all KV lives in one pool tensor
and every consumer — the prefix trie, live slot block tables, parked
(preempted) requests — holds *references* to pool blocks instead of
copies.  This module is the pure host-side accounting half; the device
tensors indexed by these ids live in ``serve.scheduler``.

Ownership model (the conservation law the property harness pins):

* each trie node holds exactly one reference to its block;
* each entry of a live slot's block table holds one reference;
* each parked pin of a preempted request holds one reference;
* a block is on the free list iff its refcount is zero.

So ``refcount(b) == 1`` means "cached prefix only, no live reader" —
the predicate that makes a trie leaf evictable.  Blocks shared between
a cached prefix and a decoding slot carry refcount >= 2 and can never
be freed out from under the reader.

The free list is popped from the *end* (LIFO): freshly freed blocks are
reused first, which keeps id allocation order identical to the pre-paged
trie-owned free list so eviction-order tests stay byte-stable.
"""
from __future__ import annotations

from typing import List

__all__ = ["BlockPool"]


class BlockPool:
    """Refcounted allocator over ``n_blocks`` abstract block ids."""

    def __init__(self, n_blocks: int):
        if n_blocks < 1:
            raise ValueError("need at least one pool block")
        self.n_blocks = int(n_blocks)
        self._free: List[int] = list(range(n_blocks))
        self._refs: List[int] = [0] * n_blocks

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def refcount(self, bid: int) -> int:
        return self._refs[bid]

    def alloc(self) -> int | None:
        """Pop a free block with refcount 1, or None when exhausted."""
        if not self._free:
            return None
        bid = self._free.pop()
        assert self._refs[bid] == 0, f"free block {bid} had refs"
        self._refs[bid] = 1
        return bid

    def ref(self, bid: int) -> None:
        """Add one reference to a live block."""
        assert self._refs[bid] > 0, f"ref on free block {bid}"
        self._refs[bid] += 1

    def deref(self, bid: int) -> None:
        """Drop one reference; the block returns to the free list at zero."""
        assert self._refs[bid] > 0, f"deref on free block {bid}"
        self._refs[bid] -= 1
        if self._refs[bid] == 0:
            self._free.append(bid)

    def check_invariants(self) -> List[str]:
        """Accounting audit -> list of violations (empty = healthy)."""
        errs: List[str] = []
        free = set(self._free)
        if len(free) != len(self._free):
            errs.append("duplicate ids on the free list")
        for bid in range(self.n_blocks):
            if self._refs[bid] < 0:
                errs.append(f"block {bid}: negative refcount")
            if (self._refs[bid] == 0) != (bid in free):
                errs.append(
                    f"block {bid}: refcount {self._refs[bid]} but "
                    f"{'on' if bid in free else 'not on'} the free list")
        return errs
