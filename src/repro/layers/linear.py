"""Linear layer — dense or CREW-backed.

The weight leaf is either a jnp array [N, M] (training / dense serving) or
a ``CrewMatrixUniform`` (serving after ``repro.serve.convert`` CREW-izes the
checkpoint).  ``apply`` dispatches on the leaf type so every model in the
framework gets CREW support for free.

``apply(..., activation=...)`` fuses the layer's bias and activation into
the matmul (DESIGN.md §3 "epilogue fusion"): on the CREW Pallas paths the
epilogue runs on the VMEM-resident output block, so an FC layer is one
kernel instead of kernel + bias-add + activation.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.convert import CrewMatrixUniform, CrewMatrixVar
from ..kernels.crew_matmul import EPILOGUE_ACTIVATIONS
from ..kernels.ops import crew_matmul

__all__ = ["init", "spec", "apply"]


def init(rng, n_in: int, n_out: int, *, bias: bool = False,
         dtype=jnp.float32, scale: Optional[float] = None,
         stack: Sequence[int] = ()):
    """Create params {"w": [*stack, N, M], ("b": [*stack, M])}.

    ``stack`` prepends scan axes (e.g. (L,) for a scanned layer stack).
    """
    if scale is None:
        scale = n_in ** -0.5
    k_w, _ = jax.random.split(rng)
    w = jax.random.normal(k_w, (*stack, n_in, n_out), dtype=jnp.float32) * scale
    params = {"w": w.astype(dtype)}
    if bias:
        params["b"] = jnp.zeros((*stack, n_out), dtype=dtype)
    return params


def spec(in_axis: Optional[str], out_axis: Optional[str], *, bias: bool = False,
         stack_axes: Sequence[Optional[str]] = ()):
    s = {"w": P(*stack_axes, in_axis, out_axis)}
    if bias:
        s["b"] = P(*stack_axes, out_axis)
    return s


def crew_spec(in_axis: Optional[str], out_axis: Optional[str], *, bias: bool = False,
              stack_axes: Sequence[Optional[str]] = ()):
    """Spec tree for a CREW-converted weight: packed words shard like the
    [N, M] weight (word dim follows M because packing is per-row and
    word-aligned); unique tables shard on N only and replicate across the
    TP axis (small)."""
    s = {
        "w": CrewMatrixUniform(
            words=P(*stack_axes, in_axis, out_axis),
            uniq=P(*stack_axes, in_axis, None),
            width=0,   # static fields ignored by sharding code
            n_out=0,
        )
    }
    if bias:
        s["b"] = P(*stack_axes, out_axis)
    return s


def apply(params, x: jnp.ndarray, *, crew_strategy: str = "auto",
          activation: Optional[str] = None) -> jnp.ndarray:
    w = params["w"]
    if isinstance(w, (CrewMatrixUniform, CrewMatrixVar)):
        return crew_matmul(x, w, strategy=crew_strategy,
                           bias=params.get("b"), activation=activation)
    y = x @ w.astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    if activation is not None:
        y = EPILOGUE_ACTIVATIONS[activation](y)
    return y
