"""Linear layer — dense or CREW-backed.

The weight leaf is either a jnp array [N, M] (training / dense serving) or
a ``CrewMatrixUniform`` (serving after ``repro.serve.convert`` CREW-izes the
checkpoint).  ``apply`` dispatches on the leaf type so every model in the
framework gets CREW support for free.

``apply(..., plan=CrewPlan(..., activation=...))`` fuses the layer's bias
and activation into the matmul (DESIGN.md §3 "epilogue fusion"): on the
CREW Pallas paths the epilogue runs on the VMEM-resident output block, so
an FC layer is one kernel instead of kernel + bias-add + activation.
The pre-CrewPlan kwargs (``crew_strategy=``, ``activation=``) still work
for one release behind a DeprecationWarning (docs/api.md).

``apply(..., state=...)`` threads the decode product-buffer state
(DESIGN.md §3): ``state`` mirrors the params dict ({"w": {"pbuf": ...}})
and switches the CREW apply onto the VMEM-resident decode kernel; the
call then returns ``(y, new_state)`` for the caller's scan carry.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.convert import CrewMatrixCached, CrewMatrixUniform, CrewMatrixVar
from ..kernels.crew_matmul import EPILOGUE_ACTIVATIONS
from ..kernels.ops import crew_matmul, crew_matmul_decode
from ..kernels.plan import CrewPlan, warn_deprecated

__all__ = ["init", "spec", "apply", "apply_with_state"]


def init(rng, n_in: int, n_out: int, *, bias: bool = False,
         dtype=jnp.float32, scale: Optional[float] = None,
         stack: Sequence[int] = ()):
    """Create params {"w": [*stack, N, M], ("b": [*stack, M])}.

    ``stack`` prepends scan axes (e.g. (L,) for a scanned layer stack).
    """
    if scale is None:
        scale = n_in ** -0.5
    k_w, _ = jax.random.split(rng)
    w = jax.random.normal(k_w, (*stack, n_in, n_out), dtype=jnp.float32) * scale
    params = {"w": w.astype(dtype)}
    if bias:
        params["b"] = jnp.zeros((*stack, n_out), dtype=dtype)
    return params


def spec(in_axis: Optional[str], out_axis: Optional[str], *, bias: bool = False,
         stack_axes: Sequence[Optional[str]] = ()):
    s = {"w": P(*stack_axes, in_axis, out_axis)}
    if bias:
        s["b"] = P(*stack_axes, out_axis)
    return s


def crew_spec(in_axis: Optional[str], out_axis: Optional[str], *, bias: bool = False,
              stack_axes: Sequence[Optional[str]] = ()):
    """Spec tree for a CREW-converted weight: packed words shard like the
    [N, M] weight (word dim follows M because packing is per-row and
    word-aligned); unique tables shard on N only and replicate across the
    TP axis (small)."""
    s = {
        "w": CrewMatrixUniform(
            words=P(*stack_axes, in_axis, out_axis),
            uniq=P(*stack_axes, in_axis, None),
            width=0,   # static fields ignored by sharding code
            n_out=0,
        )
    }
    if bias:
        s["b"] = P(*stack_axes, out_axis)
    return s


def apply(params, x: jnp.ndarray, *, plan=None, state=None,
          crew_strategy: Optional[str] = None,
          activation: Optional[str] = None):
    """Apply the layer.  ``plan`` is a CrewPlan / strategy string / None;
    its ``activation`` is the fused epilogue (also applied on the dense
    path).  With ``state`` (the decode product-buffer mirror,
    ``{"w": {"pbuf": ...}}``) the return value is ``(y, new_state)``;
    stateless calls return ``y`` alone.  ``crew_strategy=`` /
    ``activation=`` are the deprecated pre-CrewPlan spellings."""
    if crew_strategy is not None:
        warn_deprecated(
            "linear.apply:crew_strategy",
            "linear.apply(crew_strategy=...) is deprecated; pass "
            "plan=CrewPlan(strategy=...) — see docs/api.md", stacklevel=3)
        if plan is None:
            plan = CrewPlan.of(crew_strategy)
    plan = CrewPlan.of(plan)
    if activation is not None:
        warn_deprecated(
            "linear.apply:activation",
            "linear.apply(activation=...) is deprecated; fold it into the "
            "plan (CrewPlan(..., activation=...)) — see docs/api.md",
            stacklevel=3)
        plan = plan.with_activation(activation)

    w = params["w"]
    leaf_state = None if state is None else state.get("w")
    if isinstance(w, (CrewMatrixUniform, CrewMatrixCached)) \
            and leaf_state is not None:
        y, new_leaf = crew_matmul_decode(x, w, leaf_state, plan=plan,
                                         bias=params.get("b"))
        return y, {**state, "w": new_leaf}
    if isinstance(w, (CrewMatrixUniform, CrewMatrixCached, CrewMatrixVar)):
        y = crew_matmul(x, w, plan, bias=params.get("b"))
    else:
        y = x @ w.astype(x.dtype)
        if "b" in params:
            y = y + params["b"].astype(y.dtype)
        if plan.activation is not None:
            y = EPILOGUE_ACTIVATIONS[plan.activation](y)
    if state is not None:
        return y, state
    return y


def apply_with_state(params, x: jnp.ndarray, *, plan=None, state=None):
    """Uniform-arity helper for scan bodies: always returns
    ``(y, new_state)`` (``new_state`` is None / the unchanged mirror when
    the layer carries no product buffer)."""
    out = apply(params, x, plan=plan, state=state)
    if state is None:
        return out, None
    return out
