"""Classic LSTM / GRU cells and stacks — the paper's RNN workloads.

DS2 (GRU), GNMT (LSTM), PTBLM (LSTM) and the Kaldi MLP are built from
these.  The gate projections are plain FC matrices, i.e. exactly the
layers CREW targets; ``gate_matrices()`` exposes them for the offline
CREW analysis/benchmarks.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import linear

__all__ = [
    "lstm_init", "lstm_spec", "lstm_apply",
    "gru_init", "gru_spec", "gru_apply",
    "gate_matrices",
]


def lstm_init(rng, d_in: int, d_hidden: int, *, dtype=jnp.float32, stack=()):
    ks = jax.random.split(rng, 2)
    return {
        "wx": linear.init(ks[0], d_in, 4 * d_hidden, bias=True, dtype=dtype, stack=stack),
        "wh": linear.init(ks[1], d_hidden, 4 * d_hidden, dtype=dtype, stack=stack),
    }


def lstm_spec(stack_axes=()):
    return {
        "wx": linear.spec("embed", "heads", bias=True, stack_axes=stack_axes),
        "wh": linear.spec("embed", "heads", stack_axes=stack_axes),
    }


def _hidden_dim(wh):
    """Hidden width from the recurrent weight — dense array or CREW leaf."""
    w = wh["w"]
    if hasattr(w, "shape"):
        return w.shape[-2]
    return w.uniq.shape[-2]  # CrewMatrixUniform: [N, K] unique table


def lstm_apply(params, x, state=None):
    """x [B, S, d_in] -> ([B, S, d_hidden], (h, c))."""
    b, s, _ = x.shape
    dh = _hidden_dim(params["wh"])
    if state is None:
        state = (jnp.zeros((b, dh), x.dtype), jnp.zeros((b, dh), x.dtype))
    wx = linear.apply(params["wx"], x)  # [B, S, 4dh]

    def step(carry, wx_t):
        h, c = carry
        pre = wx_t + linear.apply(params["wh"], h)
        i, f, g, o = jnp.split(pre, 4, axis=-1)
        c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
        return (h_new, c_new), h_new

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    return jnp.moveaxis(hs, 0, 1), state


def gru_init(rng, d_in: int, d_hidden: int, *, dtype=jnp.float32, stack=()):
    ks = jax.random.split(rng, 2)
    return {
        "wx": linear.init(ks[0], d_in, 3 * d_hidden, bias=True, dtype=dtype, stack=stack),
        "wh": linear.init(ks[1], d_hidden, 3 * d_hidden, dtype=dtype, stack=stack),
    }


def gru_spec(stack_axes=()):
    return {
        "wx": linear.spec("embed", "heads", bias=True, stack_axes=stack_axes),
        "wh": linear.spec("embed", "heads", stack_axes=stack_axes),
    }


def gru_apply(params, x, state=None):
    """x [B, S, d_in] -> ([B, S, d_hidden], h)."""
    b, s, _ = x.shape
    dh = _hidden_dim(params["wh"])
    if state is None:
        state = jnp.zeros((b, dh), x.dtype)
    wx = linear.apply(params["wx"], x)

    def step(h, wx_t):
        xr, xz, xn = jnp.split(wx_t, 3, axis=-1)
        hr, hz, hn = jnp.split(linear.apply(params["wh"], h), 3, axis=-1)
        r = jax.nn.sigmoid(xr + hr)
        z = jax.nn.sigmoid(xz + hz)
        n = jnp.tanh(xn + r * hn)
        h_new = (1 - z) * n + z * h
        return h_new, h_new

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    return jnp.moveaxis(hs, 0, 1), state


def gate_matrices(params: Dict) -> List[Tuple[str, jnp.ndarray]]:
    """Collect every FC weight matrix in a (possibly nested) param tree —
    the offline CREW analysis input."""
    out = []

    def rec(prefix, node):
        if isinstance(node, dict):
            if "w" in node and hasattr(node["w"], "ndim") and node["w"].ndim == 2:
                out.append((prefix, node["w"]))
            for k, v in node.items():
                if k != "w":
                    rec(f"{prefix}/{k}", v)
        elif hasattr(node, "ndim") and node.ndim == 2:
            out.append((prefix, node))

    rec("", params)
    return out
