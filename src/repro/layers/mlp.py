"""Feed-forward blocks: SwiGLU (llama family) and GELU (encoder family).

Activations ride the linear layers' fused epilogue (DESIGN.md §3): the
gate/up projection emits its activation from the same kernel that does
the matmul, so a CREW-served FFN never round-trips the [.., d_ff] hidden
state through HBM between matmul and nonlinearity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import linear
from ..kernels.plan import CrewPlan

__all__ = ["swiglu_init", "swiglu_spec", "swiglu_apply",
           "gelu_init", "gelu_spec", "gelu_apply"]


def swiglu_init(rng, d_model: int, d_ff: int, *, dtype=jnp.float32, stack=()):
    ks = jax.random.split(rng, 3)
    return {
        "gate": linear.init(ks[0], d_model, d_ff, dtype=dtype, stack=stack),
        "up": linear.init(ks[1], d_model, d_ff, dtype=dtype, stack=stack),
        "down": linear.init(ks[2], d_ff, d_model, dtype=dtype,
                            scale=d_ff ** -0.5, stack=stack),
    }


def swiglu_spec(stack_axes=()):
    return {
        "gate": linear.spec("embed", "mlp", stack_axes=stack_axes),
        "up": linear.spec("embed", "mlp", stack_axes=stack_axes),
        "down": linear.spec("mlp", "embed", stack_axes=stack_axes),
    }


def swiglu_apply(params, x, *, crew_strategy="auto", crew_state=None):
    """SwiGLU FFN.  ``crew_strategy`` is a strategy string or CrewPlan.
    With ``crew_state`` (the decode product-buffer mirror of ``params``)
    the return value is ``(y, new_state)`` for the decode scan carry."""
    plan = CrewPlan.of(crew_strategy)
    st = crew_state or {}
    g, sg = linear.apply_with_state(params["gate"], x,
                                    plan=plan.with_activation("silu"),
                                    state=st.get("gate"))
    u, su = linear.apply_with_state(params["up"], x, plan=plan,
                                    state=st.get("up"))
    y, sd = linear.apply_with_state(params["down"], g * u, plan=plan,
                                    state=st.get("down"))
    if crew_state is None:
        return y
    return y, {**crew_state, "gate": sg, "up": su, "down": sd}


def gelu_init(rng, d_model: int, d_ff: int, *, dtype=jnp.float32, stack=()):
    ks = jax.random.split(rng, 2)
    return {
        "up": linear.init(ks[0], d_model, d_ff, bias=True, dtype=dtype, stack=stack),
        "down": linear.init(ks[1], d_ff, d_model, bias=True, dtype=dtype,
                            scale=d_ff ** -0.5, stack=stack),
    }


def gelu_spec(stack_axes=()):
    return {
        "up": linear.spec("embed", "mlp", bias=True, stack_axes=stack_axes),
        "down": linear.spec("mlp", "embed", bias=True, stack_axes=stack_axes),
    }


def gelu_apply(params, x, *, crew_strategy="auto", crew_state=None):
    plan = CrewPlan.of(crew_strategy)
    st = crew_state or {}
    h, su = linear.apply_with_state(params["up"], x,
                                    plan=plan.with_activation("gelu"),
                                    state=st.get("up"))
    y, sd = linear.apply_with_state(params["down"], h, plan=plan,
                                    state=st.get("down"))
    if crew_state is None:
        return y
    return y, {**crew_state, "up": su, "down": sd}
