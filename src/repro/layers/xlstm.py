"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Gating math follows the xLSTM paper (exponential input gate, stabilizer
state m).  Block wiring is the standard form: the mLSTM block up-projects
(pf=2), runs the cell, applies the learned output gate and down-projects;
the sLSTM block runs the cell at model width then applies a pf=4/3 GELU
MLP.  Both cells run as a `lax.scan` over time — O(1)-state recurrence is
what qualifies xLSTM for the long_500k decode cell; a chunked-parallel
mLSTM is a recorded perf-iteration candidate (EXPERIMENTS §Perf).

States: mLSTM (C [B, H, dk, dv], n [B, H, dk], m [B, H]);
        sLSTM (c, n, h [B, d], m [B, d]).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.ctx import constrain
from ..kernels.plan import CrewPlan
from . import linear

__all__ = [
    "mlstm_init", "mlstm_spec", "mlstm_apply", "mlstm_state", "mlstm_state_spec",
    "slstm_init", "slstm_spec", "slstm_apply", "slstm_state", "slstm_state_spec",
]


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def mlstm_init(rng, d_model: int, n_heads: int, *, pf: float = 2.0,
               dtype=jnp.float32, stack=()):
    di = int(pf * d_model)
    ks = jax.random.split(rng, 7)
    return {
        "up": linear.init(ks[0], d_model, 2 * di, dtype=dtype, stack=stack),
        "q": linear.init(ks[1], di, di, dtype=dtype, stack=stack),
        "k": linear.init(ks[2], di, di, dtype=dtype, stack=stack),
        "v": linear.init(ks[3], di, di, dtype=dtype, stack=stack),
        "ifg": linear.init(ks[4], di, 2 * n_heads, dtype=jnp.float32, stack=stack),
        "down": linear.init(ks[5], di, d_model, dtype=dtype,
                            scale=di ** -0.5, stack=stack),
    }


def mlstm_spec(stack_axes=()):
    sa = stack_axes
    return {
        "up": linear.spec("embed", "mlp", stack_axes=sa),
        "q": linear.spec("mlp", "heads", stack_axes=sa),
        "k": linear.spec("mlp", "heads", stack_axes=sa),
        "v": linear.spec("mlp", "heads", stack_axes=sa),
        "ifg": linear.spec("mlp", None, stack_axes=sa),
        "down": linear.spec("mlp", "embed", stack_axes=sa),
    }


def mlstm_state(batch: int, d_model: int, n_heads: int, *, pf: float = 2.0,
                stack=()):
    di = int(pf * d_model)
    dh = di // n_heads
    return {
        "C": jnp.zeros((*stack, batch, n_heads, dh, dh), dtype=jnp.float32),
        "n": jnp.zeros((*stack, batch, n_heads, dh), dtype=jnp.float32),
        "m": jnp.zeros((*stack, batch, n_heads), dtype=jnp.float32),
    }


def mlstm_state_spec(stack_axes=()):
    return {
        "C": P(*stack_axes, "batch", "heads", None, None),
        "n": P(*stack_axes, "batch", "heads", None),
        "m": P(*stack_axes, "batch", "heads"),
    }


def _mlstm_step(state, inp):
    c, n, m = state["C"], state["n"], state["m"]
    q, k, v, ig, fg = inp  # q/k/v [B, H, dh]; ig/fg [B, H]
    m_new = jnp.maximum(fg + m, ig)
    i_p = jnp.exp(ig - m_new)
    f_p = jnp.exp(fg + m - m_new)
    c_new = f_p[..., None, None] * c + i_p[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n_new = f_p[..., None] * n + i_p[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q)), 1.0)
    y = jnp.einsum("bhk,bhkv->bhv", q, c_new) / denom[..., None]
    return {"C": c_new, "n": n_new, "m": m_new}, y


def mlstm_apply(params, x, state=None, *, n_heads: int, pf: float = 2.0,
                crew_strategy="auto"):
    """x [B, S, d] -> ([B, S, d], final_state)."""
    b, s, d = x.shape
    di = int(pf * d)
    dh = di // n_heads
    up = linear.apply(params["up"], x, plan=crew_strategy)
    xm, og = jnp.split(up, 2, axis=-1)
    q = linear.apply(params["q"], xm, plan=crew_strategy)
    k = linear.apply(params["k"], xm, plan=crew_strategy) * dh ** -0.5
    v = linear.apply(params["v"], xm, plan=crew_strategy)
    gates = linear.apply(params["ifg"], xm.astype(jnp.float32))
    ig, fg = jnp.split(gates, 2, axis=-1)                  # [B, S, H]
    fg = jax.nn.log_sigmoid(fg)

    def resh(t):
        out = jnp.moveaxis(
            t.reshape(b, s, n_heads, dh).astype(jnp.float32), 1, 0)
        return constrain(out, None, "batch", "heads", None)

    qs, ks_, vs = map(resh, (q, k, v))
    igs = constrain(jnp.moveaxis(ig, 1, 0), None, "batch", "heads")
    fgs = constrain(jnp.moveaxis(fg, 1, 0), None, "batch", "heads")
    if state is None:
        state = mlstm_state(b, d, n_heads, pf=pf)
    state = {
        "C": constrain(state["C"], "batch", "heads", None, None),
        "n": constrain(state["n"], "batch", "heads", None),
        "m": constrain(state["m"], "batch", "heads"),
    }
    state, ys = jax.lax.scan(_mlstm_step, state, (qs, ks_, vs, igs, fgs))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, di)           # [B, S, di]
    y = y * jax.nn.silu(og.astype(jnp.float32))
    y = y.astype(x.dtype)
    return linear.apply(params["down"], y, plan=crew_strategy), state


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def slstm_init(rng, d_model: int, n_heads: int, *, pf: float = 4.0 / 3.0,
               dtype=jnp.float32, stack=()):
    ks = jax.random.split(rng, 7)
    dh = d_model // n_heads
    dff = int(pf * d_model)
    return {
        # input projections for z, i, f, o (fused)
        "wx": linear.init(ks[0], d_model, 4 * d_model, dtype=dtype, stack=stack),
        # block-diagonal recurrent weights, per head [H, dh, 4*dh]
        "r": jax.random.normal(ks[1], (*stack, n_heads, dh, 4 * dh)).astype(dtype)
        * dh ** -0.5,
        "b": jnp.zeros((*stack, 4 * d_model), dtype=jnp.float32),
        "up": linear.init(ks[2], d_model, dff, dtype=dtype, stack=stack),
        "down": linear.init(ks[3], dff, d_model, dtype=dtype,
                            scale=dff ** -0.5, stack=stack),
    }


def slstm_spec(stack_axes=()):
    sa = stack_axes
    return {
        "wx": linear.spec("embed", None, stack_axes=sa),
        "r": P(*sa, "heads", None, None),
        "b": P(*sa, None),
        "up": linear.spec("embed", "mlp", stack_axes=sa),
        "down": linear.spec("mlp", "embed", stack_axes=sa),
    }


def slstm_state(batch: int, d_model: int, stack=()):
    z = jnp.zeros((*stack, batch, d_model), dtype=jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def slstm_state_spec(stack_axes=()):
    return {k: P(*stack_axes, "batch", None) for k in ("c", "n", "h", "m")}


def _slstm_step(params_r, params_b, n_heads, state, wx_t):
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    b, d = h.shape
    dh = d // n_heads
    hh = h.reshape(b, n_heads, dh)
    rec = jnp.einsum("bhd,hdf->bhf", hh, params_r.astype(jnp.float32))
    rec = rec.reshape(b, n_heads, 4, dh).transpose(0, 2, 1, 3).reshape(b, 4 * d)
    pre = wx_t + rec + params_b
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
    zt = jnp.tanh(zt)
    ot = jax.nn.sigmoid(ot)
    ft = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(ft + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(ft + m - m_new)
    c_new = f_p * c + i_p * zt
    n_new = f_p * n + i_p
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}, h_new


def slstm_apply(params, x, state=None, *, n_heads: int,
                crew_strategy="auto"):
    """x [B, S, d] -> ([B, S, d], final_state)."""
    b, s, d = x.shape
    wx = linear.apply(params["wx"], x.astype(jnp.float32))  # [B, S, 4d]
    wx = constrain(wx, "batch", None, None)
    # reorder fused projection to (z, i, f, o) per-head contiguity handled
    # inside the step; scan over time.
    if state is None:
        state = slstm_state(b, d)
    state = {k: constrain(v, "batch", None) for k, v in state.items()}
    step = lambda st, wx_t: _slstm_step(params["r"], params["b"], n_heads, st, wx_t)
    state, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)              # [B, S, d]
    h = linear.apply(params["up"], y,
                     plan=CrewPlan.of(crew_strategy).with_activation("gelu"))
    return linear.apply(params["down"], h, plan=crew_strategy), state
