"""Mixture-of-Experts with GShard-style grouped one-hot dispatch.

Tokens are split into fixed-size groups; within a group each token picks
top-k experts, positions inside an expert's capacity buffer come from a
cumulative sum, and dispatch/combine are einsums against a one-hot
[groups, tokens, experts, capacity] tensor.

SPMD structure: the group axis G is the sharded data axis (it inherits the
batch sharding), experts shard over the "model" axis (EP), so the
dispatch/combine einsums lower to the expected all-to-all-style
collectives.  All groups are processed *vectorized* — never a scan over
groups, which would serialize data parallelism; the dispatch one-hot
[G, gs, E, C] is the largest intermediate and stays modest once sharded
over G x E (~tens of MB/device at the 4k-train shape).  Capacity overflow
drops tokens (standard GShard semantics); the residual path keeps them
alive.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..dist.ctx import constrain
from . import linear

__all__ = ["init", "spec", "apply", "MoEStats"]


class MoEStats(NamedTuple):
    aux_loss: jnp.ndarray        # load-balance loss (Switch style)
    dropped_fraction: jnp.ndarray


def init(rng, d_model: int, d_ff: int, n_experts: int, *, dtype=jnp.float32,
         stack=()):
    ks = jax.random.split(rng, 4)
    e = n_experts
    return {
        "router": linear.init(ks[0], d_model, e, dtype=jnp.float32, stack=stack),
        "gate": linear.init(ks[1], d_model, d_ff, dtype=dtype, stack=(*stack, e)),
        "up": linear.init(ks[2], d_model, d_ff, dtype=dtype, stack=(*stack, e)),
        "down": linear.init(ks[3], d_ff, d_model, dtype=dtype,
                            scale=d_ff ** -0.5, stack=(*stack, e)),
    }


def spec(stack_axes=()):
    return {
        "router": linear.spec("embed", None, stack_axes=stack_axes),
        "gate": linear.spec("embed", "mlp", stack_axes=(*stack_axes, "expert")),
        "up": linear.spec("embed", "mlp", stack_axes=(*stack_axes, "expert")),
        "down": linear.spec("mlp", "embed", stack_axes=(*stack_axes, "expert")),
    }


def apply(params, x, *, top_k: int, capacity_factor: float = 1.25,
          group_size: int = 512, crew_strategy="auto"):
    """x [B, S, d] -> ([B, S, d], MoEStats)."""
    b, s, d = x.shape
    e = params["router"]["w"].shape[-1]
    tokens = x.reshape(b * s, d)
    t = tokens.shape[0]

    group_size = min(group_size, t)
    n_groups = -(-t // group_size)
    t_pad = n_groups * group_size
    if t_pad != t:
        tokens = jnp.pad(tokens, ((0, t_pad - t), (0, 0)))
    groups = constrain(tokens.reshape(n_groups, group_size, d),
                       "batch", None, None)

    capacity = max(1, int(group_size * top_k / e * capacity_factor))

    logits = linear.apply(params["router"], groups.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                 # [G, gs, E]
    gate_vals, sel = jax.lax.top_k(probs, top_k)            # [G, gs, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style load balance loss over the whole batch
    me = probs.mean(axis=(0, 1))                            # [E]
    sel_onehot = jax.nn.one_hot(sel, e, dtype=jnp.float32)  # [G, gs, k, E]
    ce = sel_onehot.mean(axis=(0, 1)).sum(axis=0) / top_k   # [E] pick fraction
    aux = e * jnp.sum(me * ce)

    # position of each (token, k) inside its expert's buffer, per group
    flat = sel_onehot.reshape(n_groups, group_size * top_k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat)                 # [G, gs*k, E]
    pos = jnp.einsum("gte,gte->gt", pos, flat)              # selected pos
    pos = pos.reshape(n_groups, group_size, top_k).astype(jnp.int32)
    keep = pos < capacity
    dropped = 1.0 - keep.mean()

    pos_onehot = jax.nn.one_hot(pos, capacity, dtype=jnp.float32) * keep[..., None]
    # dispatch[g, t, e, c]
    disp = jnp.einsum("gtke,gtkc->gtec", sel_onehot, pos_onehot)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", sel_onehot, pos_onehot, gate_vals)

    def expert_w(name, dtype):
        """Expert weight [E, din, dout]; CREW leaves decompress on the fly
        (vmapped gather over the expert axis) — the packed indices are what
        streamed from HBM, which is CREW's bandwidth saving; the matmul
        itself runs dense on the MXU (DESIGN.md §3 'dense' strategy, the
        right one for the compute-rich grouped-expert einsum)."""
        from ..core.convert import CrewMatrixUniform, crew_reconstruct_uniform
        w = params[name]["w"]
        if isinstance(w, CrewMatrixUniform):
            return jax.vmap(crew_reconstruct_uniform)(w).astype(dtype)
        return w.astype(dtype)

    # All groups vectorized: G shards over the data axis, E over the model
    # axis (EP); the dispatch/combine einsums are the all-to-all boundary.
    xe = jnp.einsum("gtd,gtec->gecd", groups, disp.astype(groups.dtype))
    xe = constrain(xe, "batch", "expert", None, None)       # [G, E, C, d]
    gg = jnp.einsum("gecd,edf->gecf", xe, expert_w("gate", xe.dtype))
    uu = jnp.einsum("gecd,edf->gecf", xe, expert_w("up", xe.dtype))
    hh = jax.nn.silu(gg) * uu
    ye = jnp.einsum("gecf,efd->gecd", hh, expert_w("down", xe.dtype))
    ye = constrain(ye, "batch", "expert", None, None)
    out = jnp.einsum("gecd,gtec->gtd", ye, comb.astype(ye.dtype))
    out = constrain(out, "batch", None, None)               # [G, gs, d]
    out = out.reshape(t_pad, d)[:t].reshape(b, s, d)
    return out.astype(x.dtype), MoEStats(aux_loss=aux, dropped_fraction=dropped)
