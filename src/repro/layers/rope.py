"""Rotary position embeddings (interleaved-pair convention)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["rope_freqs", "apply_rope"]


def rope_freqs(d_head: int, theta: float = 10000.0) -> jnp.ndarray:
    """Inverse frequencies [d_head // 2] (fp32)."""
    exponent = jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, inv_freq: jnp.ndarray) -> jnp.ndarray:
    """x: [B, S, H, D], positions: [B, S] int32 -> same shape/dtype.

    Split-half convention (first D/2 dims paired with last D/2), matching
    the HF Llama/Qwen family.
    """
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    xf = x.astype(jnp.float32)
    d2 = x.shape[-1] // 2
    x1, x2 = xf[..., :d2], xf[..., d2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
