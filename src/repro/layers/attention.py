"""Attention: GQA/MQA/MHA projections + chunked (flash-style) softmax.

Training/prefill use ``chunked_attention`` — an online-softmax sweep over
KV chunks (and a map over Q chunks) so the [Sq, Skv] score matrix never
materializes; this is the memory-bounded, GSPMD-friendly formulation
(collectives appear automatically when the KV sequence axis is sharded,
as in the long-context decode cells).

Decode uses ``decode_attention`` — one new token against a static-size KV
cache with a length mask (S up to 512k stays cheap because the score tensor
is [B, H, 1, S]).

GQA is expressed by grouping query heads over KV heads; MQA (kv=1) falls
out as group = H.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.ctx import constrain
from ..kernels.plan import CrewPlan
from . import linear
from .rope import apply_rope, rope_freqs

__all__ = [
    "init", "spec", "crew_names",
    "chunked_attention", "decode_attention", "cached_chunk_attention",
    "attend", "attend_decode", "attend_prefill_cached",
    "attend_decode_paged", "attend_prefill_cached_paged",
    "init_kv_cache", "cache_spec",
]

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Projections
# --------------------------------------------------------------------------

def init(rng, d_model: int, n_heads: int, n_kv: int, d_head: int, *,
         qkv_bias: bool = False, dtype=jnp.float32, stack=()):
    ks = jax.random.split(rng, 4)
    return {
        "q": linear.init(ks[0], d_model, n_heads * d_head, bias=qkv_bias, dtype=dtype, stack=stack),
        "k": linear.init(ks[1], d_model, n_kv * d_head, bias=qkv_bias, dtype=dtype, stack=stack),
        "v": linear.init(ks[2], d_model, n_kv * d_head, bias=qkv_bias, dtype=dtype, stack=stack),
        "o": linear.init(ks[3], n_heads * d_head, d_model, bias=False, dtype=dtype,
                         scale=(n_heads * d_head) ** -0.5, stack=stack),
    }


def spec(*, qkv_bias: bool = False, stack_axes=(), shard_kv: bool = True):
    """Logical axes: q/k/v column-parallel over "heads"; o row-parallel.

    shard_kv=False replicates the K/V projections (MQA with kv=1 cannot
    split a single head across the TP axis)."""
    kv_axis = "heads" if shard_kv else None
    return {
        "q": linear.spec("embed", "heads", bias=qkv_bias, stack_axes=stack_axes),
        "k": linear.spec("embed", kv_axis, bias=qkv_bias, stack_axes=stack_axes),
        "v": linear.spec("embed", kv_axis, bias=qkv_bias, stack_axes=stack_axes),
        "o": linear.spec("heads", "embed", bias=False, stack_axes=stack_axes),
    }


def crew_names():
    """Weight leaves that serving-time CREW conversion targets."""
    return ("q", "k", "v", "o")


# --------------------------------------------------------------------------
# Core softmax attention
# --------------------------------------------------------------------------

def _group_scores(q, k):
    """q [B, Sq, H, D] x k [B, Sk, KV, D] -> f32 scores [B, KV, G, Sq, Sk].

    Operands stay in their storage dtype with f32 accumulation
    (preferred_element_type) — an explicit ``.astype(f32)`` on the K/V
    cache gets loop-hoisted by XLA into a full-stack f32 copy of the cache
    (+860 MB/device per tensor on the granite decode cell).
    """
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(qg.dtype),
                      preferred_element_type=jnp.float32)


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Online-softmax attention. q [B, Sq, H, D]; k, v [B, Sk, KV, D].

    Returns [B, Sq, H, D] in q.dtype.  Sq/Sk are padded internally to chunk
    multiples; padded KV positions are masked out, padded Q rows sliced off.
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = d ** -0.5

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    sq_p = -(-sq // q_chunk) * q_chunk
    sk_p = -(-sk // kv_chunk) * kv_chunk
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    nq, nk = sq_p // q_chunk, sk_p // kv_chunk

    # Pin the chunked scan inputs: GSPMD propagation through while-loop
    # bodies is unreliable and silently replicates the whole attention
    # region otherwise (batch dim must stay data-sharded inside the loops).
    chunk_spec = (None, "batch", None, "kv_heads", None)
    k_ch = constrain(jnp.moveaxis(k.reshape(b, nk, kv_chunk, kv, d), 1, 0),
                     *chunk_spec)
    v_ch = constrain(jnp.moveaxis(v.reshape(b, nk, kv_chunk, kv, d), 1, 0),
                     *chunk_spec)

    def one_q_chunk(args):
        iq, q_blk = args  # q_blk [B, cq, H, D]
        q_pos = q_offset + iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inp):
            m, l, acc = carry
            ik, k_blk, v_blk = inp
            k_pos = ik * kv_chunk + jnp.arange(kv_chunk)
            s = _group_scores(q_blk, k_blk) * scale  # [B, KV, G, cq, ck]
            # Additive [cq, ck] f32 bias, NOT a broadcast `where` over the
            # full score shape: a pred mask broadcast to [B, KV, G, cq, ck]
            # gets materialized + loop-hoisted by XLA into multi-GB stacked
            # buffers (observed 44 GB/device on the 4k-train dry-run); the
            # rank-2 bias fuses into the score add.
            bias = jnp.zeros((q_chunk, kv_chunk), dtype=jnp.float32)
            if causal:
                bias = jnp.where(q_pos[:, None] >= k_pos[None, :], 0.0, NEG_INF)
            if sk_p != sk:  # static: KV padding exists
                bias = bias + jnp.where(k_pos[None, :] < sk, 0.0, NEG_INF)
            s = s + bias[None, None, None]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            # p cast to the V storage dtype, f32 accumulation — same
            # loop-hoisting hazard as _group_scores (and the MXU-native
            # mixed-precision form: bf16 x bf16 -> f32).
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_blk.dtype),
                            v_blk, preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            # Carries shard KV heads when divisible, else the query-group
            # dim G ("heads" — e.g. MQA kv=1 has G=48): forcing only
            # kv_heads replicated the carries while the PV einsum output
            # was G-sharded, making GSPMD all-gather the accumulator on
            # EVERY kv step (observed: 25 MB x 212,992 on granite prefill).
            cs = ("batch", "kv_heads", "heads", None)
            return (constrain(m_new, *cs), constrain(l_new, *cs),
                    constrain(acc_new, *cs, None)), None

        cs0 = ("batch", "kv_heads", "heads", None)
        m0 = constrain(jnp.full((b, kv, g, q_chunk), NEG_INF,
                                dtype=jnp.float32), *cs0)
        l0 = constrain(jnp.zeros((b, kv, g, q_chunk), dtype=jnp.float32), *cs0)
        a0 = constrain(jnp.zeros((b, kv, g, q_chunk, d), dtype=jnp.float32),
                       *cs0, None)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), k_ch, v_ch)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # [B, KV, G, cq, D]
        return jnp.moveaxis(out, 3, 1).reshape(b, q_chunk, h, d)

    q_blocks = constrain(jnp.moveaxis(q.reshape(b, nq, q_chunk, h, d), 1, 0),
                         None, "batch", None, "heads", None)
    out = jax.lax.map(one_q_chunk, (jnp.arange(nq), q_blocks))  # [nq, B, cq, H, D]
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq_p, h, d)
    return out[:, :sq].astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,
) -> jnp.ndarray:
    """q [B, 1, H, D] vs cache [B, S, KV, D]; positions >= cache_len masked.

    An int8 cache runs the score and PV contractions natively in
    int8 x int8 -> int32 (the MXU's 2x-rate int8 mode): the cache streams
    from HBM at half the bf16 bytes and is never dequantized into a bf16
    twin — the §Perf decode-cell optimization.
    """
    b, _, h, d = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = d ** -0.5
    int8_kv = k_cache.dtype == jnp.int8
    if int8_kv:
        qg = jnp.clip(jnp.round(
            q.reshape(b, 1, kv, g, d).astype(jnp.float32) * KV_INT8_SCALE),
            -127, 127).astype(jnp.int8)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                            preferred_element_type=jnp.int32)
        scores = scores.astype(jnp.float32) * (scale / KV_INT8_SCALE ** 2)
    else:
        scores = _group_scores(q, k_cache) * scale      # [B, KV, G, 1, S]
    pos = jnp.arange(s)
    mask = pos[None, :] < cache_len.reshape(-1, 1)      # [B, S]
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    if int8_kv:
        pq = jnp.clip(jnp.round(p * 127.0), 0, 127).astype(jnp.int8)
        out = jnp.einsum("bkgqs,bskd->bqkgd", pq, v_cache,
                         preferred_element_type=jnp.int32)
        out = out.astype(jnp.float32) / (127.0 * KV_INT8_SCALE)
    else:
        out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_cache.dtype),
                         v_cache, preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def cached_chunk_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,
) -> jnp.ndarray:
    """Chunk-of-queries attention against a partially filled cache.

    q [B, C, H, D] at absolute positions ``pos`` [B, C]; k/v cache
    [B, S, KV, D] whose positions [0, pos) hold valid entries (a reused
    prefix plus this chunk's freshly written rows).  Position j attends
    iff ``j <= q_pos`` — everything later (unwritten cache, chunk
    padding) is masked to an exact zero, and the single-pass
    max/exp/sum/divide matches ``chunked_attention``'s one-KV-chunk
    online-softmax bit for bit, which is what makes chunked prefill
    token-identical to the monolithic prefill (DESIGN.md §5).
    """
    b, c, h, d = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = d ** -0.5
    scores = _group_scores(q, k_cache) * scale          # [B, KV, G, C, S]
    k_pos = jnp.arange(s)
    bias = jnp.where(pos[:, :, None] >= k_pos[None, None, :], 0.0, NEG_INF)
    scores = scores + bias[:, None, None]               # [B,1,1,C,S] bcast
    m = scores.max(axis=-1)
    p = jnp.exp(scores - m[..., None])
    l = p.sum(axis=-1)
    pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_cache.dtype), v_cache,
                    preferred_element_type=jnp.float32)
    out = pv / jnp.maximum(l, 1e-30)[..., None]         # [B, KV, G, C, D]
    return jnp.moveaxis(out, 3, 1).reshape(b, c, h, d).astype(q.dtype)


# --------------------------------------------------------------------------
# Full attention block (projections + rope + softmax + out-proj)
# --------------------------------------------------------------------------

def flash_sharded(q, k, v, *, causal=True, block_q=512, block_k=512):
    """Flash-attention Pallas kernel under shard_map (data x heads).

    GSPMD cannot partition a pallas_call, so the kernel runs on local
    shards: batch over ("pod","data"), heads over "model" when the head
    count divides (MQA/GQA groups divide out inside the kernel's K/V
    index maps; an indivisible head count falls back to replication,
    matching the dense path's behavior).  Outside a sharding ctx this is
    a plain single-device kernel call.
    """
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from ..dist.compat import shard_map
    from ..dist.ctx import current_ctx
    from ..dist.sharding import resolve
    from ..kernels.flash_attention import flash_attention

    fn = partial(flash_attention, causal=causal, block_q=block_q,
                 block_k=block_k)
    ctx = current_ctx()
    if ctx is None:
        return fn(q, k, v)
    mesh, rules = ctx
    qs = resolve(P("batch", None, "heads", None), q.shape, mesh, rules)
    kvs = resolve(P("batch", None, "kv_heads", None), k.shape, mesh, rules)
    if len(qs) > 2 and qs[2] is not None and not (
            len(kvs) > 2 and kvs[2] is not None):
        # q heads sharded but KV heads indivisible: only legal if every
        # shard's local head count still covers whole GQA groups — i.e.
        # kv divides the per-shard head count.  Otherwise replicate heads.
        import math
        sizes = dict(mesh.shape)
        n_shard = math.prod(sizes[a] for a in
                            ((qs[2],) if isinstance(qs[2], str) else qs[2]))
        if (q.shape[2] // n_shard) % k.shape[2] != 0:
            qs = P(*qs[:2], None, *qs[3:])
    return shard_map(fn, mesh=mesh, in_specs=(qs, kvs, kvs), out_specs=qs,
                     check_vma=False)(q, k, v)


def attend(params, x, *, n_heads, n_kv, d_head, rope_theta=10000.0,
           causal=True, q_chunk=512, kv_chunk=512, crew_strategy="auto",
           positions=None, impl="chunked"):
    """Training/prefill path. x [B, S, d] -> ([B, S, d], (k, v) for cache).

    impl="chunked" — pure-XLA online softmax (differentiable, default).
    impl="flash"   — Pallas flash kernel via shard_map (serving/dry-run
                     forward path; scores never leave VMEM).
    """
    b, s, _ = x.shape
    plan = CrewPlan.of(crew_strategy)
    q = linear.apply(params["q"], x, plan=plan)
    k = linear.apply(params["k"], x, plan=plan)
    v = linear.apply(params["v"], x, plan=plan)
    q = constrain(q.reshape(b, s, n_heads, d_head), "batch", None, "heads", None)
    k = constrain(k.reshape(b, s, n_kv, d_head), "batch", None, "kv_heads", None)
    v = constrain(v.reshape(b, s, n_kv, d_head), "batch", None, "kv_heads", None)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    inv = rope_freqs(d_head, rope_theta)
    q = apply_rope(q, positions, inv)
    k = apply_rope(k, positions, inv)
    if impl == "flash":
        out = flash_sharded(q, k, v, causal=causal, block_q=q_chunk,
                            block_k=kv_chunk)
    else:
        out = chunked_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                                kv_chunk=kv_chunk)
    out = out.reshape(b, s, n_heads * d_head)
    return linear.apply(params["o"], out, plan=plan), (k, v)


# int8 KV-cache quantization scale (§Perf decode iteration): K/V entries
# after RoPE are O(1)-scaled; a fixed power-of-two scale keeps the
# quant/dequant to a shift-like multiply and halves the dominant decode
# HBM stream vs bf16.  Per-block scales would be the production refinement.
KV_INT8_SCALE = 32.0


def _maybe_quant_kv(t: jnp.ndarray, like: jnp.ndarray) -> jnp.ndarray:
    if like.dtype == jnp.int8:
        return jnp.clip(jnp.round(t.astype(jnp.float32) * KV_INT8_SCALE),
                        -127, 127).astype(jnp.int8)
    return t.astype(like.dtype)


def _maybe_dequant_kv(t: jnp.ndarray, dtype) -> jnp.ndarray:
    if t.dtype == jnp.int8:
        return (t.astype(jnp.float32) / KV_INT8_SCALE).astype(dtype)
    return t


def _lens_vector(ln: jnp.ndarray, b: int) -> jnp.ndarray:
    """The one documented cache-length signature (DESIGN.md §5 /
    docs/api.md): ``len`` is a scalar (every lane at the same position)
    or a vector ``[B]`` of per-lane positions.  Both normalize to the
    ``[B]`` vector here — every consumer below is written against the
    vector form only, and the *returned* cache preserves the caller's
    rank (scalar in, scalar out)."""
    if ln.ndim == 1:
        return ln
    return jnp.broadcast_to(ln.reshape(1), (b,))


def attend_decode(params, x, cache, *, n_heads, n_kv, d_head,
                  rope_theta=10000.0, crew_strategy="auto",
                  crew_state=None):
    """Decode path. x [B, 1, d]; cache {"k","v","len"} -> (out, new_cache).

    ``cache["len"]`` follows the unified scalar-or-``[B]`` signature (see
    :func:`_lens_vector`): internally always the per-lane vector — each
    lane RoPEs its query/key at its own offset and scatters its new KV
    entry at its own cache position — with the returned ``len``
    preserving the caller's rank.

    ``crew_state`` is the decode product-buffer mirror of ``params``
    (repro.serve builds it); when given, the q/k/v/o projections run the
    VMEM-resident decode kernel and the returned cache carries the
    updated mirror under ``"crew"`` for the scan.

    An int8 cache (``init_kv_cache(dtype=jnp.int8)``) is quantized on
    write and dequantized on read at a fixed scale.
    """
    b = x.shape[0]
    plan = CrewPlan.of(crew_strategy)
    st = crew_state or {}
    q, sq = linear.apply_with_state(params["q"], x, plan=plan,
                                    state=st.get("q"))
    k, sk = linear.apply_with_state(params["k"], x, plan=plan,
                                    state=st.get("k"))
    v, sv = linear.apply_with_state(params["v"], x, plan=plan,
                                    state=st.get("v"))
    q = q.reshape(b, 1, n_heads, d_head)
    k = k.reshape(b, 1, n_kv, d_head)
    v = v.reshape(b, 1, n_kv, d_head)
    ln = cache["len"]
    ln_b = _lens_vector(ln, b)
    pos = ln_b[:, None]
    inv = rope_freqs(d_head, rope_theta)
    q = apply_rope(q, pos, inv)
    k = apply_rope(k, pos, inv)
    lane = jnp.arange(b)
    k_cache = cache["k"].at[lane, ln_b].set(
        _maybe_quant_kv(k, cache["k"])[:, 0])
    v_cache = cache["v"].at[lane, ln_b].set(
        _maybe_quant_kv(v, cache["v"])[:, 0])
    out = decode_attention(q, k_cache, v_cache, ln_b + 1)
    out = out.reshape(b, 1, n_heads * d_head)
    y, so = linear.apply_with_state(params["o"], out, plan=plan,
                                    state=st.get("o"))
    new_cache = {"k": k_cache, "v": v_cache, "len": cache["len"] + 1}
    if crew_state is not None:
        new_cache["crew"] = {**crew_state, "q": sq, "k": sk, "v": sv,
                             "o": so}
    return y, new_cache


def attend_prefill_cached(params, x, cache, *, n_heads, n_kv, d_head,
                          rope_theta=10000.0, crew_strategy="auto"):
    """Chunked-prefill path: a chunk of prompt tokens against prior cache.

    x [B, C, d] holds C consecutive prompt tokens whose first token sits
    at cache position ``cache["len"]`` — the unified scalar-or-``[B]``
    cache-length signature (see :func:`_lens_vector`): normalized to the
    per-lane vector internally, each lane RoPEs its chunk at its own
    offset and scatters its K/V rows at its own cache positions, and the
    returned ``len`` preserves the caller's rank.  Positions
    [0, offset) may hold *reused* KV state (a prefix-cache hit or an
    earlier chunk) — the chunk attends to them without recomputing,
    which is the whole point: prefill work becomes O(suffix), not
    O(prompt).

    Returns (out [B, C, d], new cache) with ``len`` advanced by C; a
    padded tail chunk advances past its padding, so the caller resets
    ``len`` to the true length (the padded rows' K/V are dead — masked
    until decode overwrites them, exactly like bucketed-prefill padding).

    K/V rows scatter by *index*, never ``dynamic_update_slice``: a
    padded tail whose window crosses the cache end must drop its dead
    rows (scatter's out-of-bounds semantics), not clamp the window start
    back over valid earlier rows (dus semantics — which would silently
    corrupt the cache for any prompt whose bucket padding crosses
    ``cache_len``).
    """
    b, c, _ = x.shape
    plan = CrewPlan.of(crew_strategy)
    q = linear.apply(params["q"], x, plan=plan)
    k = linear.apply(params["k"], x, plan=plan)
    v = linear.apply(params["v"], x, plan=plan)
    q = q.reshape(b, c, n_heads, d_head)
    k = k.reshape(b, c, n_kv, d_head)
    v = v.reshape(b, c, n_kv, d_head)
    off_b = _lens_vector(cache["len"], b)
    pos = off_b[:, None] + jnp.arange(c)[None]          # [B, C]
    inv = rope_freqs(d_head, rope_theta)
    q = apply_rope(q, pos, inv)
    k = apply_rope(k, pos, inv)
    lane = jnp.arange(b)[:, None]
    k_cache = cache["k"].at[lane, pos].set(_maybe_quant_kv(k, cache["k"]))
    v_cache = cache["v"].at[lane, pos].set(_maybe_quant_kv(v, cache["v"]))
    out = cached_chunk_attention(q, _maybe_dequant_kv(k_cache, q.dtype),
                                 _maybe_dequant_kv(v_cache, q.dtype), pos)
    out = out.reshape(b, c, n_heads * d_head)
    y = linear.apply(params["o"], out, plan=plan)
    return y, {"k": k_cache, "v": v_cache, "len": cache["len"] + c}


def _paged_gather(pool: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """pool [P, bs, KV, D] indexed by table [B, NB] -> [B, NB*bs, KV, D].

    The paged read path: a slot's logical KV stripe materializes as a
    gather through its block table.  Entries past the slot's blocks are 0
    (the scratch block) — their rows are garbage but every consumer masks
    positions >= the true length to an exact zero weight (NEG_INF bias ->
    exp underflow), so the gathered width never changes outputs; this is
    the same argument that makes window-bucket width changes bitwise-safe
    on the dense path.  Storage dtype is preserved (int8 pools stream
    natively into ``decode_attention``).
    """
    b, nb = table.shape
    _, bs, kv, d = pool.shape
    return pool[table].reshape(b, nb * bs, kv, d)


def attend_decode_paged(params, x, cache, *, n_heads, n_kv, d_head,
                        rope_theta=10000.0, crew_strategy="auto",
                        crew_state=None):
    """Paged decode: KV lives in a shared block pool, not a slot stripe.

    ``cache`` is {"k": [P, bs, KV, D], "v": [P, bs, KV, D], "len"
    (scalar-or-``[B]``, see :func:`_lens_vector`), "table": [B, NB]
    int32}.  Device block id 0 is the scratch block: dead lanes carry
    all-zero tables so their writes and reads land there, never on a
    live block.  Each lane writes its new K/V row at pool position
    ``(table[lane, len // bs], len % bs)`` and attends the gathered
    ``[B, NB*bs]`` stripe with positions >= len+1 masked — bitwise the
    same softmax as the dense-stripe :func:`attend_decode` because the
    extra gathered width is exactly zero-weighted.

    Write-safety is structural: a slot's write block index ``len // bs``
    is always >= its prompt's block count, and blocks shared with the
    prefix trie (or other slots) are only ever the prompt's *full*
    blocks — so decode never writes a shared block.
    """
    b = x.shape[0]
    plan = CrewPlan.of(crew_strategy)
    st = crew_state or {}
    q, sq = linear.apply_with_state(params["q"], x, plan=plan,
                                    state=st.get("q"))
    k, sk = linear.apply_with_state(params["k"], x, plan=plan,
                                    state=st.get("k"))
    v, sv = linear.apply_with_state(params["v"], x, plan=plan,
                                    state=st.get("v"))
    q = q.reshape(b, 1, n_heads, d_head)
    k = k.reshape(b, 1, n_kv, d_head)
    v = v.reshape(b, 1, n_kv, d_head)
    ln = cache["len"]
    ln_b = _lens_vector(ln, b)
    pos = ln_b[:, None]
    inv = rope_freqs(d_head, rope_theta)
    q = apply_rope(q, pos, inv)
    k = apply_rope(k, pos, inv)
    tbl = cache["table"]
    bs = cache["k"].shape[1]
    blk = jnp.take_along_axis(tbl, (ln_b // bs)[:, None], axis=1)[:, 0]
    off = ln_b % bs
    k_pool = cache["k"].at[blk, off].set(_maybe_quant_kv(k, cache["k"])[:, 0])
    v_pool = cache["v"].at[blk, off].set(_maybe_quant_kv(v, cache["v"])[:, 0])
    out = decode_attention(q, _paged_gather(k_pool, tbl),
                           _paged_gather(v_pool, tbl), ln_b + 1)
    out = out.reshape(b, 1, n_heads * d_head)
    y, so = linear.apply_with_state(params["o"], out, plan=plan,
                                    state=st.get("o"))
    new_cache = {"k": k_pool, "v": v_pool, "len": cache["len"] + 1,
                 "table": tbl}
    if crew_state is not None:
        new_cache["crew"] = {**crew_state, "q": sq, "k": sk, "v": sv,
                             "o": so}
    return y, new_cache


def attend_prefill_cached_paged(params, x, cache, *, n_heads, n_kv, d_head,
                                rope_theta=10000.0, crew_strategy="auto"):
    """Paged chunked-prefill: the block-table twin of
    :func:`attend_prefill_cached`.

    x [B, C, d] holds C consecutive prompt tokens whose first token sits
    at position ``cache["len"]`` (scalar-or-``[B]``); K/V rows scatter
    into the pool through the block table at ``(table[b, pos // bs],
    pos % bs)``.  Chunk positions whose block index falls off the table
    — bucket padding past the slot's allocation — are *explicitly
    redirected to the scratch block* (device id 0), never index-clamped:
    clamping a write position back onto the last valid block is exactly
    the ``dynamic_update_slice`` start-clamp bug class that silently
    corrupted caches three times pre-paging (DESIGN.md §5).  Positions
    inside the table but past the true prompt write dead rows into the
    slot's own tail block, masked until decode overwrites them — same
    semantics as dense bucket padding.  Prefix-hit blocks ([0, hit))
    sit strictly below every write position, so shared blocks are
    read-only here by construction.
    """
    b, c, _ = x.shape
    plan = CrewPlan.of(crew_strategy)
    q = linear.apply(params["q"], x, plan=plan)
    k = linear.apply(params["k"], x, plan=plan)
    v = linear.apply(params["v"], x, plan=plan)
    q = q.reshape(b, c, n_heads, d_head)
    k = k.reshape(b, c, n_kv, d_head)
    v = v.reshape(b, c, n_kv, d_head)
    off_b = _lens_vector(cache["len"], b)
    pos = off_b[:, None] + jnp.arange(c)[None]          # [B, C]
    inv = rope_freqs(d_head, rope_theta)
    q = apply_rope(q, pos, inv)
    k = apply_rope(k, pos, inv)
    tbl = cache["table"]
    bs = cache["k"].shape[1]
    nbw = tbl.shape[1]
    bidx = pos // bs
    blk = jnp.where(
        bidx < nbw,
        jnp.take_along_axis(tbl, jnp.minimum(bidx, nbw - 1), axis=1),
        0)                                              # [B, C]
    k_pool = cache["k"].at[blk, pos % bs].set(_maybe_quant_kv(k, cache["k"]))
    v_pool = cache["v"].at[blk, pos % bs].set(_maybe_quant_kv(v, cache["v"]))
    out = cached_chunk_attention(
        q, _maybe_dequant_kv(_paged_gather(k_pool, tbl), q.dtype),
        _maybe_dequant_kv(_paged_gather(v_pool, tbl), q.dtype), pos)
    out = out.reshape(b, c, n_heads * d_head)
    y = linear.apply(params["o"], out, plan=plan)
    return y, {"k": k_pool, "v": v_pool, "len": cache["len"] + c,
               "table": tbl}


def init_kv_cache(batch: int, seq_len: int, n_kv: int, d_head: int,
                  dtype=jnp.bfloat16, stack=()):
    return {
        "k": jnp.zeros((*stack, batch, seq_len, n_kv, d_head), dtype=dtype),
        "v": jnp.zeros((*stack, batch, seq_len, n_kv, d_head), dtype=dtype),
        "len": jnp.zeros((), dtype=jnp.int32),
    }


def cache_spec(stack_axes=(), shard_kv: bool = True):
    kv_axis = "kv_heads" if shard_kv else None
    return {
        "k": P(*stack_axes, "batch", "kv_seq", kv_axis, None),
        "v": P(*stack_axes, "batch", "kv_seq", kv_axis, None),
        "len": P(),
    }
