"""Mamba2 (SSD) block — chunked train/prefill + O(1)-state decode.

Scalar-per-head decay SSD recurrence (n_groups = 1):

    h_t = a_t * h_{t-1} + dt_t * (B_t outer x_t)        a_t = exp(-exp(A_log) dt_t)
    y_t = (C_t . h_t) + D * x_t

Train/prefill uses the chunked semi-parallel SSD form: a quadratic
intra-chunk term (masked decay matrix L[t, s] = exp(cum[t] - cum[s])) plus
an inter-chunk state scan — sub-quadratic in sequence length, which is what
qualifies the SSM/hybrid archs for the long_500k cells.  Decode is the
plain one-step recurrence against a [B, H, P, N] state cache.

Shapes: d_inner = expand * d_model, H = d_inner / head_dim, state N.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.ctx import constrain
from . import linear

__all__ = ["init", "spec", "apply_chunked", "apply_decode", "init_state",
           "state_spec", "dims"]

CONV_W = 4  # causal depthwise conv window


def dims(d_model: int, *, expand: int = 2, head_dim: int = 64, state: int = 64):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_ch = d_inner + 2 * state
    return d_inner, n_heads, conv_ch


def init(rng, d_model: int, *, expand: int = 2, head_dim: int = 64,
         state: int = 64, dtype=jnp.float32, stack=()):
    d_inner, n_heads, conv_ch = dims(d_model, expand=expand,
                                     head_dim=head_dim, state=state)
    ks = jax.random.split(rng, 4)
    d_proj = 2 * d_inner + 2 * state + n_heads  # z, x, B, C, dt
    return {
        "in_proj": linear.init(ks[0], d_model, d_proj, dtype=dtype, stack=stack),
        "conv_w": jax.random.normal(ks[1], (*stack, CONV_W, conv_ch)).astype(dtype) * 0.1,
        "conv_b": jnp.zeros((*stack, conv_ch), dtype=dtype),
        "a_log": jnp.zeros((*stack, n_heads), dtype=jnp.float32),
        "dt_bias": jnp.zeros((*stack, n_heads), dtype=jnp.float32),
        "d_skip": jnp.ones((*stack, n_heads), dtype=jnp.float32),
        "norm": jnp.ones((*stack, d_inner), dtype=dtype),
        "out_proj": linear.init(ks[3], d_inner, d_model, dtype=dtype,
                                scale=d_inner ** -0.5, stack=stack),
    }


def spec(stack_axes=()):
    sa = stack_axes
    return {
        "in_proj": linear.spec("embed", "heads", stack_axes=sa),
        "conv_w": P(*sa, None, "heads"),
        "conv_b": P(*sa, "heads"),
        "a_log": P(*sa, "heads"),
        "dt_bias": P(*sa, "heads"),
        "d_skip": P(*sa, "heads"),
        "norm": P(*sa, "heads"),
        "out_proj": linear.spec("heads", "embed", stack_axes=sa),
    }


def _split_proj(proj, d_inner, state, n_heads):
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner: 2 * d_inner + 2 * state]
    dt = proj[..., 2 * d_inner + 2 * state:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, carry=None):
    """Depthwise causal conv, window CONV_W. xbc [B, S, C].

    carry: optional [B, CONV_W-1, C] left context (decode).  Returns
    (y, new_carry)."""
    b, s, c = xbc.shape
    if carry is None:
        carry = jnp.zeros((b, CONV_W - 1, c), dtype=xbc.dtype)
    ext = jnp.concatenate([carry, xbc], axis=1)  # [B, S+3, C]
    y = sum(
        ext[:, i: i + s] * conv_w[i][None, None].astype(xbc.dtype)
        for i in range(CONV_W)
    ) + conv_b[None, None].astype(xbc.dtype)
    new_carry = ext[:, -(CONV_W - 1):]
    return jax.nn.silu(y), new_carry


def _ssd_chunk(carry, blk, *, n_heads, head_dim, state):
    """One SSD chunk. carry h [B, H, P, N]; blk tensors over chunk len Q."""
    h = carry
    x, b_in, c_in, dt, loga = blk  # x [B,Q,H,P], b/c [B,Q,N], dt/loga [B,Q,H]
    cum = jnp.cumsum(loga, axis=1)                       # [B, Q, H]
    # intra-chunk quadratic term
    scores = jnp.einsum("btn,bsn->bts", c_in, b_in)      # [B, Q, Q]
    ldecay = jnp.exp(cum[:, :, None] - cum[:, None])     # [B, Qt, Qs, H]
    q = x.shape[1]
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    ldecay = jnp.where(mask[None, :, :, None], ldecay, 0.0)
    w = scores[..., None] * ldecay * dt[:, None]         # [B, Qt, Qs, H]
    y_intra = jnp.einsum("btsh,bshp->bthp", w, x)
    # contribution of the carried state
    y_inter = jnp.einsum("btn,bhpn,bth->bthp", c_in, h, jnp.exp(cum))
    # state update to the end of the chunk: contribution of step s to h_Q is
    # prod_{r=s+1..Q} a_r * dt_s B_s x_s = exp(cum_Q - cum_s) dt_s B_s x_s
    # (cum includes a_s at position s, so the difference excludes a_s itself)
    decay_to_end = jnp.exp(cum[:, -1:, :] - cum)         # [B, Q, H]
    upd = jnp.einsum("bsh,bsn,bshp->bhpn", decay_to_end * dt, b_in, x)
    h_new = h * jnp.exp(cum[:, -1])[:, :, None, None] + upd
    return h_new, y_intra + y_inter


def apply_chunked(params, xin, *, head_dim: int = 64, state: int = 64,
                  chunk: int = 256, crew_strategy="auto", h0=None):
    """Training/prefill forward. xin [B, S, d] -> ([B, S, d], final_state)."""
    b, s, d_model = xin.shape
    proj = linear.apply(params["in_proj"], xin, plan=crew_strategy)
    d_inner = params["norm"].shape[-1]
    n_heads = d_inner // head_dim
    z, xbc, dt_pre = _split_proj(proj, d_inner, state, n_heads)
    xbc, _ = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    x = xbc[..., :d_inner].reshape(b, s, n_heads, head_dim).astype(jnp.float32)
    b_in = xbc[..., d_inner: d_inner + state].astype(jnp.float32)
    c_in = xbc[..., d_inner + state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + params["dt_bias"])
    loga = -jnp.exp(params["a_log"])[None, None] * dt    # [B, S, H]

    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    s_pad = n_chunks * chunk
    def padq(t):
        return jnp.pad(t, [(0, 0), (0, s_pad - s)] + [(0, 0)] * (t.ndim - 2))
    xc, bc, cc, dtc, lc = map(padq, (x, b_in, c_in, dt, loga))

    def to_chunks(t):
        # [nc, B, chunk, ...]: pin batch (+ heads where present) so the SSD
        # chunk scan keeps data sharding inside the while body.
        out = jnp.moveaxis(t.reshape(b, n_chunks, chunk, *t.shape[2:]), 1, 0)
        spec = [None, "batch", None] + [
            "heads" if d == n_heads else None for d in t.shape[2:]]
        return constrain(out, *spec)

    if h0 is None:
        h0 = jnp.zeros((b, n_heads, head_dim, state), dtype=jnp.float32)
    h0 = constrain(h0, "batch", "heads", None, None)
    h_fin, ys = jax.lax.scan(
        lambda c, blk: _ssd_chunk(c, blk, n_heads=n_heads, head_dim=head_dim,
                                  state=state),
        h0,
        tuple(map(to_chunks, (xc, bc, cc, dtc, lc))),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s_pad, n_heads, head_dim)[:, :s]
    y = y + params["d_skip"][None, None, :, None] * x
    y = y.reshape(b, s, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    # gated RMSNorm
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * params["norm"].astype(jnp.float32)
    y = y.astype(xin.dtype)
    return linear.apply(params["out_proj"], y, plan=crew_strategy), h_fin


def apply_decode(params, xin, cache, *, head_dim: int = 64, state: int = 64,
                 crew_strategy="auto"):
    """Single-token decode. xin [B, 1, d]; cache {"conv", "h"}."""
    b = xin.shape[0]
    proj = linear.apply(params["in_proj"], xin, plan=crew_strategy)
    d_inner = params["norm"].shape[-1]
    n_heads = d_inner // head_dim
    z, xbc, dt_pre = _split_proj(proj, d_inner, state, n_heads)
    xbc, conv_carry = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                   carry=cache["conv"])
    x = xbc[..., :d_inner].reshape(b, n_heads, head_dim).astype(jnp.float32)
    b_in = xbc[:, 0, d_inner: d_inner + state].astype(jnp.float32)
    c_in = xbc[:, 0, d_inner + state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_pre[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = jnp.exp(-jnp.exp(params["a_log"])[None] * dt)    # [B, H]
    x = x.reshape(b, n_heads, head_dim)
    h = cache["h"] * a[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, b_in, x)
    y = jnp.einsum("bn,bhpn->bhp", c_in, h)
    y = y + params["d_skip"][None, :, None] * x
    y = y.reshape(b, 1, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * params["norm"].astype(jnp.float32)
    y = y.astype(xin.dtype)
    out = linear.apply(params["out_proj"], y, plan=crew_strategy)
    return out, {"conv": conv_carry, "h": h}


def init_state(batch: int, d_model: int, *, expand: int = 2,
               head_dim: int = 64, state: int = 64, dtype=jnp.float32, stack=()):
    d_inner, n_heads, conv_ch = dims(d_model, expand=expand,
                                     head_dim=head_dim, state=state)
    return {
        "conv": jnp.zeros((*stack, batch, CONV_W - 1, conv_ch), dtype=dtype),
        "h": jnp.zeros((*stack, batch, n_heads, head_dim, state),
                       dtype=jnp.float32),
    }


def state_spec(stack_axes=()):
    return {
        "conv": P(*stack_axes, "batch", None, "heads"),
        "h": P(*stack_axes, "batch", "heads", None, None),
    }
