"""NN substrate: functional layers with (params, logical-axis spec) pairs.

Conventions
-----------
* Params are nested dicts of jnp arrays (or CREW matrix pytrees after
  serving-time conversion).
* Every ``*_init`` has a matching ``*_spec`` returning the same tree shape
  with ``jax.sharding.PartitionSpec`` leaves over *logical* axis names
  ("embed", "mlp", "heads", "vocab", "expert", ...).  repro.dist.sharding
  maps logical -> physical mesh axes.
* Scanned stacks carry a leading "layers" axis on every leaf.
"""
from . import linear, norms, rope, attention, mlp, moe, mamba2, xlstm, embed, recurrent  # noqa: F401
