"""Token embeddings and the output head (tied or untied)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.ctx import constrain

__all__ = ["init", "spec", "embed", "logits"]


def init(rng, vocab: int, d_model: int, *, tie: bool = True, dtype=jnp.float32):
    ks = jax.random.split(rng, 2)
    params = {"table": jax.random.normal(ks[0], (vocab, d_model)).astype(dtype) * 0.02}
    if not tie:
        params["head"] = (
            jax.random.normal(ks[1], (d_model, vocab)).astype(dtype) * d_model ** -0.5
        )
    return params


def spec(*, tie: bool = True):
    s = {"table": P("vocab", "embed")}
    if not tie:
        s["head"] = P("embed", "vocab")
    return s


def embed(params, tokens: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    # The gather from a vocab-sharded table involuntarily replicates under
    # GSPMD; pin the output back to batch sharding so replication does not
    # poison every downstream activation (observed on the train dry-run).
    x = params["table"].astype(dtype)[tokens]
    return constrain(x, "batch", None, None)


def logits(params, x: jnp.ndarray) -> jnp.ndarray:
    """x [..., d] -> [..., vocab] in fp32 (stable softmax/loss)."""
    if "head" in params:
        out = x.astype(jnp.float32) @ params["head"].astype(jnp.float32)
    else:
        out = x.astype(jnp.float32) @ params["table"].astype(jnp.float32).T
    return constrain(out, "batch", None, "vocab")
