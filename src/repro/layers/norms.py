"""RMSNorm / LayerNorm (fp32 statistics, cast back to input dtype)."""
from __future__ import annotations

from typing import Sequence, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["rms_init", "rms_spec", "rms_apply", "ln_init", "ln_spec", "ln_apply"]


def rms_init(d: int, *, dtype=jnp.float32, stack: Sequence[int] = ()):
    return {"scale": jnp.ones((*stack, d), dtype=dtype)}


def rms_spec(stack_axes: Sequence[Optional[str]] = ()):
    return {"scale": P(*stack_axes, None)}


def rms_apply(params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def ln_init(d: int, *, dtype=jnp.float32, stack: Sequence[int] = ()):
    return {
        "scale": jnp.ones((*stack, d), dtype=dtype),
        "bias": jnp.zeros((*stack, d), dtype=dtype),
    }


def ln_spec(stack_axes: Sequence[Optional[str]] = ()):
    return {"scale": P(*stack_axes, None), "bias": P(*stack_axes, None)}


def ln_apply(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * (var + eps) ** -0.5
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)
