"""repro.perf — measured performance infrastructure.

``autotune`` holds the measured strategy dispatch for the CREW apply hot
path: a JSON-backed cache of per-shape strategy timings that
``kernels.ops.crew_matmul(strategy="auto")`` consults, with the analytical
``pick_strategy`` prior as cold-start fallback.
"""
from .autotune import (
    AutotuneStore,
    Measurement,
    get_store,
    lookup,
    lookup_plan,
    make_key,
    measure_crew_matmul,
    measure_crew_matmul_decode,
    set_store,
)

__all__ = [
    "AutotuneStore",
    "Measurement",
    "get_store",
    "lookup",
    "lookup_plan",
    "make_key",
    "measure_crew_matmul",
    "measure_crew_matmul_decode",
    "set_store",
]
