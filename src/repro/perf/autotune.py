"""Measured strategy dispatch for the CREW apply hot path.

``crew_matmul(strategy="auto")`` chooses between the XLA paths
(decompress-and-matmul / blocked gather) and the fused Pallas kernels
(gather / one-hot MXU).  The analytical prior (``kernels.ops.pick_strategy``,
DESIGN.md §3 napkin math) extrapolates a v5e roofline from B, K and the
index width — a fixed guess that shifts with the actual backend, batch and
matrix shape.  This module replaces the guess with a measurement:

  * a dispatch key ``(B, N, M, K, width, backend)`` identifies an apply
    shape;
  * ``measure_crew_matmul`` times every candidate strategy for that shape
    once, eagerly (jit + block_until_ready, best-of-``repeats``), outside
    any trace, and records the winner;
  * the winner lives in an :class:`AutotuneStore` — an in-memory dict with
    optional JSON persistence (``REPRO_AUTOTUNE_CACHE`` or an explicit
    path), so offline conversion tooling can ship a warmed cache next to
    the converted checkpoint;
  * ``crew_matmul(strategy="auto")`` calls :func:`lookup` on every auto
    dispatch — a Python dict probe on static shapes, free at trace time —
    and falls back to the analytical prior on a cold cache.

Measurement can never run *inside* a jit trace (there is no wall clock in
an abstract evaluation), which is why the design splits into an eager
warmup pass (``serve.convert.autotune_crew_params`` walks a converted
param tree and measures each distinct leaf shape) and a pure lookup on the
hot path.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import tempfile
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

__all__ = [
    "DEFAULT_CANDIDATES",
    "DECODE_CANDIDATES",
    "Measurement",
    "AutotuneStore",
    "get_store",
    "set_store",
    "lookup",
    "lookup_plan",
    "make_key",
    "epilogue_tag",
    "measure_crew_matmul",
    "measure_crew_matmul_decode",
]

DEFAULT_CANDIDATES: Tuple[str, ...] = (
    "xla-dense", "xla-gather", "pallas-gather", "pallas-onehot")

# Decode-shaped (GEMV / skinny-batch) candidates: the decompress-once GEMM
# and the carried-product-buffer kernel first, then the per-step paths.
DECODE_CANDIDATES: Tuple[str, ...] = (
    "xla-cached", "pallas-decode",
    "xla-dense", "pallas-gather", "pallas-onehot")

_ENV_PATH = "REPRO_AUTOTUNE_CACHE"


def epilogue_tag(has_bias: bool, activation: Optional[str]) -> str:
    """Canonical epilogue component of a dispatch key.

    The fused bias/activation epilogue (DESIGN.md §3) changes the relative
    cost of the candidate strategies — the Pallas paths absorb it into the
    last n-block while the XLA paths pay separate elementwise ops — so an
    epilogue'd apply shape must never reuse a plain shape's measurement.
    """
    parts = (["bias"] if has_bias else []) + ([activation] if activation else [])
    return "+".join(parts) or "none"


def make_key(b: int, n: int, m: int, k: int, width: int, backend: str,
             epilogue: str = "none", kind: str = "matmul") -> str:
    """Dispatch key for one apply shape (all entries static at trace time).

    ``epilogue`` is an :func:`epilogue_tag`; "none" keeps the historical
    key format so pre-epilogue persisted caches stay valid.  ``kind``
    separates key spaces per apply shape *class*: "matmul" (historical,
    no suffix) vs "decode" (skinny-batch scan-carried applies, suffixed
    ``-decode``) — a decode-shaped measurement must never shadow the
    one-shot measurement for the same (B, N, M, K, width).
    """
    key = f"b{b}-n{n}-m{m}-k{k}-w{width}-{backend}"
    if epilogue != "none":
        key += f"-e{epilogue}"
    if kind != "matmul":
        key += f"-{kind}"
    return key


@dataclasses.dataclass
class Measurement:
    """Timed candidates for one dispatch key; ``strategy`` is the winner.

    ``block`` holds the winner's block-shape overrides (``block_n`` /
    ``block_words``) when the winning candidate was a swept
    :class:`~repro.kernels.plan.CrewPlan` rather than a bare strategy;
    empty for default blocking.  Absent in pre-sweep persisted caches
    (``from_json`` defaults it), so old JSON stores stay valid.
    """

    strategy: str
    times_s: Dict[str, float]
    block: Dict[str, int] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict:
        obj = {"strategy": self.strategy,
               "times_s": {k: self.times_s[k] for k in sorted(self.times_s)}}
        if self.block:
            obj["block"] = {k: self.block[k] for k in sorted(self.block)}
        return obj

    @classmethod
    def from_json(cls, obj: Dict) -> "Measurement":
        return cls(strategy=str(obj["strategy"]),
                   times_s={str(k): float(v)
                            for k, v in obj.get("times_s", {}).items()},
                   block={str(k): int(v)
                          for k, v in obj.get("block", {}).items()})


class AutotuneStore:
    """Keyed Measurement cache with optional JSON persistence.

    The JSON layout is ``{"version": 1, "records": {key: measurement}}``
    with sorted keys, written atomically (tmp file + rename) so concurrent
    benchmark runs can share one cache file.
    """

    VERSION = 1

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._records: Dict[str, Measurement] = {}

    @classmethod
    def open(cls, path: str) -> "AutotuneStore":
        store = cls(path)
        store.load(missing_ok=True)
        return store

    def __len__(self) -> int:
        return len(self._records)

    def keys(self):
        return self._records.keys()

    def get(self, key: str) -> Optional[Measurement]:
        return self._records.get(key)

    def put(self, key: str, rec: Measurement, save: bool = True) -> None:
        self._records[key] = rec
        if save and self.path:
            self.save()

    def load(self, missing_ok: bool = True) -> None:
        if not self.path:
            return
        try:
            with open(self.path) as fh:
                obj = json.load(fh)
        except FileNotFoundError:
            if missing_ok:
                return
            raise
        self._records = {
            str(k): Measurement.from_json(v)
            for k, v in obj.get("records", {}).items()
        }

    def save(self) -> None:
        if not self.path:
            return
        payload = {
            "version": self.VERSION,
            "records": {k: self._records[k].to_json()
                        for k in sorted(self._records)},
        }
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump(payload, fh, indent=2)
                fh.write("\n")
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise


_store: Optional[AutotuneStore] = None


def get_store() -> AutotuneStore:
    """Process-wide store; persistent iff $REPRO_AUTOTUNE_CACHE is set."""
    global _store
    if _store is None:
        path = os.environ.get(_ENV_PATH)
        _store = AutotuneStore.open(path) if path else AutotuneStore()
    return _store


def set_store(store: Optional[AutotuneStore]) -> None:
    """Install (or with None, reset) the process-wide store."""
    global _store
    _store = store


def lookup(key: str) -> Optional[str]:
    """Measured winner for a dispatch key, or None on a cold cache."""
    rec = get_store().get(key)
    return rec.strategy if rec is not None else None


def lookup_plan(key: str):
    """Measured winner as a :class:`~repro.kernels.plan.CrewPlan`
    (strategy + any swept block shape), or None on a cold cache."""
    from ..kernels.plan import CrewPlan
    rec = get_store().get(key)
    if rec is None:
        return None
    return CrewPlan(strategy=rec.strategy,
                    block_n=rec.block.get("block_n"),
                    block_words=rec.block.get("block_words"))


def _default_timer(fn: Callable[[], None], repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _as_plan(cand, activation=None):
    """Normalize a candidate (strategy string or CrewPlan) to a CrewPlan,
    folding the epilogue activation in."""
    from ..kernels.plan import CrewPlan
    plan = cand if isinstance(cand, CrewPlan) else CrewPlan(strategy=str(cand))
    if activation is not None:
        plan = plan.with_activation(activation)
    return plan


def _winner_record(labels, plans, times: Dict[str, float],
                   key: str) -> Measurement:
    """Pick the fastest finite candidate (ties break by candidate order)
    and fold its plan into a Measurement."""
    finite = {s: t for s, t in times.items() if t != float("inf")}
    if not finite:
        raise RuntimeError(f"no candidate strategy ran for key {key}")
    winner = min(finite, key=lambda s: (finite[s], labels.index(s)))
    plan = plans[labels.index(winner)]
    block = {}
    if plan.block_n is not None:
        block["block_n"] = plan.block_n
    if plan.block_words is not None:
        block["block_words"] = plan.block_words
    return Measurement(strategy=plan.strategy, times_s=times, block=block)


def measure_crew_matmul(
    x,
    cm,
    *,
    candidates: Sequence = DEFAULT_CANDIDATES,
    repeats: int = 3,
    interpret: bool = True,
    block_m: int = 1024,
    bias=None,
    activation: Optional[str] = None,
    store: Optional[AutotuneStore] = None,
    remeasure: bool = False,
    timer: Callable[[Callable[[], None], int], float] = _default_timer,
) -> Measurement:
    """Time each candidate for (x, cm) and cache the winner.

    A candidate is a strategy string or a
    :class:`~repro.kernels.plan.CrewPlan` (block-shape sweeps: e.g.
    ``CrewPlan("pallas-gather", block_n=64)`` times the same strategy at a
    non-default tiling and records ``times_s`` under its ``label()``).
    Runs eagerly: each candidate is jitted once (compile excluded from the
    timing via a warmup call) and timed best-of-``repeats`` with
    ``block_until_ready``.  A candidate that fails to lower/execute (e.g. a
    Pallas width the interpreter rejects) scores ``inf`` instead of
    aborting the sweep.  ``bias``/``activation`` measure the fused-epilogue
    variant of the apply and record under the epilogue-tagged key.
    Returns the (possibly cached) Measurement.
    """
    import jax

    from ..kernels.ops import crew_matmul

    store = store or get_store()
    b = 1
    for d in x.shape[:-1]:
        b *= int(d)
    epi = epilogue_tag(bias is not None, activation)
    key = make_key(b, cm.n_in, cm.n_out, cm.k, cm.width, jax.default_backend(),
                   epilogue=epi)
    cached = store.get(key)
    if cached is not None and not remeasure:
        return cached

    plans = [_as_plan(c, activation) for c in candidates]
    labels = [p.with_activation(None).label() for p in plans]
    times: Dict[str, float] = {}
    for label, plan in zip(labels, plans):
        fn = jax.jit(functools.partial(
            crew_matmul, plan=plan, interpret=interpret, block_m=block_m,
            bias=bias))
        try:
            fn(x, cm).block_until_ready()  # compile + warmup
            times[label] = timer(
                lambda: fn(x, cm).block_until_ready(), repeats)
        except Exception:
            times[label] = float("inf")
    rec = _winner_record(labels, plans, times, key)
    store.put(key, rec)
    return rec


def measure_crew_matmul_decode(
    x,
    cm,
    *,
    candidates: Sequence = DECODE_CANDIDATES,
    repeats: int = 3,
    interpret: bool = True,
    store: Optional[AutotuneStore] = None,
    remeasure: bool = False,
    timer: Callable[[Callable[[], None], int], float] = _default_timer,
) -> Measurement:
    """Time each candidate for a *decode-shaped* apply and cache the
    winner under the ``kind="decode"`` key.

    Decode candidates are timed at their steady-state cost:

    * ``"xla-cached"`` — the weight buffer is reconstructed **outside**
      the timer (serve setup does it once) and the timed step is the
      plain GEMM against the resident buffer;
    * ``"pallas-decode"`` — the product-buffer state is threaded through
      a donating jit exactly as the decode scan carries it, so the timed
      step reuses one resident buffer;
    * plain strategies — the per-step stateless apply (what the decode
      loop pays today without carried state).

    Decode keys are epilogue-independent (the winner is a representation
    decision; see ``kernels.ops.resolve_decode_plan``), so no
    bias/activation parameters here.
    """
    import jax

    from ..core.convert import CrewMatrixCached, crew_reconstruct_uniform
    from ..kernels.ops import crew_matmul, crew_matmul_decode, \
        init_decode_state

    store = store or get_store()
    b = 1
    for d in x.shape[:-1]:
        b *= int(d)
    key = make_key(b, cm.n_in, cm.n_out, cm.k, cm.width,
                   jax.default_backend(), kind="decode")
    cached = store.get(key)
    if cached is not None and not remeasure:
        return cached

    plans = [_as_plan(c) for c in candidates]
    labels = [p.label() for p in plans]
    times: Dict[str, float] = {}
    for label, plan in zip(labels, plans):
        try:
            if plan.strategy == "xla-cached":
                wrapped = CrewMatrixCached(
                    cm=cm, wbuf=crew_reconstruct_uniform(cm))
                fn = jax.jit(functools.partial(
                    crew_matmul, plan=plan, interpret=interpret))
                fn(x, wrapped).block_until_ready()
                times[label] = timer(
                    lambda: fn(x, wrapped).block_until_ready(), repeats)
            elif plan.strategy == "pallas-decode":
                step = jax.jit(
                    functools.partial(crew_matmul_decode, plan=plan,
                                      interpret=interpret),
                    donate_argnums=(2,))
                holder = {"st": init_decode_state(cm, b)}

                def run(step=step, holder=holder):
                    out, st = step(x, cm, holder["st"])
                    out.block_until_ready()
                    holder["st"] = st

                run()  # compile + warmup
                times[label] = timer(run, repeats)
            else:
                fn = jax.jit(functools.partial(
                    crew_matmul, plan=plan, interpret=interpret))
                fn(x, cm).block_until_ready()
                times[label] = timer(
                    lambda: fn(x, cm).block_until_ready(), repeats)
        except Exception:
            times[label] = float("inf")
    rec = _winner_record(labels, plans, times, key)
    store.put(key, rec)
    return rec
