"""int8-compressed data-parallel gradient mean with error feedback.

The cross-pod gradient all-reduce is the only slow-axis collective in
training (see launch.mesh); quantizing the payload to int8 quarters it.
Plain quantization biases the update, so each device keeps the residual
it rounded away and adds it back before quantizing the next step
(1-bit-Adam-style error feedback): the *accumulated* update telescopes to
the exact mean plus one bounded residual, so convergence is unaffected.

``compressed_mean`` runs per-shard inside ``shard_map`` — callers hand it
the local gradient block and the local error state and name the mesh axis
to reduce over.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["compressed_mean", "init_error"]


def init_error(grads):
    """Zero error-feedback state shaped like a gradient (py)tree."""
    return jax.tree.map(jnp.zeros_like, grads)


def compressed_mean(g: jnp.ndarray, err: jnp.ndarray,
                    axis: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean of ``g`` over mesh ``axis`` through an int8 wire format.

    Returns (mean, new_err): ``mean`` is the cross-device mean of the
    error-compensated, int8-quantized gradients (replicated over the
    axis); ``new_err`` is this device's fresh residual.
    """
    compensated = (g + err).astype(jnp.float32)
    # per-device symmetric scale; int8 payload + one f32 scale per block
    scale = jnp.maximum(jnp.max(jnp.abs(compensated)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(compensated / scale), -127, 127).astype(jnp.int8)
    local = q.astype(jnp.float32) * scale
    new_err = compensated - local
    n = lax.psum(jnp.ones((), jnp.float32), axis)
    mean = lax.psum(local, axis) / n
    return mean.astype(g.dtype), new_err.astype(err.dtype)
