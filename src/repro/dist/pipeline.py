"""Microbatch pipeline parallelism over one mesh axis.

GPipe-style schedule under ``shard_map``: stage weights shard over the
pipe axis (device *i* holds stage *i*), microbatches stay replicated, and
activations rotate stage-to-stage with ``ppermute``.  The loop runs
``n_micro + n_stages - 1`` ticks; devices compute garbage outside their
fill/drain window and the last stage masks real outputs into an
accumulator that a final ``psum`` replicates back out.

For the dry-run scale this favors clarity over schedule tightness (no
1F1B, no circular buffering); it exists to give the launch layer a
correct pipeline primitive with the collective pattern the roofline
accounts for (per-tick point-to-point permutes, one final reduction).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .compat import shard_map

__all__ = ["pipeline_apply"]


def pipeline_apply(stage_fn: Callable, stage_params, microbatches, mesh,
                   axis: str = "pipe"):
    """Run ``n_stages`` sequential stages over ``n_micro`` microbatches.

    stage_fn:      (params_one_stage, x [mb, ...]) -> y [mb, ...]
                   (activation shape must be stage-invariant).
    stage_params:  [n_stages, ...] pytree leaves stacked on dim 0.
    microbatches:  [n_micro, mb, ...].
    Returns        [n_micro, mb, ...] == stage_{n-1}(... stage_0(x)).
    """
    n_stages = int(mesh.shape[axis])
    n_micro = microbatches.shape[0]
    n_ticks = n_micro + n_stages - 1
    ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def worker(w_blk, xs):
        w = jax.tree.map(lambda l: l[0], w_blk)  # this device's stage
        stage = lax.axis_index(axis)
        carry = jnp.zeros(xs.shape[1:], xs.dtype)
        outs = jnp.zeros_like(xs)

        def tick(t, state):
            carry, outs = state
            # stage 0 feeds microbatch t during the fill window; every
            # other stage consumes what rotated in last tick.
            feed = xs[jnp.minimum(t, n_micro - 1)]
            y = stage_fn(w, jnp.where(stage == 0, feed, carry))
            # microbatch m leaves the last stage at tick m + n_stages - 1
            m = t - (n_stages - 1)
            mc = jnp.clip(m, 0, n_micro - 1)
            live = (stage == n_stages - 1) & (m >= 0)
            outs = jnp.where(
                live,
                lax.dynamic_update_index_in_dim(outs, y, mc, 0),
                outs)
            return lax.ppermute(y, axis, ring), outs

        _, outs = lax.fori_loop(0, n_ticks, tick, (carry, outs))
        return lax.psum(outs, axis)  # only the last stage wrote non-zeros

    return shard_map(worker, mesh=mesh, in_specs=(P(axis), P()),
                     out_specs=P(), check_vma=False)(
                         stage_params, microbatches)
