"""Logical -> physical sharding resolution.

Layers annotate params/activations with *logical* axis names ("embed",
"mlp", "heads", "batch", ...).  A rule table maps each logical axis to an
ordered list of physical *claims*; a claim is one mesh axis (``"model"``)
or a tuple of mesh axes (``("pod", "data")``) taken together.  ``resolve``
turns a logical PartitionSpec plus a concrete shape into a physical spec:

* **priority** — logical axes are resolved in rule-table order, not in
  tensor-dim order, so e.g. "kv_heads" wins the "model" axis over
  "kv_seq" regardless of which dim comes first.
* **divisibility** — a claim is only taken if the dim size divides by the
  claimed axes' total; tuple claims fall back to their longest divisible
  prefix (a 32-wide batch takes ("pod", "data"); a 2-wide batch takes
  just "pod").  Axes missing from the mesh are skipped, so one table
  serves the 2-d single-pod and 3-d multi-pod meshes.
* **conflicts** — each physical axis is claimed at most once per tensor;
  a loser falls through to its next candidate or replicates.

Rule tables are plain ``{logical: (claim, ...)}`` dicts (insertion order
is the priority order), so call sites can build variants by dict merge.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Mapping, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "TRAIN_RULES", "TRAIN_RULES_DP", "SERVE_RULES",
    "resolve", "resolve_tree", "named_sharding_tree",
]

Claim = Union[str, Tuple[str, ...]]
Rules = Mapping[str, Tuple[Claim, ...]]

# Training: FSDP ("embed" over the fast intra-pod "data" axis) x TP
# ("mlp"/"heads"/"vocab" over "model"); batch spans pods so the only
# cross-pod collective is the gradient all-reduce.  "expert" outranks
# "mlp" for the TP axis: an MoE ffn shards expert-parallel and keeps its
# per-expert mlp dim local.
TRAIN_RULES: Rules = {
    "batch": (("pod", "data"),),
    "expert": ("model",),
    "embed": ("data",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "seq": (),
    "kv_seq": (),
}

# DP-first variant (§Perf iteration B): batch claims every mesh axis,
# weights replicate — right for models whose head/ff dims fight 16-way TP.
TRAIN_RULES_DP: Rules = {
    "batch": (("pod", "data", "model"),),
    "expert": (),
    "embed": (),
    "mlp": (),
    "heads": (),
    "kv_heads": (),
    "vocab": (),
    "seq": (),
    "kv_seq": (),
}

# Serving: weights are TP-only (replicated over "data", which belongs to
# the request batch).  KV heads outrank the KV sequence for the TP axis
# (head-sharded attention needs no collectives; sequence sharding does);
# the sequence falls back to whatever axis the batch left free — MQA
# (kv=1) hands "model" to the sequence, a batch of 1 hands it "data"
# (sequence parallelism for long-context prefill).
SERVE_RULES: Rules = {
    "batch": (("pod", "data"),),
    "kv_heads": ("model",),
    "heads": ("model",),
    "kv_seq": ("data", "model"),
    "seq": ("data",),
    "expert": ("model",),
    "embed": (),
    "mlp": ("model",),
    "vocab": ("model",),
}


def _mesh_sizes(mesh) -> Dict[str, int]:
    """Axis name -> size for Mesh and AbstractMesh alike."""
    shape = mesh.shape  # Mapping on every supported jax version
    return dict(shape)


def resolve(spec: P, shape: Sequence[int], mesh, rules: Rules) -> P:
    """Logical PartitionSpec + shape -> physical PartitionSpec.

    Rank mismatches are tolerated: a short spec leaves trailing dims
    replicated, extra spec entries are dropped.  The result is trimmed of
    trailing Nones (``P("data", None)`` and ``P("data")`` compare unequal
    on some jax versions, so one canonical form is emitted).
    """
    sizes = _mesh_sizes(mesh)
    parts = tuple(spec)[: len(shape)]
    parts = parts + (None,) * (len(shape) - len(parts))
    priority = {name: i for i, name in enumerate(rules)}

    out: list = [None] * len(shape)
    used: set = set()
    dims = sorted(
        (i for i, p in enumerate(parts) if p is not None),
        key=lambda i: (priority.get(parts[i], len(priority)), i),
    )
    for i in dims:
        for claim in rules.get(parts[i], ()):
            axes = (claim,) if isinstance(claim, str) else tuple(claim)
            axes = tuple(a for a in axes if a in sizes and a not in used)
            while axes and shape[i] % math.prod(sizes[a] for a in axes):
                axes = axes[:-1]  # longest divisible prefix of the claim
            if axes:
                out[i] = axes[0] if len(axes) == 1 else axes
                used.update(axes)
                break
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _is_spec(x: Any) -> bool:
    return isinstance(x, P)


def _shape_of(x: Any) -> Tuple[int, ...]:
    return tuple(x.shape) if hasattr(x, "shape") else ()


def resolve_tree(spec_tree, shapes, mesh, rules: Rules):
    """Map ``resolve`` over a logical spec tree zipped with a tree of
    like-structured arrays / ShapeDtypeStructs."""
    return jax.tree.map(
        lambda s, v: resolve(s, _shape_of(v), mesh, rules),
        spec_tree, shapes, is_leaf=_is_spec)


def named_sharding_tree(spec_tree, values, mesh, rules: Rules):
    """``resolve_tree`` wrapped into NamedShardings on a concrete mesh."""
    return jax.tree.map(
        lambda s, v: NamedSharding(mesh, resolve(s, _shape_of(v), mesh,
                                                 rules)),
        spec_tree, values, is_leaf=_is_spec)
