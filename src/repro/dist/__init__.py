"""Distributed execution: logical->physical sharding, mesh context,
pipeline parallelism, and compressed gradient collectives.

Module map
----------
ctx       — ``sharding_ctx`` context manager + ``constrain`` activation
            sharding constraints (resolved at trace time).
sharding  — logical axis rule tables (TRAIN_RULES / TRAIN_RULES_DP /
            SERVE_RULES) and shape-aware ``resolve`` / ``resolve_tree`` /
            ``named_sharding_tree``.
pipeline  — ``pipeline_apply`` GPipe-style microbatch pipelining over a
            mesh axis via shard_map + ppermute.
compress  — ``compressed_mean`` int8 data-parallel gradient mean with
            error feedback.
compat    — bridges jax API renames (shard_map location/kwargs,
            AbstractMesh signature) across the versions we support.
"""
from . import compat, compress, ctx, pipeline, sharding  # noqa: F401
from .ctx import constrain, current_ctx, sharding_ctx  # noqa: F401
from .sharding import (  # noqa: F401
    SERVE_RULES,
    TRAIN_RULES,
    TRAIN_RULES_DP,
    named_sharding_tree,
    resolve,
    resolve_tree,
)
