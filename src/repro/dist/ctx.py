"""Ambient sharding context for activation constraints.

Step functions are written once against *logical* axis names; the mesh
and rule table travel as trace-time ambient state:

    with sharding_ctx(mesh, TRAIN_RULES):
        out = step_fn(state, batch)       # constrain() calls bind here

``constrain(x, "batch", None, "heads", None)`` resolves the logical spec
against ``x.shape`` and pins it with ``with_sharding_constraint``.
Outside any context (unit tests, single-device smoke runs) it is a no-op,
so layers never need a "distributed or not" switch.

The stack is thread-local: jit tracing happens on the calling thread, and
a serving thread pool must be able to trace cells for different meshes
concurrently.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .sharding import Rules, resolve

__all__ = ["sharding_ctx", "current_ctx", "constrain"]

_local = threading.local()


def _stack() -> list:
    if not hasattr(_local, "stack"):
        _local.stack = []
    return _local.stack


@contextlib.contextmanager
def sharding_ctx(mesh, rules: Rules):
    """Bind (mesh, rules) for every ``constrain`` call in the block."""
    _stack().append((mesh, rules))
    try:
        yield (mesh, rules)
    finally:
        _stack().pop()


def current_ctx() -> Optional[Tuple]:
    """Innermost (mesh, rules) pair, or None outside any context."""
    s = _stack()
    return s[-1] if s else None


def constrain(x: jax.Array, *logical) -> jax.Array:
    """Pin ``x`` to the physical sharding its logical spec resolves to.

    The resolved spec is applied exactly — axes that resolve to None are
    pinned replicated, which is the point: GSPMD propagation through scan
    bodies is unreliable, and these call sites exist to stop it from
    silently replicating (or over-sharding) loop carries.
    """
    ctx = current_ctx()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = resolve(P(*logical), x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
