"""Version bridges for the jax APIs the dist layer sits on.

The repo runs on jax 0.4.x (the container pin) but tracks the current
API names:

* ``shard_map``  — lives at ``jax.shard_map`` on new jax, at
  ``jax.experimental.shard_map.shard_map`` on 0.4.x; the replication-check
  kwarg was renamed ``check_rep`` -> ``check_vma``.
* ``AbstractMesh`` — new jax takes ``(axis_sizes, axis_names)``; 0.4.x
  takes a single tuple of ``(name, size)`` pairs.
* ``Mesh`` axis types — ``jax.sharding.AxisType`` does not exist on
  0.4.x; meshes there are implicitly Auto (GSPMD propagation).

Everything else the dist layer uses (NamedSharding, PartitionSpec,
with_sharding_constraint, make_mesh) is stable across both.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "abstract_mesh", "make_mesh"]

_NEW_SHARD_MAP = hasattr(jax, "shard_map")
if _NEW_SHARD_MAP:
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` with the replication check disabled-by-kwarg
    spelled the same way on every jax version."""
    kwargs = {}
    if check_vma is not None:
        kwargs["check_vma" if _NEW_SHARD_MAP else "check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def abstract_mesh(axis_sizes, axis_names):
    """Device-free mesh for resolving shardings without a real topology."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:  # jax 0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))
