"""ScaleSim-flavoured analytical cost/energy model — paper §VI reproduction.

The paper evaluates CREW with an extended ScaleSim: a 16x16-PE TPU-like
systolic accelerator @ 500 MHz with 24 MB on-chip SRAM and LPDDR4-16GB/s,
8-bit quantized weights/inputs, fp32 activation functions, against
(a) the TPU-like baseline (output-stationary), and (b) UCNN-style
factorization.  This module is the same style of first-order model:

  cycles  = compute cycles and DRAM cycles per layer, combined either
            serialized (ScaleSim v1 semantics, ``overlap=False`` — the
            paper's setting) or overlapped (max(), ``overlap=True`` — a
            conservative fair-overlap variant; EXPERIMENTS.md reports both).
  energy  = per-op constants (32 nm-class, Horowitz-style) x activity
            counts + DRAM energy per byte + static power x time.

Inputs are the REAL measured CREW statistics of each evaluated network
(unique counts, index widths, packed sizes from repro.core) — only the
hardware timing/energy constants are analytical.

Scheme summaries for one FC layer W[N, M], batch 1 (GEMV inference):

  baseline: mults = N*M;            DRAM weights = N*M bytes (8b)
  CREW:     mults = sum_i UW_i;     adds = N*M (indexed accumulation)
            DRAM = unique bytes + straddled index stream + 3b/row widths
  UCNN:     mults = sum_j UW_col_j; adds = N*M
            DRAM = unique bytes + N*M indices of ceil(log2 N) bits
            (input-indirection indices — for FC layers these are LARGER
            than the 8b weights they replace; §III, the reason UCNN's FC
            gains are modest)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.pack import straddled_size_bits
from ..core.quant import QuantConfig, quantize_matrix
from ..core.unique import CrewLayout, analyze_matrix

__all__ = ["AccelConfig", "LayerCost", "ModelCost", "fc_cost",
           "model_cost", "compare_schemes", "SCHEMES"]

SCHEMES = ("baseline", "ucnn", "crew")


@dataclasses.dataclass(frozen=True)
class AccelConfig:
    """Paper Table III parameters + 32 nm-class energy constants."""
    n_pes: int = 256                  # 16 x 16
    freq: float = 500e6               # Hz
    dram_bw: float = 16e9             # bytes/s (LPDDR4 dual channel)
    sram_bytes: int = 24 * 2 ** 20    # global on-chip SRAM
    # Sustained weight-stream rate into the array for the baseline's
    # output-stationary GEMV.  With batch 1 no weight is ever reused, so
    # the array cannot consume weights faster than they arrive from
    # DRAM/global SRAM — 32 B/cycle (= the DRAM rate at 500 MHz).  This is
    # the paper's core premise ("FC layers ... highly underutilized,
    # especially for small batch sizes"); CREW sidesteps it by streaming
    # 6-7x smaller indices into per-PE local buffers.
    weight_stream_bpc: float = 32.0

    # energy per operation (pJ) — Horowitz ISSCC'14 scaled to 32 nm lowpower
    e_mac8: float = 0.25
    e_add16: float = 0.05
    e_sram_byte: float = 1.0          # global SRAM access
    e_lbuf_byte: float = 0.12         # small local PE buffers (CREW/UCNN)
    e_dram_byte: float = 20.0
    e_decode_idx: float = 0.01        # CREW index decoder, per index
    # static power (W): baseline accelerator; CREW/UCNN add area overhead
    p_static: float = 0.35
    area_overhead: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {"baseline": 1.0, "ucnn": 1.04, "crew": 1.09})

    @property
    def dram_bytes_per_cycle(self) -> float:
        return self.dram_bw / self.freq


@dataclasses.dataclass
class LayerCost:
    scheme: str
    mults: float
    adds: float
    dram_bytes: float
    sram_bytes: float
    lbuf_bytes: float
    cycles_compute: float
    cycles_dram: float

    def cycles(self, overlap: bool) -> float:
        if overlap:
            return max(self.cycles_compute, self.cycles_dram)
        return self.cycles_compute + self.cycles_dram

    def dyn_energy(self, hw: AccelConfig) -> float:  # pJ
        return (self.mults * hw.e_mac8 + self.adds * hw.e_add16
                + self.dram_bytes * hw.e_dram_byte
                + self.sram_bytes * hw.e_sram_byte
                + self.lbuf_bytes * hw.e_lbuf_byte)


def _col_unique_counts(q: np.ndarray) -> np.ndarray:
    """Unique-value count per column, vectorized: one sort along axis 0,
    then run-boundary counting (== [np.unique(q[:, j]).size ...])."""
    if q.shape[0] == 0:
        return np.zeros(q.shape[1], dtype=np.int64)
    s = np.sort(q, axis=0)
    return 1 + np.count_nonzero(s[1:] != s[:-1], axis=0)


def fc_cost(scheme: str, layout: CrewLayout, *, hw: AccelConfig,
            weights_resident: bool, q: Optional[np.ndarray] = None,
            batch: int = 1) -> LayerCost:
    """Cost of one FC layer under a scheme.

    weights_resident: True when the whole model fits in on-chip SRAM, so
    weights/indices stream from DRAM only once per inference pass instead
    of once per timestep (the paper's 24 MB SRAM fits Kaldi, nothing else).
    """
    n, m = layout.n_in, layout.n_out
    uw = layout.unique_per_input
    total_unique = int(uw.sum())

    in_bytes = n * batch
    out_bytes = m * batch * 4  # fp32 pre-activation (paper §VI)

    if scheme == "baseline":
        mults = float(n * m * batch)
        adds = float(n * m * batch)
        w_bytes = n * m  # 8-bit weights
        lbuf = 0.0
        # Output-stationary GEMM: PE-bound at batch*N*M/n_pes MACs, but for
        # small batch the weight stream paces the array (no weight reuse) —
        # the paper's core FC-underutilization premise.
        cycles_compute = max(batch * n * m / hw.n_pes,
                             n * m / hw.weight_stream_bpc)
    elif scheme == "crew":
        mults = float(total_unique * batch)     # step 1: unique products
        adds = float(n * m * batch)             # step 2: indexed accumulation
        idx_bits = straddled_size_bits(layout.widths, m,
                                       include_side_channel=True)
        w_bytes = total_unique + idx_bits / 8 + (9 * n) / 8  # uniq + idx + counts
        # local buffers: partial products (16b) written once, read per use
        lbuf = batch * (total_unique * 2 + n * m * 2)
        # Step 2 runs at 1 add/PE/cycle — every PE owns an output block and
        # an index stream, no systolic pipeline fill; step 1 (the unique
        # multiplies) overlaps with step 2 of the previous block (§V-B),
        # so compute time is the max of the two streams.
        cycles_compute = batch * max((n * m) / hw.n_pes,
                                     total_unique / hw.n_pes)
    elif scheme == "ucnn":
        assert q is not None, "UCNN needs the quantized matrix for per-column stats"
        col_uw = _col_unique_counts(q)
        mults = float(col_uw.sum() * batch)
        adds = float(n * m * batch)
        idx_bits_per = max(1, int(np.ceil(np.log2(max(n, 2)))))
        w_bytes = col_uw.sum() + (n * m * idx_bits_per) / 8 + (9 * m) / 8
        lbuf = batch * (n * m * 2)
        # evaluated with the same blocking dataflow as CREW (paper §VII)
        cycles_compute = batch * (n * m) / hw.n_pes
    else:
        raise ValueError(scheme)

    dram_bytes = in_bytes + out_bytes + (0.0 if weights_resident else w_bytes * 1.0)
    sram_bytes = in_bytes + out_bytes + w_bytes  # every byte passes SRAM once
    cycles_dram = dram_bytes / hw.dram_bytes_per_cycle
    return LayerCost(scheme=scheme, mults=mults, adds=adds,
                     dram_bytes=dram_bytes, sram_bytes=sram_bytes,
                     lbuf_bytes=lbuf, cycles_compute=float(cycles_compute),
                     cycles_dram=float(cycles_dram))


@dataclasses.dataclass
class ModelCost:
    name: str
    scheme: str
    cycles_serial: float
    cycles_overlap: float
    dyn_energy_pj: float
    dram_bytes: float
    mults: float
    model_bytes: float

    def time_s(self, hw: AccelConfig, overlap: bool = False) -> float:
        return (self.cycles_overlap if overlap else self.cycles_serial) / hw.freq

    def energy_j(self, hw: AccelConfig, overlap: bool = False) -> float:
        static = hw.p_static * hw.area_overhead.get(self.scheme, 1.0) \
            * self.time_s(hw, overlap)
        return self.dyn_energy_pj * 1e-12 + static


def _prep(matrices, bits: int,
          layouts: Optional[Dict[str, CrewLayout]] = None,
          qs: Optional[Dict[str, np.ndarray]] = None):
    """Quantize + analyze every layer not already supplied by the caller
    (compare_schemes computes these once and shares them across schemes)."""
    qs = dict(qs or {})
    lts = dict(layouts or {})
    for lname, w in matrices:
        if lname not in qs:
            qs[lname] = quantize_matrix(w, QuantConfig(bits=bits)).q
        if lname not in lts or lts[lname] is None:
            lts[lname] = analyze_matrix(qs[lname])
    return qs, lts


def model_cost(name: str, matrices: List[Tuple[str, np.ndarray]], scheme: str,
               *, hw: AccelConfig = AccelConfig(), bits: int = 8,
               timesteps: int = 1, batch: int = 1,
               resident_ok: bool = False,
               layouts: Optional[Dict[str, CrewLayout]] = None,
               qs: Optional[Dict[str, np.ndarray]] = None) -> ModelCost:
    """Whole-model per-inference cost: `timesteps` sequential passes over
    all FC layers (RNN semantics; MLPs use timesteps=1).

    resident_ok=False is the paper-faithful ScaleSim-v1 semantics: weights
    stream from DRAM on every (re-)execution of a layer.  True allows a
    model that fits the 24 MB SRAM to stay resident across timesteps — a
    beyond-paper what-if reported separately in EXPERIMENTS.md (it creates
    a residency cliff that flatters whichever scheme squeezes under 24 MB).
    """
    total_serial = total_overlap = energy = dram = mults = 0.0
    model_bytes = 0.0
    qs, lts = _prep(matrices, bits, layouts, qs)
    for lname, w in matrices:
        if scheme == "crew":
            model_bytes += (lts[lname].unique_per_input.sum()
                            + straddled_size_bits(lts[lname].widths, w.shape[1]) / 8)
        else:
            model_bytes += w.size  # 8-bit dense
    weights_resident = resident_ok and model_bytes <= hw.sram_bytes

    for lname, w in matrices:
        lc = fc_cost(scheme, lts[lname], hw=hw, q=qs[lname],
                     weights_resident=weights_resident, batch=batch)
        total_serial += timesteps * lc.cycles(overlap=False)
        total_overlap += timesteps * lc.cycles(overlap=True)
        energy += timesteps * lc.dyn_energy(hw)
        dram += timesteps * lc.dram_bytes
        mults += timesteps * lc.mults
    return ModelCost(name=name, scheme=scheme, cycles_serial=total_serial,
                     cycles_overlap=total_overlap, dyn_energy_pj=energy,
                     dram_bytes=dram, mults=mults, model_bytes=model_bytes)


def compare_schemes(name: str, matrices, *, hw: AccelConfig = AccelConfig(),
                    timesteps: int = 1, batch: int = 1,
                    overlap_baseline: bool = False,
                    layouts: Optional[Dict[str, CrewLayout]] = None,
                    qs: Optional[Dict[str, np.ndarray]] = None) -> Dict[str, Dict]:
    """Per-DNN speedup/energy table vs the TPU-like baseline.

    overlap_baseline=False reproduces the paper's ScaleSim-v1 semantics
    (baseline serializes tile-load -> compute while CREW's dataflow
    explicitly overlaps); True gives every scheme the overlap benefit.
    Precomputed ``layouts``/``qs`` (e.g. from the benchmark cache) are used
    as-is; whatever is missing is quantized/analyzed once and shared across
    the three schemes.
    """
    out: Dict[str, Dict] = {}
    qs, layouts = _prep(matrices, 8, layouts, qs)
    costs = {s: model_cost(name, matrices, s, hw=hw, timesteps=timesteps,
                           batch=batch, layouts=layouts, qs=qs)
             for s in SCHEMES}
    base = costs["baseline"]
    t_base = base.time_s(hw, overlap=overlap_baseline)
    e_base = base.energy_j(hw, overlap=overlap_baseline)
    for s in SCHEMES:
        overlap = True if s != "baseline" else overlap_baseline
        t = costs[s].time_s(hw, overlap=overlap)
        e = costs[s].energy_j(hw, overlap=overlap)
        out[s] = {
            "time_s": t,
            "energy_j": e,
            "speedup": t_base / t,
            "energy_savings": e_base / e,
            "dram_gb": costs[s].dram_bytes / 1e9,
            "mults_frac": costs[s].mults / max(costs["baseline"].mults, 1.0),
            "model_mb": costs[s].model_bytes / 2 ** 20,
        }
    return out
