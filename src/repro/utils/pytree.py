"""Small pytree helpers (no flax in this environment).

``register_dataclass_pytree`` registers a dataclass whose fields are split
into *data* (traced arrays / child pytrees) and *static* (hashable metadata
baked into the treedef).  Fields default to data; mark static ones with
``static_field()``.
"""
from __future__ import annotations

import dataclasses

import jax


def static_field(**kwargs):
    return dataclasses.field(metadata={"pytree_static": True}, **kwargs)


def data_field(**kwargs):
    return dataclasses.field(metadata={"pytree_static": False}, **kwargs)


def register_dataclass_pytree(cls):
    """Class decorator: dataclass -> pytree with static/data field split."""
    cls = dataclasses.dataclass(cls)
    data_fields = []
    meta_fields = []
    for f in dataclasses.fields(cls):
        if f.metadata.get("pytree_static", False):
            meta_fields.append(f.name)
        else:
            data_fields.append(f.name)
    jax.tree_util.register_dataclass(cls, data_fields=data_fields, meta_fields=meta_fields)
    return cls
