from .pytree import static_field, data_field, register_dataclass_pytree  # noqa: F401
