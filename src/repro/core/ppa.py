"""Partial Product Approximation (PPA) — paper §IV-B, Algorithm 1.

PPA shrinks a row's unique-weight count below the next-lower power of two by
merging its *least frequently used* unique values into their nearest
surviving neighbour, which removes one bit from every index of that row.
A threshold on the merged frequency mass (`thr`, paper sweeps 0..20 % in 5 %
steps) bounds the distortion; rows whose low-frequency mass exceeds the
threshold are left untouched.

Two entry points:

* ``ppa_row`` / ``ppa_layout``: the paper's heuristic, per-row, possibly
  reducing multiple bits (``max_bits``; the paper uses 1, and 2 for
  Transformer/PTBLM).
* ``force_max_unique``: deployment helper (DESIGN.md §3) that merges *only
  overflow rows* down to a cap K so a whole network can use one uniform
  index width — the scan/stacking-friendly mode.  With a cap of 2^8 this is
  a no-op for 8-bit quantization.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .unique import CrewLayout, RowUnique, index_width

__all__ = ["PPAResult", "ppa_row", "ppa_layout", "force_max_unique"]


@dataclasses.dataclass
class PPAResult:
    layout: CrewLayout
    rows_approximated: int
    uniques_removed: int
    weight_mass_moved: float  # fraction of all weights whose value changed


def _merge_row(row: RowUnique, idx_row: np.ndarray, keep_mask: np.ndarray):
    """Remap removed uniques of one row onto their nearest kept value.

    Returns (new RowUnique, new idx_row).  Nearest = closest on the integer
    quantization grid, ties toward the smaller value (stable).
    """
    values = row.values
    kept = values[keep_mask]
    # nearest kept value for every original unique
    pos = np.searchsorted(kept, values)
    pos = np.clip(pos, 0, kept.size - 1)
    left = np.clip(pos - 1, 0, kept.size - 1)
    choose_left = np.abs(values - kept[left]) <= np.abs(values - kept[pos])
    nearest = np.where(choose_left, left, pos)
    # old unique-id -> new unique-id (kept values keep identity)
    old_to_new = np.where(keep_mask, np.cumsum(keep_mask) - 1, nearest)
    new_idx = old_to_new[idx_row]
    new_counts = np.bincount(new_idx, minlength=kept.size).astype(np.int64)
    return RowUnique(values=kept.astype(np.int32), counts=new_counts), new_idx.astype(np.int32)


def ppa_row(row: RowUnique, idx_row: np.ndarray, thr: float, max_bits: int = 1):
    """Apply Algorithm 1 to a single row.

    Tries to reduce the index width by up to ``max_bits`` bits; each bit
    reduction requires the frequency mass of the merged uniques to stay
    under ``thr``.  Returns (row', idx_row', removed, mass_moved).
    """
    removed_total = 0
    mass_total = 0.0
    n_weights = idx_row.size
    for _ in range(max_bits):
        uw = row.n_unique
        width = index_width(uw)
        if width <= 1:
            break
        target = 1 << (width - 1)  # next lower power of two
        dist = uw - target
        if dist <= 0:
            # already a power of two: halving means removing uw/2
            target = uw // 2
            dist = uw - target
        order = np.argsort(row.counts, kind="stable")  # least frequent first
        low = order[:dist]
        low_mass = float(row.counts[low].sum()) / float(n_weights)
        if low_mass >= thr:
            break
        keep = np.ones(uw, dtype=bool)
        keep[low] = False
        row, idx_row = _merge_row(row, idx_row, keep)
        removed_total += dist
        mass_total += low_mass
    return row, idx_row, removed_total, mass_total


def ppa_layout(layout: CrewLayout, thr: float, max_bits: int = 1) -> PPAResult:
    """Paper Algorithm 1 over a whole matrix decomposition."""
    n, m = layout.idx.shape
    new_rows: List[RowUnique] = []
    new_idx = np.empty_like(layout.idx)
    approx = 0
    removed = 0
    mass = 0.0
    for i in range(n):
        row, idx_row, rem, mm = ppa_row(layout.rows[i], layout.idx[i], thr, max_bits)
        new_rows.append(row)
        new_idx[i] = idx_row
        if rem > 0:
            approx += 1
            removed += rem
            mass += mm * m  # weights moved in this row
    widths = np.array([index_width(r.n_unique) for r in new_rows], dtype=np.int32)
    return PPAResult(
        layout=CrewLayout(rows=new_rows, idx=new_idx, widths=widths),
        rows_approximated=approx,
        uniques_removed=removed,
        weight_mass_moved=mass / float(n * m),
    )


def force_max_unique(layout: CrewLayout, k: int) -> PPAResult:
    """Merge overflow rows (UW_i > k) down to exactly k uniques.

    Unlike Algorithm 1 this ignores the threshold: it is the deployment
    knob that guarantees a uniform index width of ceil(log2 k) across the
    whole network (DESIGN.md §3, scan-stackable CREW).  The number of rows
    touched and the weight mass moved are reported so callers can assert
    the approximation stayed negligible (it is exactly zero when
    k >= max UW_i, e.g. k=256 for 8-bit quantization).
    """
    n, m = layout.idx.shape
    new_rows: List[RowUnique] = []
    new_idx = np.empty_like(layout.idx)
    approx = 0
    removed = 0
    moved = 0.0
    for i in range(n):
        row = layout.rows[i]
        idx_row = layout.idx[i]
        if row.n_unique > k:
            order = np.argsort(row.counts, kind="stable")
            low = order[: row.n_unique - k]
            keep = np.ones(row.n_unique, dtype=bool)
            keep[low] = False
            moved += float(row.counts[low].sum())
            row, idx_row = _merge_row(row, idx_row, keep)
            approx += 1
            removed += low.size
        new_rows.append(row)
        new_idx[i] = idx_row
    widths = np.array([index_width(r.n_unique) for r in new_rows], dtype=np.int32)
    return PPAResult(
        layout=CrewLayout(rows=new_rows, idx=new_idx, widths=widths),
        rows_approximated=approx,
        uniques_removed=removed,
        weight_mass_moved=moved / float(n * m),
    )
