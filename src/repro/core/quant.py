"""Linear (uniform) quantization of FC weights — paper §III.

The paper applies symmetric, uniformly-distributed linear quantization to
the weights of every FC layer (8-bit by default, as in the TPU baseline),
with activations following the same scheme at run time and activation
functions evaluated in fp32.  Quantization is the *enabler* of CREW: it
collapses the continuous weight distribution into <= 2^q discrete levels,
and the per-input-row unique count UW_i is measured on the quantized grid.

This module is pure NumPy: it runs offline, once per model, exactly like
the paper's static analysis pass.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "QuantConfig",
    "QuantizedMatrix",
    "quantize_matrix",
    "dequantize_matrix",
    "quantize_activations",
]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Symmetric linear quantization config.

    bits:       total bits per weight (paper: 8).
    per_channel: if True, one scale per output column (axis=1 of [N, M]);
                 the paper uses per-tensor scales, which is the default.
    clip_percentile: optional percentile-based range calibration.  The paper
                 uses plain max-abs; percentile clipping is exposed because
                 the UW statistics are sensitive to the calibration rule and
                 EXPERIMENTS.md reports that sensitivity.
    """

    bits: int = 8
    per_channel: bool = False
    clip_percentile: Optional[float] = None

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1

    @property
    def levels(self) -> int:
        return 1 << self.bits


@dataclasses.dataclass
class QuantizedMatrix:
    """An [N, M] weight matrix on the integer grid.

    q:     int32 array [N, M] of quantized levels in [-qmax, qmax].
           (int32 so downstream index math never overflows; values fit int8
           for bits<=8.)
    scale: per-tensor scalar or per-column [M] float32 scale such that
           W ~= q * scale.
    cfg:   the quantization config used.
    """

    q: np.ndarray
    scale: np.ndarray
    cfg: QuantConfig

    @property
    def n_in(self) -> int:
        return self.q.shape[0]

    @property
    def n_out(self) -> int:
        return self.q.shape[1]

    def dequantize(self) -> np.ndarray:
        return dequantize_matrix(self)

    def storage_bits_dense(self) -> int:
        """Bits to store this matrix densely at `bits` per weight."""
        return self.q.size * self.cfg.bits


def _calibrate_range(w: np.ndarray, cfg: QuantConfig, axis=None) -> np.ndarray:
    if cfg.clip_percentile is not None:
        r = np.percentile(np.abs(w), cfg.clip_percentile, axis=axis)
    else:
        # max |w| without materializing |w|
        r = np.maximum(w.max(axis=axis), -w.min(axis=axis))
    return np.maximum(r, np.finfo(np.float32).tiny)


def quantize_matrix(w: np.ndarray, cfg: QuantConfig = QuantConfig()) -> QuantizedMatrix:
    """Symmetric linear quantization of a [N, M] weight matrix."""
    if w.ndim != 2:
        raise ValueError(f"expected [N, M] weight matrix, got shape {w.shape}")
    w = np.asarray(w, dtype=np.float32)
    if cfg.per_channel:
        rng = _calibrate_range(w, cfg, axis=0)  # [M]
        scale = (rng / cfg.qmax).astype(np.float32)
        qf = w / scale[None, :]
    else:
        rng = _calibrate_range(w, cfg)
        scale = np.float32(rng / cfg.qmax)
        qf = w / scale
    np.rint(qf, out=qf)
    np.clip(qf, -cfg.qmax, cfg.qmax, out=qf)
    q = qf.astype(np.int32)
    return QuantizedMatrix(q=q, scale=np.asarray(scale, dtype=np.float32), cfg=cfg)


def dequantize_matrix(qm: QuantizedMatrix) -> np.ndarray:
    if qm.scale.ndim == 0:
        return qm.q.astype(np.float32) * float(qm.scale)
    return qm.q.astype(np.float32) * qm.scale[None, :]


def quantize_activations(x: np.ndarray, bits: int = 8):
    """Symmetric per-tensor activation quantization (used by the perf model
    to count integer-datapath traffic; the JAX runtime keeps activations in
    bf16/fp32 like a TPU serving stack would)."""
    qmax = (1 << (bits - 1)) - 1
    scale = max(float(np.abs(x).max()), np.finfo(np.float32).tiny) / qmax
    q = np.clip(np.rint(x / scale), -qmax, qmax).astype(np.int32)
    return q, np.float32(scale)
