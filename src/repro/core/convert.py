"""Dense <-> CREW conversion and the XLA (pure-jnp) CREW matmul paths.

Runtime representations (JAX pytrees):

* ``CrewMatrixUniform`` — single index width for the whole matrix
  (DESIGN.md §3 "uniform mode").  Structure is identical across layers, so
  converted networks remain `lax.scan`-stackable and TP-shardable.  This is
  the deployment format used by the big-architecture serve paths.

* ``CrewMatrixVar`` — per-row variable widths grouped into word-aligned
  width classes (paper-faithful compression).  Used by the paper-model
  benchmarks and the kernel tests.

XLA apply strategies (the Pallas kernel lives in repro/kernels):

* ``dense``  — decompress W = uniq[i, idx[i, j]] then ``x @ W``.  Keeps the
  paper's *storage/bandwidth* saving (packed indices are what stream from
  HBM), spends MXU FLOPs to skip the irregular accumulation.  Best for
  compute-rich shapes (prefill/training-like).
* ``gather`` — memoized partial products ``P[b, i, k] = x[b, i] * uniq[i, k]``
  then an indexed sum over rows (the paper's actual dataflow).  Best for
  memory-bound decode; the blocked variant bounds the [B, N, Mblk]
  intermediate.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..utils.pytree import register_dataclass_pytree, static_field
from . import pack as packlib
from .ppa import force_max_unique, ppa_layout
from .quant import QuantConfig, quantize_matrix
from .unique import CrewLayout, analyze_matrix, index_width

__all__ = [
    "CrewMatrixUniform",
    "CrewMatrixCached",
    "CrewMatrixVar",
    "crew_uniform_from_dense",
    "crew_var_from_dense",
    "crew_reconstruct_uniform",
    "crew_reconstruct_var",
    "crew_matmul_uniform",
    "crew_matmul_var",
    "unpack_words",
]


# --------------------------------------------------------------------------
# jnp word unpack (runtime analogue of pack.unpack_rows_word_aligned)
# --------------------------------------------------------------------------

def unpack_words(words: jnp.ndarray, width: int, m: int) -> jnp.ndarray:
    """words[..., R, W] uint32 -> idx[..., R, M] int32 (shift+mask decode)."""
    epw = 32 // width
    shifts = (jnp.arange(epw, dtype=jnp.uint32) * np.uint32(width))
    mask = np.uint32((1 << width) - 1)
    fields = (words[..., :, :, None] >> shifts) & mask  # [..., R, W, epw]
    flat = fields.reshape(*words.shape[:-1], -1)
    return flat[..., :m].astype(jnp.int32)


# --------------------------------------------------------------------------
# Pytree containers
# --------------------------------------------------------------------------

@register_dataclass_pytree
class CrewMatrixUniform:
    """CREW-compressed [N, M] matrix with one index width for every row.

    words:  [N, W] uint32 packed indices (W = ceil(M_pad/epw)).
    uniq:   [N, K] dequantized unique values (compute dtype), rows padded
            with their last value.
    width:  static index bit width (K == 2**width unless K padded smaller).
    n_out:  static logical M.
    """

    words: jnp.ndarray
    uniq: jnp.ndarray
    width: int = static_field()
    n_out: int = static_field()

    @property
    def n_in(self) -> int:
        return self.uniq.shape[0]

    @property
    def k(self) -> int:
        return self.uniq.shape[1]


@register_dataclass_pytree
class CrewMatrixCached:
    """A :class:`CrewMatrixUniform` plus its decompressed weight buffer.

    CREW's compressed form stays the source of truth (``cm``); ``wbuf``
    is ``crew_reconstruct_uniform(cm)`` materialized **once** at serve
    setup (``repro.serve.cache_decode_weights``) so decode-shaped applies
    become a plain GEMV against a resident buffer instead of a
    decompress-per-dispatch.  Stored in the *params* tree (never donated,
    shared freely across prefill/decode programs and batch buckets),
    unlike the per-bucket ``pbuf`` decode state which lives in the cache.

    ``layers/linear.apply`` / ``kernels/ops.crew_matmul`` dispatch on the
    type: the apply is bitwise-identical to the ``xla-dense`` strategy on
    ``cm`` (same reconstruct -> cast -> matmul -> epilogue pipeline).
    """

    cm: CrewMatrixUniform
    wbuf: jnp.ndarray     # [..., N, M] reconstructed weights (uniq dtype)

    @property
    def width(self) -> int:
        return self.cm.width

    @property
    def n_out(self) -> int:
        return self.cm.n_out

    @property
    def n_in(self) -> int:
        return self.cm.n_in

    @property
    def k(self) -> int:
        return self.cm.k


@register_dataclass_pytree
class CrewWidthClass:
    """One width class of a variable-width CREW matrix."""

    row_ids: jnp.ndarray  # [R] int32, rows of the original matrix
    words: jnp.ndarray    # [R, W] uint32
    uniq: jnp.ndarray     # [R, 2**width] dequantized values
    width: int = static_field()


@register_dataclass_pytree
class CrewMatrixVar:
    """Paper-faithful variable-width CREW matrix as width classes."""

    classes: Tuple[CrewWidthClass, ...]
    n_in: int = static_field()
    n_out: int = static_field()


# --------------------------------------------------------------------------
# Conversion (offline, numpy in / pytree out)
# --------------------------------------------------------------------------

def _dequant_table(layout: CrewLayout, k: int, scale: np.ndarray, dtype) -> np.ndarray:
    table = layout.padded_unique_table(k).astype(np.float32)
    return (table * float(scale)).astype(dtype)


def crew_uniform_from_dense(
    w: np.ndarray,
    *,
    bits: int = 8,
    max_unique: Optional[int] = None,
    ppa_thr: Optional[float] = None,
    dtype=jnp.bfloat16,
    qcfg: Optional[QuantConfig] = None,
):
    """Quantize + CREW-decompose + (optionally) PPA + uniform-width pack.

    Returns (CrewMatrixUniform, CrewLayout, QuantizedMatrix).  With
    ``max_unique=None`` the width is the max over rows (lossless vs the
    quantized model); a smaller cap invokes ``force_max_unique``.
    """
    qcfg = qcfg or QuantConfig(bits=bits)
    qm = quantize_matrix(w, qcfg)
    layout = analyze_matrix(qm.q)
    if ppa_thr is not None:
        layout = ppa_layout(layout, ppa_thr).layout
    if max_unique is not None and layout.max_unique() > max_unique:
        layout = force_max_unique(layout, max_unique).layout
    width = index_width(layout.max_unique())
    k = 1 << width
    words = packlib.pack_rows_word_aligned(layout.idx, width)
    uniq = _dequant_table(layout, k, qm.scale, np.float32)
    cm = CrewMatrixUniform(
        words=jnp.asarray(words),
        uniq=jnp.asarray(uniq, dtype=dtype),
        width=width,
        n_out=w.shape[1],
    )
    return cm, layout, qm


def crew_var_from_dense(
    w: np.ndarray,
    *,
    bits: int = 8,
    ppa_thr: Optional[float] = None,
    dtype=jnp.bfloat16,
    qcfg: Optional[QuantConfig] = None,
):
    """Quantize + CREW-decompose + variable-width width-class pack."""
    qcfg = qcfg or QuantConfig(bits=bits)
    qm = quantize_matrix(w, qcfg)
    layout = analyze_matrix(qm.q)
    if ppa_thr is not None:
        layout = ppa_layout(layout, ppa_thr).layout
    classes = []
    for c in packlib.build_width_classes(layout.idx, layout.widths):
        k = 1 << c.width
        table = layout.padded_unique_table(k, row_ids=c.row_ids)
        table = table.astype(np.float32) * float(qm.scale)
        classes.append(
            CrewWidthClass(
                row_ids=jnp.asarray(c.row_ids),
                words=jnp.asarray(c.words),
                uniq=jnp.asarray(table, dtype=dtype),
                width=c.width,
            )
        )
    cm = CrewMatrixVar(classes=tuple(classes), n_in=w.shape[0], n_out=w.shape[1])
    return cm, layout, qm


# --------------------------------------------------------------------------
# Reconstruction (for exactness tests) and apply paths
# --------------------------------------------------------------------------

def crew_reconstruct_uniform(cm: CrewMatrixUniform) -> jnp.ndarray:
    """Decompress to the dequantized dense matrix W'[N, M]."""
    idx = unpack_words(cm.words, cm.width, cm.n_out)
    return jnp.take_along_axis(cm.uniq, idx, axis=1)


def crew_reconstruct_var(cm: CrewMatrixVar) -> jnp.ndarray:
    w = jnp.zeros((cm.n_in, cm.n_out), dtype=cm.classes[0].uniq.dtype)
    for c in cm.classes:
        idx = unpack_words(c.words, c.width, cm.n_out)
        w = w.at[c.row_ids].set(jnp.take_along_axis(c.uniq, idx, axis=1))
    return w


def _gather_blocked(x, uniq, idx, block_m: int):
    """out[b, j] = sum_i x[b, i] * uniq[i, idx[i, j]] with M blocked.

    P = x[:, :, None] * uniq stays resident ([B, N, K]); each M-block
    gathers [B, N, blk] then reduces — the XLA sketch of the Pallas
    dataflow (kernel keeps the block in VMEM instead).
    """
    b, n = x.shape
    m = idx.shape[1]
    p = x[:, :, None] * uniq[None]  # [B, N, K]
    n_blocks = (m + block_m - 1) // block_m
    m_pad = n_blocks * block_m
    idx_p = jnp.pad(idx, ((0, 0), (0, m_pad - m)))
    idx_b = idx_p.T.reshape(n_blocks, block_m, n)  # [nb, blk, N]

    def one_block(ib):  # ib: [blk, N]
        # gathered[b, i, j] = p[b, i, ib[j, i]]
        g = jnp.take_along_axis(p, ib.T[None], axis=2)  # [B, N, blk]
        return g.sum(axis=1)                            # [B, blk]

    out = jax.lax.map(one_block, idx_b)  # [nb, B, blk]
    out = jnp.moveaxis(out, 0, 1).reshape(b, m_pad)
    return out[:, :m]


def crew_matmul_uniform(
    x: jnp.ndarray,
    cm: CrewMatrixUniform,
    *,
    strategy: str = "dense",
    block_m: int = 1024,
) -> jnp.ndarray:
    """x[..., N] @ crew(W[N, M]) -> [..., M] via the XLA path."""
    lead = x.shape[:-1]
    xb = x.reshape(-1, x.shape[-1])
    if strategy == "dense":
        w = crew_reconstruct_uniform(cm).astype(x.dtype)
        out = xb @ w
    elif strategy == "gather":
        idx = unpack_words(cm.words, cm.width, cm.n_out)
        out = _gather_blocked(xb, cm.uniq.astype(x.dtype), idx, block_m)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return out.reshape(*lead, cm.n_out)


def crew_matmul_var(
    x: jnp.ndarray,
    cm: CrewMatrixVar,
    *,
    strategy: str = "gather",
    block_m: int = 1024,
) -> jnp.ndarray:
    """Variable-width apply: sum of per-width-class contributions."""
    lead = x.shape[:-1]
    xb = x.reshape(-1, x.shape[-1])
    out = jnp.zeros((xb.shape[0], cm.n_out), dtype=x.dtype)
    for c in cm.classes:
        xc = xb[:, c.row_ids]  # [B, R]
        idx = unpack_words(c.words, c.width, cm.n_out)
        if strategy == "dense":
            wc = jnp.take_along_axis(c.uniq, idx, axis=1).astype(x.dtype)
            out = out + xc @ wc
        elif strategy == "gather":
            out = out + _gather_blocked(xc, c.uniq.astype(x.dtype), idx, block_m)
        else:
            raise ValueError(f"unknown strategy {strategy!r}")
    return out.reshape(*lead, cm.n_out)
