"""Per-input-neuron unique-weight analysis — paper §III and §IV-A.

For a quantized FC matrix q[N, M] (N input neurons, M output neurons) CREW
observes that each *row* q[i, :] contains few distinct values (UW_i ~ 44 on
average for 8-bit quantization across the paper's five DNNs).  This module
computes, offline:

  * the per-row unique value tables  u[i, 0:UW_i]           (sorted),
  * the per-row index tables         idx[i, j] in [0, UW_i)  such that
        q[i, j] == u[i, idx[i, j]],
  * the per-row index bit-widths     width_i = max(1, ceil(log2 UW_i)),
  * per-row usage frequencies        (for the PPA heuristic, paper Fig. 5).

The decomposition is *exact*: reconstructing q from (u, idx) is lossless,
which is the basis of the hypothesis property tests.

The analysis is whole-matrix vectorized: one stable argsort over axis 1,
diff-based run boundaries on the sorted rows, and a rank scatter for the
inverse indices — no per-row ``np.unique`` calls.  ``analyze_matrix`` also
caches a flat (values, offsets) view on the returned layout so the padded
table build and ``reconstruct`` are single gathers; layouts built row-wise
(e.g. by PPA) reconstruct that view on demand.  The output is bit-identical
to the historical per-row ``np.unique`` loop (tests/test_convert_parity.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["RowUnique", "CrewLayout", "analyze_matrix", "reconstruct", "index_width"]


def index_width(n_unique: int) -> int:
    """Bits needed to index a table of `n_unique` entries (min 1)."""
    if n_unique <= 1:
        return 1
    return int(np.ceil(np.log2(n_unique)))


def _index_widths(uw: np.ndarray) -> np.ndarray:
    """Vectorized ``index_width`` over a count vector (exact integer math:
    ceil(log2 n) == bit_length(n - 1) for n >= 2, via frexp exponents)."""
    uw = np.asarray(uw, dtype=np.int64)
    widths = np.ones(uw.shape, dtype=np.int32)
    big = uw > 1
    if big.any():
        widths[big] = np.frexp((uw[big] - 1).astype(np.float64))[1].astype(np.int32)
    return widths


@dataclasses.dataclass
class RowUnique:
    """Unique-weight decomposition of one input row."""

    values: np.ndarray  # [UW_i] int32, sorted ascending
    counts: np.ndarray  # [UW_i] int64, occurrences of each unique value

    @property
    def n_unique(self) -> int:
        return int(self.values.size)

    @property
    def width(self) -> int:
        return index_width(self.n_unique)


@dataclasses.dataclass
class CrewLayout:
    """Whole-matrix CREW decomposition (variable-width, paper-faithful).

    rows:   per-input-row unique tables (ragged).
    idx:    [N, M] int32 indices into each row's table.
    widths: [N] int32 per-row index bit-widths.

    The two trailing fields cache the flat concatenation of the row tables
    (values and [N+1] row offsets); they are populated by ``analyze_matrix``
    and rebuilt lazily for layouts constructed row-by-row.
    """

    rows: List[RowUnique]
    idx: np.ndarray
    widths: np.ndarray
    _flat_values: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)
    _row_offsets: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def n_in(self) -> int:
        return self.idx.shape[0]

    @property
    def n_out(self) -> int:
        return self.idx.shape[1]

    def _flat(self) -> Tuple[np.ndarray, np.ndarray]:
        """(flat_values [sum UW_i] int32, row_offsets [N+1] int64)."""
        if self._flat_values is None:
            uw = np.fromiter((r.n_unique for r in self.rows), dtype=np.int64,
                             count=len(self.rows))
            offsets = np.zeros(uw.size + 1, dtype=np.int64)
            np.cumsum(uw, out=offsets[1:])
            if self.rows:
                values = np.concatenate(
                    [r.values for r in self.rows]).astype(np.int32)
            else:
                values = np.zeros(0, dtype=np.int32)
            self._flat_values = values
            self._row_offsets = offsets
        return self._flat_values, self._row_offsets

    @property
    def total_unique(self) -> int:
        return int(self.unique_per_input.sum())

    @property
    def unique_per_input(self) -> np.ndarray:
        _, offsets = self._flat()
        return np.diff(offsets)

    def max_unique(self) -> int:
        return int(self.unique_per_input.max())

    def padded_unique_table(self, k: int | None = None,
                            row_ids: Optional[np.ndarray] = None) -> np.ndarray:
        """[N, K] int32 table, rows padded with their own last value (so any
        out-of-range index still reads a *valid* level — keeps padded lanes
        NaN-free in kernels).  ``row_ids`` restricts the table to a subset of
        rows (used by the width-class converter)."""
        values, offsets = self._flat()
        uw = np.diff(offsets)
        starts = offsets[:-1]
        if row_ids is not None:
            sel = np.asarray(row_ids, dtype=np.int64)
            starts, uw = starts[sel], uw[sel]
        if k is None:
            k = int(uw.max()) if uw.size else 1
        over = uw > k
        if over.any():
            bad = int(np.argmax(over))
            orig = int(row_ids[bad]) if row_ids is not None else bad
            raise ValueError(f"row {orig} has {int(uw[bad])} uniques > K={k}")
        cols = np.minimum(np.arange(k, dtype=np.int64)[None, :],
                          (uw - 1)[:, None])
        return values[starts[:, None] + cols].astype(np.int32)


# Widest value range for which the per-row histogram path beats sorting.
# Quantized matrices span <= 2^bits levels, so the histogram costs
# O(N*M + N*levels) versus the sort's O(N*M log M).
_HIST_MAX_LEVELS = 4096


def _analyze_hist(q: np.ndarray, lo: int, levels: int) -> CrewLayout:
    """Histogram-based decomposition for small value ranges (the quantized
    case): per-row value counts via one flat bincount, inverse indices via a
    rank-table gather.  Output is identical to the sort path."""
    n, m = q.shape
    # Flat bin id of every element (intp up front: bincount and take then
    # skip their internal index casts); reused for both the histogram and
    # the rank gather.
    flat = q + (np.arange(n, dtype=np.intp) * levels - lo)[:, None]
    flat = flat.ravel()
    hist = np.bincount(flat, minlength=n * levels).reshape(n, levels)
    present = hist > 0

    uw = present.sum(axis=1, dtype=np.int64)
    offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(uw, out=offsets[1:])

    level_rows, level_cols = np.nonzero(present)
    flat_values = (level_cols + lo).astype(np.int32)
    flat_counts = hist[level_rows, level_cols].astype(np.int64)

    ranks = np.cumsum(present, axis=1, dtype=np.int32) - np.int32(1)
    idx = ranks.reshape(-1).take(flat).reshape(n, m)

    rows = [
        RowUnique(values=flat_values[offsets[i]:offsets[i + 1]],
                  counts=flat_counts[offsets[i]:offsets[i + 1]])
        for i in range(n)
    ]
    return CrewLayout(rows=rows, idx=idx, widths=_index_widths(uw),
                      _flat_values=flat_values, _row_offsets=offsets)


def analyze_matrix(q: np.ndarray) -> CrewLayout:
    """Compute the CREW decomposition of a quantized matrix q[N, M]."""
    if q.ndim != 2:
        raise ValueError(f"expected [N, M], got {q.shape}")
    n, m = q.shape
    q = np.ascontiguousarray(q)

    if n and m and np.issubdtype(q.dtype, np.integer):
        lo, hi = int(q.min()), int(q.max())
        levels = hi - lo + 1
        # Histogram must stay comparable to the input in size and the flat
        # bin ids must fit int32.
        if (levels <= _HIST_MAX_LEVELS and levels <= 8 * m
                and n * levels <= max(1 << 25, n * m) and n * levels < 2 ** 31):
            return _analyze_hist(q.astype(np.int32, copy=False), lo, levels)

    # Sort each row once; run boundaries in the sorted rows mark the uniques.
    # (No stability needed: equal elements land on the same rank either way.)
    order = np.argsort(q, axis=1)
    s = np.take_along_axis(q, order, axis=1)
    boundary = np.empty((n, m), dtype=bool)
    boundary[:, :1] = True
    np.not_equal(s[:, 1:], s[:, :-1], out=boundary[:, 1:])

    uw = boundary.sum(axis=1, dtype=np.int64)
    offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(uw, out=offsets[1:])
    flat_values = s[boundary].astype(np.int32)

    # Run lengths: distance between consecutive boundary positions in the
    # row-major flat view (each row starts with a boundary, so the run of
    # row i's last unique ends exactly at the next row start).
    flat_pos = np.flatnonzero(boundary.ravel())
    ends = np.empty_like(flat_pos)
    ends[:-1] = flat_pos[1:]
    if flat_pos.size:
        ends[-1] = n * m
    flat_counts = (ends - flat_pos).astype(np.int64)

    # Inverse indices: rank of each element's unique within its row,
    # scattered back through the sort permutation.
    ranks = np.cumsum(boundary, axis=1, dtype=np.int64) - 1
    idx = np.empty((n, m), dtype=np.int32)
    np.put_along_axis(idx, order, ranks.astype(np.int32), axis=1)

    rows = [
        RowUnique(values=flat_values[offsets[i]:offsets[i + 1]],
                  counts=flat_counts[offsets[i]:offsets[i + 1]])
        for i in range(n)
    ]
    return CrewLayout(rows=rows, idx=idx, widths=_index_widths(uw),
                      _flat_values=flat_values, _row_offsets=offsets)


def reconstruct(layout: CrewLayout) -> np.ndarray:
    """Losslessly rebuild q[N, M] from the decomposition."""
    values, offsets = layout._flat()
    return values[offsets[:-1, None] + layout.idx].astype(np.int32)
