"""Per-input-neuron unique-weight analysis — paper §III and §IV-A.

For a quantized FC matrix q[N, M] (N input neurons, M output neurons) CREW
observes that each *row* q[i, :] contains few distinct values (UW_i ~ 44 on
average for 8-bit quantization across the paper's five DNNs).  This module
computes, offline:

  * the per-row unique value tables  u[i, 0:UW_i]           (sorted),
  * the per-row index tables         idx[i, j] in [0, UW_i)  such that
        q[i, j] == u[i, idx[i, j]],
  * the per-row index bit-widths     width_i = max(1, ceil(log2 UW_i)),
  * per-row usage frequencies        (for the PPA heuristic, paper Fig. 5).

The decomposition is *exact*: reconstructing q from (u, idx) is lossless,
which is the basis of the hypothesis property tests.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

__all__ = ["RowUnique", "CrewLayout", "analyze_matrix", "reconstruct", "index_width"]


def index_width(n_unique: int) -> int:
    """Bits needed to index a table of `n_unique` entries (min 1)."""
    if n_unique <= 1:
        return 1
    return int(np.ceil(np.log2(n_unique)))


@dataclasses.dataclass
class RowUnique:
    """Unique-weight decomposition of one input row."""

    values: np.ndarray  # [UW_i] int32, sorted ascending
    counts: np.ndarray  # [UW_i] int64, occurrences of each unique value

    @property
    def n_unique(self) -> int:
        return int(self.values.size)

    @property
    def width(self) -> int:
        return index_width(self.n_unique)


@dataclasses.dataclass
class CrewLayout:
    """Whole-matrix CREW decomposition (variable-width, paper-faithful).

    rows:   per-input-row unique tables (ragged).
    idx:    [N, M] int32 indices into each row's table.
    widths: [N] int32 per-row index bit-widths.
    """

    rows: List[RowUnique]
    idx: np.ndarray
    widths: np.ndarray

    @property
    def n_in(self) -> int:
        return self.idx.shape[0]

    @property
    def n_out(self) -> int:
        return self.idx.shape[1]

    @property
    def total_unique(self) -> int:
        return int(sum(r.n_unique for r in self.rows))

    @property
    def unique_per_input(self) -> np.ndarray:
        return np.array([r.n_unique for r in self.rows], dtype=np.int64)

    def max_unique(self) -> int:
        return int(max(r.n_unique for r in self.rows))

    def padded_unique_table(self, k: int | None = None) -> np.ndarray:
        """[N, K] int32 table, rows padded with their own last value (so any
        out-of-range index still reads a *valid* level — keeps padded lanes
        NaN-free in kernels)."""
        if k is None:
            k = self.max_unique()
        n = len(self.rows)
        out = np.zeros((n, k), dtype=np.int32)
        for i, r in enumerate(self.rows):
            if r.n_unique > k:
                raise ValueError(f"row {i} has {r.n_unique} uniques > K={k}")
            out[i, : r.n_unique] = r.values
            out[i, r.n_unique :] = r.values[-1]
        return out


def analyze_matrix(q: np.ndarray) -> CrewLayout:
    """Compute the CREW decomposition of a quantized matrix q[N, M]."""
    if q.ndim != 2:
        raise ValueError(f"expected [N, M], got {q.shape}")
    n, m = q.shape
    idx = np.empty((n, m), dtype=np.int32)
    rows: List[RowUnique] = []
    widths = np.empty((n,), dtype=np.int32)
    for i in range(n):
        vals, inv, counts = np.unique(q[i], return_inverse=True, return_counts=True)
        rows.append(RowUnique(values=vals.astype(np.int32), counts=counts))
        idx[i] = inv.astype(np.int32)
        widths[i] = index_width(vals.size)
    return CrewLayout(rows=rows, idx=idx, widths=widths)


def reconstruct(layout: CrewLayout) -> np.ndarray:
    """Losslessly rebuild q[N, M] from the decomposition."""
    n, m = layout.idx.shape
    q = np.empty((n, m), dtype=np.int32)
    for i in range(n):
        q[i] = layout.rows[i].values[layout.idx[i]]
    return q
