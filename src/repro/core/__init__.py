"""CREW core — the paper's contribution as a composable JAX module.

Offline pipeline (numpy): quantize -> analyze -> (PPA) -> pack.
Runtime (jnp pytrees): CrewMatrixUniform / CrewMatrixVar + matmul paths.
Pallas TPU kernels live in repro.kernels and consume these containers.
"""
from .quant import QuantConfig, QuantizedMatrix, quantize_matrix, dequantize_matrix
from .unique import CrewLayout, RowUnique, analyze_matrix, reconstruct, index_width
from .pack import (
    pack_bits_straddled,
    unpack_bits_straddled,
    straddled_size_bits,
    pack_rows_word_aligned,
    unpack_rows_word_aligned,
    build_width_classes,
    elems_per_word,
)
from .ppa import PPAResult, ppa_layout, ppa_row, force_max_unique
from .convert import (
    CrewMatrixUniform,
    CrewMatrixCached,
    CrewMatrixVar,
    crew_uniform_from_dense,
    crew_var_from_dense,
    crew_reconstruct_uniform,
    crew_reconstruct_var,
    crew_matmul_uniform,
    crew_matmul_var,
    unpack_words,
)
from .stats import CrewStats, layout_stats, aggregate_stats, unique_histogram, frequency_histogram

__all__ = [
    "QuantConfig", "QuantizedMatrix", "quantize_matrix", "dequantize_matrix",
    "CrewLayout", "RowUnique", "analyze_matrix", "reconstruct", "index_width",
    "pack_bits_straddled", "unpack_bits_straddled", "straddled_size_bits",
    "pack_rows_word_aligned", "unpack_rows_word_aligned", "build_width_classes",
    "elems_per_word",
    "PPAResult", "ppa_layout", "ppa_row", "force_max_unique",
    "CrewMatrixUniform", "CrewMatrixCached", "CrewMatrixVar",
    "crew_uniform_from_dense", "crew_var_from_dense",
    "crew_reconstruct_uniform", "crew_reconstruct_var",
    "crew_matmul_uniform", "crew_matmul_var", "unpack_words",
    "CrewStats", "layout_stats", "aggregate_stats", "unique_histogram",
    "frequency_histogram",
]
