"""Index bit-packing — paper §IV-A / §V-B, TPU-adapted per DESIGN.md §3.

Two formats:

1. **Straddled storage format** (paper-faithful).  Indices of row i are
   written as width_i-bit fields, bit-contiguous, rows concatenated; a 3-bit
   side channel per row records width_i (paper: "a single value of three
   bits per input neuron").  This is the *model file* format and what the
   storage-reduction numbers (paper Table II) are computed from.  Pure
   NumPy, offline.

2. **Word-aligned runtime format** (TPU adaptation).  Rows are permuted
   into *width classes* (all rows sharing a width w), and each row packs
   floor(32/w) indices per uint32 with no straddling, so in-register decode
   is a shift+mask — the vectorized replacement for the paper's per-PE
   hardware decoder.  Padding overhead vs format 1 is <= 32 % worst-case
   (w=7 -> 4/word) and ~7 % typical; EXPERIMENTS.md reports both sizes.

Both formats round-trip exactly; the hypothesis tests sweep widths 1..8.

The straddled codec is whole-matrix vectorized: every field's bit position
is computed up front (row offsets via one cumsum), the field value is
shifted by its in-byte phase, and the result is scattered/gathered through
at most ceil((w_max + 14)/8) byte slots — no per-row or per-bit Python
loops.  The bitstream is bit-identical to the historical per-row/per-bit
implementation (tests/test_convert_parity.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

__all__ = [
    "pack_bits_straddled",
    "unpack_bits_straddled",
    "straddled_size_bits",
    "elems_per_word",
    "pack_rows_word_aligned",
    "unpack_rows_word_aligned",
    "WidthClass",
    "build_width_classes",
]

ROW_WIDTH_SIDE_CHANNEL_BITS = 3  # paper §V-B


# --------------------------------------------------------------------------
# Format 1: straddled bitstream (storage / model file)
# --------------------------------------------------------------------------

def _field_starts(widths: np.ndarray, m: int) -> Tuple[np.ndarray, int]:
    """Bit position of every w_i-bit field: starts[i, j] = sum_{r<i} w_r*m
    + w_i*j.  Returns (starts [N, M] int64, total_bits)."""
    row_offsets = np.zeros(widths.size + 1, dtype=np.int64)
    np.cumsum(widths * m, out=row_offsets[1:])
    starts = (row_offsets[:-1, None]
              + widths[:, None] * np.arange(m, dtype=np.int64)[None, :])
    return starts, int(row_offsets[-1])


def _byte_slots(max_width: int) -> int:
    """Bytes a field can touch: in-byte phase (<= 7 bits) + the field."""
    return (int(max_width) + 7 + 7) // 8


def pack_bits_straddled(idx: np.ndarray, widths: np.ndarray) -> np.ndarray:
    """Pack idx[N, M] with per-row bit widths into a uint8 bitstream.

    Bit order: row-major, little-endian within the stream (bit b of the
    stream is bit b%8 of byte b//8).
    """
    n, m = idx.shape
    widths = np.asarray(widths, dtype=np.int64)
    bad = np.any(idx.astype(np.uint64) >= (np.uint64(1) << widths.astype(np.uint64))[:, None],
                 axis=1) if n and m else np.zeros(n, dtype=bool)
    if bad.any():
        i = int(np.argmax(bad))
        raise ValueError(f"row {i}: index exceeds {int(widths[i])} bits")
    if n == 0 or m == 0:
        total_bits = int((widths * m).sum())
        return np.zeros(((total_bits + 7) // 8,), dtype=np.uint8)

    starts, total_bits = _field_starts(widths, m)
    slots = _byte_slots(widths.max())
    out = np.zeros(((total_bits + 7) // 8 + slots,), dtype=np.uint8)

    byte0 = (starts >> 3).ravel()
    shifted = (idx.astype(np.uint64)
               << (starts & 7).astype(np.uint64)).ravel()
    for b in range(slots):
        np.bitwise_or.at(out, byte0 + b,
                         ((shifted >> np.uint64(8 * b))
                          & np.uint64(0xFF)).astype(np.uint8))
    return out[:(total_bits + 7) // 8]


def unpack_bits_straddled(stream: np.ndarray, widths: np.ndarray, m: int) -> np.ndarray:
    """Inverse of pack_bits_straddled -> idx[N, M] int32."""
    widths = np.asarray(widths, dtype=np.int64)
    n = widths.size
    if n == 0 or m == 0:
        return np.zeros((n, m), dtype=np.int32)

    starts, _ = _field_starts(widths, m)
    slots = _byte_slots(widths.max())
    buf = np.zeros(stream.size + slots, dtype=np.uint8)
    buf[:stream.size] = stream

    byte0 = starts >> 3
    word = np.zeros(starts.shape, dtype=np.uint64)
    for b in range(slots):
        word |= buf[byte0 + b].astype(np.uint64) << np.uint64(8 * b)
    mask = ((np.uint64(1) << widths.astype(np.uint64)) - np.uint64(1))[:, None]
    fields = (word >> (starts & 7).astype(np.uint64)) & mask
    return fields.astype(np.int32)


def straddled_size_bits(widths: np.ndarray, m: int, include_side_channel: bool = True) -> int:
    """Exact storage-format size in bits (paper's accounting)."""
    widths = np.asarray(widths, dtype=np.int64)
    bits = int((widths * m).sum())
    if include_side_channel:
        bits += ROW_WIDTH_SIDE_CHANNEL_BITS * widths.size
    return bits


# --------------------------------------------------------------------------
# Format 2: word-aligned width classes (runtime / kernels)
# --------------------------------------------------------------------------

def elems_per_word(width: int) -> int:
    if not 1 <= width <= 16:
        raise ValueError(f"width {width} out of range")
    return 32 // width


def pack_rows_word_aligned(idx: np.ndarray, width: int) -> np.ndarray:
    """Pack idx[R, M] (all rows share `width`) -> words[R, ceil(M/epw)] uint32.

    Index j of a row lives in word j // epw, bit-slot (j % epw) * width.
    No field straddles a word boundary.
    """
    r, m = idx.shape
    epw = elems_per_word(width)
    n_words = (m + epw - 1) // epw
    if np.any(idx < 0) or np.any(idx >= (1 << width)):
        raise ValueError(f"index exceeds {width} bits")
    padded = np.zeros((r, n_words * epw), dtype=np.uint32)
    padded[:, :m] = idx.astype(np.uint32)
    padded = padded.reshape(r, n_words, epw)
    shifts = (np.arange(epw, dtype=np.uint32) * np.uint32(width))[None, None, :]
    return np.bitwise_or.reduce(padded << shifts, axis=2)


def unpack_rows_word_aligned(words: np.ndarray, width: int, m: int) -> np.ndarray:
    """Inverse of pack_rows_word_aligned -> idx[R, M] int32 (NumPy oracle;
    the jnp/in-kernel versions live in kernels/ref.py and the Pallas body)."""
    r, n_words = words.shape
    epw = elems_per_word(width)
    mask = np.uint32((1 << width) - 1)
    shifts = (np.arange(epw, dtype=np.uint32) * np.uint32(width))[None, None, :]
    fields = (words[:, :, None] >> shifts) & mask
    return fields.reshape(r, n_words * epw)[:, :m].astype(np.int32)


@dataclasses.dataclass
class WidthClass:
    """All rows of a matrix whose index width is `width`.

    row_ids: [R_w] original row indices (into the [N, M] matrix).
    words:   [R_w, ceil(M/epw)] uint32 packed indices.
    """

    width: int
    row_ids: np.ndarray
    words: np.ndarray

    @property
    def n_rows(self) -> int:
        return int(self.row_ids.size)

    def size_bits(self) -> int:
        return int(self.words.size) * 32


def build_width_classes(idx: np.ndarray, widths: np.ndarray) -> List[WidthClass]:
    """Group the rows of idx[N, M] by index width and pack each class.

    Returned classes are sorted by width ascending; every original row
    appears in exactly one class.
    """
    widths = np.asarray(widths)
    classes: List[WidthClass] = []
    for w in np.unique(widths):
        w = int(w)
        rid = np.nonzero(widths == w)[0]
        classes.append(
            WidthClass(width=w, row_ids=rid.astype(np.int32),
                       words=pack_rows_word_aligned(idx[rid], w))
        )
    return classes


def word_aligned_size_bits(classes: List[WidthClass]) -> int:
    return sum(c.size_bits() for c in classes)
