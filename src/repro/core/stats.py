"""CREW compression / reuse statistics — paper Tables I & II, Figs 1/3/5.

All accounting matches the paper's definitions:

* UW/I           — mean unique weights per input neuron (Table I).
* MULs %         — (sum_i UW_i) / (N*M): multiplications CREW performs as a
                   fraction of the dense dot products (Table I).
* saved MULs %   — 1 - MULs% (Table II reports ~96-99 %).
* storage        — dense quantized model vs CREW model *including all
                   metadata* (unique values at q bits, per-row unique counts,
                   3-bit width side channel, straddled variable-width index
                   stream) — Table II reports 16-34 % reduction.
* runtime storage— the word-aligned width-class format actually streamed on
                   TPU (DESIGN.md §3), reported alongside.
* memory access reduction — bytes fetched per inference for weights/indices
                   (paper: ~40 % fewer memory accesses).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from . import pack as packlib
from .unique import CrewLayout

__all__ = ["CrewStats", "layout_stats", "aggregate_stats", "unique_histogram",
           "frequency_histogram"]

UW_COUNT_BITS = 9  # per-row "number of unique weights" metadata (<=256 -> 9 bits)


@dataclasses.dataclass
class CrewStats:
    n_in: int
    n_out: int
    bits: int
    uw_per_input_mean: float
    uw_per_input_max: int
    total_unique: int
    muls_fraction: float            # Table I "MULs (%)" / 100
    dense_bits: int                 # quantized dense storage
    crew_bits_storage: int          # straddled + all metadata (paper Table II)
    crew_bits_runtime: int          # word-aligned width classes + tables
    index_bits_mean: float          # mean index width

    @property
    def saved_muls(self) -> float:
        return 1.0 - self.muls_fraction

    @property
    def storage_reduction(self) -> float:
        return 1.0 - self.crew_bits_storage / self.dense_bits

    @property
    def runtime_reduction(self) -> float:
        return 1.0 - self.crew_bits_runtime / self.dense_bits

    def row(self) -> Dict[str, float]:
        return {
            "UW/I": round(self.uw_per_input_mean, 1),
            "MULs%": round(100 * self.muls_fraction, 2),
            "saved_MULs%": round(100 * self.saved_muls, 2),
            "storage_red%": round(100 * self.storage_reduction, 2),
            "runtime_red%": round(100 * self.runtime_reduction, 2),
            "idx_bits": round(self.index_bits_mean, 2),
        }


def layout_stats(layout: CrewLayout, bits: int = 8) -> CrewStats:
    n, m = layout.idx.shape
    uw = layout.unique_per_input
    total_unique = int(uw.sum())

    dense_bits = n * m * bits
    idx_bits = packlib.straddled_size_bits(layout.widths, m, include_side_channel=True)
    meta_bits = total_unique * bits + n * UW_COUNT_BITS
    crew_storage = idx_bits + meta_bits

    # Word-aligned runtime sizes follow from the width histogram alone —
    # rows of width w pack into ceil(M/epw(w)) uint32 words and carry a
    # 2^w-entry table — so no actual packing is needed for the accounting.
    class_widths, class_rows = np.unique(layout.widths, return_counts=True)
    words_per_row = np.array(
        [-(-m // packlib.elems_per_word(int(w))) for w in class_widths],
        dtype=np.int64)
    runtime_idx_bits = int((class_rows * words_per_row).sum()) * 32
    runtime_table_bits = int(
        (class_rows * (np.int64(1) << class_widths.astype(np.int64))).sum()
    ) * bits
    crew_runtime = runtime_idx_bits + runtime_table_bits + n * 32  # row perm ids

    return CrewStats(
        n_in=n,
        n_out=m,
        bits=bits,
        uw_per_input_mean=float(uw.mean()),
        uw_per_input_max=int(uw.max()),
        total_unique=total_unique,
        muls_fraction=total_unique / float(n * m),
        dense_bits=dense_bits,
        crew_bits_storage=crew_storage,
        crew_bits_runtime=crew_runtime,
        index_bits_mean=float(layout.widths.mean()),
    )


def aggregate_stats(stats: List[CrewStats]) -> CrewStats:
    """Aggregate per-layer stats into model-level numbers (weight-weighted)."""
    if not stats:
        raise ValueError("no stats to aggregate")
    tot_w = sum(s.n_in * s.n_out for s in stats)
    tot_in = sum(s.n_in for s in stats)
    return CrewStats(
        n_in=tot_in,
        n_out=tot_w // max(tot_in, 1),
        bits=stats[0].bits,
        uw_per_input_mean=sum(s.uw_per_input_mean * s.n_in for s in stats) / tot_in,
        uw_per_input_max=max(s.uw_per_input_max for s in stats),
        total_unique=sum(s.total_unique for s in stats),
        muls_fraction=sum(s.total_unique for s in stats) / float(tot_w),
        dense_bits=sum(s.dense_bits for s in stats),
        crew_bits_storage=sum(s.crew_bits_storage for s in stats),
        crew_bits_runtime=sum(s.crew_bits_runtime for s in stats),
        index_bits_mean=sum(s.index_bits_mean * s.n_in for s in stats) / tot_in,
    )


def unique_histogram(layout: CrewLayout, max_uw: int = 256) -> np.ndarray:
    """Histogram of UW_i (paper Fig. 3); bin i = #rows with UW == i."""
    return np.bincount(layout.unique_per_input, minlength=max_uw + 1)


def frequency_histogram(layout: CrewLayout, bins: int = 50) -> np.ndarray:
    """Histogram of per-unique usage frequency (paper Fig. 5)."""
    m = layout.n_out
    if layout.rows:
        counts = np.concatenate([r.counts for r in layout.rows])
    else:
        counts = np.zeros(0, dtype=np.int64)
    hist, _ = np.histogram(counts / m, bins=bins, range=(0.0, 1.0))
    return hist
