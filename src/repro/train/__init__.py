"""Training runtime: optimizers, schedules, the train-step builder."""
from .optim import (
    Optimizer, adamw, sgd, apply_updates, cosine_warmup, constant_lr,
    global_norm, clip_by_global_norm,
)
from .step import TrainState, make_train_step, make_loss_fn, cross_entropy, init_state

__all__ = [
    "Optimizer", "adamw", "sgd", "apply_updates", "cosine_warmup",
    "constant_lr", "global_norm", "clip_by_global_norm",
    "TrainState", "make_train_step", "make_loss_fn", "cross_entropy",
    "init_state",
]
