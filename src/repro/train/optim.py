"""Optimizers and LR schedules (no optax in this environment).

Minimal optax-shaped interface so the trainer is implementation-agnostic:

    opt = adamw(lr_schedule, ...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)

All states are plain pytrees (checkpointable, shardable like params).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer", "adamw", "sgd", "apply_updates",
    "cosine_warmup", "constant_lr", "global_norm", "clip_by_global_norm",
]

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params, step) -> (updates, state)


def constant_lr(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, dtype=jnp.float32)


def cosine_warmup(peak: float, warmup: int, total: int,
                  floor: float = 0.0) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return sched


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def adamw(
    lr: Schedule | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: Optional[float] = 1.0,
) -> Optimizer:
    sched = constant_lr(lr) if isinstance(lr, (int, float)) else lr

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip is not None:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            gnorm = global_norm(grads)
        t = step.astype(jnp.float32) + 1.0
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["nu"], grads)
        mu_hat = jax.tree.map(lambda m: m / (1 - b1 ** t), mu)
        nu_hat = jax.tree.map(lambda v: v / (1 - b2 ** t), nu)
        lr_t = sched(step)

        def upd(m, v, p):
            u = m / (jnp.sqrt(v) + eps) + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype)

        updates = jax.tree.map(upd, mu_hat, nu_hat, params)
        return updates, {"mu": mu, "nu": nu}, {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init=init, update=update)


def sgd(lr: Schedule | float, *, momentum: float = 0.9,
        grad_clip: Optional[float] = None) -> Optimizer:
    sched = constant_lr(lr) if isinstance(lr, (int, float)) else lr

    def init(params):
        return {"m": jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)}

    def update(grads, state, params, step):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip is not None:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            gnorm = global_norm(grads)
        m = jax.tree.map(lambda mm, g: momentum * mm + g, state["m"], grads)
        lr_t = sched(step)
        updates = jax.tree.map(lambda mm, p: (-lr_t * mm).astype(p.dtype), m, params)
        return updates, {"m": m}, {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
