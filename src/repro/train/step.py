"""Train-step builder: loss, microbatched grad accumulation, remat, donation.

``make_train_step(api, opt)`` returns a pure function

    state, metrics = train_step(state, batch)

with ``state = TrainState(step, params, opt_state)``.  Microbatching runs
grad accumulation as a ``lax.scan`` over the leading batch split, so peak
activation memory is one microbatch regardless of global batch; remat
(``jax.checkpoint`` around each layer block) bounds it further to one
layer's activations per microbatch.

The function is pjit-ready: the launcher wraps it with in/out shardings
resolved from TRAIN_RULES and donates ``state``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from ..models import ModelApi
from ..utils.pytree import register_dataclass_pytree
from .optim import Optimizer, apply_updates

__all__ = ["TrainState", "make_train_step", "cross_entropy", "init_state"]


@register_dataclass_pytree
class TrainState:
    step: jnp.ndarray
    params: Any
    opt: Any


def init_state(api: ModelApi, opt: Optimizer, rng, *, dtype=jnp.float32) -> TrainState:
    params = api.init(rng, dtype=dtype)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt=opt.init(params))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  ignore: int = -1) -> jnp.ndarray:
    """Mean masked token CE. logits [B, S, V] f32, labels [B, S] int32.

    The label pick is a one-hot contraction, NOT take_along_axis: a gather
    over a vocab-sharded logits axis forces GSPMD into involuntary full
    rematerialization (replicating [B, S, V]); the iota-compare contraction
    fuses into the reduction and lowers to a partial sum + psum instead.
    """
    mask = (labels != ignore).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = (labels_safe[..., None] ==
              jnp.arange(logits.shape[-1], dtype=labels.dtype)).astype(jnp.float32)
    ll = jnp.sum(logp * onehot, axis=-1)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def make_loss_fn(api: ModelApi, *, dtype=jnp.bfloat16, remat: bool = True,
                 moe_aux_weight: float = 0.01,
                 q_chunk: int = 512, kv_chunk: int = 512):
    cfg = api.cfg

    def loss_fn(params, batch):
        fw_kw: Dict[str, Any] = dict(dtype=dtype, remat=remat)
        if cfg.family != "ssm_xlstm":
            fw_kw.update(q_chunk=q_chunk, kv_chunk=kv_chunk)
        logits, aux = api.forward(params, batch, **fw_kw)
        if cfg.family == "vlm":
            logits = logits[:, cfg.vision_patches:]
        # labels are pre-shifted by the data pipeline (labels[t] = tokens[t+1])
        loss = cross_entropy(logits, batch["labels"])
        total = loss + moe_aux_weight * aux["moe_aux"]
        return total, {"loss": loss, "moe_aux": aux["moe_aux"]}

    return loss_fn


def make_train_step(
    api: ModelApi,
    opt: Optimizer,
    *,
    n_microbatches: int = 1,
    dtype=jnp.bfloat16,
    remat: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 512,
) -> Callable[[TrainState, Dict[str, jnp.ndarray]], Any]:
    loss_fn = make_loss_fn(api, dtype=dtype, remat=remat,
                           q_chunk=q_chunk, kv_chunk=kv_chunk)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        params = state.params
        if n_microbatches <= 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % n_microbatches == 0, (b, n_microbatches)
                return x.reshape(n_microbatches, b // n_microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                m_acc = jax.tree.map(jnp.add, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": jnp.zeros(()), "moe_aux": jnp.zeros(())}
            (grads, metrics), _ = jax.lax.scan(acc_step, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            metrics = jax.tree.map(lambda m: m / n_microbatches, metrics)

        updates, opt_state, opt_metrics = opt.update(
            grads, state.opt, params, state.step)
        new_params = apply_updates(params, updates)
        metrics = {**metrics, **opt_metrics}
        return TrainState(step=state.step + 1, params=new_params,
                          opt=opt_state), metrics

    return train_step
