"""Pure-jnp oracles for the CREW Pallas kernels.

Every kernel in this package must match its oracle here to numerical
tolerance across the shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["unpack_ref", "crew_matmul_ref", "crew_partial_products_ref"]


def unpack_ref(words: jnp.ndarray, width: int, m: int) -> jnp.ndarray:
    """words[R, W] uint32 -> idx[R, M] int32 (word-aligned format)."""
    epw = 32 // width
    shifts = jnp.arange(epw, dtype=jnp.uint32) * np.uint32(width)
    mask = np.uint32((1 << width) - 1)
    fields = (words[:, :, None] >> shifts[None, None, :]) & mask
    return fields.reshape(words.shape[0], -1)[:, :m].astype(jnp.int32)


def crew_partial_products_ref(x: jnp.ndarray, uniq: jnp.ndarray) -> jnp.ndarray:
    """Step 1 of the paper's dataflow: P[b, i, k] = x[b, i] * uniq[i, k]."""
    return x[:, :, None].astype(jnp.float32) * uniq[None].astype(jnp.float32)


def crew_matmul_ref(
    x: jnp.ndarray,
    words: jnp.ndarray,
    uniq: jnp.ndarray,
    *,
    width: int,
    m: int,
) -> jnp.ndarray:
    """Oracle: decompress W'[i, j] = uniq[i, idx[i, j]], return x @ W' in f32.

    x:     [B, N]
    words: [N, W] uint32 packed indices (word-aligned, `width` bits)
    uniq:  [N, K] dequantized unique values
    """
    idx = unpack_ref(words, width, m)
    w = jnp.take_along_axis(uniq, idx, axis=1).astype(jnp.float32)
    return x.astype(jnp.float32) @ w
