"""CREW matmul as a Pallas TPU kernel — DESIGN.md §3.

The kernel fuses the paper's two dataflow steps inside one VMEM-resident
block pipeline:

  step 1 (VPU):  P[b, i, k] = x[b, i] * uniq[i, k]  for a row block
                 (the paper's "multiply each input by its unique weights";
                 P is the on-chip Partial Product Buffer — it never touches
                 HBM),
  decode (VPU):  shift+mask unpack of the word-aligned index block (the
                 vectorized replacement for the paper's per-PE decoder),
  step 2:        indexed accumulation out[b, j] += P[b, i, idx[i, j]],
                 realized either as
                   * ``gather``  — jnp.take_along_axis inside VMEM, or
                   * ``onehot``  — (P reshaped [B, bn*K]) @ onehot(idx)
                     reshaped [bn*K, bm] on the MXU (burns idle MXU FLOPs
                     to keep the VPU free; memory-bound-safe for
                     B * K * width <~ 960*8, see DESIGN.md napkin math).

Grid: (M blocks, N blocks) with N innermost, so each output block stays
resident in VMEM while the reduction over row blocks streams through —
Pallas's automatic double-buffering of the index/unique blocks plays the
role of the paper's double-buffered local buffers.

An optional **fused epilogue** (`bias`, `activation`) is applied to the
VMEM-resident output block on the *last* n-block (`pl.when`), so an FC
layer's bias-add and activation never round-trip the [B, M] output
through HBM as separate XLA ops — DESIGN.md §3 "epilogue fusion".

HBM traffic per output tile: packed words (width/8 bytes per weight) +
unique tables (amortized over M) — this is the entire point of CREW on TPU.

The container runs on CPU, so tests exercise ``interpret=True``; the
BlockSpecs below are the TPU tiling contract (bm multiple of 128 lanes,
bn multiple of 8 sublanes for f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["crew_matmul_pallas", "EPILOGUE_ACTIVATIONS",
           "DEFAULT_BLOCK_N", "DEFAULT_BLOCK_WORDS"]

DEFAULT_BLOCK_N = 128      # input rows per block (sublane-aligned)
DEFAULT_BLOCK_WORDS = 32   # packed words per block -> bm = 32 * epw

# Epilogue activations the kernel can fuse (all map 0 -> 0, so the padded
# M region stays zero and the m_out slice is unaffected).
EPILOGUE_ACTIVATIONS = {
    "relu": jax.nn.relu,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
}


def _kernel(x_ref, words_ref, uniq_ref, *rest, width: int, strategy: str,
            grid_n: int, activation):
    """One (m-block, n-block) grid step: decode the index block, form the
    partial products, and accumulate into the VMEM-resident output block
    (initialized on the first n-block; the n grid axis is innermost).
    On the last n-block the optional bias/activation epilogue transforms
    the finished accumulator in place, still in VMEM."""
    bias_ref = rest[0] if len(rest) == 2 else None
    out_ref = rest[-1]
    nn = pl.program_id(1)

    @pl.when(nn == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)          # [B, bn]
    words = words_ref[...]                      # [bn, bw] uint32
    uniq = uniq_ref[...].astype(jnp.float32)    # [bn, K]
    b, bn = x.shape
    k = uniq.shape[1]
    epw = 32 // width
    bw = words.shape[1]
    bm = bw * epw

    # ---- decode: word-aligned shift+mask unpack -> idx [bn, bm] ----
    shifts = (jax.lax.broadcasted_iota(jnp.uint32, (1, 1, epw), 2)
              * np.uint32(width))
    mask = np.uint32((1 << width) - 1)
    fields = (words[:, :, None] >> shifts) & mask
    idx = fields.reshape(bn, bm).astype(jnp.int32)

    # ---- step 1: partial products, VMEM-resident ----
    p = x[:, :, None] * uniq[None]              # [B, bn, K]

    # ---- step 2: indexed accumulation ----
    if strategy == "gather":
        gathered = jnp.take_along_axis(
            p, jnp.broadcast_to(idx[None], (b, bn, bm)), axis=2
        )                                        # [B, bn, bm]
        contrib = gathered.sum(axis=1)           # [B, bm]
    elif strategy == "onehot":
        kk = jax.lax.broadcasted_iota(jnp.int32, (bn, k, bm), 1)
        oh = (idx[:, None, :] == kk).astype(jnp.float32)  # [bn, K, bm]
        contrib = jnp.dot(
            p.reshape(b, bn * k),
            oh.reshape(bn * k, bm),
            preferred_element_type=jnp.float32,
        )
    else:
        raise ValueError(f"unknown strategy {strategy!r}")

    out_ref[...] += contrib

    if bias_ref is not None or activation is not None:
        @pl.when(nn == grid_n - 1)
        def _epilogue():
            acc = out_ref[...]
            if bias_ref is not None:
                acc = acc + bias_ref[...].astype(jnp.float32)  # [1, bm]
            if activation is not None:
                acc = EPILOGUE_ACTIVATIONS[activation](acc)
            out_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("width", "m_out", "strategy", "activation", "block_n",
                     "block_words", "interpret"),
)
def crew_matmul_pallas(
    x: jnp.ndarray,
    words: jnp.ndarray,
    uniq: jnp.ndarray,
    *,
    width: int,
    m_out: int,
    strategy: str = "gather",
    bias=None,
    activation=None,
    block_n: int = DEFAULT_BLOCK_N,
    block_words: int = DEFAULT_BLOCK_WORDS,
    interpret: bool = True,
) -> jnp.ndarray:
    """CREW matmul: x[B, N] x crew(W[N, M]) -> f32 [B, M].

    words: [N, W] uint32, uniq: [N, K].  Pads N and W to block multiples
    (zero rows contribute zero: x pad is 0 so P rows are 0; padded words
    decode to index 0 which reads a zero P row).  Slices the M padding off.

    bias ([M] or None) and activation (a key of EPILOGUE_ACTIVATIONS or
    None) form the fused epilogue: applied in f32 to the VMEM-resident
    output block on the last n-block, before the result ever reaches HBM.
    """
    if activation is not None and activation not in EPILOGUE_ACTIVATIONS:
        raise ValueError(f"unknown epilogue activation {activation!r}")
    b, n = x.shape
    n_words = words.shape[1]
    k = uniq.shape[1]
    epw = 32 // width

    block_n = min(block_n, max(8, n))
    block_words = min(block_words, n_words)

    n_pad = (n + block_n - 1) // block_n * block_n
    w_pad = (n_words + block_words - 1) // block_words * block_words
    if n_pad != n:
        x = jnp.pad(x, ((0, 0), (0, n_pad - n)))
        words = jnp.pad(words, ((0, n_pad - n), (0, 0)))
        uniq = jnp.pad(uniq, ((0, n_pad - n), (0, 0)))
    if w_pad != n_words:
        words = jnp.pad(words, ((0, 0), (0, w_pad - n_words)))

    bm = block_words * epw
    grid = (w_pad // block_words, n_pad // block_n)

    in_specs = [
        pl.BlockSpec((b, block_n), lambda im, inn: (0, inn)),
        pl.BlockSpec((block_n, block_words), lambda im, inn: (inn, im)),
        pl.BlockSpec((block_n, k), lambda im, inn: (inn, 0)),
    ]
    args = [x, words, uniq]
    if bias is not None:
        bias_p = jnp.pad(bias.astype(jnp.float32).reshape(-1),
                         (0, grid[0] * bm - m_out)).reshape(1, -1)
        in_specs.append(pl.BlockSpec((1, bm), lambda im, inn: (0, im)))
        args.append(bias_p)

    out = pl.pallas_call(
        functools.partial(_kernel, width=width, strategy=strategy,
                          grid_n=grid[1], activation=activation),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((b, bm), lambda im, inn: (0, im)),
        out_shape=jax.ShapeDtypeStruct((b, grid[0] * bm), jnp.float32),
        interpret=interpret,
    )(*args)
    return out[:, :m_out]
